"""Deterministic synthetic token pipeline with host-side prefetch.

Real-deployment shape: a background thread produces numpy batches (the "IO"
stage), batches are placed onto the mesh as globally-sharded arrays, and the
training loop consumes a bounded prefetch queue so input never serializes
with compute.  Deterministic per (seed, step) for exact restart-reproducible
training (checkpoint restore replays the stream position).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class SyntheticTokens:
    """Zipf-ish synthetic LM tokens; labels are next-token shifted."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 start_step: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = start_step

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed << 32) + self.step)
        self.step += 1
        # zipf-like marginal over the vocab, cheap to sample
        u = rng.random((self.batch, self.seq + 1))
        toks = np.minimum((self.vocab * u ** 2.5).astype(np.int32),
                          self.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def shard_batch(batch: dict, mesh=None) -> dict:
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    names = [n for n in ("pod", "data") if n in mesh.shape]
    out = {}
    for k, v in batch.items():
        spec = P(tuple(names)) if len(names) > 1 else P(names[0] if names else None)
        spec = P(*( (spec[0],) + (None,) * (v.ndim - 1) ))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


class Prefetcher:
    """Bounded background prefetch of sharded batches."""

    def __init__(self, source: SyntheticTokens, mesh=None, depth: int = 2):
        self.source = source
        self.mesh = mesh
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = False
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        while not self._stop:
            b = shard_batch(self.source.next_batch(), self.mesh)
            self.q.put(b)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop = True
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass
