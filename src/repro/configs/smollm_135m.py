"""Config: smollm_135m (see registry.py for the full definition)."""
from .registry import SMOLLM_135M as CONFIG

__all__ = ["CONFIG"]
