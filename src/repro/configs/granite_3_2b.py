"""Config: granite_3_2b (see registry.py for the full definition)."""
from .registry import GRANITE_3_2B as CONFIG

__all__ = ["CONFIG"]
