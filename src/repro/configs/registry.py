"""All assigned architectures (exact published dimensions; see DESIGN.md §4)."""
from __future__ import annotations

from .base import ArchConfig

# [audio] enc-dec, conv frontend stubbed (precomputed frame embeddings)
WHISPER_MEDIUM = ArchConfig(
    name="whisper-medium", family="encdec", enc_dec=True,
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, act="gelu", gated_mlp=False, use_bias=True,
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, no rope
    frontend="audio-stub", enc_seq=1500, tie_embeddings=True, qk_norm=False)

GRANITE_3_2B = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab=49155, tie_embeddings=True)

COMMAND_R_35B = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
    vocab=256000, use_bias=False, tie_embeddings=True)

QWEN3_0_6B = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab=151936, qk_norm=True, rope_theta=1e6, tie_embeddings=True)

SMOLLM_135M = ArchConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab=49152, tie_embeddings=True)

MAMBA2_780M = ArchConfig(
    name="mamba2-780m", family="ssm", ssm=True,
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_groups=1,
    conv_width=4, tie_embeddings=True)

DEEPSEEK_MOE_16B = ArchConfig(
    name="deepseek-moe-16b", family="moe", moe=True,
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, n_experts=64, n_shared_experts=2, top_k=6,
    expert_d_ff=1408, first_dense_layers=1, first_dense_d_ff=10944,
    tie_embeddings=True)

GRANITE_MOE_3B = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", moe=True,
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, n_experts=40, n_shared_experts=0, top_k=8, expert_d_ff=512,
    tie_embeddings=True)

RECURRENTGEMMA_2B = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, attn_kind="local", local_window=2048,
    block_pattern=("rglru", "rglru", "attn"), rnn_width=2560,
    act="gelu", tie_embeddings=True)

CHAMELEON_34B = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, qk_norm=True, frontend="vq-tokens", tie_embeddings=True)

ARCHS = {c.name: c for c in (
    WHISPER_MEDIUM, GRANITE_3_2B, COMMAND_R_35B, QWEN3_0_6B, SMOLLM_135M,
    MAMBA2_780M, DEEPSEEK_MOE_16B, GRANITE_MOE_3B, RECURRENTGEMMA_2B,
    CHAMELEON_34B)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
