"""Config: command_r_35b (see registry.py for the full definition)."""
from .registry import COMMAND_R_35B as CONFIG

__all__ = ["CONFIG"]
