"""Config: mamba2_780m (see registry.py for the full definition)."""
from .registry import MAMBA2_780M as CONFIG

__all__ = ["CONFIG"]
