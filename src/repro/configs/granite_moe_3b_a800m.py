"""Config: granite_moe_3b_a800m (see registry.py for the full definition)."""
from .registry import GRANITE_MOE_3B as CONFIG

__all__ = ["CONFIG"]
