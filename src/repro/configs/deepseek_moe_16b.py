"""Config: deepseek_moe_16b (see registry.py for the full definition)."""
from .registry import DEEPSEEK_MOE_16B as CONFIG

__all__ = ["CONFIG"]
