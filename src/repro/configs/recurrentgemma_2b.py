"""Config: recurrentgemma_2b (see registry.py for the full definition)."""
from .registry import RECURRENTGEMMA_2B as CONFIG

__all__ = ["CONFIG"]
