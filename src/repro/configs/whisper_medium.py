"""Config: whisper_medium (see registry.py for the full definition)."""
from .registry import WHISPER_MEDIUM as CONFIG

__all__ = ["CONFIG"]
