"""Config: qwen3_0_6b (see registry.py for the full definition)."""
from .registry import QWEN3_0_6B as CONFIG

__all__ = ["CONFIG"]
