"""Config: chameleon_34b (see registry.py for the full definition)."""
from .registry import CHAMELEON_34B as CONFIG

__all__ = ["CONFIG"]
