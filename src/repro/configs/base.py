"""Architecture + input-shape configuration system.

Every assigned architecture is an ``ArchConfig``; the four assigned input
shapes are ``ShapeSpec``s.  ``reduced()`` derives the structure-preserving
small config used by CPU smoke tests (full configs are only ever lowered
abstractly via the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    use_bias: bool = False
    act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # attention pattern
    attn_kind: str = "full"  # full | local
    local_window: int = 2048
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500  # post-conv-frontend frames (frontend stubbed)
    frontend: Optional[str] = None  # audio-stub | vq-tokens | None
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm: bool = False
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256
    # hybrid temporal pattern, e.g. ("rglru", "rglru", "attn")
    block_pattern: Optional[Tuple[str, ...]] = None
    rnn_width: Optional[int] = None
    # numerics / training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    ce_chunk: int = 512  # sequence-chunked cross entropy

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds over the decoder stack."""
        if self.ssm:
            return ("ssm",) * self.n_layers
        if self.block_pattern:
            p = self.block_pattern
            return tuple(p[i % len(p)] for i in range(self.n_layers))
        kinds = []
        for i in range(self.n_layers):
            if self.moe and i >= self.first_dense_layers:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    def reduced(self) -> "ArchConfig":
        """Structure-preserving small config for CPU smoke tests."""
        if self.block_pattern:
            # one full pattern period + the stack's remainder layers
            pat = len(self.block_pattern)
            n_layers = pat + self.n_layers % pat
        elif self.moe:
            n_layers = self.first_dense_layers + 2
        else:
            n_layers = 2
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab=256,
            enc_seq=min(self.enc_seq, 16),
            n_experts=min(self.n_experts, 8) if self.moe else 0,
            top_k=min(self.top_k, 2) if self.moe else 0,
            expert_d_ff=32 if self.moe else 0,
            # drop-free capacity so decode-vs-full consistency is exact
            capacity_factor=float(min(self.n_experts, 8)) if self.moe else 1.25,
            first_dense_d_ff=64 if self.first_dense_d_ff else 0,
            ssm_state=16 if self.ssm else self.ssm_state,
            ssm_headdim=16 if self.ssm else self.ssm_headdim,
            ssd_chunk=8,
            local_window=min(self.local_window, 8),
            rnn_width=64 if self.rnn_width else None,
            ce_chunk=8,
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic attention: SSM / hybrid only (see
    DESIGN.md §Shape-cell skips)."""
    if shape.name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True
