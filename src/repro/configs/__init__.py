from .base import SHAPES, ArchConfig, ShapeSpec, shape_applicable
from .registry import ARCHS, get_arch

__all__ = ["SHAPES", "ArchConfig", "ShapeSpec", "shape_applicable", "ARCHS",
           "get_arch"]
