# Pallas TPU kernels for the perf-critical compute layers, each with:
#   kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
#   ops.py    — jit'd wrapper dispatching pallas (TPU) vs reference (CPU)
#   ref.py    — pure-jnp oracle used by tests and the CPU dry-run
