# Pallas TPU kernels for the perf-critical compute layers, each with:
#   kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
#   ops.py    — jit'd wrapper dispatching pallas (TPU) vs reference (CPU)
#   ref.py    — pure-jnp oracle used by tests and the CPU dry-run

from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Compat shim: pltpu.TPUCompilerParams (jax <= 0.4.x) was renamed to
    pltpu.CompilerParams (jax >= 0.5); accept either so the kernels run on
    both toolchains."""
    cls = getattr(_pltpu, "CompilerParams", None)
    if cls is None:
        cls = _pltpu.TPUCompilerParams
    return cls(**kwargs)
