"""Jit'd SSD entry point: Pallas intra-chunk kernel + jnp state passing on
TPU, chunked pure-jnp implementation elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas
from .ref import ssd_chunked_ref, ssd_decode_step, ssd_ref  # noqa: F401


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def ssd(x, dt, A, B, C, D, *, chunk: int = 256, h0=None, impl: str = "auto",
        interpret: bool = False):
    """Mamba2 SSD forward. x: (Bt,S,H,P); dt: (Bt,S,H); A,D: (H,);
    B,C: (Bt,S,G,N).  Returns (y, h_final)."""
    if impl == "auto":
        impl = _default_impl()
    S = x.shape[1]
    pad = (-S) % chunk
    if pad and impl != "sequential":
        # dt = 0 padding: decay exp(A·0) = 1 and zero input leave the state
        # untouched, so trailing pad steps are inert.
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, h = ssd(zp(x), zp(dt), A, zp(B), zp(C), D, chunk=chunk, h0=h0,
                   impl=impl, interpret=interpret)
        return y[:, :S], h
    if impl == "reference":
        return ssd_chunked_ref(x, dt, A, B, C, D, chunk=chunk, h0=h0)
    if impl == "sequential":
        return ssd_ref(x, dt, A, B, C, D, h0=h0)

    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    nc = S // chunk
    dtf = dt.astype(jnp.float32)
    cum_full = jnp.cumsum((A[None, None, :] * dtf).reshape(Bt, nc, chunk, H),
                          axis=2).reshape(Bt, S, H)
    # head-major flattening for the kernel
    xh = x.transpose(0, 2, 1, 3).reshape(Bt * H, S, P)
    dth = dtf.transpose(0, 2, 1).reshape(Bt * H, S)
    cumh = cum_full.transpose(0, 2, 1).reshape(Bt * H, S)
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3).reshape(Bt * H, S, N)
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(Bt * H, S, N)

    y_intra, chunk_in = ssd_chunk_pallas(xh, dth, cumh, Bh, Ch, chunk=chunk,
                                         interpret=interpret)

    chunk_decay = jnp.exp(cumh.reshape(Bt * H, nc, chunk)[:, :, -1])  # (BH,nc)
    if h0 is None:
        h0_f = jnp.zeros((Bt * H, P, N), jnp.float32)
    else:
        h0_f = h0.reshape(Bt * H, P, N).astype(jnp.float32)

    def pass_state(h, inp):
        dec, cin = inp
        return h * dec[:, None, None] + cin, h

    h_final, h_ins = jax.lax.scan(
        pass_state, h0_f,
        (chunk_decay.transpose(1, 0), chunk_in.transpose(1, 0, 2, 3)))
    h_ins = h_ins.transpose(1, 0, 2, 3)  # (BH, nc, P, N)

    # carry contribution: (C_q · h_in) * exp(cum_q)
    Chc = Ch.reshape(Bt * H, nc, chunk, N)
    y_carry = jnp.einsum("scqn,scpn->scqp", Chc, h_ins) \
        * jnp.exp(cumh).reshape(Bt * H, nc, chunk)[..., None]
    y = y_intra + y_carry.reshape(Bt * H, S, P)
    y = y.reshape(Bt, H, S, P).transpose(0, 2, 1, 3)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final.reshape(Bt, H, P, N)
