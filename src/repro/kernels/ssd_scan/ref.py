"""Mamba2 SSD (state-space duality) oracles.

``ssd_ref`` is the literal sequential recurrence:

    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t + D ⊙ x_t

``ssd_chunked_ref`` is the matmul-friendly chunked form (the algorithm the
Pallas kernel implements): within a chunk the quadratic "attention-like"
masked C·Bᵀ path, across chunks a state-passing scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D, h0=None):
    """x: (Bt, S, H, P); dt: (Bt, S, H); A: (H,) (negative); B, C:
    (Bt, S, G, N) with H % G == 0; D: (H,).  Returns (y, h_final) with
    h shape (Bt, H, P, N)."""
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)  # (Bt,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bt, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (Bt,H,P), (Bt,H), (Bt,H,N), (Bt,H,N)
        a = jnp.exp(A * dtt)  # (Bt,H)
        h = h * a[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3) + xf * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_chunked_ref(x, dt, A, B, C, D, chunk: int, h0=None):
    """Chunked SSD, same contract as ``ssd_ref``; S % chunk == 0."""
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(Bt, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, chunk, H)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32).reshape(
        Bt, nc, chunk, H, N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(
        Bt, nc, chunk, H, N)

    cum = jnp.cumsum(A[None, None, None, :] * dtf, axis=2)  # (Bt,nc,Q,H)
    # intra-chunk "attention": L[q,k] = exp(cum_q - cum_k) for q >= k.
    # Mask BEFORE exp: masked (q < k) entries have positive diff whose exp
    # can overflow, and inf·0 in the backward pass poisons gradients.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (Bt,nc,Q,K,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Ch, Bh) * L
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", scores, dtf, xf)

    # per-chunk input->state contribution and full-chunk decay
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (Bt,nc,Q,H)
    chunk_in = jnp.einsum("bckh,bckh,bckhp,bckhn->bchpn",
                          dtf, decay_to_end, xf, Bh)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (Bt,nc,H)

    if h0 is None:
        h0 = jnp.zeros((Bt, H, P, N), jnp.float32)

    def pass_state(h, inp):
        dec, cin = inp  # (Bt,H), (Bt,H,P,N)
        h_out = h * dec[..., None, None] + cin
        return h_out, h  # emit the INCOMING state for each chunk

    (h_final, h_ins) = jax.lax.scan(
        pass_state, h0.astype(jnp.float32),
        (chunk_decay.transpose(1, 0, 2), chunk_in.transpose(1, 0, 2, 3, 4)))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)  # (Bt,nc,H,P,N)

    # carry-in contribution: y_carry[q] = (C_q · h_in) * exp(cum_q)
    y_carry = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, h_ins, jnp.exp(cum))
    y = (y_intra + y_carry).reshape(Bt, S, H, P) + \
        x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_decode_step(h, x, dt, A, B, C, D):
    """Single-token recurrent update.  x: (Bt,H,P); dt: (Bt,H); B/C: (Bt,G,N).
    Returns (y (Bt,H,P), h_new)."""
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(A * dt.astype(jnp.float32))
    h = h * a[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt.astype(jnp.float32), x.astype(jnp.float32), Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), h
