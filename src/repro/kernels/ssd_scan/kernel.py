"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

Grid: (batch·heads, n_chunks).  Each cell computes, for one (head, chunk):

    scores = (C · Bᵀ) ⊙ L ⊙ dtᵀ          (Q×Q masked decay "attention")
    y_intra = scores · x                  (Q×P)
    chunk_in = (x ⊙ dt·decay_to_end)ᵀ · B (P×N input->state contribution)

Cumulative log-decays are precomputed outside (cheap elementwise); the
inter-chunk state passing is a tiny scan over n_chunks in the ops wrapper.
Q (chunk) = 256 and N = 128 keep every matmul MXU-aligned; the working set
(~0.5 MB fp32) fits VMEM comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params


def _kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, cin_ref, *,
            chunk: int):
    x = x_ref[0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Q,)
    cum = cum_ref[0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)     # (Q, N)

    diff = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(qi >= ki, diff, -jnp.inf))  # mask pre-exp
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ()))) * L
    scores = scores * dt[None, :]
    y_ref[0] = jax.lax.dot(scores, x).astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)  # (Q,)
    xw = x * (dt * decay_end)[:, None]  # (Q, P)
    cin_ref[0, 0] = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ()))).astype(cin_ref.dtype)  # (P, N)


def ssd_chunk_pallas(x, dt, cum, Bm, Cm, *, chunk: int,
                     interpret: bool = False):
    """x: (BH, S, P); dt/cum: (BH, S); Bm/Cm: (BH, S, N) (already
    head-expanded).  Returns (y_intra (BH,S,P), chunk_in (BH,nc,P,N))."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_kernel, chunk=chunk)
    y, cin = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bh, ci: (bh, ci, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, dt, cum, Bm, Cm)
    return y, cin
