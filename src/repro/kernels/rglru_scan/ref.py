"""RG-LRU (RecurrentGemma) gated linear recurrence oracles.

Given per-step decay a_t ∈ (0,1) and pre-gated input u_t:

    h_t = a_t · h_{t-1} + u_t

(with u_t = sqrt(1 − a_t²) · i_t ⊙ x_t computed by the caller).  The
sequential scan is the oracle; an associative log-depth scan is the fast
XLA path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, u, h0=None):
    """a, u: (B, S, R).  Returns (h_seq (B,S,R), h_final (B,R))."""
    B, S, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)

    def step(h, inp):
        at, ut = inp
        h = at * h + ut
        return h, h

    h_final, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.astype(jnp.float32).transpose(1, 0, 2),
         u.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(u.dtype), h_final


def rglru_scan_assoc(a, u, h0=None):
    """Log-depth associative scan: compose (a1,u1)∘(a2,u2) = (a1a2, a2u1+u2)."""
    af = a.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if h0 is not None:
        uf = uf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        ax, ux = x
        ay, uy = y
        return ax * ay, ay * ux + uy

    _, hs = jax.lax.associative_scan(combine, (af, uf), axis=1)
    return hs.astype(u.dtype), hs[:, -1].astype(jnp.float32)
