"""Pallas TPU kernel for the RG-LRU linear recurrence.

Grid: (batch, R/block_r, S/block_s) with the sequence dimension innermost
and sequential; the hidden state is carried across sequence blocks in VMEM
scratch.  Within a block, a fori_loop walks the rows — each step is a fused
multiply-add over a (block_r,) vector lane, which is VPU-bound by nature
(the recurrence has no matmul to feed the MXU; the surrounding projections
do that).  block_r = 512 lanes amortizes loop overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params


def _kernel(a_ref, u_ref, h0_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (block_s, block_r)
    u = u_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + u[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h


def rglru_scan_pallas(a, u, h0, *, block_r: int = 512, block_s: int = 256,
                      interpret: bool = False):
    """a, u: (B, S, R); h0: (B, R).  Returns h_seq (B, S, R)."""
    B, S, R = a.shape
    block_r = min(block_r, R)
    block_s = min(block_s, S)
    assert R % block_r == 0 and S % block_s == 0
    kernel = functools.partial(_kernel, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=(B, R // block_r, S // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_r), lambda b, ri, si: (b, si, ri)),
            pl.BlockSpec((1, block_s, block_r), lambda b, ri, si: (b, si, ri)),
            pl.BlockSpec((1, block_r), lambda b, ri, si: (b, ri)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_r),
                               lambda b, ri, si: (b, si, ri)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), u.dtype),
        scratch_shapes=[pltpu.VMEM((block_r,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, u, h0)
    return out
