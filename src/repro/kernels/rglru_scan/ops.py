"""Jit'd RG-LRU scan entry point."""
from __future__ import annotations

import jax

from .kernel import rglru_scan_pallas
from .ref import rglru_scan_assoc, rglru_scan_ref  # noqa: F401


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def rglru_scan(a, u, h0=None, *, impl: str = "auto", interpret: bool = False):
    """h_t = a_t h_{t-1} + u_t over axis 1.  Returns (h_seq, h_final)."""
    if impl == "auto":
        impl = _default_impl()
    if impl == "pallas":
        import jax.numpy as jnp
        if h0 is None:
            h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
        hs = rglru_scan_pallas(a, u, h0, interpret=interpret)
        return hs, hs[:, -1].astype(jnp.float32)
    if impl == "sequential":
        return rglru_scan_ref(a, u, h0)
    return rglru_scan_assoc(a, u, h0)
