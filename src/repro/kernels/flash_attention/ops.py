"""Jit'd attention entry point: dispatches to the Pallas TPU kernel on TPU
backends and the pure-jnp reference elsewhere (CPU dry-run / smoke tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_chunked, attention_ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_positions=None, k_positions=None,
                    impl: str = "auto", interpret: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KH, hd).

    The Pallas path covers the contiguous-position train/prefill case.  On
    non-TPU backends, long sequences use the chunked online-softmax
    implementation so memory/traffic in the lowered HLO match a flash-style
    schedule (the dry-run depends on this).  Decode (explicit position
    arrays, single-token queries) uses the naive einsum path — a bandwidth-
    bound matvec where a custom kernel buys nothing.
    """
    if impl == "auto":
        impl = _default_impl()
    contiguous = q_positions is None and k_positions is None \
        and q.shape[1] == k.shape[1]
    if impl == "pallas" and contiguous and q.shape[1] >= 8:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=interpret)
    if impl in ("reference", "chunked") and contiguous and q.shape[1] > 512:
        return attention_chunked(q, k, v, causal=causal, window=window)
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_positions=q_positions, k_positions=k_positions)
