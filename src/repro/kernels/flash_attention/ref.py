"""Pure-jnp oracles for (GQA, causal/local) attention.

``attention_ref``      — naive O(S²)-memory softmax attention (the oracle).
``attention_chunked``  — memory-efficient online-softmax attention (scan over
query blocks × kv blocks), numerically equivalent; this is what the dry-run
lowers on non-TPU backends so HLO memory/traffic reflects a flash-style
schedule instead of a materialized score matrix.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_positions: Optional[jnp.ndarray] = None,
                  k_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KH, hd) with H % KH == 0.

    Masking uses absolute positions (default arange).  Scores/softmax in f32.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if k_positions is None:
        k_positions = jnp.arange(Sk)
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, g, hd)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / jnp.sqrt(hd).astype(jnp.float32)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= q_positions[:, None] >= k_positions[None, :]
    if window is not None:
        mask &= q_positions[:, None] - k_positions[None, :] < window
    mask &= k_positions[None, :] >= 0  # slots marked invalid with pos=-1
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: Optional[int] = None,
                      block_q: int = 512) -> jnp.ndarray:
    """Flash-style memory in pure XLA: sequential map over query blocks,
    each block rematerialized in backward (jax.checkpoint), so live memory
    is one (bq × Sk) score block and the saved residuals are just the block
    outputs — O(S·hd) like a flash kernel, at ~1.5× recompute.  Same
    contract as ``attention_ref`` with contiguous positions."""
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    bq = min(block_q, Sq)
    if Sq % bq:
        return attention_ref(q, k, v, causal=causal, window=window)
    nq = Sq // bq
    scale = 1.0 / float(hd) ** 0.5
    qb = q.reshape(B, nq, bq, KH, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(Sk)

    @jax.checkpoint
    def q_block(qi, q_i):
        q_f = q_i.astype(jnp.float32) * scale  # (B, bq, KH, g, hd)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_f, kf)  # (B, KH, g, bq, Sk)
        qpos = qi * bq + jnp.arange(bq)
        mask = jnp.ones((bq, Sk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        # finite sentinel (not -inf): keeps exp/backward NaN-free even for
        # fully-masked rows
        s = jnp.where(mask[None, None, None], s, -1e30)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m), 0.0)
        p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
        return o  # (B, bq, KH, g, hd)

    ob = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb))
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return o.astype(q.dtype)
