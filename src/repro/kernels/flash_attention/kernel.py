"""Pallas TPU flash attention (causal / local-window, GQA).

Grid: (batch·q_heads, Sq/block_q, Sk/block_k) with the KV dimension
innermost and sequential; online-softmax statistics (m, l) and the output
accumulator live in VMEM scratch across KV iterations.  Blocks are
MXU-aligned (block_q = block_k = 128 by default).  Causal/local block
skipping prunes fully-masked KV blocks via pl.when.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level skip: causal => kv block must start at/below the last query
    # row; local window => kv block must end within the window of the first
    # query row.
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KH, hd).  Sq == Sk (self-attention
    train/prefill); decode-style single-token attention should use the
    reference matvec path instead."""
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    assert H % KH == 0
    group = H // KH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = 1.0 / (hd ** 0.5)

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KH, Sk, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KH, Sk, hd)
    nq, nk = Sq // block_q, Sk // block_k

    def kv_index(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * KH + h // group, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m: running max
            pltpu.VMEM((block_q,), jnp.float32),      # l: running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
