"""Checkpointing: flat-key npz + JSON manifest, async save thread, restore
with resharding — the substrate for Eva's task migration (checkpoint on the
source instance, restart on the destination) and for elastic re-scaling
(restore onto a different mesh: arrays are re-sharded on load).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save_checkpoint(path: str, state, step: int, *,
                    extra: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    tmp = os.path.join(path, f".tmp-{step}.npz")
    np.savez(tmp, **arrays)
    final = os.path.join(path, f"step-{step}.npz")
    os.replace(tmp, final)
    manifest = {"step": step, "keys": sorted(arrays),
                "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight at a time)."""

    def __init__(self, path: str):
        self.path = path
        self._thread: Optional[threading.Thread] = None

    def save(self, state, step: int, extra=None) -> None:
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO async
        flat = _flatten(state)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def write():
            os.makedirs(self.path, exist_ok=True)
            tmp = os.path.join(self.path, f".tmp-{step}.npz")
            np.savez(tmp, **arrays)
            os.replace(tmp, os.path.join(self.path, f"step-{step}.npz"))
            with open(os.path.join(self.path, "manifest.json"), "w") as f:
                json.dump({"step": step, "keys": sorted(arrays),
                           "extra": extra or {}}, f)

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(path: str) -> Optional[int]:
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return None
    with open(mf) as f:
        return json.load(f)["step"]


def restore_checkpoint(path: str, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, dict]:
    """Load a checkpoint; with ``shardings`` (a matching pytree of
    NamedSharding), arrays are placed directly onto the (possibly different)
    mesh — elastic restart onto a new cluster shape."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"step-{step}.npz"))
    flat = {k: data[k] for k in data.files}
    tree = _unflatten(flat)
    if shardings is not None:
        flat_s = _flatten(shardings)
        tree = _unflatten({
            k: jax.device_put(v, flat_s[k]) if k in flat_s else jnp.asarray(v)
            for k, v in _flatten(tree).items()})
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, step, manifest.get("extra", {})
