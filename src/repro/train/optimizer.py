"""AdamW with global-norm clipping and cosine schedule (no external deps)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(step, oc: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - oc.warmup_steps)
                 / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * cos


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros_like(p), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, oc: OptConfig):
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gn, 1e-9))
    lr = lr_at(step, oc)
    c1 = 1.0 - oc.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - oc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * g
        v = oc.b2 * v.astype(jnp.float32) + (1 - oc.b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(p.dtype), v.astype(p.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gn
