"""Gradient compression with error feedback (distributed-optimization trick).

Per-tensor symmetric int8 quantization of gradients before the data-parallel
reduction, with an error-feedback accumulator (Karimireddy et al., 2019) so
quantization error is re-injected next step and convergence is preserved.

On a real mesh this pairs with a shard_map reduce over the `data`/`pod`
axes (quantize → psum int32 → dequantize), cutting cross-pod gradient
traffic 4× vs f32; under jit-with-shardings we apply the
quantize-dequantize + error feedback transform to the gradient pytree (the
numerics are identical; the collective itself is emitted by XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_dequantize_int8(g: jax.Array):
    """Symmetric per-tensor int8 quantize->dequantize; returns (ĝ, error)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    deq = q * scale
    return deq, gf - deq


def compress_grads(grads, error_state):
    """Apply error feedback + int8 q/dq to every gradient leaf.

    error_state: pytree like grads (running quantization error), or None
    on the first step.  Returns (compressed_grads, new_error_state).
    """
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error_state)
    out = jax.tree.map(quantize_dequantize_int8, corrected)
    comp = jax.tree.map(lambda ge: ge[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda ge: ge[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    comp = jax.tree.map(lambda c, g: c.astype(g.dtype), comp, grads)
    return comp, err
