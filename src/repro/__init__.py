"""repro: Eva (EuroSys'25) cost-efficient cloud cluster scheduling as a
production-grade JAX framework — scheduler core, cloud simulator, baselines,
10 assigned architectures with FSDP/TP/EP sharding, Pallas TPU kernels,
multi-pod dry-run and roofline tooling."""

__version__ = "1.0.0"
