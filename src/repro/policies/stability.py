"""Stability-vs-cost admission: drift-plus-penalty queue control.

The strike-chasing ``AutoscaleLayer`` holds every deferrable job while the
market sits above its strike — on a market that stays dear, the pending
queue grows without bound until latest-start deadlines force a burst of
simultaneous admissions.  ``StabilityLayer`` schedules for *queue
stability against server running cost* ("Scheduling Policies for Stability
and Optimal Server Running Cost in Cloud Computing Platforms",
arXiv 2201.09050): a Lyapunov drift-plus-penalty trade-off between
pending-queue growth and the price premium of running now.

Mechanics, all against the policy-stack hooks (this is the first layer
written purely on the new API — no scheduler-core edits):

* **drift-plus-penalty admission** (``StabilityController``): each held
  job accrues queue backlog ``q_j`` (rounds held, the per-job share of the
  controller's ``held_job_rounds`` drift term).  The job is admitted when
  the market is at or below its anchor (the strike test), **or** as soon
  as the backlog term outweighs the cost penalty of paying today's
  premium::

      q_j · rp_anchor  >  V · (rp_forecast − strike · rp_anchor)

  ``V`` is the patience dial (rounds of queueing tolerated per unit of
  relative price premium): ``V → ∞`` recovers pure strike-price chasing,
  ``V = 0`` admits after a single held round.  Because OU/trace market
  premiums are bounded (spot is capped at on-demand), every job's backlog
  eventually dominates — queue length is bounded without ever touching
  the latest-start deadline backstop, which remains in force unchanged.
* **warm-keep pricing** (``StabilityLayer.keep_bonus``): while jobs are
  queued, each live instance's keep test gains slack equal to its
  relaunch overhead (acquisition + setup billed idle, plus each resident
  task's checkpoint + launch delay) amortized over D̂ and scaled by the
  queue pressure — keeping capacity warm through a dear phase is priced
  against the relaunch overhead a strike-chaser pays on every dip.

``benchmarks/bench_stability.py`` pins the acceptance trade-off: on the
bundled OU market, eva-stability holds the max pending-queue length below
always-defer eva-autoscale at a total cost within 5 %.
"""
from __future__ import annotations

from typing import Optional

from ..autoscale.admission import AdmissionController
from .layers import AdmissionLayerBase, relaunch_penalty


class StabilityController(AdmissionController):
    """Drift-plus-penalty admission over the pending queue.

    Subclasses ``AdmissionController``'s review loop (latest-start
    deadline bound, re-deferral hysteresis, forecaster caching all
    inherited) and replaces the pure strike test with the Lyapunov
    trade-off above.  ``v`` is the cost-vs-stability dial.
    """

    def __init__(self, catalog, forecaster=None, *, v: float = 32.0,
                 strike: float = 1.0, **kw):
        super().__init__(catalog, forecaster, strike=strike, **kw)
        assert v >= 0.0
        self.v = float(v)

    def _drift_dominates(self, jid: int, rp_f: float, rp_a: float) -> bool:
        """Queue backlog outweighs the premium penalty: admit."""
        q = self._held_rounds.get(jid, 0)
        return q * rp_a > self.v * (rp_f - self.strike * rp_a) + 1e-12

    def _admit_now(self, jid: int, rp_f: float, rp_a: float) -> bool:
        return (super()._admit_now(jid, rp_f, rp_a)
                or self._drift_dominates(jid, rp_f, rp_a))

    def _re_defer(self, jid: int, rp_f: float, rp_a: float) -> bool:
        # a job whose backlog would re-admit it immediately is never
        # bounced back to the queue by a price spike
        return (super()._re_defer(jid, rp_f, rp_a)
                and not self._drift_dominates(jid, rp_f, rp_a))


class StabilityLayer(AdmissionLayerBase):
    """Queue-stability-aware admission + warm-keep pricing, written purely
    against the policy-stack hooks (``pre_round`` / ``keep_bonus`` /
    ``on_pressure``)."""

    name = "stability"

    def __init__(self, controller=None, *, v: float = 32.0,
                 strike: float = 0.9, warm_keep: bool = True,
                 warm_ref: float = 4.0):
        super().__init__(controller)
        self.v = float(v)
        self.strike = float(strike)
        self.warm_keep = bool(warm_keep)
        self.warm_ref = float(warm_ref)  # queue length of full warm pressure
        self.queue_peak = 0  # max held-queue length observed
        self.warm_rounds = 0  # rounds where the warm-keep slack was active

    def _make_controller(self, catalog, type_mask):
        return StabilityController(catalog, v=self.v, strike=self.strike,
                                   type_mask=type_mask)

    def pre_round(self, view, d_hat_s):
        view, resumed = super().pre_round(view, d_hat_s)
        if len(self.last_held) > self.queue_peak:
            self.queue_peak = len(self.last_held)
        return view, resumed

    def keep_bonus(self, raw, cat, view) -> Optional[object]:
        """Warm-keep slack: while jobs are queued, an instance's relaunch
        overhead (amortized over D̂, scaled by queue pressure) is priced
        into its keep test — capacity that queued jobs will soon need is
        held through a dear phase instead of being cycled."""
        if not (self.warm_keep and self.last_held):
            return None
        self.warm_rounds += 1
        sched = self.sched
        pressure = min(1.0, len(self.last_held) / max(self.warm_ref, 1e-9))
        d_hr = max(sched.estimator.d_hat() / 3600.0, 1e-9)
        task_workload = view.task_workload
        scale = sched.migration_delay_scale

        def warm_bonus(k: int, tids) -> float:
            return pressure * relaunch_penalty(cat, k, k, tids,
                                               task_workload, scale) / d_hr

        return warm_bonus

    def summary(self) -> dict:
        out = super().summary()
        out["queue_peak"] = self.queue_peak
        out["warm_rounds"] = self.warm_rounds
        return out
