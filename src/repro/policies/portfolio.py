"""Commitment-portfolio layer: sunk-cost planning over reserved pools plus
a periodic commitment-inventory pass (Voorsluys et al., 1110.5972).

Reserved/committed capacity inverts the per-round economics Algorithm 1
prices: a commitment pool bills its discounted rate for every slot every
hour *whether used or idle*, so the **marginal** price of placing work on
a pool slot is ≈ 0 while an empty slot is pure waste.  ``PortfolioLayer``
expresses that inversion purely on the PR 5 hooks — the scheduler itself
stays Algorithm 1 + the ensemble criterion:

* ``plan_catalog`` (PLANNING phase) re-prices pool types at
  ``sunk_fraction`` × their committed rate (0 by default: sunk cost), so
  reservation prices and Algorithm 1's descending-cost order fill the
  commitments first and overflow lands on the market types at their
  spot/on-demand prices.  Billing always uses the raw catalog — the
  simulator's standing pool bill is what actually pays for the slots.
* ``region_caps`` bounds each pool at its size (``max_instances`` on the
  pool region), so the planner never over-fills a commitment; the
  simulator's launch denial is the hard backstop.
* ``keep_bonus`` grants pool residents slack equal to the committed rate:
  evicting them saves nothing (the slot bills regardless), so the
  S·D̂ > ΔM test never churns committed residents for a market price dip.
* a periodic **inventory pass** (``pre_round``) re-sizes commitments from
  the observed steady-state base: it tracks the occupied same-hardware
  fleet per pool, takes the windowed *minimum* as the committed-capacity
  candidate (the base that persisted, not the burst), and grows the pool
  — monotonically; commitments cannot be un-bought — when the
  ``PriceForecaster`` horizon estimate of the market price exceeds the
  committed rate.  Orders flow to the simulator through the scheduler's
  ``commitment_orders`` attribute and to the planner by replacing
  ``stack.caps`` (read every round).
* cross-provider arbitrage needs no code here: it rides the existing
  per-region-pair repack (``MultiRegionLayer.refine``) — the
  provider-aware ``TransferMatrix`` already prices inter-provider egress
  into S·D̂ > ΔM through ``task_move_cost`` / ``migration_cost``.

The layer is hook-for-hook the identity on catalogs without commitment
pools (including any single- or multi-region catalog and commitment-free
``multi_provider_catalog``s), pinned by the bit-identity tests in
``tests/test_policies.py``.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

import dataclasses

import numpy as np

from .base import PLANNING, PolicyLayer


class PortfolioLayer(PolicyLayer):
    """Commitment-portfolio awareness over ``multi_provider_catalog``s.

    Knobs
    -----
    sunk_fraction     : planning price of a pool type as a fraction of its
                        committed rate (0 = pure sunk cost; 1 disables the
                        fill-first repricing)
    resize            : enable the periodic commitment-inventory pass
    resize_interval_s : how often the inventory pass may re-size pools
    window            : demand samples (rounds) the steady-base minimum is
                        taken over — the base must persist a full window
                        before the layer commits to it
    forecast_horizon_s: floor on the market-price forecast horizon the
                        buy-more test compares the committed rate against
                        (the effective horizon is ``max(horizon, D̂)``)
    """

    name = "portfolio"
    catalog_phase = PLANNING

    def __init__(self, *, sunk_fraction: float = 0.0, resize: bool = True,
                 resize_interval_s: float = 3600.0, window: int = 6,
                 forecast_horizon_s: float = 4 * 3600.0):
        assert 0.0 <= sunk_fraction <= 1.0
        self.sunk_fraction = float(sunk_fraction)
        self.resize = bool(resize)
        self.resize_interval_s = float(resize_interval_s)
        self.window = int(window)
        self.forecast_horizon_s = float(forecast_horizon_s)
        # pool-region-name -> target size; the simulator polls this via the
        # scheduler's commitment_orders property and applies it
        # monotonically, so the dict holds current targets, not deltas
        self.commitment_orders: Dict[str, int] = {}
        self.resizes_ordered = 0
        self._pools: List[Tuple[int, int, int, int, str]] = []
        self._last_inventory: float = -1.0

    # -- binding -------------------------------------------------------------
    def bind(self, scheduler) -> None:
        super().bind(scheduler)
        cat = scheduler.catalog
        self._pools = []
        self._pool_mask = None
        if cat.regions is None:
            return
        for ri, cm in cat.commitment_pools():
            ks = np.nonzero(cat.region_ids == ri)[0]
            k = int(ks[0])
            b = int(cat.base_index[k])
            prov = cat.regions[ri].provider
            # the market copy of the committed hardware in the same
            # provider: the overflow price the buy-more test compares to
            k_mkt = k
            for k2 in np.nonzero(cat.base_index == b)[0].tolist():
                r2 = int(cat.region_ids[k2])
                if (cat.regions[r2].commitment is None
                        and cat.regions[r2].provider == prov):
                    k_mkt = int(k2)
                    break
            self._pools.append((ri, k, b, k_mkt, cat.regions[ri].name))
        if self._pools:
            self._pool_mask = cat.commitment_type_mask()
            self._sizes = {ri: int(cat.regions[ri].commitment.pool_size)
                           for ri, *_ in self._pools}
            self._samples = {ri: [] for ri, *_ in self._pools}

    def post_bind(self, stack) -> None:
        self._stack = stack

    # -- planning: commitments fill first ------------------------------------
    def plan_catalog(self, catalog, view, d_hat_s):
        """Pool slots are already paid for: present them at marginal price
        ``sunk_fraction`` × rate (≈ 0) so Algorithm 1 fills them first,
        bounded by the pool caps.  Identity without pools."""
        if not self._pools or self.sunk_fraction == 1.0:
            return catalog
        costs = catalog.costs * np.where(self._pool_mask,
                                         self.sunk_fraction, 1.0)
        order = np.argsort(-costs, kind="stable")
        return dataclasses.replace(catalog, costs=costs, order_desc=order)

    # -- keep test: committed residents are free to keep ---------------------
    def keep_bonus(self, raw, cat, view):
        """Evicting a pool resident saves nothing — the slot's standing
        bill continues either way — so grant exactly the committed rate
        as keep slack against the S·D̂ > ΔM test."""
        if not self._pools:
            return None
        mask = self._pool_mask
        costs = raw.costs

        def pool_bonus(k: int, tids) -> float:
            return float(costs[k]) if mask[k] else 0.0

        return pool_bonus

    # -- packing budgets -----------------------------------------------------
    def region_caps(self, catalog):
        """Pool sizes bound the planner (same values MultiRegionLayer
        derives; first non-None wins, so stacking both is harmless)."""
        if not self._pools:
            return None
        return tuple(r.max_instances for r in catalog.regions)

    # -- inventory pass ------------------------------------------------------
    def pre_round(self, view, d_hat_s) -> Tuple[object, Set[int]]:
        if not self._pools or not self.resize:
            return view, set()
        cat = self.sched.catalog
        for ri, k, b, _k_mkt, _name in self._pools:
            prov = cat.regions[ri].provider
            n = 0
            for inst in view.live:
                ki = inst.type_index
                if (int(cat.base_index[ki]) == b and inst.task_ids
                        and cat.provider_of(ki) == prov):
                    n += 1
            s = self._samples[ri]
            s.append(n)
            del s[:-self.window]
        if self._last_inventory < 0.0:
            self._last_inventory = view.time
        elif view.time - self._last_inventory >= self.resize_interval_s:
            self._inventory(view.time, d_hat_s)
            self._last_inventory = view.time
        return view, set()

    def _inventory(self, now_s: float, d_hat_s: float) -> None:
        """Grow each pool to the windowed steady-base minimum when the
        forecast market price of the same hardware exceeds the committed
        rate.  Monotonic: a commitment, once bought, stays bought."""
        # deferred import: repro.autoscale itself imports core submodules
        from ..autoscale.forecast import PriceForecaster
        cat = self.sched.catalog
        fc = PriceForecaster.for_catalog(cat)
        horizon = max(self.forecast_horizon_s, d_hat_s)
        mult = fc.mean_multipliers(len(cat), now_s, horizon)
        for ri, k, _b, k_mkt, name in self._pools:
            samples = self._samples[ri]
            if len(samples) < self.window:
                continue  # the base has not persisted a full window yet
            steady = min(samples)
            if steady <= self._sizes[ri]:
                continue
            rate = float(cat.costs[k])  # committed $/h (static)
            forecast_market = float(cat.costs[k_mkt] * mult[k_mkt])
            if rate >= forecast_market:
                continue  # the market is forecast cheaper: stay on spot
            self._sizes[ri] = int(steady)
            self.commitment_orders[name] = int(steady)
            self.resizes_ordered += 1
            if self._stack.caps is not None:
                caps = list(self._stack.caps)
                caps[ri] = int(steady)
                self._stack.caps = tuple(caps)

    # -- observability -------------------------------------------------------
    def summary(self) -> dict:
        if not self._pools:
            return {}
        return {"commitment_resizes_ordered": self.resizes_ordered}
