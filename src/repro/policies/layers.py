"""The four existing scenario axes, ported onto the policy-layer protocol.

Each layer is the exact decision logic the corresponding ``EvaScheduler``
boolean flag used to interleave through ``core/scheduler.py`` — the
bit-identity tests in ``tests/test_policies.py`` pin flag-API and
stack-API decisions to each other on every bundled demo catalog.

* ``SpotLayer``       — re-price every round against ``catalog.at(t)`` and
                        evacuate instances under a revocation notice.
* ``RegionPinLayer``  — pin packing to one region of a multi-region
                        catalog (the single-market baseline).
* ``MultiRegionLayer``— capacity-aware packing budgets, the cross-region
                        keep-test slack, and the per-region-pair S·D̂ > ΔM
                        arbitrage refinement.
* ``CreditLayer``     — plan against ``credit_priced(D̂)``, decay the
                        keep-test slack with live balances, and drain
                        throttled instances onto steady types.
* ``AutoscaleLayer``  — forecast-driven admission control over deferrable
                        jobs (wraps ``repro.autoscale.AdmissionController``).

``repro.policies.stability.StabilityLayer`` — the first axis written
purely against these hooks — lives in its own module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Set, Tuple

import numpy as np

from ..core.cluster_types import ClusterConfig
from ..core.plan import diff_configs, migration_cost, task_move_cost
from ..core.workloads import INSTANCE_ACQUISITION_S, INSTANCE_SETUP_S
from .base import PLANNING, SNAPSHOT, PolicyLayer
from .pressure import CREDIT, DEADLINE, PressureSignal


def relaunch_penalty(cat, k_src: int, k_dst: int, tids, task_workload,
                     delay_scale: float) -> float:
    """One-off $ cost of standing an instance's task set up elsewhere:
    fresh-instance acquisition + setup billed idle at the destination's
    price, plus each resident task's checkpoint + launch move cost
    (``k_src == k_dst`` prices a same-type relaunch).  Shared by the
    multi-region re-home slack and the stability warm-keep slack so the
    two keep tests can never diverge on relaunch-overhead pricing."""
    pen = ((INSTANCE_ACQUISITION_S + INSTANCE_SETUP_S) / 3600.0
           * cat.costs[k_dst])
    for t in tids:
        pen += task_move_cost(cat, task_workload[t], k_src, k_dst,
                              delay_scale)
    return pen


class SpotLayer(PolicyLayer):
    """Spot-market awareness: time-varying prices + revocation evacuation.

    ``plan_catalog`` snapshots the catalog at the round's time (the
    identity on static catalogs), and ``evacuate`` forces instances under
    an active revocation notice out of the config so their tasks re-enter
    the repack set within the notice window.
    """

    name = "spot"
    catalog_phase = SNAPSHOT

    def plan_catalog(self, catalog, view, d_hat_s):
        return catalog.at(view.time)

    def evacuate(self, raw, view) -> Set[int]:
        return set(view.revoked) if view.revoked else set()


class RegionPinLayer(PolicyLayer):
    """Pin packing to a single region of a multi-region catalog."""

    name = "region-pin"

    def __init__(self, region: str):
        self.region = region

    def bind(self, scheduler) -> None:
        super().bind(scheduler)
        assert scheduler.catalog.is_multi_region, \
            "a region pin needs a multi_region_catalog"

    def type_mask(self, catalog) -> Optional[np.ndarray]:
        return catalog.region_type_mask(catalog.region_index(self.region))


class MultiRegionLayer(RegionPinLayer):
    """Multi-region arbitrage: capacity budgets, cross-region keep slack,
    and the per-region-pair reconfiguration trade-off.

    ``region=`` optionally pins the layer to a single region (then only
    the capacity budgets and keep slack remain active — every arbitrage
    candidate outside the pin is masked out).
    """

    name = "multi-region"

    def __init__(self, region: Optional[str] = None):
        self.region = region
        self.arbitrage_moves = 0

    def bind(self, scheduler) -> None:
        PolicyLayer.bind(self, scheduler)
        assert scheduler.catalog.is_multi_region, \
            "MultiRegionLayer needs a multi_region_catalog"

    def type_mask(self, catalog) -> Optional[np.ndarray]:
        if self.region is None:
            return None
        return super().type_mask(catalog)

    def region_caps(self, catalog) -> Optional[tuple]:
        if any(r.max_instances is not None for r in catalog.regions):
            return tuple(r.max_instances for r in catalog.regions)
        return None

    def keep_bonus(self, raw, cat, view):
        """Amortized ($/h over D̂) cost of re-homing an instance's task set
        to the cheapest same-hardware region copy — relaunch idle time,
        per-task checkpoint+launch delay, checkpoint transfer time, and the
        egress fee.  Zero when the instance already sits in the cheapest
        region, so intra-region evictions are untouched.

        Known trade-off: the slack assumes an eviction from a dear region
        re-homes cross-region (true when the price gap is what made the set
        inefficient, since RP anchors to the cheapest region).  An instance
        that turned inefficient for other reasons (e.g. a completed sibling
        shrank the set) gets the same slack and may be held up to one D̂
        window before intra-region consolidation — bounded by the slack
        being the one-off move cost spread over D̂."""
        sched = self.sched
        mask = sched.stack.mask
        task_workload = view.task_workload
        d_hr = max(sched.estimator.d_hat() / 3600.0, 1e-9)

        def region_bonus(k: int, tids) -> float:
            k2 = cat.cheapest_copy(k, mask)
            if cat.region_of(k2) == cat.region_of(k):
                return 0.0
            return relaunch_penalty(cat, k, k2, tids, task_workload,
                                    sched.migration_delay_scale) / d_hr

        return region_bonus

    def refine(self, config, view, cat):
        """Per-region-pair reconfiguration trade-off (the paper's S·D̂ > M
        criterion applied to region moves): re-home each slot to the
        cheapest same-hardware copy in another region iff the hourly price
        saving, amortized over D̂ (the estimated time to the next Full
        Reconfiguration), exceeds the migration-cost *delta* of the
        rewrite — which prices the checkpoint transfer, egress fee, and
        fresh-instance launch via ``migration_cost`` on the diffed plans.
        Each adopted rewrite re-diffs the whole plan (exact, O(slots·live)
        per candidate — slot-local deltas would miss greedy-matching
        interactions between same-type slots); rounds here are tens of
        slots, so this is cheap.

        Capacity headroom is tracked against the *configuration being
        refined* (slots per region, updated as rewrites are adopted),
        since the config is what the executor will instantiate; the
        simulator's per-region denial remains the hard backstop."""
        if len(cat.regions) < 2:
            return config
        sched = self.sched
        mask = sched.stack.mask
        assignments = list(config.assignments)
        d_hr = sched.estimator.d_hat() / 3600.0
        caps = [r.max_instances for r in cat.regions]
        counts = np.zeros(len(cat.regions), dtype=np.int64)
        for k, _ in assignments:
            counts[cat.region_of(k)] += 1
        cur_m: Optional[float] = None
        changed = False
        for slot, (k, tids) in enumerate(assignments):
            base = int(cat.base_index[k])
            cand = cat.base_index == base
            if mask is not None:  # honour a region pin
                cand = cand & mask
            # cheapest same-hardware region copy with capacity headroom
            best_k = int(k)
            for k2 in np.nonzero(cand)[0].tolist():
                r2 = cat.region_of(k2)
                if (r2 != cat.region_of(k) and caps[r2] is not None
                        and counts[r2] >= caps[r2]):
                    continue
                if cat.costs[k2] < cat.costs[best_k] - 1e-12:
                    best_k = int(k2)
            if best_k == k:
                continue
            if cur_m is None:
                cur_m = migration_cost(
                    diff_configs(view.live, ClusterConfig(assignments)),
                    view.live, cat, view.task_workload,
                    sched.migration_delay_scale,
                    task_ckpt_region=view.task_ckpt_region)
            trial = list(assignments)
            trial[slot] = (best_k, tids)
            trial_m = migration_cost(
                diff_configs(view.live, ClusterConfig(trial)), view.live,
                cat, view.task_workload, sched.migration_delay_scale,
                task_ckpt_region=view.task_ckpt_region)
            saving = float(cat.costs[k] - cat.costs[best_k]) * d_hr
            if saving > trial_m - cur_m:
                assignments = trial
                cur_m = trial_m
                counts[cat.region_of(best_k)] += 1
                counts[cat.region_of(k)] -= 1  # slot vacated its old region
                self.arbitrage_moves += 1
                changed = True
        return ClusterConfig(assignments) if changed else config

    def summary(self) -> dict:
        return {"arbitrage_moves": self.arbitrage_moves}


class CreditLayer(PolicyLayer):
    """Burstable-credit awareness (CASH): effective $/throughput planning,
    balance-decayed keep slack, and throttled-instance drains.

    Inert (hook-for-hook the identity) on catalogs without burstable
    types, so spot / multi-region stacks that include it are bit-identical
    to stacks that do not.
    """

    name = "credit"
    catalog_phase = PLANNING

    def __init__(self):
        self.credit_signals = 0  # exhausted instances signalled to us
        self.credit_drains = 0  # forced partials that drained throttled insts

    def plan_catalog(self, catalog, view, d_hat_s):
        # effective $/throughput over the D̂ horizon (identity for
        # non-burstable catalogs) — billing still happens at the raw
        # prices; this is purely the planning view.
        if not catalog.is_burstable:
            return catalog
        return catalog.credit_priced(d_hat_s)

    def keep_bonus(self, raw, cat, view):
        """Planning cost of a *fresh* instance of the type (``cat.costs[k]``,
        launch-credit priced over D̂) minus the effective cost of *this*
        instance at its live balance.  ~0 while the balance matches a fresh
        launch, decaying below zero as credits drain; at exhaustion the
        keep test effectively demands TNRP ≥ cost/baseline_fraction, which
        collapses with the throughput and evicts the set into the repack."""
        if not raw.is_burstable or not view.instance_credits:
            return None
        balances = view.instance_credits
        task_iid = {t: i.instance_id for i in view.live
                    for t in i.task_ids}
        horizon_h = self.sched.estimator.d_hat() / 3600.0

        def credit_bonus(k: int, tids) -> float:
            cm = raw.credit_models[k]
            if cm is None or not tids:
                return 0.0
            bal = balances.get(task_iid.get(tids[0], -1))
            if bal is None:
                return 0.0
            eff = raw.costs[k] / cm.avg_speed_over(bal, horizon_h)
            return float(cat.costs[k] - eff)

        return credit_bonus

    def evacuate(self, raw, view) -> Set[int]:
        if raw.is_burstable and view.throttled:
            return set(view.throttled)
        return set()

    def drain_mask(self, raw, view) -> Optional[np.ndarray]:
        """Drain onto steady (non-burstable) types: an anonymous slot of
        the same burstable type would simply re-match the exhausted
        instance, so the escape must change type.  Fresh arrivals burst
        again in later (unmasked) rounds."""
        if not (raw.is_burstable and view.throttled):
            return None
        self.credit_drains += 1
        return np.array([cm is None for cm in raw.credit_models])

    def on_pressure(self, signal: PressureSignal) -> None:
        if signal.kind == CREDIT:
            self.credit_signals += len(signal.ids)

    def summary(self) -> dict:
        return {"credit_drains": self.credit_drains,
                "credit_signals": self.credit_signals}


class AdmissionLayerBase(PolicyLayer):
    """Shared plumbing for admission-control layers (autoscale,
    stability): wrap a controller with a ``review(view, d_hat) -> (held,
    forced)`` contract, strip held jobs' tasks from the round's view, and
    feed latest-start pressure signals back into the controller."""

    needs_runtime_estimates = True  # latest-start bounds need D̂_j

    def __init__(self, controller=None):
        self._controller = controller
        self.deadline_signals = 0  # latest-start deadlines signalled to us
        self.last_held: Set[int] = set()

    def _make_controller(self, catalog, type_mask):
        raise NotImplementedError

    def post_bind(self, stack) -> None:
        if self._controller is None:
            # a region pin restricts the strike test too: the controller
            # may only price a job against types the packer can use
            self._controller = self._make_controller(self.sched.catalog,
                                                     stack.mask)

    @property
    def controller(self):
        return self._controller

    def pre_round(self, view, d_hat_s) -> Tuple[object, Set[int]]:
        """Run the admission review and strip held jobs' tasks from the
        round's view, so Algorithm 1 never provisions for them.  Returns
        the (possibly filtered) view plus the jobs force-admitted by their
        latest-start bound this round."""
        if not view.deferrable:
            self.last_held = set()  # no live deferrable jobs: queue empty
            return view, set()
        held, resumed = self._controller.review(view, d_hat_s)
        self.last_held = held
        if held:
            ids = view.tasks.ids.tolist()
            jids = view.tasks.job_ids.tolist()
            held_t = {t for t, j in zip(ids, jids) if j in held}
            view = dataclasses.replace(
                view, tasks=view.tasks.subset(
                    [t for t in ids if t not in held_t]),
                pending_ids=set(view.pending_ids) - held_t)
        return view, resumed

    def on_pressure(self, signal: PressureSignal) -> None:
        if signal.kind == DEADLINE:
            self.deadline_signals += len(signal.ids)
            self._controller.note_deadline(signal.ids)

    def summary(self) -> dict:
        ctl = self._controller
        return {"admissions": ctl.admissions,
                "forced_admissions": ctl.forced_admissions,
                "re_deferrals": ctl.re_deferrals,
                "held_job_rounds": ctl.held_job_rounds}


class AutoscaleLayer(AdmissionLayerBase):
    """Price-pressure admission control over the job population: hold each
    deferrable not-yet-started job while the forecast effective
    $/throughput over its estimated duration sits above ``strike`` × its
    long-run-anchor reservation price, bounded by per-job latest-start
    deadlines (``repro.autoscale.AdmissionController``)."""

    name = "autoscale"

    def __init__(self, controller=None, *, strike: float = 1.0):
        super().__init__(controller)
        self.strike = float(strike)

    def _make_controller(self, catalog, type_mask):
        # deferred import: repro.autoscale itself imports core submodules
        from ..autoscale.admission import AdmissionController
        return AdmissionController(catalog, strike=self.strike,
                                   type_mask=type_mask)


def stack_from_flags(*, spot_aware: bool = False, multi_region: bool = False,
                     credit_aware: bool = False, autoscale: bool = False,
                     stability: bool = False, slo: bool = False,
                     portfolio: bool = False,
                     region: Optional[str] = None,
                     admission=None, strike: Optional[float] = None,
                     v: Optional[float] = None,
                     extra: Sequence[PolicyLayer] = ()):
    """Build the policy stack equivalent to the legacy boolean-flag API.

    This is both the ``EvaScheduler`` deprecation shim and the benchmark
    factory's translation layer; the bit-identity tests pin its output to
    the historical flag behaviour.  Note ``multi_region`` and
    ``credit_aware`` imply the spot behaviour (time-snapshot pricing +
    revocation evacuation), exactly as the flags did.
    """
    from .base import PolicyStack
    layers: list = []
    if spot_aware or multi_region or credit_aware:
        layers.append(SpotLayer())
    if multi_region:
        layers.append(MultiRegionLayer(region=region))
    elif region is not None:
        layers.append(RegionPinLayer(region))
    if credit_aware:
        layers.append(CreditLayer())
    if portfolio:
        from .portfolio import PortfolioLayer
        layers.append(PortfolioLayer())
    # strike / v fall back to each layer's own default when not given
    knobs = {k: val for k, val in (("strike", strike), ("v", v))
             if val is not None}
    if autoscale:
        kw = {k: v_ for k, v_ in knobs.items() if k == "strike"}
        layers.append(AutoscaleLayer(admission, **kw))
    if stability:
        from .stability import StabilityLayer
        layers.append(StabilityLayer(admission, **knobs))
    if slo:
        from .slo import SLOLayer
        layers.append(SLOLayer())
    layers.extend(extra)
    return PolicyStack(layers)
