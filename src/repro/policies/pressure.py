"""Pressure signals and the bus that delivers them.

Before the policy-layer refactor the simulator owned three parallel
pressure wirings — spot revocation notices (``on_preemption_notice``),
credit exhaustion (``on_credit_pressure``) and deferral latest-start
deadlines (``on_deadline_pressure``) — each its own callback + an
immediate extra scheduling round.  ``PressureBus`` replaces the trio with
one channel: the simulator *publishes* a ``PressureSignal`` and every
subscriber (normally just ``scheduler.on_pressure``, which fans out to the
policy stack and to the legacy per-kind hooks) receives it exactly once.

The bus is deliberately tiny and dependency-free: the delivery guarantee
("each signal reaches each subscriber exactly once, and coincident signals
do not double-fire the reaction round") lives here and in the simulator's
round de-duplication, and is pinned by ``tests/test_policies.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Set, Tuple

# signal kinds (the former three parallel wirings, plus the serving axis)
SPOT = "spot"          # revocation notice: instance ids about to be reclaimed
CREDIT = "credit"      # burstable credits exhausted: instance ids throttled
DEADLINE = "deadline"  # deferral latest-start reached: job ids to force-admit
SLO = "slo"            # service job entered utility risk: job ids at risk
KINDS = (SPOT, CREDIT, DEADLINE, SLO)


@dataclasses.dataclass(frozen=True)
class PressureSignal:
    """One scheduler-visible pressure event.

    ``ids`` are instance ids for ``spot``/``credit`` signals and job ids
    for ``deadline``/``slo`` signals — the same payloads the three legacy
    hooks carried, plus the serving axis.
    """

    kind: str
    ids: Tuple[int, ...]
    time: float


def dirty_instance_ids(signals: Iterable[PressureSignal]) -> Set[int]:
    """Union of the *instance* ids the given signals touched — the dirty
    set for incremental partial reconfiguration.  ``spot`` and ``credit``
    signals carry instance ids; ``deadline`` and ``slo`` signals carry job
    ids (their tasks enter the re-plan through the pending set, not through
    a dirty instance), so they contribute nothing here.
    """
    dirty: Set[int] = set()
    for s in signals:
        if s.kind in (SPOT, CREDIT):
            dirty.update(s.ids)
    return dirty


class PressureBus:
    """Exactly-once fan-out of pressure signals to subscribers.

    The simulator owns one bus per run and publishes every pressure event
    through it; subscribers are callables taking a ``PressureSignal``.
    ``published`` / ``delivered`` are observability counters (``delivered``
    counts subscriber deliveries, so it equals ``published`` × the
    subscriber count when nothing unsubscribes mid-run).
    """

    def __init__(self) -> None:
        self._subscribers: List[Callable[[PressureSignal], None]] = []
        self.published = 0
        self.delivered = 0

    def subscribe(self, fn: Callable[[PressureSignal], None]) -> None:
        self._subscribers.append(fn)

    def publish(self, signal: PressureSignal) -> None:
        self.published += 1
        for fn in self._subscribers:
            fn(signal)
            self.delivered += 1
