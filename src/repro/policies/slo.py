"""SLO-aware serving policy: provision and *hold* capacity headroom for
latency jobs in Eva's reservation-price market.

Batch tasks are priced by what completion is worth; an inference replica is
priced by what *latency* is worth — its value evaporates the moment the
fleet saturates mid-surge, and the S·D̂ > ΔM evict test knows nothing about
that.  ``SLOLayer`` closes the gap purely on the PolicyLayer hook points
(no scheduler-core edits, like ``StabilityLayer`` before it):

* **standing headroom** (``pre_round``): every service task's CPU/RAM
  demand is inflated by ``headroom`` in the *planning view only* (billing
  and execution use true demands).  Replicas get room from the moment they
  are packed — fewer interfering co-tenants per box, so effective serving
  capacity stays near the undegraded fleet rate.  The GPU coordinate is
  left exact (it is the integral packing key).
* **warm-keep exemption** (``keep_bonus``): an instance hosting a replica
  of an at-utility-risk job gets an effectively infinite keep slack —
  exempt from the S·D̂ > ΔM evict test until the risk clears.  Off-risk,
  replica hosts keep a standing slack equal to the replicas' relaunch
  overhead amortized over D̂ (a replica in flight is serving capacity
  lost for minutes, which is exactly what the relaunch penalty prices).
* **risk-damped repacking** (``plan_catalog``): the layer keeps an EMA of
  the planning price vector; while any service job is at utility risk,
  prices *below* their EMA are lifted toward it (dips damped, rises
  untouched) so the ensemble does not chase a transient spot dip with
  replica migrations mid-surge.  Identity when no job is at risk.
* **capacity-aware move veto** (``refine``): the S·D̂ > ΔM criterion
  prices a replica migration at its checkpoint-and-relaunch overhead, but
  a replica in flight is also *serving capacity offline* — a term ΔM
  cannot see (and Full Reconfiguration never consults the keep test at
  all, so a price dip can put every replica in flight at once).  The
  post-pass re-diffs the adopted config and admits replica moves one at a
  time only while the surviving in-place capacity still clears the job's
  utility-risk margin at the *current* request rate; vetoed replicas are
  restored to their live instances.  At the diurnal trough most of the
  fleet may chase cheaper types (staggered, never all at once); at the
  surge peak nothing moves.

Utility risk arrives two ways, both deterministic: the per-round
``view.slo_risk`` set, and rising-edge ``slo`` pressure signals
(``on_pressure`` + the simulator's immediate extra round), so the layer
reacts the instant a surge or a capacity loss puts the SLO in danger —
the pre-warming idea of predictive autoscalers (arXiv 2010.05049) keyed
off the risk margin instead of a learned forecast.

Hook-for-hook the identity on views without service jobs, so stacks that
include ``SLOLayer`` are bit-identical to stacks that do not on pure batch
traces (pinned in ``tests/test_policies.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set

import numpy as np

from ..core.cluster_types import ClusterConfig, TaskSet
from ..core.plan import diff_configs
from .base import PLANNING, PolicyLayer
from .layers import relaunch_penalty
from .pressure import SLO, PressureSignal

# keep slack handed to instances hosting at-risk replicas: large enough to
# defeat any hourly price gap (the dearest type is ~$25/h), finite so the
# summed slack stays a well-behaved float
EXEMPT_SLACK = 1e9


class SLOLayer(PolicyLayer):
    """Serving-aware headroom provisioning and warm-keep, written purely
    against the policy-stack hooks (``pre_round`` / ``keep_bonus`` /
    ``plan_catalog`` / ``on_pressure``)."""

    name = "slo"
    catalog_phase = PLANNING

    def __init__(self, *, headroom: float = 1.3, hold: float = 1.0,
                 damp: float = 1.0, ema_alpha: float = 0.2):
        assert headroom >= 1.0 and hold >= 0.0 and 0.0 <= damp <= 1.0
        self.headroom = float(headroom)
        self.hold = float(hold)
        self.damp = float(damp)
        self.ema_alpha = float(ema_alpha)
        self._risk: Set[int] = set()  # service jobs currently at utility risk
        self._service: Set[int] = set()  # live service jobs this round
        self._ema: Optional[np.ndarray] = None  # planning-price EMA
        self.slo_signals = 0  # risk rising edges signalled to us
        self.risk_rounds = 0  # rounds planned with some job at risk
        self.move_vetoes = 0  # replica moves reverted by the capacity veto

    # ------------------------------------------------------------ pre_round
    def pre_round(self, view, d_hat_s):
        if not view.service:
            self._risk = set()
            self._service = set()
            return view, set()
        self._service = set(view.service)
        # signals that raced ahead of the view are already folded in: the
        # simulator latches risk before publishing, so view.slo_risk is the
        # authoritative per-round set
        self._risk = set(view.slo_risk or ()) & self._service
        if self._risk:
            self.risk_rounds += 1
        if self.headroom > 1.0:
            view = self._inflate_service_demand(view)
        return view, set()

    def _inflate_service_demand(self, view):
        """Standing headroom: service tasks plan with CPU/RAM inflated by
        ``headroom`` so Algorithm 1 leaves them co-tenant room.  View-only —
        the executor and biller always use true demands."""
        ts = view.tasks
        rows = np.isin(ts.job_ids, np.fromiter(self._service, dtype=np.int64))
        if not rows.any():
            return view
        d = ts.demand_by_family.copy()
        d[rows, :, 1:] *= self.headroom  # (gpu, cpu, ram): gpu stays exact
        # drop the Task-object list: a subset() downstream would otherwise
        # rebuild from true demands and silently lose the inflation
        inflated = TaskSet.from_arrays(ts.ids, ts.job_ids, ts.workloads, d)
        return dataclasses.replace(view, tasks=inflated)

    # --------------------------------------------------------- plan_catalog
    def plan_catalog(self, catalog, view, d_hat_s):
        costs = np.asarray(catalog.costs, dtype=np.float64)
        if self._ema is None or self._ema.shape != costs.shape:
            self._ema = costs.copy()
        else:
            a = self.ema_alpha
            self._ema = a * costs + (1.0 - a) * self._ema
        if not self._risk or self.damp <= 0.0:
            return catalog
        # dips damped toward the running average while utility is at risk;
        # price rises pass through untouched (they still justify keeps via
        # the exemption, not via stale cheap prices)
        lifted = costs + self.damp * (self._ema - costs)
        damped = np.where(costs < self._ema, lifted, costs)
        order = np.argsort(-damped, kind="stable")
        return dataclasses.replace(catalog, costs=damped, order_desc=order)

    # ----------------------------------------------------------- keep_bonus
    def keep_bonus(self, raw, cat, view):
        if not self._service:
            return None
        service, risk, hold = self._service, self._risk, self.hold
        jid_of = dict(zip(view.tasks.ids.tolist(),
                          view.tasks.job_ids.tolist()))
        sched = self.sched
        d_hr = max(sched.estimator.d_hat() / 3600.0, 1e-9)
        task_workload = view.task_workload
        scale = sched.migration_delay_scale

        def slo_bonus(k: int, tids) -> float:
            svc = [t for t in tids if jid_of.get(t) in service]
            if not svc:
                return 0.0
            if any(jid_of[t] in risk for t in svc):
                return EXEMPT_SLACK  # warm host: exempt while at risk
            if hold <= 0.0:
                return 0.0
            # off-risk: hold the host at the replicas' relaunch overhead —
            # migrating a replica is minutes of lost serving capacity
            return hold * relaunch_penalty(cat, k, k, svc, task_workload,
                                           scale) / d_hr

        return slo_bonus

    # --------------------------------------------------------------- refine
    def refine(self, config, view, cat):
        """Capacity-aware replica-move veto (see module docstring).

        Re-diffs the adopted config against the live fleet and walks each
        service job's replica moves in deterministic (task id) order,
        admitting one only while the job stays clear of utility risk with
        that many replicas in flight — each in-flight replica is charged
        its per-replica share of the job's *current* (interference-
        degraded) capacity.  Vetoed replicas go back into the slot their
        live instance was matched to (or a restored slot for it), so the
        executor keeps the instance and no migration happens.  Moves off
        revoked or throttled hosts are never vetoed: those raise capacity.
        """
        if not self._service or view.service_specs is None:
            return config
        plan = diff_configs(view.live, config)
        jid_of = dict(zip(view.tasks.ids.tolist(),
                          view.tasks.job_ids.tolist()))
        doomed = set(view.revoked or ()) | set(view.throttled or ())
        moved: dict = {}  # jid -> [(tid, src iid)], replica moves to judge
        for m in plan.migrations:
            if m.src_instance is None or m.src_instance in doomed:
                continue  # fresh launch or escape from a dying host
            jid = jid_of.get(m.task_id)
            if jid in self._service:
                moved.setdefault(jid, []).append((m.task_id, m.src_instance))
        if not moved:
            return config
        live_by_id = {i.instance_id: i for i in view.live}
        # live replica count per service job (tasks physically on instances)
        n_live = {jid: 0 for jid in moved}
        for inst in view.live:
            for t in inst.task_ids:
                j = jid_of.get(t)
                if j in n_live:
                    n_live[j] += 1
        vetoed: dict = {}  # src iid -> [tids to restore there]
        for jid, mv in sorted(moved.items()):
            spec = view.service_specs.get(jid)
            n = n_live.get(jid, 0)
            if spec is None or n == 0:
                continue
            lam = (view.service_rps or {}).get(jid, 0.0)
            cap = (view.service_capacity or {}).get(jid, 0.0)
            in_flight = 0
            for tid, src in sorted(mv):
                survive = cap * (n - in_flight - 1) / n
                if spec.at_risk(lam, survive):
                    vetoed.setdefault(src, []).append(tid)
                else:
                    in_flight += 1
        if not vetoed:
            return config
        self.move_vetoes += sum(len(ts) for ts in vetoed.values())
        assignments = [(k, list(tids)) for k, tids, _ in plan.slots]
        slot_of_iid = {iid: s for s, (_, _, iid) in enumerate(plan.slots)
                       if iid is not None}
        revert = {t for ts in vetoed.values() for t in ts}
        for _, tids in assignments:
            tids[:] = [t for t in tids if t not in revert]
        for src, tids in sorted(vetoed.items()):
            inst = live_by_id[src]
            s = slot_of_iid.get(src)
            if s is not None and assignments[s][0] == inst.type_index:
                assignments[s][1].extend(tids)
            else:
                assignments.append((inst.type_index, tids))
        return ClusterConfig([(k, tuple(tids)) for k, tids in assignments
                              if tids])

    # ----------------------------------------------------------- on_pressure
    def on_pressure(self, signal: PressureSignal) -> None:
        if signal.kind == SLO:
            self.slo_signals += len(signal.ids)
            # react in the forced round the signal triggers, before the
            # next view refresh
            self._risk |= set(signal.ids)

    def summary(self) -> dict:
        return {"slo_signals": self.slo_signals,
                "risk_rounds": self.risk_rounds,
                "move_vetoes": self.move_vetoes}
