# Composable policy-layer stack: one scenario axis = one PolicyLayer, a
# PolicyStack owns ordering/composition, and a PressureBus replaces the
# three parallel spot/credit/deadline pressure wirings.
from .base import PLANNING, SNAPSHOT, PolicyLayer, PolicyStack
from .layers import (AdmissionLayerBase, AutoscaleLayer, CreditLayer,
                     MultiRegionLayer, RegionPinLayer, SpotLayer,
                     stack_from_flags)
from .portfolio import PortfolioLayer
from .pressure import (CREDIT, DEADLINE, KINDS, SLO, SPOT, PressureBus,
                       PressureSignal, dirty_instance_ids)
from .slo import SLOLayer
from .stability import StabilityController, StabilityLayer

__all__ = [
    "PLANNING", "SNAPSHOT", "PolicyLayer", "PolicyStack",
    "AdmissionLayerBase", "AutoscaleLayer", "CreditLayer",
    "MultiRegionLayer", "PortfolioLayer", "RegionPinLayer", "SpotLayer",
    "stack_from_flags",
    "CREDIT", "DEADLINE", "KINDS", "SLO", "SPOT", "PressureBus",
    "PressureSignal", "dirty_instance_ids",
    "SLOLayer",
    "StabilityController", "StabilityLayer",
]
