"""The composable policy-layer protocol and the stack that composes it.

Four PRs of scenario axes (spot, multi-region, burstable credits,
price-pressure autoscaling) originally accreted as boolean flags on
``EvaScheduler``, each axis interleaving its catalog transforms, keep-test
modifiers and pressure handlers through ``core/scheduler.py``.  This
module is the decomposition: one axis = one ``PolicyLayer``, and a
``PolicyStack`` owns ordering and composition so axes stack declaratively
instead of branching imperatively.

Hook points (all optional — the base class is the inert identity layer):

===================  =======================================================
hook                 what it composes
===================  =======================================================
``plan_catalog``     catalog-snapshot transforms, generalizing the existing
                     ``at → credit_priced → forecast_catalog`` chain.  Each
                     layer declares a ``catalog_phase``: ``SNAPSHOT``
                     transforms re-price from base costs (``catalog.at``,
                     ``forecast_catalog`` — they do *not* commute with the
                     planning stage and must come first), ``PLANNING``
                     transforms derive effective planning prices from the
                     snapshot (``credit_priced``).  The stack validates the
                     documented order at construction and folds
                     left-to-right, returning ``(raw, cat)`` — the snapshot
                     (billing-accurate) and planning catalogs.
``pre_round``        admission / job-population edits, run before anything
                     is priced: a layer may strip held jobs' tasks from the
                     round's view and return force-admitted job ids (routed
                     through the scheduler's forced-partial path).
``keep_bonus``       per-instance keep-test slack; the stack sums every
                     layer's bonus (addition commutes, so keep-test layers
                     may appear in any order).
``type_mask``        standing pack restriction (e.g. a region pin); masks
                     from all layers are AND-combined once at bind time.
``region_caps``      per-region Algorithm-1 pack budgets (first non-None
                     wins; only the multi-region layer provides one).
``evacuate``         live instances to force out of the config this round
                     (spot revocations, credit drains); the union triggers
                     one shared forced partial reconfiguration.
``drain_mask``       extra type restriction applied only to that forced
                     partial (e.g. credit drains escape to steady types).
``refine``           post-pass config refinement (the multi-region
                     arbitrage rewrite), folded in stack order.
``on_pressure``      one ``PressureSignal`` handler replacing the three
                     parallel spot/credit/deadline wirings.
===================  =======================================================
"""
from __future__ import annotations

from typing import Callable, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from .pressure import PressureSignal

# catalog-pipeline phases: SNAPSHOT transforms re-price from base costs and
# must precede PLANNING transforms, which derive effective planning prices
# from the snapshot (applying `at` after `credit_priced` would silently
# discard the credit adjustment — the documented order is load-bearing).
SNAPSHOT = 0
PLANNING = 1


class PolicyLayer:
    """One scenario axis, expressed against the hook points above.

    The base class is the identity on every hook, so a layer only
    implements the hooks its axis needs.  ``bind`` attaches the layer to
    its scheduler (catalog, D̂ estimator, migration-delay scale);
    ``post_bind`` runs after the whole stack is bound, when the combined
    ``PolicyStack.mask`` is available (admission layers thread it into
    their controllers).
    """

    name = "layer"
    catalog_phase: Optional[int] = None  # SNAPSHOT / PLANNING / None
    needs_runtime_estimates = False

    def bind(self, scheduler) -> None:
        self.sched = scheduler

    def post_bind(self, stack: "PolicyStack") -> None:
        pass

    # -- catalog pipeline ----------------------------------------------------
    def plan_catalog(self, catalog, view, d_hat_s: float):
        return catalog

    # -- job population ------------------------------------------------------
    def pre_round(self, view, d_hat_s: float) -> Tuple[object, Set[int]]:
        """Return ``(view, resumed)``: the possibly-filtered round view and
        the job ids force-admitted this round."""
        return view, set()

    # -- keep test / packing modifiers ---------------------------------------
    def keep_bonus(self, raw, cat, view) -> Optional[Callable]:
        """Optional ``(type_index, task_ids) -> $/h`` keep-test slack."""
        return None

    def type_mask(self, catalog) -> Optional[np.ndarray]:
        return None

    def region_caps(self, catalog) -> Optional[tuple]:
        return None

    # -- pressure reactions --------------------------------------------------
    def evacuate(self, raw, view) -> Set[int]:
        """Live instance ids to force out of this round's config."""
        return set()

    def drain_mask(self, raw, view) -> Optional[np.ndarray]:
        """Extra type restriction for the forced partial (drains only)."""
        return None

    def on_pressure(self, signal: PressureSignal) -> None:
        pass

    # -- post-pass -----------------------------------------------------------
    def refine(self, config, view, cat):
        return config

    # -- observability -------------------------------------------------------
    def summary(self) -> dict:
        """Per-layer counters merged into benchmark result rows."""
        return {}


class PolicyStack:
    """Ordered composition of policy layers.

    Owns the one composition rule that is *not* commutative — the catalog
    pipeline (``SNAPSHOT`` before ``PLANNING``, validated here) — and folds
    every other hook across layers in stack order (keep bonuses sum, masks
    AND, evacuation sets union, refinements chain).
    """

    def __init__(self, layers: Sequence[PolicyLayer] = ()):
        self.layers: Tuple[PolicyLayer, ...] = tuple(layers)
        seen_planning = False
        for layer in self.layers:
            if layer.catalog_phase == SNAPSHOT and seen_planning:
                raise ValueError(
                    f"layer '{layer.name}' re-prices from base costs and "
                    "must precede planning transforms (the documented "
                    "snapshot -> planning order: at/forecast before "
                    "credit_priced)")
            if layer.catalog_phase == PLANNING:
                seen_planning = True
        self._snapshot = [la for la in self.layers
                          if la.catalog_phase == SNAPSHOT]
        self._planning = [la for la in self.layers
                          if la.catalog_phase == PLANNING]
        self.mask: Optional[np.ndarray] = None
        self.caps: Optional[tuple] = None
        # provenance (set at bind): which layers contributed to the combined
        # mask, and which layer's region_caps won — read by the decision
        # trace, never by planning itself
        self.mask_layers: Tuple[str, ...] = ()
        self.caps_layer: Optional[str] = None

    # -- container protocol --------------------------------------------------
    def __iter__(self) -> Iterator[PolicyLayer]:
        return iter(self.layers)

    def __len__(self) -> int:
        return len(self.layers)

    def get(self, key) -> Optional[PolicyLayer]:
        """Layer by name (str) or class; None when absent."""
        for layer in self.layers:
            if isinstance(key, str):
                if layer.name == key:
                    return layer
            elif isinstance(layer, key):
                return layer
        return None

    def has(self, key) -> bool:
        return self.get(key) is not None

    def describe(self) -> str:
        return " + ".join(layer.name for layer in self.layers) or "(empty)"

    @property
    def needs_runtime_estimates(self) -> bool:
        return any(la.needs_runtime_estimates for la in self.layers)

    # -- binding -------------------------------------------------------------
    def bind(self, scheduler) -> None:
        for layer in self.layers:
            layer.bind(scheduler)
        mask: Optional[np.ndarray] = None
        mask_layers: List[str] = []
        for layer in self.layers:
            m = layer.type_mask(scheduler.catalog)
            if m is not None:
                m = np.asarray(m, dtype=bool)
                mask = m if mask is None else (mask & m)
                mask_layers.append(layer.name)
        self.mask = mask
        self.mask_layers = tuple(mask_layers)
        self.caps = None
        self.caps_layer = None
        for layer in self.layers:
            caps = layer.region_caps(scheduler.catalog)
            if caps is not None:
                self.caps = caps
                self.caps_layer = layer.name
                break
        for layer in self.layers:
            layer.post_bind(self)

    # -- hook folds ----------------------------------------------------------
    def pre_round(self, view, d_hat_s: float) -> Tuple[object, Set[int]]:
        resumed: Set[int] = set()
        for layer in self.layers:
            view, r = layer.pre_round(view, d_hat_s)
            resumed |= r
        return view, resumed

    def plan(self, catalog, view, d_hat_s: float):
        """Fold the catalog pipeline; returns ``(raw, cat)`` — the snapshot
        (billing-accurate, post-``at``) and planning (effective-price)
        catalogs."""
        cur = catalog
        for layer in self._snapshot:
            cur = layer.plan_catalog(cur, view, d_hat_s)
        raw = cur
        for layer in self._planning:
            cur = layer.plan_catalog(cur, view, d_hat_s)
        return raw, cur

    def keep_bonus_parts(self, raw, cat, view) -> List[Tuple[str, Callable]]:
        """Per-layer ``(layer_name, fn)`` keep-slack contributions this
        round — each layer's hook invoked exactly once, so the decision
        trace can decompose the summed bonus without re-running hooks."""
        parts: List[Tuple[str, Callable]] = []
        for layer in self.layers:
            fn = layer.keep_bonus(raw, cat, view)
            if fn is not None:
                parts.append((layer.name, fn))
        return parts

    @staticmethod
    def combine(fns: Sequence[Callable]) -> Optional[Callable]:
        """Sum keep-bonus callables (bit-identical to the single-fn case:
        ``sum`` over one term adds exact float zero)."""
        fns = list(fns)
        if not fns:
            return None
        if len(fns) == 1:
            return fns[0]
        return lambda k, tids: sum(f(k, tids) for f in fns)

    def keep_bonus(self, raw, cat, view) -> Optional[Callable]:
        return self.combine(
            fn for _, fn in self.keep_bonus_parts(raw, cat, view))

    def evacuate(self, raw, view) -> Set[int]:
        evac: Set[int] = set()
        for layer in self.layers:
            evac |= layer.evacuate(raw, view)
        return evac

    def drain_mask(self, raw, view) -> Optional[np.ndarray]:
        """Type mask for a forced partial: the standing mask AND any drain
        restrictions — falling back to the standing mask when the combined
        restriction would leave no feasible type."""
        extra: Optional[np.ndarray] = None
        for layer in self.layers:
            m = layer.drain_mask(raw, view)
            if m is not None:
                m = np.asarray(m, dtype=bool)
                extra = m if extra is None else (extra & m)
        if extra is None:
            return self.mask
        if self.mask is not None:
            extra = extra & self.mask
        return extra if extra.any() else self.mask

    def refine(self, config, view, cat):
        for layer in self.layers:
            config = layer.refine(config, view, cat)
        return config

    def on_pressure(self, signal: PressureSignal) -> None:
        for layer in self.layers:
            layer.on_pressure(signal)

    def summary(self) -> dict:
        out: dict = {}
        for layer in self.layers:
            out.update(layer.summary())
        return out
