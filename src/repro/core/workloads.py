"""Workload profiles (paper Table 7) and the co-location interference model.

Each workload has a per-family resource-demand vector (GPU tasks demand CPUs
on P3; CPU tasks need fewer vCPUs on C7i/R7i due to higher clocks — the
parenthesized numbers in Table 7), plus measured checkpoint/launch delays.

The ground-truth pairwise interference matrix models Figure 1 of the paper
(normalized co-location throughput in [0.64, 1.0], i.e. 0-36 % degradation).
Figure 1's raw cell values are not machine-readable from the paper, so we
encode a fixed seeded matrix with the same structure the paper describes:
disk/CPU/cache-heavy pairs (graph embedding, bioinfo, CFD) interfere most,
GPU-compute-bound pairs least.  The *scheduler never sees this matrix* — it
only observes throughputs through the ThroughputMonitor, exactly as in §4.3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .catalog import FAMILIES, NUM_RESOURCES


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    # demand[family] -> (gpu, cpu, ram); families without an entry fall back
    # to the "p3" vector.
    demands: dict
    checkpoint_delay_s: float
    launch_delay_s: float
    n_tasks: int = 1  # tasks per job for this workload (ResNet18 has 2/4)
    # Burst duty cycle: fraction of wall time the task actually drives the
    # CPU (burstable-instance credit drain; 1.0 = fully compute-bound).
    # Only the credit layer reads it — on non-burstable catalogs it is inert.
    burst_duty: float = 1.0
    # Autoscaling defaults (price-pressure admission control): jobs of a
    # deferrable workload may be held pending while the market is dear, and
    # ``deadline_s`` is the default completion deadline relative to arrival
    # (None = no deadline).  Trace generators stamp these onto each ``Job``
    # (which may override them per job); the Table-7 profiles keep the
    # non-deferrable defaults, so existing traces are untouched.
    deferrable: bool = False
    deadline_s: Optional[float] = None
    # Serving axis: ``kind`` is "batch" (Table-7 iteration workloads) or
    # "service" (latency-SLO inference replicas).  For service workloads the
    # three fields below are the per-replica serving defaults that trace
    # generators fold into each job's ``ServiceSpec``; the simulator and
    # scheduler only ever read the spec on the ``Job``, so these are inert
    # for batch workloads and for any code path that predates the axis.
    kind: str = "batch"
    per_replica_rps: float = 0.0
    base_latency_ms: float = 0.0
    target_p99_ms: Optional[float] = None

    @property
    def is_service(self) -> bool:
        return self.kind == "service"

    def demand_for_family(self, family: str) -> tuple:
        return self.demands.get(family, self.demands["p3"])


def _w(name, gpu, cpu_p3, ram, ckpt, launch, cpu_c=None, n_tasks=1,
       duty=1.0):
    d = {"p3": (float(gpu), float(cpu_p3), float(ram))}
    if cpu_c is not None:  # CPU-only task: cheaper CPU demand on C7i/R7i
        d["c7i"] = (float(gpu), float(cpu_c), float(ram))
        d["r7i"] = (float(gpu), float(cpu_c), float(ram))
    return WorkloadProfile(name, d, float(ckpt), float(launch), n_tasks,
                           float(duty))


# Table 7 (demands per task; checkpoint/launch migration delays in seconds).
# Burst duty cycles are beyond-paper: a3c alternates environment stepping
# with learner updates and openfoam interleaves I/O-bound write phases, so
# neither saturates a burstable instance's CPU the way the dense-compute
# workloads do (duty 1.0).
BATCH_WORKLOADS: tuple = (
    _w("resnet18-2", 1, 4, 24, 2, 80, n_tasks=2),
    _w("resnet18-4", 1, 4, 24, 2, 80, n_tasks=4),
    _w("vit", 2, 8, 60, 3, 143),
    _w("cyclegan", 1, 4, 10, 7, 2),
    _w("gpt2", 4, 4, 10, 30, 15),
    _w("graphsage", 1, 8, 50, 2, 160),
    _w("gcn", 0, 12, 40, 2, 28, cpu_c=6),
    _w("a3c", 0, 10, 8, 2, 10, cpu_c=4, duty=0.7),
    _w("diamond", 0, 14, 16, 8, 12, cpu_c=8),
    _w("openfoam", 0, 8, 8, 21, 1, cpu_c=6, duty=0.85),
)


def _sw(name, gpu, cpu_p3, ram, ckpt, launch, rps, base_ms, target_ms,
        cpu_c=None):
    base = _w(name, gpu, cpu_p3, ram, ckpt, launch, cpu_c=cpu_c)
    return dataclasses.replace(base, kind="service",
                               per_replica_rps=float(rps),
                               base_latency_ms=float(base_ms),
                               target_p99_ms=float(target_ms))


# Serving replicas (beyond-paper; mirrors the repo's launch/serve.py stack).
# llm-serve is a single-GPU decoder replica (qwen-class model: ~40 s weight
# load, small state snapshot); embed-serve is a CPU embedding/rerank replica.
# Demands sit in Table-7 units so replicas pack into the same market.
SERVICE_WORKLOADS: tuple = (
    _sw("llm-serve", 1, 4, 24, 3, 40, rps=120, base_ms=60, target_ms=240),
    _sw("embed-serve", 0, 8, 16, 2, 20, rps=400, base_ms=25, target_ms=100,
        cpu_c=6),
)

WORKLOADS: tuple = BATCH_WORKLOADS + SERVICE_WORKLOADS

# Batch trace generators sample workload indices below NUM_BATCH_WORKLOADS,
# so pre-serving traces stay bit-identical with the extended table.
NUM_BATCH_WORKLOADS = len(BATCH_WORKLOADS)
NUM_WORKLOADS = len(WORKLOADS)
WORKLOAD_INDEX = {w.name: i for i, w in enumerate(WORKLOADS)}

# Table 1: instance-level delays (seconds).
INSTANCE_ACQUISITION_S = 19.0
INSTANCE_SETUP_S = 190.0

# Checkpoint snapshot sizes, used to price cross-region migrations (transfer
# time + egress).  Table 7 reports checkpoint *delays*; at a ~1 GB/s local
# checkpoint write bandwidth those delays double as snapshot sizes in GB
# (resnet18 ≈ 2 GB ... gpt2 ≈ 30 GB), which is the scale real checkpoints
# for these models have.
CKPT_LOCAL_WRITE_GB_PER_S = 1.0


def checkpoint_size_gb(workload: int) -> float:
    return WORKLOADS[workload].checkpoint_delay_s * CKPT_LOCAL_WRITE_GB_PER_S


def _build_interference_matrix() -> np.ndarray:
    """Ground-truth pairwise normalized throughput, modeled on Figure 1.

    M[i, j] = normalized throughput of workload i when co-located with one
    task of workload j.  Not symmetric in general (Fig. 1 is not symmetric).
    """
    rng = np.random.default_rng(20250330)  # EuroSys'25 dates, fixed seed
    # Contention intensity per workload: how much pressure it PUTS on shared
    # resources (LLC / disk / net), and sensitivity: how much it SUFFERS.
    # The ^1.5 exponent skews the matrix the way Figure 1 looks: most pairs
    # are mild (mean pairwise tput ≈ 0.95) while the worst I/O-heavy pairs
    # (graph embedding × bioinformatics) lose up to 36 %.
    #            rn2   rn4   vit   cgan  gpt2  sage  gcn   a3c   diam  foam
    pressure = [0.35, 0.35, 0.45, 0.20, 0.25, 0.75, 0.60, 0.30, 1.00, 0.55]
    # Serving replicas: memory-bandwidth pressure from KV-cache / embedding
    # reads, and high sensitivity — tail latency degrades before batch
    # throughput does.  Appended past the Table-7 block.
    pressure += [0.45, 0.55]                      # llm-serve  embed-serve
    sensitive = [0.40, 0.40, 0.35, 0.20, 0.15, 0.95, 0.70, 0.30, 0.85, 0.60]
    sensitive += [0.90, 0.80]
    # The Table-7 10x10 block must stay bit-identical to the pre-serving
    # matrix (traces and benchmarks pin decisions against it), so the base
    # block consumes the original seeded draw sequence row-major over the
    # batch workloads, and cells involving a service workload draw from a
    # separate stream.
    nb, n = NUM_BATCH_WORKLOADS, NUM_WORKLOADS
    m = np.ones((n, n))
    rng_svc = np.random.default_rng(20260807)
    for i in range(n):
        for j in range(n):
            base = 0.36 * (sensitive[i] * pressure[j]) ** 1.5
            r = rng if (i < nb and j < nb) else rng_svc
            noise = r.uniform(-0.02, 0.02)
            m[i, j] = float(np.clip(1.0 - base + noise, 0.64, 1.0))
    return m


# M_TRUE[i, j]: throughput of workload i co-located with a task of workload j.
M_TRUE = _build_interference_matrix()


def true_throughput(w: int, colocated: tuple) -> float:
    """Ground-truth normalized throughput of workload ``w`` co-located with
    the (possibly empty) multiset ``colocated`` of other workloads.  Pairwise
    effects compose multiplicatively (paper simulator §5)."""
    t = 1.0
    for w2 in colocated:
        t *= M_TRUE[w, w2]
    return float(t)
