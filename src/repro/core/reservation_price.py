"""Reservation price (§4.2) and throughput-normalized reservation price (§4.3).

RP(τ) = hourly cost of the cheapest instance type whose capacity fits τ's
demand (per-family demand vectors supported).  TNRP(τ, T) = tput(τ,T) · RP(τ);
for a task of a multi-task job j (§4.4):

    TNRP(τ, T) = RP(τ) − Σ_{τ'∈j} (1 − tput(τ,T)) · RP(τ')

which reduces to tput·RP for single-task jobs.

All price-consuming entry points accept an optional ``time_s``: when given,
the catalog is snapshotted via ``catalog.at(time_s)`` so reservation prices
track a spot market's current prices (static catalogs are unaffected).

They also accept an optional ``type_mask`` ((K,) bool): masked-out types are
treated as unavailable (priced at +inf).  Schedulers use it to restrict
packing to one region or to route around regions at capacity.  On a
region-expanded catalog (``core.catalog.multi_region_catalog``) plain
``reservation_prices`` already prices candidates across *all* regions — the
cheapest feasible region-qualified type wins; ``regional_reservation_prices``
exposes the per-region breakdown for region-level analyses (examples, tests,
price-dispersion diagnostics).

Burstable catalogs (``core.catalog.CreditModel``) add ``credit_horizon_s``:
when given, prices are taken from ``catalog.credit_priced(horizon_s)`` —
each burstable type's cost divided by its forecast mean effective
throughput over the horizon, starting from a fresh instance's launch
credits.  RP(τ) then answers the credit-aware question: what is the
cheapest *effective* $/throughput way to run τ for the next D̂ seconds?  A
burstable type whose credits outlast the horizon keeps its discounted
sticker price; one that would throttle mid-horizon is inflated toward
``cost / baseline_fraction``.  The identity on non-burstable catalogs.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .catalog import Catalog, FAMILIES
from .cluster_types import TaskSet


def feasibility_matrix(tasks: TaskSet, catalog: Catalog) -> np.ndarray:
    """(T, K) bool: does task t fit alone on an empty instance of type k?"""
    # Grouped by family instead of gathering a (T, K, R) float tensor: at
    # fleet scale (10⁵–10⁶ tasks × hundreds of region-qualified types) the
    # gather dominated RP computation; the per-family slices are (T, R).
    fam = catalog.family_ids  # (K,)
    out = np.empty((len(tasks), fam.shape[0]), dtype=bool)
    for fi in np.unique(fam):
        ks = np.nonzero(fam == fi)[0]
        d = tasks.demand_by_family[:, fi, None, :]  # (T, 1, R)
        out[:, ks] = np.all(d <= catalog.capacities[None, ks, :], axis=-1)
    return out


def _masked_costs(tasks: TaskSet, catalog: Catalog,
                  type_mask: Optional[np.ndarray]) -> np.ndarray:
    """(T, K) per-type cost with infeasible / masked-out types at +inf."""
    feas = feasibility_matrix(tasks, catalog)
    costs = np.where(feas, catalog.costs[None, :], np.inf)
    if type_mask is not None:
        costs = np.where(np.asarray(type_mask)[None, :], costs, np.inf)
    return costs


def reservation_prices(tasks: TaskSet, catalog: Catalog,
                       time_s: Optional[float] = None,
                       type_mask: Optional[np.ndarray] = None,
                       credit_horizon_s: Optional[float] = None) -> np.ndarray:
    """(T,) RP(τ).  Raises if some task fits no instance type (the paper
    removes such jobs from the trace; callers should filter first).
    ``credit_horizon_s`` prices burstable types at their credit-adjusted
    effective cost over the horizon (see module docstring)."""
    if time_s is not None:
        catalog = catalog.at(time_s)
    if credit_horizon_s is not None:
        catalog = catalog.credit_priced(credit_horizon_s)
    rp = _masked_costs(tasks, catalog, type_mask).min(axis=1)
    if np.any(~np.isfinite(rp)):
        bad = tasks.ids[~np.isfinite(rp)]
        raise ValueError(f"tasks {bad.tolist()} fit no instance type")
    return rp


def cheapest_type(tasks: TaskSet, catalog: Catalog,
                  time_s: Optional[float] = None,
                  type_mask: Optional[np.ndarray] = None,
                  credit_horizon_s: Optional[float] = None) -> np.ndarray:
    """(T,) index of the reservation-price instance type of each task."""
    if time_s is not None:
        catalog = catalog.at(time_s)
    if credit_horizon_s is not None:
        catalog = catalog.credit_priced(credit_horizon_s)
    return _masked_costs(tasks, catalog, type_mask).argmin(axis=1)


def regional_reservation_prices(tasks: TaskSet, catalog: Catalog,
                                time_s: Optional[float] = None) -> np.ndarray:
    """(T, R) cheapest feasible price of each task *within each region* of a
    multi-region catalog (+inf where a region has no feasible type).  The
    row-wise minimum equals the global ``reservation_prices``; the spread
    across columns is the per-task price dispersion arbitrage can capture."""
    if time_s is not None:
        catalog = catalog.at(time_s)
    assert catalog.is_multi_region, "needs a multi_region_catalog"
    costs = _masked_costs(tasks, catalog, None)
    n_regions = len(catalog.regions)
    out = np.full((len(tasks), n_regions), np.inf)
    for r in range(n_regions):
        out[:, r] = costs[:, catalog.region_type_mask(r)].min(axis=1)
    return out


def job_rp_sums(tasks: TaskSet, rp: np.ndarray) -> np.ndarray:
    """(T,) Σ_{τ'∈job(τ)} RP(τ') — the multi-task penalty base for each task."""
    # bincount accumulates in input order, so this matches the former
    # per-task dict loop bit for bit while staying O(T) vectorized.
    _, inv = np.unique(tasks.job_ids, return_inverse=True)
    sums = np.bincount(inv, weights=rp)
    return sums[inv]


def tnrp(rp: np.ndarray, tput: np.ndarray,
         job_rp: Optional[np.ndarray] = None) -> np.ndarray:
    """Vectorized TNRP for tasks with throughputs ``tput`` (both (T,)).

    With ``job_rp`` (Σ RP over the task's whole job), applies the §4.4
    multi-task definition; otherwise the single-task tput·RP definition.
    """
    if job_rp is None:
        return tput * rp
    return rp - (1.0 - tput) * job_rp
