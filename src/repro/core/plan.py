"""Configuration diffing: turn an abstract ClusterConfig into an execution
plan against live instances, minimizing actual task migrations.

Algorithm-level configurations are anonymous (type, task-set) slots.  The
executor matches each slot to a live instance of the same type maximizing
task overlap (greedy by overlap size), so tasks that stay on their matched
instance do not migrate.  The same plan is used to *estimate* migration cost
M_F / M_P for the ensemble criterion (§4.5) before committing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .catalog import Catalog
from .cluster_types import ClusterConfig
from .workloads import (INSTANCE_ACQUISITION_S, INSTANCE_SETUP_S, WORKLOADS,
                        checkpoint_size_gb)


@dataclasses.dataclass
class LiveInstance:
    instance_id: int
    type_index: int
    task_ids: Tuple[int, ...]


@dataclasses.dataclass
class Migration:
    task_id: int
    src_instance: Optional[int]  # None = task was pending (fresh launch)
    dst_slot: int  # index into plan.slots


@dataclasses.dataclass
class Plan:
    # slot -> (type_index, task_ids, matched live instance id or None)
    slots: List[Tuple[int, Tuple[int, ...], Optional[int]]]
    migrations: List[Migration]
    terminations: List[int]  # live instance ids
    launches: List[int]  # slot indices needing a fresh instance

    @property
    def num_migrations(self) -> int:
        return len(self.migrations)


def diff_configs(live: Sequence[LiveInstance], new: ClusterConfig) -> Plan:
    task_loc: Dict[int, int] = {}
    for inst in live:
        for t in inst.task_ids:
            task_loc[t] = inst.instance_id
    by_id = {i.instance_id: i for i in live}

    # Greedy matching: per type, (slot, live instance) pairs by overlap.
    # Only pairs that actually share a task are enumerated (O(total tasks)
    # via the task-location index, instead of slots × live set
    # intersections — quadratic in fleet size for a same-type fleet);
    # the zero-overlap pairs the dense enumeration used to sort behind
    # them are reproduced below by handing unmatched slots the lowest
    # unused same-type instance ids, which is exactly where the
    # (-overlap, slot, instance_id) order landed them.
    ov_count: Dict[Tuple[int, int], int] = {}
    for slot, (k, tids) in enumerate(new.assignments):
        for t in tids:
            iid = task_loc.get(t)
            if iid is not None and by_id[iid].type_index == k:
                key = (slot, iid)
                ov_count[key] = ov_count.get(key, 0) + 1
    pairs = [(-ov, slot, iid) for (slot, iid), ov in ov_count.items()]
    pairs.sort()
    slot_match: Dict[int, int] = {}
    used = set()
    for _nov, slot, iid in pairs:
        if slot in slot_match or iid in used:
            continue
        slot_match[slot] = iid
        used.add(iid)
    # zero-overlap phase: slots ascending, each takes the smallest unused
    # live instance id of its type (a per-type cursor over the sorted ids
    # keeps this linear — `used` only grows, so skipped ids stay skipped)
    ids_of_type: Dict[int, List[int]] = {}
    for inst in live:
        ids_of_type.setdefault(inst.type_index, []).append(inst.instance_id)
    cursor: Dict[int, int] = {}
    for k in ids_of_type:
        ids_of_type[k].sort()
        cursor[k] = 0
    for slot, (k, _tids) in enumerate(new.assignments):
        if slot in slot_match:
            continue
        ids = ids_of_type.get(k)
        if ids is None:
            continue
        c = cursor[k]
        while c < len(ids) and ids[c] in used:
            c += 1
        cursor[k] = c
        if c < len(ids):
            slot_match[slot] = ids[c]
            used.add(ids[c])
            cursor[k] = c + 1

    slots, migrations, launches = [], [], []
    for slot, (k, tids) in enumerate(new.assignments):
        matched = slot_match.get(slot)
        slots.append((k, tuple(tids), matched))
        if matched is None:
            launches.append(slot)
        stay = set(by_id[matched].task_ids) if matched is not None else set()
        for t in tids:
            if t in stay:
                continue
            migrations.append(Migration(t, task_loc.get(t), slot))
    terminations = [i.instance_id for i in live if i.instance_id not in used]
    return Plan(slots, migrations, terminations, launches)


def task_move_cost(catalog: Catalog, workload: int, src_k: int, dst_k: int,
                   delay_scale: float = 1.0) -> float:
    """$ cost of moving one resident task from an instance of type ``src_k``
    to one of type ``dst_k``: checkpoint + launch delay billed idle on both
    ends, plus — when the types live in different regions of a multi-region
    catalog — the checkpoint transfer time (also billed on both ends) and
    the egress fee.  Single source of truth for the per-task move price the
    keep test, the arbitrage pass, and the plan M terms all consume."""
    w = WORKLOADS[workload]
    delay = (w.checkpoint_delay_s + w.launch_delay_s) * delay_scale
    cost = 0.0
    if catalog.transfer is not None and catalog.region_ids is not None:
        r_s, r_d = catalog.region_of(src_k), catalog.region_of(dst_k)
        if r_s != r_d:
            gb = checkpoint_size_gb(workload)
            delay += catalog.transfer.transfer_time_s(r_s, r_d, gb) * delay_scale
            cost += catalog.transfer.egress_usd(r_s, r_d, gb)
    return cost + delay / 3600.0 * float(catalog.costs[src_k]
                                         + catalog.costs[dst_k])


def migration_cost(plan: Plan, live: Sequence[LiveInstance], catalog: Catalog,
                   task_workload: Dict[int, int],
                   delay_scale: float = 1.0,
                   task_ckpt_region: Optional[Dict[int, int]] = None) -> float:
    """Dollar estimate of a plan's migration overhead (§4.5 M term).

    Per migrated task: (checkpoint + launch delay) during which both the
    source and destination instances are provisioned but the task is idle.
    Per fresh launch: acquisition + setup time billed idle.

    On a multi-region catalog, a migration whose source and destination
    types live in different regions additionally pays the checkpoint
    transfer: the transfer *time* (snapshot GB over the inter-region
    bandwidth) billed idle on both ends, plus the egress fee in dollars —
    the explicit penalty the cross-region reconfiguration trade-off weighs
    against price dispersion.  ``task_ckpt_region`` (task id → region of its
    durable checkpoint, from ``SchedulerView``) prices the same transfer for
    *pending* tasks whose checkpoint was stranded by a reclaim, so restores
    are charged in the model exactly as the simulator bills them.
    """
    by_id = {i.instance_id: i for i in live}
    cross = catalog.transfer is not None and catalog.region_ids is not None
    cost = 0.0
    for slot in plan.launches:
        k = plan.slots[slot][0]
        cost += (INSTANCE_ACQUISITION_S + INSTANCE_SETUP_S) / 3600.0 * catalog.costs[k]
    for m in plan.migrations:
        wl = task_workload[m.task_id]
        dst_k = plan.slots[m.dst_slot][0]
        if m.src_instance is not None:
            cost += task_move_cost(catalog, wl,
                                   by_id[m.src_instance].type_index, dst_k,
                                   delay_scale)
            continue
        # pending task: launch delay billed on the destination only, plus a
        # cross-region restore of any stranded checkpoint
        w = WORKLOADS[wl]
        delay = (w.checkpoint_delay_s + w.launch_delay_s) * delay_scale
        if cross and task_ckpt_region is not None:
            r_s = task_ckpt_region.get(m.task_id)
            r_d = catalog.region_of(dst_k)
            if r_s is not None and r_s != r_d:
                gb = checkpoint_size_gb(wl)
                delay += (catalog.transfer.transfer_time_s(r_s, r_d, gb)
                          * delay_scale)
                cost += catalog.transfer.egress_usd(r_s, r_d, gb)
        cost += delay / 3600.0 * float(catalog.costs[dst_k])
    return float(cost)
