"""Ensemble criterion (§4.5): choose between Full and Partial Reconfiguration.

Adopt Full iff   S_F · D̂ − M_F  >  S_P · D̂ − M_P
with D̂ = −1/(λ ln(1−p)) the mean time to the next Full Reconfiguration,
where λ is the Poisson rate of events (job arrivals + completions) and p the
empirical probability that an event triggers a Full Reconfiguration.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Optional

import numpy as np

_P_CLAMP = (1e-3, 1.0 - 1e-3)


def mean_time_to_full_reconfig(lam: float, p: float) -> float:
    """D̂ = −1/(λ ln(1−p)), λ in events/second → D̂ in seconds."""
    p = min(max(p, _P_CLAMP[0]), _P_CLAMP[1])
    lam = max(lam, 1e-9)
    return -1.0 / (lam * math.log1p(-p))


@dataclasses.dataclass
class EnsembleDecision:
    adopt_full: bool
    s_full: float
    s_partial: float
    m_full: float
    m_partial: float
    d_hat_s: float


class EventRateEstimator:
    """Online estimation of λ (events/sec) and p (Full-trigger probability).

    λ: sliding window of recent event timestamps (default last 50 events);
    p: Laplace-smoothed ratio of Full adoptions to events.
    Priors before data: one event per 20 min (the trace generator default)
    and p = 0.5.
    """

    def __init__(self, window: int = 50, prior_interarrival_s: float = 1200.0,
                 prior_p: float = 0.5):
        self._times: Deque[float] = deque(maxlen=window)
        self._events = 0
        self._fulls = 0
        self._prior_lam = 1.0 / prior_interarrival_s
        self._prior_p = prior_p

    def on_event(self, time_s: float) -> None:
        self._times.append(time_s)
        self._events += 1

    def on_full_reconfig(self) -> None:
        self._fulls += 1

    @property
    def lam(self) -> float:
        if len(self._times) < 2:
            return self._prior_lam
        span = self._times[-1] - self._times[0]
        if span <= 0:
            return self._prior_lam
        return (len(self._times) - 1) / span

    @property
    def p(self) -> float:
        # Laplace smoothing with the prior as one pseudo-observation.
        return (self._fulls + self._prior_p) / (self._events + 1.0)

    def d_hat(self) -> float:
        return mean_time_to_full_reconfig(self.lam, self.p)


def instantaneous_saving(tnrps: np.ndarray, costs: np.ndarray) -> float:
    """S = Σ_i (TNRP(T_i) − C_i): hourly value retained beyond what is paid."""
    return float((tnrps - costs).sum())


def choose(s_full: float, m_full: float, s_partial: float, m_partial: float,
           d_hat_s: float) -> EnsembleDecision:
    d_hr = d_hat_s / 3600.0  # savings are $/hr, migration costs are $
    adopt = (s_full * d_hr - m_full) > (s_partial * d_hr - m_partial)
    return EnsembleDecision(adopt, s_full, s_partial, m_full, m_partial, d_hat_s)
