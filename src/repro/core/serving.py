"""Latency-SLO serving primitives: utility curves, request profiles, queueing.

Eva's market prices *batch* work by reservation price; this module supplies
the vocabulary for the online-serving axis, where a job is a fleet of
inference replicas and its value is a smooth function of served latency
rather than a completion time.  Following the utility/cost framing of
Haritha & Singh (arXiv 2201.09050), hard ``deadline_s`` cutoffs are replaced
by a per-job :class:`UtilityCurve` — full utility while p99 latency is at or
below target, smooth exponential decay beyond it.

The latency model is deliberately coarse (an M/M/1-style amplification of a
base service latency by ``1 / (1 - rho)``): the point is not queueing-theory
fidelity but a monotone, closed-form map from *capacity headroom* to *p99
latency* that the simulator can bill deterministically and a policy layer
can invert (``max_utilization`` below) to know how much headroom keeps the
SLO safe.

Everything here is pure (numpy + math only, no simulator or catalog
imports) so traces, the simulator, and policy layers can all share it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "UtilityCurve",
    "RequestProfile",
    "ServiceSpec",
    "p99_latency_ms",
    "p99_latency_ms_np",
    "utility_np",
]


def p99_latency_ms(base_ms: float, rho: float) -> float:
    """p99 latency of a replica fleet at utilization ``rho``.

    ``base_ms`` is the unloaded p99 (queueing-free service latency); load
    amplifies it by ``1 / (1 - rho)``.  At or beyond saturation the queue
    diverges and latency is infinite.
    """
    if rho < 0.0:
        rho = 0.0
    if rho >= 1.0:
        return math.inf
    return base_ms / (1.0 - rho)


def p99_latency_ms_np(base_ms: np.ndarray, rho: np.ndarray) -> np.ndarray:
    """Vectorized :func:`p99_latency_ms` over aligned arrays.

    The division is the same IEEE double op per lane as the scalar path, so
    finite lanes match bit-for-bit; saturated lanes (``rho >= 1``) are
    ``inf`` just like the scalar.
    """
    base_ms = np.asarray(base_ms, dtype=np.float64)
    rho = np.maximum(np.asarray(rho, dtype=np.float64), 0.0)
    sat = rho >= 1.0
    return np.where(sat, np.inf, base_ms / np.where(sat, 0.5, 1.0 - rho))


def utility_np(latency_ms: np.ndarray, target_p99_ms: np.ndarray,
               softness_ms: np.ndarray, floor: np.ndarray) -> np.ndarray:
    """Vectorized :meth:`UtilityCurve.utility` over aligned arrays.

    Lanes at/below target get exactly 1.0 and non-finite lanes exactly
    ``floor``, same as the scalar; decaying lanes use ``np.exp`` where the
    scalar uses ``math.exp``, which may differ in the last ulp — within the
    simulator's documented <=1e-9 relative tolerance for utility integrals.
    """
    lat = np.asarray(latency_ms, dtype=np.float64)
    decay = np.exp(-np.maximum(lat - target_p99_ms, 0.0) / softness_ms)
    u = floor + (1.0 - floor) * decay
    u = np.where(lat <= target_p99_ms, 1.0, u)
    return np.where(np.isfinite(lat), u, floor)


@dataclass(frozen=True)
class UtilityCurve:
    """Smooth latency-utility curve: 1.0 at/below the p99 target, then
    exponential decay with scale ``softness_ms`` down to ``floor``.

    Monotone non-increasing and continuous in latency — the smooth
    replacement for a hard deadline cliff.
    """

    target_p99_ms: float
    softness_ms: float = 100.0
    floor: float = 0.0

    def utility(self, latency_ms: float) -> float:
        if not math.isfinite(latency_ms):
            return self.floor
        if latency_ms <= self.target_p99_ms:
            return 1.0
        decay = math.exp(-(latency_ms - self.target_p99_ms) / self.softness_ms)
        return self.floor + (1.0 - self.floor) * decay


@dataclass(frozen=True)
class RequestProfile:
    """Piecewise-constant request rate over time.

    ``times_s`` are ascending breakpoints; ``rps[i]`` holds on
    ``[times_s[i], times_s[i+1])``.  Before ``times_s[0]`` the rate is 0.
    The simulator schedules a rate-update event at every breakpoint, so
    billing integrals only ever see segments of constant rate.
    """

    times_s: Tuple[float, ...]
    rps: Tuple[float, ...]
    _times: np.ndarray = field(init=False, repr=False, compare=False)
    _rps: np.ndarray = field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        t = np.asarray(self.times_s, dtype=np.float64)
        r = np.asarray(self.rps, dtype=np.float64)
        if t.shape != r.shape or t.ndim != 1 or t.size == 0:
            raise ValueError("times_s and rps must be equal-length 1-D")
        if np.any(np.diff(t) <= 0):
            raise ValueError("times_s must be strictly increasing")
        object.__setattr__(self, "_times", t)
        object.__setattr__(self, "_rps", r)

    def rate_at(self, t: float) -> float:
        i = int(np.searchsorted(self._times, t, side="right")) - 1
        return float(self._rps[i]) if i >= 0 else 0.0

    def breakpoints_between(self, start_s: float,
                            end_s: float) -> Tuple[float, ...]:
        """Breakpoints strictly inside ``(start_s, end_s)``."""
        m = (self._times > start_s) & (self._times < end_s)
        return tuple(self._times[m].tolist())

    def segments(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(times_s, rps)`` as the profile's precomputed breakpoint
        arrays.  Consumers that walk time monotonically (the simulator's
        accrual sweeps) cache these once and advance a segment cursor
        instead of re-searching the piecewise representation per call.
        Treat as read-only: both arrays are the profile's own state.
        """
        return self._times, self._rps

    def peak_rps(self) -> float:
        return float(self._rps.max())

    @staticmethod
    def diurnal(peak_rps: float, *, start_s: float = 0.0,
                duration_s: float = 24 * 3600.0, step_s: float = 900.0,
                trough: float = 0.3, peak_hour: float = 14.0,
                surges: Sequence[Tuple[float, float, float]] = (),
                ) -> "RequestProfile":
        """Diurnal load on a step grid: sinusoid between ``trough*peak_rps``
        (at ``peak_hour - 12h``) and ``peak_rps`` (at ``peak_hour``),
        multiplied by ``mult`` inside each surge window ``(t0_s, t1_s,
        mult)``.  Snap surge edges to the grid yourself if you need the
        simulator to see them exactly.
        """
        times = np.arange(start_s, start_s + duration_s, step_s, dtype=np.float64)
        hours = times / 3600.0
        shape = 0.5 * (1.0 - np.cos(2.0 * np.pi * (hours - peak_hour) / 24.0 + np.pi))
        rps = peak_rps * (trough + (1.0 - trough) * shape)
        for t0, t1, mult in surges:
            rps = np.where((times >= t0) & (times < t1), rps * mult, rps)
        return RequestProfile(tuple(times.tolist()), tuple(rps.tolist()))


@dataclass(frozen=True)
class ServiceSpec:
    """Per-job serving contract: request load, utility curve, and replica
    capacity.  A service job's tasks are interchangeable replicas; fleet
    capacity is ``per_replica_rps`` summed over replicas, scaled by each
    replica's observed throughput (interference / throttling degrade
    serving rate exactly like batch iteration rate).
    """

    requests: RequestProfile
    utility: UtilityCurve
    per_replica_rps: float
    base_latency_ms: float
    # utilization fraction of max_utilization at which the job counts as
    # "at utility risk" (SLO pressure fires on the rising edge)
    risk_fraction: float = 0.8

    def max_utilization(self) -> float:
        """Highest utilization at which p99 still meets target:
        base/(1-rho) <= target  ⇒  rho <= 1 - base/target."""
        t = self.utility.target_p99_ms
        if t <= self.base_latency_ms:
            return 0.0
        return 1.0 - self.base_latency_ms / t

    def p99_ms(self, rps: float, capacity_rps: float) -> float:
        if rps <= 0.0:
            return self.base_latency_ms
        if capacity_rps <= 0.0:
            return math.inf
        return p99_latency_ms(self.base_latency_ms, rps / capacity_rps)

    def at_risk(self, rps: float, capacity_rps: float) -> bool:
        """True when load sits within the risk margin of the SLO-feasible
        utilization ceiling (or capacity is short entirely)."""
        if rps <= 0.0:
            return False
        if capacity_rps <= 0.0:
            return True
        ceiling = self.risk_fraction * self.max_utilization()
        return rps / capacity_rps >= ceiling
