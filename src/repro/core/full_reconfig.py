"""Full Reconfiguration (paper Algorithm 1) and configuration evaluation.

Two equivalent engines are provided:

* ``engine="python"`` — a literal transcription of the paper's pseudocode
  (argmax over unassigned tasks of TNRP(T ∪ {τ}), O(|T|²) evaluations).
* ``engine="numpy"``  — vectorized candidate evaluation: adding τ to a set T
  multiplies every member's predicted throughput by P[w_m, w_τ] and gives τ
  the product Π_m P[w_τ, w_m]; TNRP sums for all candidates are computed in
  one shot.  Identical tie-breaking (first maximal row index).
* ``engine="jax"``    — jitted lax.while_loop engine (see engine_jax.py).

Predicted throughput during packing uses the pairwise-product estimator over
the online co-location table snapshot (§4.3); evaluation of *live* instances
(`evaluate_assignments`) uses exact-or-pairwise table lookups.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog
from .cluster_types import Assignment, ClusterConfig, TaskSet
from .reservation_price import job_rp_sums, reservation_prices
from .throughput_table import ThroughputTable

EPS = 1e-9


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------
def _tnrp_terms(rp: np.ndarray, tput: np.ndarray, job_rp: Optional[np.ndarray]):
    """Per-task TNRP values given throughputs (vectorized, any shape)."""
    if job_rp is None:
        return tput * rp
    return rp - (1.0 - tput) * job_rp


def predicted_set_tnrp(rows: Sequence[int], workloads: np.ndarray,
                       pairwise: np.ndarray, rp: np.ndarray,
                       job_rp: Optional[np.ndarray]) -> float:
    """TNRP(T) for a hypothetical co-located set, pairwise-product predictor."""
    rows = list(rows)
    if not rows:
        return 0.0
    w = workloads[rows]
    P = pairwise[np.ix_(w, w)]
    np.fill_diagonal(P, 1.0)
    tputs = P.prod(axis=1)
    jr = job_rp[rows] if job_rp is not None else None
    return float(_tnrp_terms(rp[rows], tputs, jr).sum())


# --------------------------------------------------------------------------
# paper-faithful engine (Algorithm 1 verbatim)
# --------------------------------------------------------------------------
def _pack_python(demand: np.ndarray, workloads: np.ndarray, rp: np.ndarray,
                 job_rp: Optional[np.ndarray], catalog: Catalog,
                 pairwise: np.ndarray,
                 type_mask: Optional[np.ndarray] = None,
                 region_budget: Optional[np.ndarray] = None
                 ) -> List[Tuple[int, List[int]]]:
    T = demand.shape[0]
    unassigned = set(range(T))
    out: List[Tuple[int, List[int]]] = []
    for k in catalog.order_desc.tolist():  # descending cost (Line 2)
        if type_mask is not None and not type_mask[k]:
            continue  # type unavailable (region restriction)
        rid = catalog.region_of(k) if region_budget is not None else None
        fam = catalog.family_ids[k]
        d = demand[:, fam, :]
        cost = catalog.costs[k]
        while True:  # Line 4: keep provisioning this type
            if rid is not None and region_budget[rid] <= 0:
                break  # region at its instance-count cap
            cap = catalog.capacities[k].copy()
            members: List[int] = []
            cur = 0.0
            while True:  # Lines 7-13: fill the instance
                best_row, best_val = -1, -np.inf
                for r in sorted(unassigned):
                    if r in members or np.any(d[r] > cap + EPS):
                        continue
                    v = predicted_set_tnrp(members + [r], workloads, pairwise,
                                           rp, job_rp)
                    if v > best_val + EPS:
                        best_row, best_val = r, v
                if best_row < 0:
                    break  # nothing fits
                if best_val < cur - EPS:
                    break  # Line 9-11: adding decreases TNRP
                members.append(best_row)
                cap = cap - d[best_row]
                cur = best_val
            if members and cur >= cost - EPS:  # Line 14: cost-efficient
                out.append((k, members))
                unassigned -= set(members)
                if rid is not None:
                    region_budget[rid] -= 1
            else:
                break  # Line 17: move to a cheaper type
    return out


# --------------------------------------------------------------------------
# vectorized engine
# --------------------------------------------------------------------------
def _pack_numpy(demand: np.ndarray, workloads: np.ndarray, rp: np.ndarray,
                job_rp: Optional[np.ndarray], catalog: Catalog,
                pairwise: np.ndarray,
                type_mask: Optional[np.ndarray] = None,
                region_budget: Optional[np.ndarray] = None
                ) -> List[Tuple[int, List[int]]]:
    T = demand.shape[0]
    unassigned = np.ones(T, dtype=bool)
    out: List[Tuple[int, List[int]]] = []
    has_jr = job_rp is not None
    for k in catalog.order_desc.tolist():
        if type_mask is not None and not type_mask[k]:
            continue  # type unavailable (region restriction)
        rid = catalog.region_of(k) if region_budget is not None else None
        fam = catalog.family_ids[k]
        d = demand[:, fam, :]  # (T, R)
        cost = catalog.costs[k]
        cap_full = catalog.capacities[k]
        while unassigned.any():
            if rid is not None and region_budget[rid] <= 0:
                break  # region at its instance-count cap
            cap = cap_full.copy()
            members: List[int] = []
            m_w = np.zeros(0, dtype=np.int64)  # member workloads
            m_tput = np.zeros(0)  # member predicted throughputs
            avail = unassigned.copy()
            cur = 0.0
            while True:
                feas = avail & np.all(d <= cap[None, :] + EPS, axis=1)
                cand = np.nonzero(feas)[0]
                if cand.size == 0:
                    break
                wc = workloads[cand]
                if members:
                    fm = pairwise[np.ix_(m_w, wc)]  # (|T|, C) member degradation
                    new_m_tput = m_tput[:, None] * fm
                    cand_tput = pairwise[wc[:, None], m_w[None, :]].prod(axis=1)
                else:
                    new_m_tput = np.zeros((0, cand.size))
                    cand_tput = np.ones(cand.size)
                if has_jr:
                    m_terms = (rp[members, None]
                               - (1.0 - new_m_tput) * job_rp[members, None]).sum(0)
                    c_terms = rp[cand] - (1.0 - cand_tput) * job_rp[cand]
                else:
                    m_terms = (rp[members, None] * new_m_tput).sum(0)
                    c_terms = rp[cand] * cand_tput
                tot = m_terms + c_terms
                b = int(np.argmax(tot))  # first max == python engine tie-break
                if tot[b] < cur - EPS:
                    break
                r = int(cand[b])
                members.append(r)
                if m_tput.size:
                    m_tput = m_tput * fm[:, b]
                m_tput = np.concatenate([m_tput, [cand_tput[b]]])
                m_w = np.concatenate([m_w, [wc[b]]])
                cap = cap - d[r]
                avail[r] = False
                cur = float(tot[b])
            if members and cur >= cost - EPS:
                out.append((k, members))
                unassigned[members] = False
                if rid is not None:
                    region_budget[rid] -= 1
            else:
                break
    return out


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
def full_reconfiguration(tasks: TaskSet, catalog: Catalog,
                         table: Optional[ThroughputTable] = None, *,
                         interference_aware: bool = True,
                         multi_task_aware: bool = True,
                         engine: str = "numpy",
                         rp: Optional[np.ndarray] = None,
                         job_rp: Optional[np.ndarray] = None,
                         time_s: Optional[float] = None,
                         type_mask: Optional[np.ndarray] = None,
                         region_caps: Optional[Sequence[Optional[int]]] = None,
                         credit_horizon_s: Optional[float] = None
                         ) -> ClusterConfig:
    """Run Algorithm 1 over ``tasks`` and return the packed configuration.

    ``rp``/``job_rp`` may be precomputed (partial reconfiguration passes the
    system-wide job RP sums so multi-task penalties count non-migrating
    siblings too).  ``time_s`` snapshots a spot catalog at the given instant
    so packing order and reservation prices follow current prices.
    ``type_mask`` ((K,) bool) excludes types from both reservation prices and
    provisioning — used to restrict packing to one region of a multi-region
    catalog.  ``region_caps`` (one optional int per region) bounds how many
    instances the pack may emit per region: once a region's budget is spent,
    provisioning overflows to the next type in descending-cost order, so
    capped-but-cheap regions fill to their cap instead of starving the
    overflow.  On a region-expanded catalog without mask or caps, Algorithm 1
    prices candidate instances across every region (region-qualified types
    are ordinary types to it).  ``credit_horizon_s`` packs against the
    credit-priced planning snapshot (``catalog.credit_priced``): burstable
    types whose launch credits will not last the horizon look
    proportionally dearer, so both the descending-cost order and the
    cost-efficiency bar see effective $/throughput instead of the sticker
    price (identity for non-burstable catalogs).
    """
    if time_s is not None:
        catalog = catalog.at(time_s)
    if credit_horizon_s is not None:
        catalog = catalog.credit_priced(credit_horizon_s)
    if len(tasks) == 0:
        return ClusterConfig([])
    region_budget = None
    if region_caps is not None and catalog.region_ids is not None \
            and any(c is not None for c in region_caps):
        big = np.iinfo(np.int64).max
        region_budget = np.array([big if c is None else int(c)
                                  for c in region_caps], dtype=np.int64)
    if rp is None:
        rp = reservation_prices(tasks, catalog, type_mask=type_mask)
    if multi_task_aware and job_rp is None:
        job_rp = job_rp_sums(tasks, rp)
    if not multi_task_aware:
        job_rp = None
    if interference_aware and table is not None:
        pairwise = table.pairwise_matrix()
    else:
        n = int(tasks.workloads.max()) + 1 if len(tasks) else 1
        pairwise = np.ones((max(n, 1), max(n, 1)))
    if engine == "jax":
        from .engine_jax import pack_jax
        packer = pack_jax
    else:
        packer = {"python": _pack_python, "numpy": _pack_numpy}[engine]
    packed = packer(tasks.demand_by_family, tasks.workloads, rp,
                    job_rp, catalog, pairwise, type_mask, region_budget)
    assignments: List[Assignment] = [
        (k, tuple(int(tasks.ids[r]) for r in rows)) for k, rows in packed
    ]
    if region_budget is not None:
        # Overflow re-pack: RP is the *global* cheapest price, so once a
        # cheap region's budget is spent, dearer regions' types can never
        # look cost-efficient against it and the overflow would starve.
        # Re-anchor reservation prices to the still-available types and pack
        # the remainder (repeat until everyone is placed or nothing is
        # available — truly full markets leave tasks pending for the
        # simulator/next round to retry).
        sub_packer = packer
        placed = {t for _, ts in assignments for t in ts}
        left = [int(t) for t in tasks.ids.tolist() if t not in placed]
        while left:
            avail = region_budget[catalog.region_ids] > 0
            if type_mask is not None:
                avail = avail & np.asarray(type_mask)
            if not avail.any():
                break
            sub = tasks.subset(left)
            try:
                rp_sub = reservation_prices(sub, catalog, type_mask=avail)
            except ValueError:
                break  # remainder fits no available type
            # multi-task penalties keep the *system-wide* job RP sums (already
            # placed siblings still count), same as partial_reconfiguration
            jr_sub = None
            if job_rp is not None:
                jr_sub = job_rp[np.array([tasks.row(t) for t in left])]
            sub_packed = sub_packer(sub.demand_by_family, sub.workloads,
                                    rp_sub, jr_sub, catalog, pairwise,
                                    avail, region_budget)
            if not sub_packed:
                break
            assignments += [(k, tuple(int(sub.ids[r]) for r in rows))
                            for k, rows in sub_packed]
            placed = {t for _, ts in assignments for t in ts}
            left = [t for t in left if t not in placed]
    return ClusterConfig(assignments)


def evaluate_assignments(assignments: Sequence[Assignment], tasks: TaskSet,
                         catalog: Catalog, table: Optional[ThroughputTable],
                         multi_task_aware: bool = True,
                         type_mask: Optional[np.ndarray] = None):
    """Per-instance (TNRP(T_i), C_i) for *live* placements, using
    exact-or-pairwise table lookups of the actual co-location sets."""
    rp = reservation_prices(tasks, catalog, type_mask=type_mask)
    job_rp = job_rp_sums(tasks, rp) if multi_task_aware else None
    tnrps, costs = [], []
    for k, tids in assignments:
        rows = [tasks.row(t) for t in tids]
        ws = tasks.workloads[rows]
        total = 0.0
        for i, r in enumerate(rows):
            others = np.delete(ws, i)
            tput = table.lookup(int(ws[i]), others.tolist()) if table else 1.0
            jr = job_rp[r] if job_rp is not None else None
            total += float(_tnrp_terms(rp[r], np.asarray(tput), jr))
        tnrps.append(total)
        costs.append(float(catalog.costs[k]))
    return np.array(tnrps), np.array(costs)
