"""Core data model: tasks, jobs, instances, cluster configurations.

The scheduler-facing representation is deliberately array-friendly: a
``TaskSet`` holds (T, F, R) demand tensors so reservation prices and packing
feasibility are vectorized across all tasks and instance types at once.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog import FAMILIES, NUM_RESOURCES, Catalog
from .workloads import WORKLOADS

if TYPE_CHECKING:  # pragma: no cover - annotation-only import
    from .serving import ServiceSpec


@dataclasses.dataclass
class Task:
    task_id: int
    job_id: int
    workload: int  # index into the workload-profile table (interference key)
    # demands[f] = (gpu, cpu, ram) for family f; missing families fall back to
    # demands[0] (the "p3" vector), mirroring Table 7.
    demands: Dict[str, Tuple[float, float, float]]

    def demand_for_family(self, family: str) -> Tuple[float, float, float]:
        return self.demands.get(family, self.demands.get("p3"))


@dataclasses.dataclass
class Job:
    job_id: int
    workload: int
    arrival_time: float  # seconds
    duration_s: float  # standalone (no-interference) runtime
    n_tasks: int
    tasks: List[Task] = dataclasses.field(default_factory=list)
    # price-pressure autoscaling: a deferrable job may be held pending (not
    # admitted, zero billing) while the market is dear; ``deadline_s`` is the
    # absolute completion deadline (None = none).  Defaults keep every
    # existing trace on the admit-immediately path.
    deadline_s: Optional[float] = None
    deferrable: bool = False
    # serving axis: a job carrying a ServiceSpec is a fleet of inference
    # replicas — it runs for a fixed wall-clock window (duration_s) and is
    # billed by served-request latency against its utility curve instead of
    # by iteration progress.  Service jobs are never deferrable batch.
    service: Optional["ServiceSpec"] = None
    # runtime bookkeeping (filled by the simulator)
    completion_time: Optional[float] = None

    @property
    def is_service(self) -> bool:
        return self.service is not None

    @property
    def total_iters(self) -> float:
        # normalize standalone rate to 1 iter/sec
        return self.duration_s


@dataclasses.dataclass
class Instance:
    instance_id: int
    type_index: int  # into the catalog
    launch_time: float = 0.0  # when requested from the cloud
    ready_time: float = 0.0  # after acquisition + setup
    terminate_time: Optional[float] = None


# A cluster configuration: list of (type_index, tuple-of-task-ids).  Instances
# are anonymous at the algorithm level; the executor diffs configurations
# against live instances to minimize actual migrations.
Assignment = Tuple[int, Tuple[int, ...]]


@dataclasses.dataclass
class ClusterConfig:
    assignments: List[Assignment] = dataclasses.field(default_factory=list)

    def total_hourly_cost(self, catalog: Catalog) -> float:
        return float(sum(catalog.costs[k] for k, _ in self.assignments))

    def task_to_slot(self) -> Dict[int, int]:
        out = {}
        for slot, (_, tids) in enumerate(self.assignments):
            for t in tids:
                out[t] = slot
        return out

    def num_tasks(self) -> int:
        return sum(len(tids) for _, tids in self.assignments)


class TaskSet:
    """Array view over a list of tasks.

    demand_by_family : (T, F, R) — demand of task t if placed on family f
    job_ids, workloads : (T,) int64
    """

    def __init__(self, tasks: Sequence[Task]):
        self.tasks: Optional[List[Task]] = list(tasks)
        self.ids = np.array([t.task_id for t in self.tasks], dtype=np.int64)
        self.job_ids = np.array([t.job_id for t in self.tasks], dtype=np.int64)
        self.workloads = np.array([t.workload for t in self.tasks], dtype=np.int64)
        T = len(self.tasks)
        d = np.zeros((T, len(FAMILIES), NUM_RESOURCES), dtype=np.float64)
        for i, t in enumerate(self.tasks):
            for fi, fam in enumerate(FAMILIES):
                d[i, fi] = t.demand_for_family(fam)
        self.demand_by_family = d
        self._index_of = {tid: i for i, tid in enumerate(self.ids.tolist())}
        self._job_sizes: Optional[Dict[int, int]] = None

    @classmethod
    def from_arrays(cls, ids: np.ndarray, job_ids: np.ndarray,
                    workloads: np.ndarray, demand_by_family: np.ndarray,
                    tasks: Optional[Sequence[Task]] = None) -> "TaskSet":
        """Build directly from the array view, skipping the per-task Python
        loop — the fleet-scale constructor (``tasks`` objects optional; the
        planning engines only consume the arrays)."""
        self = cls.__new__(cls)
        self.tasks = list(tasks) if tasks is not None else None
        self.ids = np.asarray(ids, dtype=np.int64)
        self.job_ids = np.asarray(job_ids, dtype=np.int64)
        self.workloads = np.asarray(workloads, dtype=np.int64)
        self.demand_by_family = np.asarray(demand_by_family, dtype=np.float64)
        self._index_of = {tid: i for i, tid in enumerate(self.ids.tolist())}
        self._job_sizes = None
        return self

    def __len__(self) -> int:
        return self.ids.shape[0]

    def row(self, task_id: int) -> int:
        return self._index_of[task_id]

    def job_size(self, job_id: int) -> int:
        """Number of tasks of ``job_id`` in this set (cached)."""
        if self._job_sizes is None:
            uniq, cnt = np.unique(self.job_ids, return_counts=True)
            self._job_sizes = dict(zip(uniq.tolist(), cnt.tolist()))
        return self._job_sizes.get(job_id, 0)

    def subset(self, task_ids: Sequence[int]) -> "TaskSet":
        rows = [self._index_of[t] for t in task_ids]
        if self.tasks is not None:
            return TaskSet([self.tasks[r] for r in rows])
        rx = np.asarray(rows, dtype=np.int64)
        return TaskSet.from_arrays(self.ids[rx], self.job_ids[rx],
                                   self.workloads[rx],
                                   self.demand_by_family[rx])


_task_counter = itertools.count()


def make_task(job_id: int, workload: int, task_id: Optional[int] = None) -> Task:
    prof = WORKLOADS[workload]
    demands = {fam: prof.demand_for_family(fam) for fam in FAMILIES}
    tid = next(_task_counter) if task_id is None else task_id
    return Task(task_id=tid, job_id=job_id, workload=workload, demands=demands)


def make_job(job_id: int, workload: int, arrival_time: float, duration_s: float,
             n_tasks: Optional[int] = None, deadline_s: Optional[float] = None,
             deferrable: bool = False,
             service: Optional["ServiceSpec"] = None) -> Job:
    prof = WORKLOADS[workload]
    n = prof.n_tasks if n_tasks is None else n_tasks
    job = Job(job_id=job_id, workload=workload, arrival_time=arrival_time,
              duration_s=duration_s, n_tasks=n, deadline_s=deadline_s,
              deferrable=deferrable, service=service)
    job.tasks = [make_task(job_id, workload) for _ in range(n)]
    return job
