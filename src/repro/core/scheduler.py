"""Scheduler interface + the Eva scheduler (ensemble of Full/Partial, §4.5).

The simulator (and the local-cloud physical harness) call ``schedule(view)``
each scheduling round and execute the returned abstract configuration via
``core.plan.diff_configs``.  Throughput observations flow back through
``observe_*`` callbacks, and arrival/completion events through ``on_event``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .catalog import Catalog
from .cluster_types import ClusterConfig, TaskSet
from .ensemble import EnsembleDecision, EventRateEstimator, choose, instantaneous_saving
from .full_reconfig import evaluate_assignments, full_reconfiguration
from .partial_reconfig import partial_reconfiguration
from .plan import LiveInstance, diff_configs, migration_cost
from .reservation_price import cheapest_type
from .throughput_table import ThroughputTable
from .workloads import NUM_WORKLOADS


@dataclasses.dataclass
class SchedulerView:
    """Snapshot handed to a scheduler at each round."""
    time: float
    tasks: TaskSet  # all live tasks (placed + pending)
    pending_ids: Set[int]
    live: List[LiveInstance]
    task_workload: Dict[int, int]
    # runtime estimates (iters remaining / standalone rate), only for
    # schedulers that declare needs_runtime_estimates (Stratus best-case).
    remaining_s: Optional[Dict[int, float]] = None
    # live instance ids under a spot revocation notice (reclaim imminent);
    # None outside spot scenarios.
    revoked: Optional[Set[int]] = None


class SchedulerBase:
    name = "base"
    needs_runtime_estimates = False
    needs_true_profile = False

    def __init__(self, catalog: Catalog):
        self.catalog = catalog

    # -- monitor hooks ------------------------------------------------------
    def on_event(self, time_s: float) -> None:  # job arrival/completion
        pass

    def on_preemption_notice(self, instance_ids: Sequence[int],
                             time_s: float) -> None:  # spot revocation notice
        pass

    def observe_single(self, workload: int, colocated: Sequence[int],
                       value: float) -> None:
        pass

    def observe_job(self, placements, value: float) -> None:
        pass

    # -- main entry ---------------------------------------------------------
    def schedule(self, view: SchedulerView) -> ClusterConfig:
        raise NotImplementedError


class EvaScheduler(SchedulerBase):
    """Eva (§4): ensemble of Full and Partial Reconfiguration over TNRP.

    Variants used in the paper's ablations:
      * interference_aware=False  -> Eva-RP  (Fig. 4)
      * multi_task_aware=False    -> Eva-Single (Table 6 / Fig. 7)
      * mode="full-only" / "partial-only"  (Fig. 5b / Fig. 6)

    Beyond the paper, ``spot_aware=True`` targets a spot-market catalog
    (dynamic ``PriceModel``): every round re-evaluates reservation prices
    against the catalog snapshot at the current time, and a revocation notice
    forces a partial reconfiguration that evacuates the revoked instances
    (their tasks re-enter the repack set; the instances are dropped from the
    live view so nothing new lands on them).
    """

    name = "eva"

    def __init__(self, catalog: Catalog, *, interference_aware: bool = True,
                 multi_task_aware: bool = True, mode: str = "ensemble",
                 default_t: float = 0.95, engine: str = "numpy",
                 migration_delay_scale: float = 1.0,
                 spot_aware: bool = False):
        super().__init__(catalog)
        assert mode in ("ensemble", "full-only", "partial-only")
        self.interference_aware = interference_aware
        self.multi_task_aware = multi_task_aware
        self.mode = mode
        self.engine = engine
        self.migration_delay_scale = migration_delay_scale
        self.spot_aware = spot_aware
        self.forced_partials = 0
        self.table = ThroughputTable(NUM_WORKLOADS, default=default_t)
        self.estimator = EventRateEstimator()
        self.decisions: List[EnsembleDecision] = []
        self.full_adoptions = 0
        self.rounds = 0

    # -- monitor ------------------------------------------------------------
    def on_event(self, time_s: float) -> None:
        self.estimator.on_event(time_s)

    def observe_single(self, workload, colocated, value) -> None:
        if self.interference_aware:
            self.table.observe_single(workload, colocated, value)

    def observe_job(self, placements, value) -> None:
        if self.interference_aware:
            self.table.observe_job(placements, value)

    # -- scheduling ---------------------------------------------------------
    def schedule(self, view: SchedulerView) -> ClusterConfig:
        self.rounds += 1
        table = self.table if self.interference_aware else None
        kw = dict(interference_aware=self.interference_aware,
                  multi_task_aware=self.multi_task_aware, engine=self.engine)
        # Spot awareness: all prices this round come from the catalog
        # snapshot at the current time (identity for static catalogs).
        cat = self.catalog.at(view.time) if self.spot_aware else self.catalog

        if self.spot_aware and view.revoked:
            # Forced partial reconfiguration: evacuate revoked instances.
            # Their tasks join the repack set; dropping the instances from
            # the live view guarantees nothing is kept (or placed) on them.
            live = [i for i in view.live if i.instance_id not in view.revoked]
            pending = set(view.pending_ids)
            for inst in view.live:
                if inst.instance_id in view.revoked:
                    pending |= set(inst.task_ids)
            self.forced_partials += 1
            return partial_reconfiguration(
                view.tasks, [(i.type_index, i.task_ids) for i in live],
                pending, cat, table, **kw)

        live_assignments = [(i.type_index, i.task_ids) for i in view.live]
        if self.mode == "full-only":
            cfg = full_reconfiguration(view.tasks, cat, table, **kw)
            self.full_adoptions += 1
            return cfg
        partial = partial_reconfiguration(view.tasks, live_assignments,
                                          view.pending_ids, cat,
                                          table, **kw)
        if self.mode == "partial-only":
            return partial
        full = full_reconfiguration(view.tasks, cat, table, **kw)

        s_f = instantaneous_saving(*evaluate_assignments(
            full.assignments, view.tasks, cat, table,
            self.multi_task_aware))
        s_p = instantaneous_saving(*evaluate_assignments(
            partial.assignments, view.tasks, cat, table,
            self.multi_task_aware))
        m_f = migration_cost(diff_configs(view.live, full), view.live,
                             cat, view.task_workload,
                             self.migration_delay_scale)
        m_p = migration_cost(diff_configs(view.live, partial), view.live,
                             cat, view.task_workload,
                             self.migration_delay_scale)
        decision = choose(s_f, m_f, s_p, m_p, self.estimator.d_hat())
        self.decisions.append(decision)
        if decision.adopt_full:
            self.full_adoptions += 1
            self.estimator.on_full_reconfig()
            return full
        return partial

    @property
    def full_adoption_rate(self) -> float:
        return self.full_adoptions / max(self.rounds, 1)


class NoPackingScheduler(SchedulerBase):
    """One task per instance, each on its reservation-price type (§6.1)."""

    name = "no-packing"

    def schedule(self, view: SchedulerView) -> ClusterConfig:
        system_ids = set(view.tasks.ids.tolist())
        assignments = []
        for inst in view.live:
            alive = tuple(t for t in inst.task_ids if t in system_ids)
            if alive:
                assignments.append((inst.type_index, alive))
        placed = {t for _, tids in assignments for t in tids}
        todo = sorted(t for t in system_ids if t not in placed)
        if todo:
            sub = view.tasks.subset(todo)
            kinds = cheapest_type(sub, self.catalog)
            for tid, k in zip(todo, kinds.tolist()):
                assignments.append((int(k), (tid,)))
        return ClusterConfig(assignments)
