"""Scheduler interface + the Eva scheduler (ensemble of Full/Partial, §4.5).

Public API (docs/ARCHITECTURE.md diagrams the round-by-round data flow):

* ``SchedulerView`` — the per-round snapshot a scheduler sees: live tasks,
  pending ids, live placements, (spot scenarios) revocation notices,
  (burstable scenarios) per-instance credit balances + throttled set, and
  (deferral scenarios) deferrable job ids, per-job deadlines and the
  still-pending job set.
* ``SchedulerBase`` — ``schedule(view) -> ClusterConfig`` plus the monitor
  hooks (``on_event``, ``on_preemption_notice``, ``on_credit_pressure``,
  ``on_deadline_pressure``, ``observe_single/job``).
* ``EvaScheduler`` — the paper's ensemble of Full and Partial
  Reconfiguration over TNRP, with the ablation knobs
  (``interference_aware``, ``multi_task_aware``, ``mode``) and the
  beyond-paper scenario flags: ``spot_aware`` (re-price each round against
  the spot snapshot, evacuate revoked instances), ``multi_region``
  (spot behaviour + per-region-pair arbitrage on a
  ``core.catalog.multi_region_catalog``: re-home instances to the cheapest
  region copy whenever the amortized price saving beats the cross-region
  migration penalty) and ``credit_aware`` (burstable catalogs: price every
  round against ``catalog.credit_priced(D̂)``, decay the keep-test slack
  with each instance's live credit balance, and answer credit-pressure
  signals with a forced partial that drains throttled instances onto
  steady types) and ``autoscale`` (price-pressure admission control: a
  ``repro.autoscale.AdmissionController`` holds deferrable jobs pending
  while forecast prices sit above their strike, bounded by per-job
  deadlines).  ``region="name"`` pins a scheduler to a single
  region of a multi-region catalog (the single-market baseline).
* ``NoPackingScheduler`` — one task per reservation-price instance (§6.1).

The simulator (and the local-cloud physical harness) call ``schedule(view)``
each scheduling round and execute the returned abstract configuration via
``core.plan.diff_configs``.  Throughput observations flow back through
``observe_*`` callbacks, and arrival/completion events through ``on_event``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .catalog import Catalog
from .cluster_types import ClusterConfig, TaskSet
from .ensemble import EnsembleDecision, EventRateEstimator, choose, instantaneous_saving
from .full_reconfig import evaluate_assignments, full_reconfiguration
from .partial_reconfig import partial_reconfiguration
from .plan import LiveInstance, diff_configs, migration_cost, task_move_cost
from .reservation_price import cheapest_type
from .throughput_table import ThroughputTable
from .workloads import (INSTANCE_ACQUISITION_S, INSTANCE_SETUP_S,
                        NUM_WORKLOADS)


@dataclasses.dataclass
class SchedulerView:
    """Snapshot handed to a scheduler at each round."""
    time: float
    tasks: TaskSet  # all live tasks (placed + pending)
    pending_ids: Set[int]
    live: List[LiveInstance]
    task_workload: Dict[int, int]
    # runtime estimates (iters remaining / standalone rate), only for
    # schedulers that declare needs_runtime_estimates (Stratus best-case).
    remaining_s: Optional[Dict[int, float]] = None
    # live instance ids under a spot revocation notice (reclaim imminent);
    # None outside spot scenarios.
    revoked: Optional[Set[int]] = None
    # task id -> region index of its durable checkpoint (multi-region only;
    # lets migration_cost price a cross-region restore of a reclaimed task)
    task_ckpt_region: Optional[Dict[int, int]] = None
    # burstable scenarios only: live burstable instance id -> credit balance
    # (full-speed hours), and the subset currently throttled to baseline.
    instance_credits: Optional[Dict[int, float]] = None
    throttled: Optional[Set[int]] = None
    # deferral scenarios only (some job deferrable or deadlined; None
    # otherwise): job ids marked deferrable, job id -> absolute completion
    # deadline, and the jobs still *pending* — no task running or mid-launch,
    # so holding (or re-deferring) them costs nothing but time.
    deferrable: Optional[Set[int]] = None
    deadline_s: Optional[Dict[int, float]] = None
    pending: Optional[Set[int]] = None


class SchedulerBase:
    name = "base"
    needs_runtime_estimates = False
    needs_true_profile = False

    def __init__(self, catalog: Catalog):
        self.catalog = catalog

    # -- monitor hooks ------------------------------------------------------
    def on_event(self, time_s: float) -> None:  # job arrival/completion
        pass

    def on_preemption_notice(self, instance_ids: Sequence[int],
                             time_s: float) -> None:  # spot revocation notice
        pass

    def on_credit_pressure(self, instance_ids: Sequence[int],
                           time_s: float) -> None:  # credits just exhausted
        pass

    def on_deadline_pressure(self, job_ids: Sequence[int],
                             time_s: float) -> None:  # latest start reached
        pass

    def observe_single(self, workload: int, colocated: Sequence[int],
                       value: float) -> None:
        pass

    def observe_job(self, placements, value: float) -> None:
        pass

    # -- main entry ---------------------------------------------------------
    def schedule(self, view: SchedulerView) -> ClusterConfig:
        raise NotImplementedError


class EvaScheduler(SchedulerBase):
    """Eva (§4): ensemble of Full and Partial Reconfiguration over TNRP.

    Variants used in the paper's ablations:
      * interference_aware=False  -> Eva-RP  (Fig. 4)
      * multi_task_aware=False    -> Eva-Single (Table 6 / Fig. 7)
      * mode="full-only" / "partial-only"  (Fig. 5b / Fig. 6)

    Beyond the paper, ``spot_aware=True`` targets a spot-market catalog
    (dynamic ``PriceModel``): every round re-evaluates reservation prices
    against the catalog snapshot at the current time, and a revocation notice
    forces a partial reconfiguration that evacuates the revoked instances
    (their tasks re-enter the repack set; the instances are dropped from the
    live view so nothing new lands on them).

    ``multi_region=True`` targets a region-expanded catalog
    (``core.catalog.multi_region_catalog``): it implies the spot behaviour
    and adds (a) capacity awareness — Algorithm-1 packs carry per-region
    instance-count budgets (``region_caps``), so a capped-but-cheap region
    fills to its cap and the overflow lands in the next-cheapest region
    instead of starving — and (b) a per-region-pair *arbitrage refinement*:
    each slot of the chosen configuration is re-homed to the cheapest
    same-hardware region copy whenever the hourly saving, amortized over the
    estimated time to the next Full Reconfiguration (D̂, §4.5), exceeds the
    migration-cost delta of the move (checkpoint transfer time + egress fee,
    priced by ``core.plan.migration_cost``).  ``region="name"`` instead pins
    all packing to one region of the catalog (single-market baseline).

    ``credit_aware=True`` targets a burstable catalog (types carrying a
    ``core.catalog.CreditModel``, e.g. ``burstable_demo_catalog``).  Three
    mechanisms, all riding the D̂ horizon the ensemble already estimates:

    * *credit-adjusted pricing* — every round plans against
      ``catalog.credit_priced(D̂)``: each burstable type's cost is divided
      by the forecast mean speed of a *fresh* instance over the next D̂
      seconds, so reservation prices, Algorithm 1's order/cost-efficiency
      bar, savings S and migration costs M all see effective $/throughput.
      A burstable type is cheap exactly while its launch credits outlast
      the horizon.
    * *balance-decayed keep test* — each live burstable instance gets a
      ``keep_bonus`` equal to the planning cost of a fresh instance minus
      its own effective cost at its *live* balance
      (``SchedulerView.instance_credits``).  The slack is ~0 while the
      balance is healthy, decays as it drains, and at exhaustion the keep
      test effectively compares TNRP against ``cost/baseline_fraction`` —
      collapsing exactly when throughput does, so the instance's tasks are
      evicted into the repack set and the S·D̂ > ΔM economics decide the
      move.
    * *credit-pressure reaction* — exhaustion signals
      (``on_credit_pressure`` + ``SchedulerView.throttled``) force a
      partial reconfiguration, the same wiring spot revocation notices
      use: throttled instances are dropped from the live view, their tasks
      join the repack set, and — because anonymous slots of the same
      burstable type would simply re-match the exhausted instance — the
      drain repack is masked to *steady* (non-burstable) types.  Fresh
      arrivals in later rounds burst again on new instances with launch
      credits.

    On a catalog without burstable types ``credit_aware=True`` is inert
    (``credit_priced`` is the identity, no bonuses, no forced drains):
    decisions are bit-for-bit those of the PR-2 scheduler.

    ``autoscale=True`` adds price-pressure admission control over the job
    population (``repro.autoscale``): each round, *before* Algorithm 1 sees
    the task set, an ``AdmissionController`` reviews every deferrable
    not-yet-started job (``SchedulerView.deferrable`` / ``pending`` /
    ``deadline_s``) and holds it out of the round while the forecast
    effective $/throughput of running it over its estimated duration
    (``PriceForecaster`` + ``credit_priced`` — all three price axes priced
    in) sits above its reservation-price-derived strike.  A held job's
    tasks are simply absent from the packed task set, so nothing is
    provisioned for them (zero billing while pending).  Each job is
    admitted when the market dips below its strike, or unconditionally
    once its latest-start time (deadline − margin·D̂_j − overhead)
    arrives — deadline-forced admissions are routed through the same
    forced-partial path spot notices and credit drains use, so they are
    placed in the very round the ``DEFER_DEADLINE`` signal fires.
    Admitted-but-unstarted jobs are re-deferred (with hysteresis) when
    prices spike; the simulator withdraws their not-yet-launched
    placements.  On a trace with no deferrable jobs the controller never
    holds anything: decisions are bit-for-bit those of ``autoscale=False``
    (the PR-3 scheduler).
    """

    name = "eva"

    def __init__(self, catalog: Catalog, *, interference_aware: bool = True,
                 multi_task_aware: bool = True, mode: str = "ensemble",
                 default_t: float = 0.95, engine: str = "numpy",
                 migration_delay_scale: float = 1.0,
                 spot_aware: bool = False, multi_region: bool = False,
                 credit_aware: bool = False, autoscale: bool = False,
                 admission: Optional[object] = None, strike: float = 1.0,
                 region: Optional[str] = None):
        super().__init__(catalog)
        assert mode in ("ensemble", "full-only", "partial-only")
        self.interference_aware = interference_aware
        self.multi_task_aware = multi_task_aware
        self.mode = mode
        self.engine = engine
        self.migration_delay_scale = migration_delay_scale
        self.spot_aware = spot_aware
        self.multi_region = multi_region
        self.credit_aware = credit_aware
        self.autoscale = autoscale
        if multi_region:
            assert catalog.is_multi_region, \
                "multi_region=True needs a multi_region_catalog"
        self._region_mask: Optional[np.ndarray] = None
        if region is not None:
            assert catalog.is_multi_region, "region= needs a multi_region_catalog"
            self._region_mask = catalog.region_type_mask(
                catalog.region_index(region))
        self.admission = None
        if autoscale:
            # deferred import: repro.autoscale itself imports core submodules
            from ..autoscale.admission import AdmissionController
            # a region pin restricts the strike test too: the controller may
            # only price a job against types the packer can actually use
            self.admission = admission if admission is not None \
                else AdmissionController(catalog, strike=strike,
                                         type_mask=self._region_mask)
            # latest-start bounds need per-job duration estimates
            self.needs_runtime_estimates = True
        # per-region instance-count budgets for the Algorithm-1 packs
        self._region_caps = None
        if multi_region and any(r.max_instances is not None
                                for r in catalog.regions):
            self._region_caps = tuple(r.max_instances
                                      for r in catalog.regions)
        self.forced_partials = 0
        self.arbitrage_moves = 0
        self.credit_signals = 0  # exhausted instances signalled to us
        self.credit_drains = 0  # forced partials that drained throttled insts
        self.deadline_signals = 0  # latest-start deadlines signalled to us
        self.table = ThroughputTable(NUM_WORKLOADS, default=default_t)
        self.estimator = EventRateEstimator()
        self.decisions: List[EnsembleDecision] = []
        self.full_adoptions = 0
        self.rounds = 0

    # -- monitor ------------------------------------------------------------
    def on_event(self, time_s: float) -> None:
        self.estimator.on_event(time_s)

    def on_credit_pressure(self, instance_ids, time_s: float) -> None:
        self.credit_signals += len(instance_ids)

    def on_deadline_pressure(self, job_ids, time_s: float) -> None:
        self.deadline_signals += len(job_ids)
        if self.admission is not None:
            self.admission.note_deadline(job_ids)

    def observe_single(self, workload, colocated, value) -> None:
        if self.interference_aware:
            self.table.observe_single(workload, colocated, value)

    def observe_job(self, placements, value) -> None:
        if self.interference_aware:
            self.table.observe_job(placements, value)

    # -- scheduling ---------------------------------------------------------
    def schedule(self, view: SchedulerView) -> ClusterConfig:
        self.rounds += 1
        table = self.table if self.interference_aware else None
        kw = dict(interference_aware=self.interference_aware,
                  multi_task_aware=self.multi_task_aware, engine=self.engine)
        # Admission control first: deferrable jobs the controller holds are
        # removed from the round's task set before anything is priced, so
        # Algorithm 1 never provisions for them.
        resumed: Set[int] = set()
        if self.admission is not None and view.deferrable:
            view, resumed = self._apply_admission(view)
        track = self.spot_aware or self.multi_region or self.credit_aware
        # Spot awareness: all prices this round come from the catalog
        # snapshot at the current time (identity for static catalogs).
        raw = self.catalog.at(view.time) if track else self.catalog
        credits_on = self.credit_aware and raw.is_burstable
        # Credit awareness: plan against effective $/throughput over the D̂
        # horizon (identity for non-burstable catalogs) — billing still
        # happens at the raw prices; this is purely the planning view.
        cat = raw.credit_priced(self.estimator.d_hat()) if credits_on else raw
        keep_bonus = self._keep_bonus_fn(raw, cat, view, credits_on)

        evac: Set[int] = set(view.revoked) if (track and view.revoked) else set()
        throttled: Set[int] = set()
        if credits_on and view.throttled:
            throttled = set(view.throttled)
            evac |= throttled
        if evac or resumed:
            return self._forced_partial(view, raw, cat, table, kw, keep_bonus,
                                        evac, throttled)

        live_assignments = [(i.type_index, i.task_ids) for i in view.live]
        if self.mode == "full-only":
            cfg = full_reconfiguration(view.tasks, cat, table,
                                       type_mask=self._region_mask,
                                       region_caps=self._region_caps, **kw)
            self.full_adoptions += 1
            return self._finish(cfg, view, cat)
        partial = partial_reconfiguration(view.tasks, live_assignments,
                                          view.pending_ids, cat,
                                          table, type_mask=self._region_mask,
                                          region_caps=self._region_caps,
                                          keep_bonus=keep_bonus, **kw)
        if self.mode == "partial-only":
            return self._finish(partial, view, cat)
        full = full_reconfiguration(view.tasks, cat, table,
                                    type_mask=self._region_mask,
                                    region_caps=self._region_caps, **kw)

        s_f = instantaneous_saving(*evaluate_assignments(
            full.assignments, view.tasks, cat, table,
            self.multi_task_aware, type_mask=self._region_mask))
        s_p = instantaneous_saving(*evaluate_assignments(
            partial.assignments, view.tasks, cat, table,
            self.multi_task_aware, type_mask=self._region_mask))
        m_f = migration_cost(diff_configs(view.live, full), view.live,
                             cat, view.task_workload,
                             self.migration_delay_scale,
                             task_ckpt_region=view.task_ckpt_region)
        m_p = migration_cost(diff_configs(view.live, partial), view.live,
                             cat, view.task_workload,
                             self.migration_delay_scale,
                             task_ckpt_region=view.task_ckpt_region)
        decision = choose(s_f, m_f, s_p, m_p, self.estimator.d_hat())
        self.decisions.append(decision)
        if decision.adopt_full:
            self.full_adoptions += 1
            self.estimator.on_full_reconfig()
            return self._finish(full, view, cat)
        return self._finish(partial, view, cat)

    # -- pressure reactions (spot / credit / deferral), one shared path ------
    def _apply_admission(self, view: SchedulerView
                         ) -> Tuple[SchedulerView, Set[int]]:
        """Run the admission controller and strip held jobs' tasks from the
        round's view.  Returns the (possibly filtered) view plus the jobs
        force-admitted by their latest-start bound this round."""
        held, resumed = self.admission.review(view, self.estimator.d_hat())
        if held:
            ids = view.tasks.ids.tolist()
            jids = view.tasks.job_ids.tolist()
            held_t = {t for t, j in zip(ids, jids) if j in held}
            view = dataclasses.replace(
                view, tasks=view.tasks.subset(
                    [t for t in ids if t not in held_t]),
                pending_ids=set(view.pending_ids) - held_t)
        return view, resumed

    def _forced_partial(self, view: SchedulerView, raw: Catalog, cat: Catalog,
                        table, kw, keep_bonus, evac: Set[int],
                        throttled: Set[int]) -> ClusterConfig:
        """Shared forced-partial wiring for every pressure signal: spot
        revocation notices *evacuate* the doomed instances, credit
        exhaustion *drains* throttled ones onto steady types, and a
        deferral resume (latest-start deadline) *places* the force-admitted
        job's tasks — all via one partial reconfiguration whose repack set
        holds the triggering tasks.  Evacuated/drained instances are
        dropped from the live view so nothing is kept (or placed) on them;
        resumed jobs' tasks are already in ``pending_ids``."""
        live = [i for i in view.live if i.instance_id not in evac]
        pending = set(view.pending_ids)
        for inst in view.live:
            if inst.instance_id in evac:
                pending |= set(inst.task_ids)
        mask = self._region_mask
        if throttled:
            # Drain onto steady (non-burstable) types: an anonymous slot
            # of the same burstable type would simply re-match the
            # exhausted instance, so the escape must change type.  Fresh
            # arrivals burst again in later (unmasked) rounds.
            steady = np.array([cm is None for cm in raw.credit_models])
            if mask is not None:
                steady = steady & mask
            if steady.any():  # burstable-only catalogs cannot drain
                mask = steady
            self.credit_drains += 1
        self.forced_partials += 1
        cfg = partial_reconfiguration(
            view.tasks, [(i.type_index, i.task_ids) for i in live],
            pending, cat, table, type_mask=mask,
            region_caps=self._region_caps, keep_bonus=keep_bonus, **kw)
        return self._finish(cfg, view, cat)

    # -- keep-test slack (multi-region + credit) -----------------------------
    def _keep_bonus_fn(self, raw: Catalog, cat: Catalog, view: SchedulerView,
                       credits_on: bool):
        """Composite per-instance keep-test slack.

        Multi-region part (``multi_region=True``): the amortized ($/h over
        D̂) cost of re-homing an instance's task set to the cheapest
        same-hardware region copy — relaunch idle time, per-task
        checkpoint+launch delay, checkpoint transfer time, and the egress
        fee.  Zero when the instance already sits in the cheapest region,
        so intra-region evictions are untouched.

        Known trade-off: the slack assumes an eviction from a dear region
        re-homes cross-region (true when the price gap is what made the set
        inefficient, since RP anchors to the cheapest region).  An instance
        that turned inefficient for other reasons (e.g. a completed sibling
        shrank the set) gets the same slack and may be held up to one D̂
        window before intra-region consolidation — bounded by the slack
        being the one-off move cost spread over D̂.

        Credit part (``credit_aware=True`` on a burstable catalog): the
        planning cost of a *fresh* instance of the type (``cat.costs[k]``,
        launch-credit priced over D̂) minus the effective cost of *this*
        instance at its live balance.  ~0 while the balance matches a fresh
        launch, decaying below zero as credits drain; at exhaustion the
        keep test effectively demands TNRP ≥ cost/baseline_fraction, which
        collapses with the throughput and evicts the set into the repack."""
        fns = []
        task_workload = view.task_workload
        if self.multi_region:
            d_hr = max(self.estimator.d_hat() / 3600.0, 1e-9)

            def region_bonus(k: int, tids) -> float:
                k2 = cat.cheapest_copy(k, self._region_mask)
                if cat.region_of(k2) == cat.region_of(k):
                    return 0.0
                pen = ((INSTANCE_ACQUISITION_S + INSTANCE_SETUP_S) / 3600.0
                       * cat.costs[k2])
                for t in tids:
                    pen += task_move_cost(cat, task_workload[t], k, k2,
                                          self.migration_delay_scale)
                return pen / d_hr

            fns.append(region_bonus)
        if credits_on and view.instance_credits:
            balances = view.instance_credits
            task_iid = {t: i.instance_id for i in view.live
                        for t in i.task_ids}
            horizon_h = self.estimator.d_hat() / 3600.0

            def credit_bonus(k: int, tids) -> float:
                cm = raw.credit_models[k]
                if cm is None or not tids:
                    return 0.0
                bal = balances.get(task_iid.get(tids[0], -1))
                if bal is None:
                    return 0.0
                eff = raw.costs[k] / cm.avg_speed_over(bal, horizon_h)
                return float(cat.costs[k] - eff)

            fns.append(credit_bonus)
        if not fns:
            return None
        if len(fns) == 1:
            return fns[0]
        return lambda k, tids: sum(f(k, tids) for f in fns)

    def _finish(self, config: ClusterConfig, view: SchedulerView,
                cat: Catalog) -> ClusterConfig:
        if self.multi_region:
            config = self._region_arbitrage(config, view, cat)
        return config

    def _region_arbitrage(self, config: ClusterConfig, view: SchedulerView,
                          cat: Catalog) -> ClusterConfig:
        """Per-region-pair reconfiguration trade-off (the paper's S·D̂ > M
        criterion applied to region moves): re-home each slot to the cheapest
        same-hardware copy in another region iff the hourly price saving,
        amortized over D̂ (the estimated time to the next Full
        Reconfiguration), exceeds the migration-cost *delta* of the rewrite —
        which prices the checkpoint transfer, egress fee, and fresh-instance
        launch via ``migration_cost`` on the diffed plans.  Each adopted
        rewrite re-diffs the whole plan (exact, O(slots·live) per candidate
        — slot-local deltas would miss greedy-matching interactions between
        same-type slots); rounds here are tens of slots, so this is cheap.

        Capacity headroom is tracked against the *configuration being
        refined* (slots per region, updated as rewrites are adopted), since
        the config is what the executor will instantiate; the simulator's
        per-region denial remains the hard backstop."""
        if len(cat.regions) < 2:
            return config
        assignments = list(config.assignments)
        d_hr = self.estimator.d_hat() / 3600.0
        caps = [r.max_instances for r in cat.regions]
        counts = np.zeros(len(cat.regions), dtype=np.int64)
        for k, _ in assignments:
            counts[cat.region_of(k)] += 1
        cur_m: Optional[float] = None
        changed = False
        for slot, (k, tids) in enumerate(assignments):
            base = int(cat.base_index[k])
            cand = cat.base_index == base
            if self._region_mask is not None:  # honour a region pin
                cand = cand & self._region_mask
            # cheapest same-hardware region copy with capacity headroom
            best_k = int(k)
            for k2 in np.nonzero(cand)[0].tolist():
                r2 = cat.region_of(k2)
                if (r2 != cat.region_of(k) and caps[r2] is not None
                        and counts[r2] >= caps[r2]):
                    continue
                if cat.costs[k2] < cat.costs[best_k] - 1e-12:
                    best_k = int(k2)
            if best_k == k:
                continue
            if cur_m is None:
                cur_m = migration_cost(
                    diff_configs(view.live, ClusterConfig(assignments)),
                    view.live, cat, view.task_workload,
                    self.migration_delay_scale,
                    task_ckpt_region=view.task_ckpt_region)
            trial = list(assignments)
            trial[slot] = (best_k, tids)
            trial_m = migration_cost(
                diff_configs(view.live, ClusterConfig(trial)), view.live,
                cat, view.task_workload, self.migration_delay_scale,
                task_ckpt_region=view.task_ckpt_region)
            saving = float(cat.costs[k] - cat.costs[best_k]) * d_hr
            if saving > trial_m - cur_m:
                assignments = trial
                cur_m = trial_m
                counts[cat.region_of(best_k)] += 1
                counts[cat.region_of(k)] -= 1  # slot vacated its old region
                self.arbitrage_moves += 1
                changed = True
        return ClusterConfig(assignments) if changed else config

    @property
    def full_adoption_rate(self) -> float:
        return self.full_adoptions / max(self.rounds, 1)


class NoPackingScheduler(SchedulerBase):
    """One task per instance, each on its reservation-price type (§6.1)."""

    name = "no-packing"

    def schedule(self, view: SchedulerView) -> ClusterConfig:
        system_ids = set(view.tasks.ids.tolist())
        assignments = []
        for inst in view.live:
            alive = tuple(t for t in inst.task_ids if t in system_ids)
            if alive:
                assignments.append((inst.type_index, alive))
        placed = {t for _, tids in assignments for t in tids}
        todo = sorted(t for t in system_ids if t not in placed)
        if todo:
            sub = view.tasks.subset(todo)
            kinds = cheapest_type(sub, self.catalog)
            for tid, k in zip(todo, kinds.tolist()):
                assignments.append((int(k), (tid,)))
        return ClusterConfig(assignments)
