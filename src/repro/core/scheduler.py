"""Scheduler interface + the Eva scheduler (ensemble of Full/Partial, §4.5).

Public API (docs/ARCHITECTURE.md diagrams the round-by-round data flow):

* ``SchedulerView`` — the per-round snapshot a scheduler sees: live tasks,
  pending ids, live placements, (spot scenarios) revocation notices,
  (burstable scenarios) per-instance credit balances + throttled set, and
  (deferral scenarios) deferrable job ids, per-job deadlines and the
  still-pending job set.
* ``SchedulerBase`` — ``schedule(view) -> ClusterConfig`` plus the monitor
  hooks (``on_event``, ``on_pressure`` — which fans out to the legacy
  per-kind hooks ``on_preemption_notice`` / ``on_credit_pressure`` /
  ``on_deadline_pressure`` — and ``observe_single/job``).
* ``EvaScheduler`` — the paper's ensemble of Full and Partial
  Reconfiguration over TNRP, with the ablation knobs
  (``interference_aware``, ``multi_task_aware``, ``mode``).  Beyond-paper
  scenario axes compose as a **policy stack** (``repro.policies``): pass
  ``policies=[SpotLayer(), MultiRegionLayer(), CreditLayer(),
  AutoscaleLayer(strike=0.9)]`` (any subset, in the documented order) and
  the scheduler folds their hooks — catalog snapshot transforms, admission
  edits, keep-test slack, pack masks/budgets, forced evacuations and
  config refinements — around the unchanged Algorithm-1 ensemble.  The
  legacy boolean kwargs (``spot_aware`` / ``multi_region`` /
  ``credit_aware`` / ``autoscale`` + ``region=`` / ``strike=`` /
  ``admission=``) remain as a deprecation shim that builds the equivalent
  stack, bit-identical by test.
* ``NoPackingScheduler`` — one task per reservation-price instance (§6.1).

The simulator (and the local-cloud physical harness) call ``schedule(view)``
each scheduling round and execute the returned abstract configuration via
``core.plan.diff_configs``.  Throughput observations flow back through
``observe_*`` callbacks, arrival/completion events through ``on_event``,
and pressure signals (spot revocations, credit exhaustion, deferral
deadlines) through one ``PressureBus`` (``repro.policies.pressure``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Set

from ..obs.trace import DecisionRecord, KeepEntry
from .catalog import Catalog
from .cluster_types import ClusterConfig, TaskSet
from .ensemble import EnsembleDecision, EventRateEstimator, choose, instantaneous_saving
from .full_reconfig import EPS, evaluate_assignments, full_reconfiguration
from .partial_reconfig import (incremental_reconfiguration,
                               partial_reconfiguration)
from .plan import LiveInstance, diff_configs, migration_cost
from .reservation_price import cheapest_type, reservation_prices
from .throughput_table import ThroughputTable
from .workloads import NUM_WORKLOADS


@dataclasses.dataclass
class SchedulerView:
    """Snapshot handed to a scheduler at each round."""
    time: float
    tasks: TaskSet  # all live tasks (placed + pending)
    pending_ids: Set[int]
    live: List[LiveInstance]
    task_workload: Dict[int, int]
    # runtime estimates (iters remaining / standalone rate), only for
    # schedulers that declare needs_runtime_estimates (Stratus best-case).
    remaining_s: Optional[Dict[int, float]] = None
    # live instance ids under a spot revocation notice (reclaim imminent);
    # None outside spot scenarios.
    revoked: Optional[Set[int]] = None
    # task id -> region index of its durable checkpoint (multi-region only;
    # lets migration_cost price a cross-region restore of a reclaimed task)
    task_ckpt_region: Optional[Dict[int, int]] = None
    # burstable scenarios only: live burstable instance id -> credit balance
    # (full-speed hours), and the subset currently throttled to baseline.
    instance_credits: Optional[Dict[int, float]] = None
    throttled: Optional[Set[int]] = None
    # deferral scenarios only (some job deferrable or deadlined; None
    # otherwise): job ids marked deferrable, job id -> absolute completion
    # deadline, and the jobs still *pending* — no task running or mid-launch,
    # so holding (or re-deferring) them costs nothing but time.
    deferrable: Optional[Set[int]] = None
    deadline_s: Optional[Dict[int, float]] = None
    pending: Optional[Set[int]] = None
    # serving scenarios only (some job carries a ServiceSpec; None
    # otherwise): live service job ids, job id -> current request rate
    # (rps), job id -> current effective serving capacity (rps at observed
    # replica throughput), and the subset at utility risk — utilization
    # within the risk margin of the job's SLO-feasible ceiling, or capacity
    # short of load entirely.
    service: Optional[Set[int]] = None
    service_rps: Optional[Dict[int, float]] = None
    service_capacity: Optional[Dict[int, float]] = None
    slo_risk: Optional[Set[int]] = None
    # job id -> its ServiceSpec (latency model + utility curve), so serving
    # layers can evaluate `at_risk` against hypothetical capacities
    service_specs: Optional[Dict[int, object]] = None


class SchedulerBase:
    name = "base"
    needs_runtime_estimates = False
    needs_true_profile = False

    def __init__(self, catalog: Catalog):
        self.catalog = catalog

    # -- monitor hooks ------------------------------------------------------
    def on_event(self, time_s: float) -> None:  # job arrival/completion
        pass

    def on_pressure(self, signal) -> None:
        """One ``repro.policies.pressure.PressureSignal`` per pressure
        event.  The base implementation fans out to the legacy per-kind
        hooks so flag-era subclasses (and the baselines) keep working."""
        if signal.kind == "spot":
            self.on_preemption_notice(signal.ids, signal.time)
        elif signal.kind == "credit":
            self.on_credit_pressure(signal.ids, signal.time)
        elif signal.kind == "deadline":
            self.on_deadline_pressure(signal.ids, signal.time)
        elif signal.kind == "slo":
            self.on_slo_pressure(signal.ids, signal.time)

    def on_preemption_notice(self, instance_ids: Sequence[int],
                             time_s: float) -> None:  # spot revocation notice
        pass

    def on_credit_pressure(self, instance_ids: Sequence[int],
                           time_s: float) -> None:  # credits just exhausted
        pass

    def on_deadline_pressure(self, job_ids: Sequence[int],
                             time_s: float) -> None:  # latest start reached
        pass

    def on_slo_pressure(self, job_ids: Sequence[int],
                        time_s: float) -> None:  # service utility at risk
        pass

    def observe_single(self, workload: int, colocated: Sequence[int],
                       value: float) -> None:
        pass

    def observe_job(self, placements, value: float) -> None:
        pass

    # -- main entry ---------------------------------------------------------
    def schedule(self, view: SchedulerView) -> ClusterConfig:
        raise NotImplementedError


class EvaScheduler(SchedulerBase):
    """Eva (§4): ensemble of Full and Partial Reconfiguration over TNRP.

    Variants used in the paper's ablations:
      * interference_aware=False  -> Eva-RP  (Fig. 4)
      * multi_task_aware=False    -> Eva-Single (Table 6 / Fig. 7)
      * mode="full-only" / "partial-only"  (Fig. 5b / Fig. 6)

    Beyond the paper, scenario axes attach as a **policy stack**
    (``repro.policies``): the scheduler itself is Algorithm 1 + the
    ensemble criterion, and every axis-specific behaviour — spot
    re-pricing and revocation evacuation (``SpotLayer``), multi-region
    capacity budgets / keep slack / arbitrage (``MultiRegionLayer``),
    credit-aware planning and drains (``CreditLayer``), admission control
    (``AutoscaleLayer``, ``StabilityLayer``) — enters through the stack's
    hook points:

    * ``pre_round``      — admission layers strip held jobs' tasks from
      the round's view before anything is priced;
    * ``plan``           — the catalog pipeline (snapshot transforms, then
      planning transforms: ``at → credit_priced``) yields the round's
      billing-accurate ``raw`` and planning ``cat`` catalogs;
    * ``keep_bonus``     — summed per-instance keep-test slack;
    * ``mask`` / ``caps``— standing type restrictions and per-region pack
      budgets threaded into RP / Full / Partial;
    * ``evacuate`` + ``drain_mask`` — pressure reactions, answered by one
      shared forced partial reconfiguration;
    * ``refine``         — post-pass config rewrites (region arbitrage).

    The legacy boolean kwargs (``spot_aware=True`` etc.) are a
    deprecation shim: they emit a ``DeprecationWarning`` and build the
    equivalent stack via ``repro.policies.stack_from_flags``, with
    decisions bit-identical to the flag-era scheduler
    (``tests/test_policies.py`` pins this on every bundled demo catalog).
    """

    name = "eva"

    def __init__(self, catalog: Catalog, *, interference_aware: bool = True,
                 multi_task_aware: bool = True, mode: str = "ensemble",
                 default_t: float = 0.95, engine: str = "numpy",
                 migration_delay_scale: float = 1.0,
                 incremental: bool = False,
                 policies: Optional[object] = None,
                 spot_aware: bool = False, multi_region: bool = False,
                 credit_aware: bool = False, autoscale: bool = False,
                 admission: Optional[object] = None, strike: float = 1.0,
                 region: Optional[str] = None, recorder=None):
        super().__init__(catalog)
        # flight recorder (repro.obs.FlightRecorder): pure observer — every
        # trace path below is gated on self._rec, and the decision trace is
        # assembled from the same inputs the decision used (re-running only
        # pure evaluation helpers), so decisions are unchanged when on
        self._rec = recorder
        self._trace_pending: Optional[DecisionRecord] = None
        self._trace_parts: List = []
        assert mode in ("ensemble", "full-only", "partial-only")
        self.interference_aware = interference_aware
        self.multi_task_aware = multi_task_aware
        self.mode = mode
        self.engine = engine
        self.migration_delay_scale = migration_delay_scale
        # deferred import: repro.policies imports core submodules
        from ..policies import PolicyStack, stack_from_flags
        flags_used = spot_aware or multi_region or credit_aware or autoscale
        legacy_used = (flags_used or region is not None
                       or admission is not None or strike != 1.0)
        if policies is not None and legacy_used:
            raise ValueError(
                "pass either policies=[...] or the legacy flag kwargs "
                "(spot_aware/multi_region/credit_aware/autoscale/region/"
                "admission/strike), not both")
        if legacy_used:
            if flags_used:
                warnings.warn(
                    "EvaScheduler's boolean scenario flags (spot_aware/"
                    "multi_region/credit_aware/autoscale) are deprecated; "
                    "pass the equivalent policy stack, e.g. "
                    "policies=[SpotLayer(), ...] (repro.policies)",
                    DeprecationWarning, stacklevel=2)
            policies = stack_from_flags(
                spot_aware=spot_aware, multi_region=multi_region,
                credit_aware=credit_aware, autoscale=autoscale,
                region=region, admission=admission, strike=strike)
        if policies is None:
            policies = PolicyStack()
        elif not isinstance(policies, PolicyStack):
            policies = PolicyStack(policies)
        self.stack = policies
        self.stack.bind(self)
        self.needs_runtime_estimates = self.stack.needs_runtime_estimates
        self.forced_partials = 0
        # incremental repack: buffer the round's pressure signals so the
        # forced partial can re-plan only the instances they touched
        self.incremental = incremental
        self._pressure_buffer: List[object] = []
        self.incremental_rounds = 0
        self.incremental_fallbacks = 0
        self.table = ThroughputTable(NUM_WORKLOADS, default=default_t)
        self.estimator = EventRateEstimator()
        self.decisions: List[EnsembleDecision] = []
        self.full_adoptions = 0
        self.rounds = 0

    # -- legacy introspection (flag-era attribute surface) -------------------
    @property
    def spot_aware(self) -> bool:
        return self.stack.has("spot")

    @property
    def multi_region(self) -> bool:
        return self.stack.has("multi-region")

    @property
    def credit_aware(self) -> bool:
        return self.stack.has("credit")

    @property
    def autoscale(self) -> bool:
        return self.stack.has("autoscale")

    @property
    def admission(self) -> Optional[object]:
        """Controller of the first admission layer (autoscale/stability),
        if any — the simulator reads its margin/overhead for the
        DEFER_DEADLINE backstop."""
        from ..policies import AdmissionLayerBase
        layer = self.stack.get(AdmissionLayerBase)
        return None if layer is None else layer.controller

    @property
    def commitment_orders(self) -> Optional[Dict[str, int]]:
        """Pool-region-name -> desired pool size from portfolio layers —
        the inventory channel the simulator polls after each round (like
        ``admission``), applied monotonically (pools grow, never shrink)."""
        out: Dict[str, int] = {}
        for la in self.stack:
            orders = getattr(la, "commitment_orders", None)
            if orders:
                out.update(orders)
        return out or None

    @property
    def arbitrage_moves(self) -> int:
        return sum(getattr(la, "arbitrage_moves", 0) for la in self.stack)

    @property
    def credit_signals(self) -> int:
        return sum(getattr(la, "credit_signals", 0) for la in self.stack)

    @property
    def credit_drains(self) -> int:
        return sum(getattr(la, "credit_drains", 0) for la in self.stack)

    @property
    def deadline_signals(self) -> int:
        return sum(getattr(la, "deadline_signals", 0) for la in self.stack)

    # -- monitor ------------------------------------------------------------
    def on_event(self, time_s: float) -> None:
        self.estimator.on_event(time_s)

    def on_pressure(self, signal) -> None:
        super().on_pressure(signal)  # legacy per-kind hooks (subclasses)
        self.stack.on_pressure(signal)
        if self.incremental:
            self._pressure_buffer.append(signal)

    def observe_single(self, workload, colocated, value) -> None:
        if self.interference_aware:
            self.table.observe_single(workload, colocated, value)

    def observe_job(self, placements, value) -> None:
        if self.interference_aware:
            self.table.observe_job(placements, value)

    # -- scheduling ---------------------------------------------------------
    def schedule(self, view: SchedulerView) -> ClusterConfig:
        self.rounds += 1
        table = self.table if self.interference_aware else None
        kw = dict(interference_aware=self.interference_aware,
                  multi_task_aware=self.multi_task_aware, engine=self.engine)
        d_hat = self.estimator.d_hat()
        # Admission layers first: jobs a controller holds are removed from
        # the round's task set before anything is priced, so Algorithm 1
        # never provisions for them.
        view, resumed = self.stack.pre_round(view, d_hat)
        # Catalog pipeline: snapshot transforms (spot re-pricing at the
        # current time), then planning transforms (credit-effective
        # $/throughput) — `raw` bills, `cat` plans.
        raw, cat = self.stack.plan(self.catalog, view, d_hat)
        if self._rec is None:
            keep_bonus = self.stack.keep_bonus(raw, cat, view)
        else:
            # identical fold, but keep the per-layer parts so the decision
            # trace can decompose the summed slack by contributing layer
            # (each layer's hook still runs exactly once)
            self._trace_parts = self.stack.keep_bonus_parts(raw, cat, view)
            keep_bonus = self.stack.combine(
                fn for _, fn in self._trace_parts)
            self._trace_pending = self._trace_begin(view, cat, d_hat)
        mask, caps = self.stack.mask, self.stack.caps

        evac = self.stack.evacuate(raw, view)
        if evac or resumed:
            if self._trace_pending is not None:
                self._trace_pending.kind = "forced-partial"
                self._trace_pending.evacuated = tuple(sorted(evac))
                self._trace_pending.resumed_jobs = tuple(sorted(resumed))
            return self._forced_partial(view, raw, cat, table, kw,
                                        keep_bonus, evac)
        self._pressure_buffer.clear()  # nothing forced a reaction round

        live_assignments = [(i.type_index, i.task_ids) for i in view.live]
        if self._trace_pending is not None and self.mode != "full-only":
            self._trace_pending.keep_table = self._trace_keep_table(
                view.live, view.tasks, cat, table, mask)
        if self.mode == "full-only":
            cfg = full_reconfiguration(view.tasks, cat, table,
                                       type_mask=mask,
                                       region_caps=caps, **kw)
            self.full_adoptions += 1
            if self._trace_pending is not None:
                self._trace_pending.kind = "full-only"
            return self._finish(cfg, view, cat)
        partial = partial_reconfiguration(view.tasks, live_assignments,
                                          view.pending_ids, cat,
                                          table, type_mask=mask,
                                          region_caps=caps,
                                          keep_bonus=keep_bonus, **kw)
        if self.mode == "partial-only":
            if self._trace_pending is not None:
                self._trace_pending.kind = "partial-only"
            return self._finish(partial, view, cat)
        full = full_reconfiguration(view.tasks, cat, table,
                                    type_mask=mask,
                                    region_caps=caps, **kw)

        s_f = instantaneous_saving(*evaluate_assignments(
            full.assignments, view.tasks, cat, table,
            self.multi_task_aware, type_mask=mask))
        s_p = instantaneous_saving(*evaluate_assignments(
            partial.assignments, view.tasks, cat, table,
            self.multi_task_aware, type_mask=mask))
        m_f = migration_cost(diff_configs(view.live, full), view.live,
                             cat, view.task_workload,
                             self.migration_delay_scale,
                             task_ckpt_region=view.task_ckpt_region)
        m_p = migration_cost(diff_configs(view.live, partial), view.live,
                             cat, view.task_workload,
                             self.migration_delay_scale,
                             task_ckpt_region=view.task_ckpt_region)
        decision = choose(s_f, m_f, s_p, m_p, self.estimator.d_hat())
        self.decisions.append(decision)
        if self._trace_pending is not None:
            self._trace_pending.kind = "ensemble"
            self._trace_pending.s_full = float(s_f)
            self._trace_pending.m_full = float(m_f)
            self._trace_pending.s_partial = float(s_p)
            self._trace_pending.m_partial = float(m_p)
            self._trace_pending.adopt_full = bool(decision.adopt_full)
        if decision.adopt_full:
            self.full_adoptions += 1
            self.estimator.on_full_reconfig()
            return self._finish(full, view, cat)
        return self._finish(partial, view, cat)

    # -- pressure reactions (spot / credit / deferral), one shared path ------
    def _forced_partial(self, view: SchedulerView, raw: Catalog, cat: Catalog,
                        table, kw, keep_bonus,
                        evac: Set[int]) -> ClusterConfig:
        """Shared forced-partial wiring for every pressure signal: spot
        revocation notices *evacuate* the doomed instances, credit
        exhaustion *drains* throttled ones onto steady types, and a
        deferral resume (latest-start deadline) *places* the force-admitted
        job's tasks — all via one partial reconfiguration whose repack set
        holds the triggering tasks.  Evacuated/drained instances are
        dropped from the live view so nothing is kept (or placed) on them;
        resumed jobs' tasks are already in ``pending_ids``.  The type mask
        is the stack's drain mask (standing mask AND any drain
        restrictions, e.g. steady-types-only for credit drains)."""
        mask = self.stack.drain_mask(raw, view)
        self.forced_partials += 1
        if self.incremental:
            from ..policies.pressure import dirty_instance_ids
            dirty = dirty_instance_ids(self._pressure_buffer) | evac
            self._pressure_buffer.clear()
            self.incremental_rounds += 1
            cfg, fallback = incremental_reconfiguration(
                view.tasks, view.live, dirty, view.pending_ids, cat, table,
                evacuate=evac, type_mask=mask, region_caps=self.stack.caps,
                keep_bonus=keep_bonus, **kw)
            if fallback is not None:
                self.incremental_fallbacks += 1
            if self._trace_pending is not None:
                self._trace_pending.dirty = tuple(sorted(dirty))
                self._trace_pending.incremental_fallback = fallback
            return self._finish(cfg, view, cat)
        live = [i for i in view.live if i.instance_id not in evac]
        pending = set(view.pending_ids)
        for inst in view.live:
            if inst.instance_id in evac:
                pending |= set(inst.task_ids)
        if self._trace_pending is not None:
            # the forced partial's keep test runs over the survivors under
            # the drain mask — record exactly that landscape
            self._trace_pending.keep_table = self._trace_keep_table(
                live, view.tasks, cat, table, mask)
        cfg = partial_reconfiguration(
            view.tasks, [(i.type_index, i.task_ids) for i in live],
            pending, cat, table, type_mask=mask,
            region_caps=self.stack.caps, keep_bonus=keep_bonus, **kw)
        return self._finish(cfg, view, cat)

    def _finish(self, config: ClusterConfig, view: SchedulerView,
                cat: Catalog) -> ClusterConfig:
        if self._rec is None:
            return self.stack.refine(config, view, cat)
        before = self._numeric_summary()
        config = self.stack.refine(config, view, cat)
        after = self._numeric_summary()
        trace = self._trace_pending
        if trace is not None:
            self._trace_pending = None
            trace.refine_deltas = {k: after[k] - before[k] for k in after
                                   if k in before and after[k] != before[k]}
            self._rec.decisions.append(trace)
        return config

    # -- decision trace (pure observers; recorder attached only) -------------
    def _numeric_summary(self) -> Dict[str, float]:
        return {k: v for k, v in self.stack.summary().items()
                if isinstance(v, (int, float)) and not isinstance(v, bool)}

    def _trace_begin(self, view: SchedulerView, cat: Catalog,
                     d_hat: float) -> DecisionRecord:
        n = len(view.tasks.ids)
        if n:
            rp = reservation_prices(view.tasks, cat,
                                    type_mask=self.stack.mask)
            rp_min, rp_mean, rp_max = (float(rp.min()), float(rp.mean()),
                                       float(rp.max()))
        else:
            rp_min = rp_mean = rp_max = 0.0
        return DecisionRecord(
            t=view.time, round_index=self.rounds - 1, kind="",
            d_hat_s=float(d_hat), n_tasks=n,
            n_pending=len(view.pending_ids), rp_min=rp_min, rp_mean=rp_mean,
            rp_max=rp_max, mask_layers=self.stack.mask_layers,
            caps_layer=self.stack.caps_layer)

    def _trace_keep_table(self, live: Sequence[LiveInstance], tasks: TaskSet,
                          cat: Catalog, table, mask) -> List[KeepEntry]:
        """Replay the partial keep test (same pure helpers, same inputs)
        with the summed ``keep_bonus`` decomposed by contributing layer."""
        system_ids = set(tasks.ids.tolist())
        trimmed, iids = [], []
        for inst in live:
            alive = tuple(t for t in inst.task_ids if t in system_ids)
            if alive:
                trimmed.append((inst.type_index, alive))
                iids.append(inst.instance_id)
        if not trimmed:
            return []
        tnrps, costs = evaluate_assignments(trimmed, tasks, cat, table,
                                            self.multi_task_aware,
                                            type_mask=mask)
        out: List[KeepEntry] = []
        for iid, (k, tids), s, c in zip(iids, trimmed, tnrps, costs):
            by_layer = {name: float(fn(k, tids))
                        for name, fn in self._trace_parts}
            bonus = sum(by_layer.values())
            out.append(KeepEntry(
                instance_id=iid, type_index=int(k), saving=float(s),
                cost=float(c), bonus=bonus,
                bonus_by_layer={n2: v for n2, v in by_layer.items()
                                if v != 0.0},
                kept=bool(s >= c - bonus - EPS)))
        return out

    @property
    def full_adoption_rate(self) -> float:
        return self.full_adoptions / max(self.rounds, 1)


class NoPackingScheduler(SchedulerBase):
    """One task per instance, each on its reservation-price type (§6.1)."""

    name = "no-packing"

    def schedule(self, view: SchedulerView) -> ClusterConfig:
        system_ids = set(view.tasks.ids.tolist())
        assignments = []
        for inst in view.live:
            alive = tuple(t for t in inst.task_ids if t in system_ids)
            if alive:
                assignments.append((inst.type_index, alive))
        placed = {t for _, tids in assignments for t in tids}
        todo = sorted(t for t in system_ids if t not in placed)
        if todo:
            sub = view.tasks.subset(todo)
            kinds = cheapest_type(sub, self.catalog)
            for tid, k in zip(todo, kinds.tolist()):
                assignments.append((int(k), (tid,)))
        return ClusterConfig(assignments)
