"""Heterogeneous-resource extension of reservation price (paper §4.2,
"Generalizability to Heterogeneous Resources").

When instance families carry different versions of a resource (A100 vs V100
GPUs; higher-clock C7i CPUs), a task's throughput depends on the family it
lands on.  The paper prescribes: redefine RP as the minimum cost of
executing ONE ITERATION, and evaluate a task-to-instance assignment by
multiplying each task's iteration-RP by its throughput on that instance's
family before comparing against the hourly cost:

    RP_iter(τ) = min_{k feasible} C_k / tput_fam(τ, family(k))
    value of τ on family f = RP_iter(τ) · tput_f(τ)
    assignment cost-efficient  iff  Σ_τ value_f(τ) · tput_coloc(τ,T) ≥ C_k

Implemented as a thin wrapper over the numpy packing engine: the per-type
loop swaps in the family-specific RP vector, so Algorithm 1's structure
(descending-cost types, argmax fills, cost-efficiency gate) is unchanged.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .catalog import Catalog, FAMILIES
from .cluster_types import Assignment, ClusterConfig, TaskSet
from .full_reconfig import EPS, _pack_numpy
from .reservation_price import feasibility_matrix
from .throughput_table import ThroughputTable


def family_tput_matrix(tasks: TaskSet,
                       family_tput: Optional[Dict[int, Dict[str, float]]]
                       ) -> np.ndarray:
    """(T, F) relative standalone throughput of each task per family
    (default 1.0).  family_tput: task_id -> {family_name: tput}."""
    T = len(tasks)
    m = np.ones((T, len(FAMILIES)))
    if family_tput:
        for i, tid in enumerate(tasks.ids.tolist()):
            for fam, v in family_tput.get(tid, {}).items():
                m[i, FAMILIES.index(fam)] = float(v)
    return m


def iteration_rp(tasks: TaskSet, catalog: Catalog,
                 fam_tput: np.ndarray) -> np.ndarray:
    """(T,) RP_iter: minimum hourly cost per unit of standalone work."""
    feas = feasibility_matrix(tasks, catalog)  # (T, K)
    tput_k = fam_tput[:, catalog.family_ids]  # (T, K)
    cost_per_work = np.where(feas & (tput_k > 0),
                             catalog.costs[None, :] / np.maximum(tput_k, 1e-9),
                             np.inf)
    rp = cost_per_work.min(axis=1)
    if np.any(~np.isfinite(rp)):
        bad = tasks.ids[~np.isfinite(rp)]
        raise ValueError(f"tasks {bad.tolist()} fit no instance type")
    return rp


def full_reconfiguration_hetero(
        tasks: TaskSet, catalog: Catalog,
        table: Optional[ThroughputTable] = None, *,
        family_tput: Optional[Dict[int, Dict[str, float]]] = None,
        interference_aware: bool = True) -> ClusterConfig:
    """Algorithm 1 with per-family throughput-scaled reservation prices."""
    if len(tasks) == 0:
        return ClusterConfig([])
    fam_tput = family_tput_matrix(tasks, family_tput)
    rp_iter = iteration_rp(tasks, catalog, fam_tput)
    if interference_aware and table is not None:
        pairwise = table.pairwise_matrix()
    else:
        n = int(tasks.workloads.max()) + 1
        pairwise = np.ones((n, n))

    # per-type packing with the family-specific value vector; mirrors the
    # descending-cost outer loop of Algorithm 1 by restricting the catalog
    # to one type per call and keeping a shared unassigned pool.
    assignments: List[Assignment] = []
    remaining = tasks
    id_rows = {int(t): i for i, t in enumerate(tasks.ids.tolist())}
    unassigned = set(tasks.ids.tolist())
    for k in catalog.order_desc.tolist():
        if not unassigned:
            break
        sub_ids = sorted(unassigned)
        sub = tasks.subset(sub_ids)
        rows = np.array([id_rows[t] for t in sub_ids])
        fam = catalog.family_ids[k]
        rp_fam = rp_iter[rows] * fam_tput[rows, fam]
        one_type = Catalog.from_types([catalog.types[k]])
        packed = _pack_numpy(sub.demand_by_family, sub.workloads, rp_fam,
                             rp_fam, one_type, pairwise)
        for _, prows in packed:
            tids = tuple(int(sub.ids[r]) for r in prows)
            assignments.append((k, tids))
            unassigned -= set(tids)
    return ClusterConfig(assignments)
