"""ILP formulation of the provisioning problem (§4.1).

The paper solves this with Gurobi; offline we use scipy's HiGHS MILP.  Same
model (Table 2) plus two standard tightenings that do not change the optimum:

* symmetry breaking — task τ may only be placed on instances i ≤ row(τ)
  (any packing can be relabeled so each instance's index equals its minimum
  task row);
* instead of an explicit zero-cost "ghost" type, Σ_k x_ik ≤ 1 with a linking
  constraint Σ_τ y_iτ ≤ T · Σ_k x_ik.

Per-family demand vectors are handled with per-(instance, type) big-M
capacity constraints.  Also provides a cheap resource-based lower bound used
to report optimality gaps when the solver times out (as Gurobi did for the
paper at 200 tasks / 30 min).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from .catalog import Catalog
from .cluster_types import Assignment, ClusterConfig, TaskSet


@dataclasses.dataclass
class ILPResult:
    config: Optional[ClusterConfig]
    cost: float
    lower_bound: float
    status: str


def cost_lower_bound(tasks: TaskSet, catalog: Catalog) -> float:
    """max_r (Σ_τ min-family demand_τ^r) · min_k (C_k / Q_k^r): any valid
    provisioning must pay at least this to cover each resource."""
    best = 0.0
    demand = tasks.demand_by_family.min(axis=1)  # optimistic family
    for r in range(demand.shape[1]):
        total = demand[:, r].sum()
        if total <= 0:
            continue
        have = catalog.capacities[:, r] > 0
        dollars_per_unit = (catalog.costs[have] / catalog.capacities[have, r]).min()
        best = max(best, total * dollars_per_unit)
    return float(best)


def solve_ilp(tasks: TaskSet, catalog: Catalog, *, time_limit_s: float = 60.0,
              mip_rel_gap: float = 0.0) -> ILPResult:
    T = len(tasks)
    K = len(catalog)
    if T == 0:
        return ILPResult(ClusterConfig([]), 0.0, 0.0, "optimal")

    # per-(task, type) demands: (T, K, R)
    D = tasks.demand_by_family[:, catalog.family_ids, :]
    Q = catalog.capacities  # (K, R)
    R = Q.shape[1]

    # variable layout: x[i, k] for i in 0..T-1 -> T*K vars, then
    # y[i, tau] for tau in 0..T-1, i in 0..tau (lower triangular)
    nx = T * K
    y_index = {}
    ny = 0
    for tau in range(T):
        for i in range(tau + 1):
            y_index[(i, tau)] = nx + ny
            ny += 1
    nvar = nx + ny

    def xi(i, k):
        return i * K + k

    c = np.zeros(nvar)
    for i in range(T):
        for k in range(K):
            c[xi(i, k)] = catalog.costs[k]

    rows, cols, vals, lo, hi = [], [], [], [], []
    ncon = 0

    def add_row(entries, lb, ub):
        nonlocal ncon
        for col, v in entries:
            rows.append(ncon)
            cols.append(col)
            vals.append(v)
        lo.append(lb)
        hi.append(ub)
        ncon += 1

    # each task on exactly one instance
    for tau in range(T):
        add_row([(y_index[(i, tau)], 1.0) for i in range(tau + 1)], 1.0, 1.0)
    # each instance has at most one type (none = not provisioned)
    for i in range(T):
        add_row([(xi(i, k), 1.0) for k in range(K)], 0.0, 1.0)
    # linking: tasks only on provisioned instances
    for i in range(T):
        ent = [(y_index[(i, tau)], 1.0) for tau in range(i, T)]
        ent += [(xi(i, k), -float(T)) for k in range(K)]
        add_row(ent, -np.inf, 0.0)
    # capacity with big-M per (i, k, r)
    bigM = D.max(axis=1).sum(axis=0)  # (R,) total worst-case demand
    for i in range(T):
        for k in range(K):
            for r in range(R):
                ent = [(y_index[(i, tau)], float(D[tau, k, r]))
                       for tau in range(i, T) if D[tau, k, r] > 0]
                if not ent:
                    continue
                ent.append((xi(i, k), float(bigM[r])))
                add_row(ent, -np.inf, float(Q[k, r] + bigM[r]))

    A = sp.csc_matrix((vals, (rows, cols)), shape=(ncon, nvar))
    con = LinearConstraint(A, np.array(lo), np.array(hi))
    res = milp(c=c, constraints=con, integrality=np.ones(nvar),
               bounds=Bounds(0, 1),
               options={"time_limit": time_limit_s, "mip_rel_gap": mip_rel_gap})

    lb = cost_lower_bound(tasks, catalog)
    if res.x is None:
        return ILPResult(None, np.inf, lb, res.message)
    x = np.round(res.x).astype(int)
    assignments: List[Assignment] = []
    for i in range(T):
        ks = [k for k in range(K) if x[xi(i, k)]]
        if not ks:
            continue
        tids = tuple(int(tasks.ids[tau]) for tau in range(i, T)
                     if x[y_index[(i, tau)]])
        if tids:
            assignments.append((ks[0], tids))
    cfg = ClusterConfig(assignments)
    lb = max(lb, float(getattr(res, "mip_dual_bound", 0.0) or 0.0))
    status = "optimal" if res.status == 0 else f"status={res.status}"
    return ILPResult(cfg, float(res.fun), lb, status)
