"""Co-location throughput table (§4.3) + multi-task attribution rules (§4.4).

Entries are keyed by (workload, sorted-tuple-of-co-located-workloads).  A
lookup returns the exact entry when the set has been observed, otherwise the
product of pairwise entries; unseen pairwise entries default to ``t``
(0.95 in all paper experiments).

For multi-task (data-parallel) jobs, a single observed job throughput must be
attributed to ONE straggler entry; the three rules from §4.4 keep recorded
values lower bounds of the true co-location throughput, adjusted upwards as
more observations arrive.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

Key = Tuple[int, Tuple[int, ...]]


def _key(w: int, colocated: Sequence[int]) -> Key:
    return (int(w), tuple(sorted(int(x) for x in colocated)))


class ThroughputTable:
    def __init__(self, num_workloads: int, default: float = 0.95):
        self.num_workloads = int(num_workloads)
        self.default = float(default)
        self.entries: Dict[Key, float] = {}

    # ------------------------------------------------------------------ read
    def pairwise(self, w1: int, w2: int) -> float:
        return self.entries.get(_key(w1, (w2,)), self.default)

    def pairwise_matrix(self) -> np.ndarray:
        """(W, W) snapshot of current pairwise estimates (default-filled).
        Used by the packing engines for vectorized TNRP prediction."""
        n = self.num_workloads
        m = np.full((n, n), self.default)
        for (w, co), v in self.entries.items():
            if len(co) == 1:
                m[w, co[0]] = v
        return m

    def lookup(self, w: int, colocated: Sequence[int]) -> float:
        """Exact entry if the co-location set was observed, else the product
        of pairwise estimates (§4.3)."""
        co = tuple(sorted(int(x) for x in colocated))
        if not co:
            return 1.0
        exact = self.entries.get((int(w), co))
        if exact is not None:
            return exact
        t = 1.0
        for w2 in co:
            t *= self.pairwise(w, w2)
        return t

    def recorded(self, w: int, colocated: Sequence[int]):
        return self.entries.get(_key(w, colocated))

    # ----------------------------------------------------------------- write
    def record(self, w: int, colocated: Sequence[int], value: float) -> None:
        if not colocated:  # solo tasks have tput 1 by definition
            return
        self.entries[_key(w, colocated)] = float(value)

    def observe_single(self, w: int, colocated: Sequence[int], value: float) -> None:
        """Single-task job: degradation is attributable directly (§4.4)."""
        self.record(w, colocated, value)

    def observe_job(self, placements: List[Tuple[int, Tuple[int, ...]]],
                    value: float) -> None:
        """Multi-task job observation.

        placements: per task, (workload, tuple of co-located workloads).
        value: observed normalized job throughput (shared by all tasks of a
        data-parallel job).  Applies the three attribution rules of §4.4 and
        updates exactly one entry.
        """
        # Solo tasks (empty co-location set) have tput 1 by definition and
        # cannot be the straggler entry.
        cands = [(w, co) for (w, co) in placements if co]
        if not cands:
            return
        recs = [(w, co, self.recorded(w, co)) for (w, co) in cands]
        unrecorded = [(w, co) for (w, co, r) in recs if r is None]
        recorded = [(w, co, r) for (w, co, r) in recs if r is not None]

        if not recorded:
            # Rule 1: no previous observations -> update the task co-located
            # with the most tasks.
            w, co = max(unrecorded, key=lambda x: len(x[1]))
            self.record(w, co, value)
            return
        lower = [(w, co, r) for (w, co, r) in recorded if r < value]
        if lower:
            # Rule 2: some recorded throughput is lower than observed ->
            # update (raise) the entry with the lowest recorded throughput.
            w, co, _ = min(lower, key=lambda x: x[2])
            self.record(w, co, value)
            return
        if unrecorded:
            # Rule 3: all recorded are higher -> the straggler must be an
            # unrecorded task; update the one co-located with the most tasks.
            w, co = max(unrecorded, key=lambda x: len(x[1]))
            self.record(w, co, value)
            return
        # Edge case (not covered by the paper's rules): everything recorded
        # and all recorded values exceed the observation.  Preserve the
        # lower-bound invariant by lowering the minimum entry.
        w, co, _ = min(recorded, key=lambda x: x[2])
        self.record(w, co, value)

    # ------------------------------------------------------------------ misc
    def __len__(self) -> int:
        return len(self.entries)
