"""Partial Reconfiguration (§4.5).

Keeps every live instance whose task set is still cost-efficient
(TNRP(T_i) ≥ C_i after completions / observed interference) and re-packs only

  * tasks from recently submitted jobs not yet assigned to any instance, and
  * tasks on instances that are no longer cost-efficient,

via Algorithm 1.  Multi-task RP penalties are computed over the *system-wide*
job membership (non-migrating siblings still count).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Set

import numpy as np

from .catalog import Catalog
from .cluster_types import Assignment, ClusterConfig, TaskSet
from .full_reconfig import EPS, evaluate_assignments, full_reconfiguration
from .reservation_price import job_rp_sums, reservation_prices
from .throughput_table import ThroughputTable


def partial_reconfiguration(tasks: TaskSet, live_assignments: Sequence[Assignment],
                            pending_ids: Set[int], catalog: Catalog,
                            table: Optional[ThroughputTable] = None, *,
                            interference_aware: bool = True,
                            multi_task_aware: bool = True,
                            engine: str = "numpy",
                            time_s: Optional[float] = None) -> ClusterConfig:
    if time_s is not None:
        catalog = catalog.at(time_s)  # all downstream prices from one instant
    live_task_ids = {t for _, tids in live_assignments for t in tids}
    # Drop completed tasks from live assignments.
    system_ids = set(tasks.ids.tolist())
    trimmed: List[Assignment] = []
    for k, tids in live_assignments:
        alive = tuple(t for t in tids if t in system_ids)
        if alive:
            trimmed.append((k, alive))

    repack: Set[int] = set(pending_ids) & system_ids
    keep: List[Assignment] = []
    if trimmed:
        tnrps, costs = evaluate_assignments(trimmed, tasks, catalog, table,
                                            multi_task_aware)
        for (k, tids), s, c in zip(trimmed, tnrps, costs):
            if s >= c - EPS:
                keep.append((k, tids))
            else:  # no longer cost-efficient -> evict for re-packing
                repack |= set(tids)

    if not repack:
        return ClusterConfig(keep)

    rp_all = reservation_prices(tasks, catalog)
    job_rp_all = job_rp_sums(tasks, rp_all) if multi_task_aware else None

    # First, best-fit repack tasks into spare capacity on KEPT instances
    # (no extra provisioning, no migration of existing tenants) whenever the
    # grown set stays cost-efficient under TNRP.
    keep = [list(a) for a in keep]
    for tid in sorted(repack, key=lambda t: -rp_all[tasks.row(t)]):
        row = tasks.row(tid)
        best, best_left = -1, np.inf
        for i, (k, tids) in enumerate(keep):
            fam = catalog.family_ids[k]
            used = tasks.demand_by_family[
                [tasks.row(x) for x in tids], fam, :].sum(axis=0)
            d = tasks.demand_by_family[row, fam, :]
            if np.any(used + d > catalog.capacities[k] + EPS):
                continue
            grown = (k, tuple(tids) + (tid,))
            s, c = evaluate_assignments([grown], tasks, catalog, table,
                                        multi_task_aware)
            if s[0] < c[0] - EPS:
                continue
            left = float(((catalog.capacities[k] - used - d)
                          / np.maximum(catalog.capacities[k], 1.0)).sum())
            if left < best_left:
                best, best_left = i, left
        if best >= 0:
            keep[best][1] = tuple(keep[best][1]) + (tid,)
            repack.discard(tid)
    keep = [(k, tuple(tids)) for k, tids in keep]

    if not repack:
        return ClusterConfig(keep)
    sub = tasks.subset(sorted(repack))
    rows = np.array([tasks.row(t) for t in sub.ids.tolist()])
    packed = full_reconfiguration(
        sub, catalog, table, interference_aware=interference_aware,
        multi_task_aware=multi_task_aware, engine=engine,
        rp=rp_all[rows],
        job_rp=job_rp_all[rows] if job_rp_all is not None else None)
    return ClusterConfig(keep + packed.assignments)
