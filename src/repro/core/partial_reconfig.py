"""Partial Reconfiguration (§4.5).

Keeps every live instance whose task set is still cost-efficient
(TNRP(T_i) ≥ C_i after completions / observed interference) and re-packs only

  * tasks from recently submitted jobs not yet assigned to any instance, and
  * tasks on instances that are no longer cost-efficient,

via Algorithm 1.  Multi-task RP penalties are computed over the *system-wide*
job membership (non-migrating siblings still count).

``type_mask`` restricts which instance types may be used (region pinning);
it applies to reservation prices, the keep/evict cost-efficiency test,
spare-capacity best-fit, and the Algorithm-1 repack.  ``region_caps``
bounds per-region instance counts: kept instances consume their region's
budget and the repack only provisions into the remaining headroom (overflow
goes to the next-cheapest region).  On a multi-region catalog without mask
or caps, repacked tasks are priced across every region's current prices.

``keep_bonus(k, tids) -> $/h`` shifts the keep test by a per-instance slack.
Two schedulers use it:

* multi-region: a *positive* bonus equal to the amortized cost of actually
  moving the set elsewhere (cross-region checkpoint transfer + egress over
  the D-hat horizon), so instances are only evicted toward a cheaper market
  when the move pays for itself;
* credit-aware (burstable): the difference between the planning cost of a
  *fresh* instance of the type and the effective cost of *this* instance at
  its current credit balance.  The slack decays toward zero as the balance
  drains and turns negative once the instance forecasts worse than a fresh
  launch — at zero balance the keep test effectively compares TNRP against
  ``cost / baseline_fraction``, so exhausted instances are evicted into the
  repack set exactly when the throughput collapse makes the move worth its
  migration cost under the ensemble's S·D̂ > ΔM criterion.

``credit_horizon_s`` snapshots the catalog through
``catalog.credit_priced`` (fresh-launch balances) before any pricing, same
as ``full_reconfiguration``.
"""
from __future__ import annotations

from typing import (Callable, Iterable, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from .catalog import Catalog
from .cluster_types import Assignment, ClusterConfig, TaskSet
from .full_reconfig import EPS, evaluate_assignments, full_reconfiguration
from .plan import LiveInstance
from .reservation_price import job_rp_sums, reservation_prices
from .throughput_table import ThroughputTable


def partial_reconfiguration(tasks: TaskSet, live_assignments: Sequence[Assignment],
                            pending_ids: Set[int], catalog: Catalog,
                            table: Optional[ThroughputTable] = None, *,
                            interference_aware: bool = True,
                            multi_task_aware: bool = True,
                            engine: str = "numpy",
                            time_s: Optional[float] = None,
                            type_mask: Optional[np.ndarray] = None,
                            region_caps: Optional[
                                Sequence[Optional[int]]] = None,
                            keep_bonus: Optional[
                                Callable[[int, Tuple[int, ...]], float]
                            ] = None,
                            credit_horizon_s: Optional[float] = None
                            ) -> ClusterConfig:
    if time_s is not None:
        catalog = catalog.at(time_s)  # all downstream prices from one instant
    if credit_horizon_s is not None:
        catalog = catalog.credit_priced(credit_horizon_s)
    live_task_ids = {t for _, tids in live_assignments for t in tids}
    # Drop completed tasks from live assignments.
    system_ids = set(tasks.ids.tolist())
    trimmed: List[Assignment] = []
    for k, tids in live_assignments:
        alive = tuple(t for t in tids if t in system_ids)
        if alive:
            trimmed.append((k, alive))

    repack: Set[int] = set(pending_ids) & system_ids
    keep: List[Assignment] = []
    if trimmed:
        tnrps, costs = evaluate_assignments(trimmed, tasks, catalog, table,
                                            multi_task_aware,
                                            type_mask=type_mask)
        for (k, tids), s, c in zip(trimmed, tnrps, costs):
            # keep_bonus amortizes the cost of *moving* this set (multi-region:
            # checkpoint transfer + egress + relaunch over the D-hat horizon)
            # into the keep test: evicting for a cheaper market only pays off
            # if the price gap beats the migration penalty.
            slack = keep_bonus(k, tids) if keep_bonus is not None else 0.0
            if s >= c - slack - EPS:
                keep.append((k, tids))
            else:  # no longer cost-efficient -> evict for re-packing
                repack |= set(tids)

    if not repack:
        return ClusterConfig(keep)

    rp_all = reservation_prices(tasks, catalog, type_mask=type_mask)
    job_rp_all = job_rp_sums(tasks, rp_all) if multi_task_aware else None

    # First, best-fit repack tasks into spare capacity on KEPT instances
    # (no extra provisioning, no migration of existing tenants) whenever the
    # grown set stays cost-efficient under TNRP.
    keep = [list(a) for a in keep]
    for tid in sorted(repack, key=lambda t: -rp_all[tasks.row(t)]):
        row = tasks.row(tid)
        best, best_left = -1, np.inf
        for i, (k, tids) in enumerate(keep):
            fam = catalog.family_ids[k]
            used = tasks.demand_by_family[
                [tasks.row(x) for x in tids], fam, :].sum(axis=0)
            d = tasks.demand_by_family[row, fam, :]
            if np.any(used + d > catalog.capacities[k] + EPS):
                continue
            grown = (k, tuple(tids) + (tid,))
            s, c = evaluate_assignments([grown], tasks, catalog, table,
                                        multi_task_aware,
                                        type_mask=type_mask)
            if s[0] < c[0] - EPS:
                continue
            left = float(((catalog.capacities[k] - used - d)
                          / np.maximum(catalog.capacities[k], 1.0)).sum())
            if left < best_left:
                best, best_left = i, left
        if best >= 0:
            keep[best][1] = tuple(keep[best][1]) + (tid,)
            repack.discard(tid)
    keep = [(k, tuple(tids)) for k, tids in keep]

    if not repack:
        return ClusterConfig(keep)
    # Kept instances consume their region's instance-count budget; the
    # Algorithm-1 repack only gets the remaining headroom.
    sub_caps = region_caps
    if region_caps is not None and catalog.region_ids is not None:
        kept_per_region = [0] * len(region_caps)
        for k, _ in keep:
            kept_per_region[catalog.region_of(k)] += 1
        sub_caps = [None if c is None else max(int(c) - kept_per_region[r], 0)
                    for r, c in enumerate(region_caps)]
    sub = tasks.subset(sorted(repack))
    rows = np.array([tasks.row(t) for t in sub.ids.tolist()])
    packed = full_reconfiguration(
        sub, catalog, table, interference_aware=interference_aware,
        multi_task_aware=multi_task_aware, engine=engine,
        rp=rp_all[rows],
        job_rp=job_rp_all[rows] if job_rp_all is not None else None,
        type_mask=type_mask, region_caps=sub_caps)
    return ClusterConfig(keep + packed.assignments)


def incremental_reconfiguration(tasks: TaskSet,
                                live: Sequence[LiveInstance],
                                dirty_ids: Iterable[int],
                                pending_ids: Set[int], catalog: Catalog,
                                table: Optional[ThroughputTable] = None, *,
                                evacuate: Iterable[int] = (),
                                interference_aware: bool = True,
                                multi_task_aware: bool = True,
                                engine: str = "numpy",
                                time_s: Optional[float] = None,
                                type_mask: Optional[np.ndarray] = None,
                                region_caps: Optional[
                                    Sequence[Optional[int]]] = None,
                                keep_bonus: Optional[
                                    Callable[[int, Tuple[int, ...]], float]
                                ] = None,
                                credit_horizon_s: Optional[float] = None,
                                max_dirty_fraction: float = 0.5
                                ) -> Tuple[ClusterConfig, Optional[str]]:
    """Incremental partial reconfiguration: re-plan only the disturbance.

    ``dirty_ids`` are the live instance ids a pressure signal touched (see
    ``repro.policies.pressure.dirty_instance_ids``); ``evacuate`` is the
    subset that must additionally be vacated (spot revocations, credit
    drains).  Every *clean* live instance passes through verbatim, and one
    ordinary ``partial_reconfiguration`` runs over just the affected
    sub-problem — dirty instances keep/evict-tested as usual, evacuated
    instances' tasks plus ``pending_ids`` as the repack set, region budgets
    reduced by the clean fleet's footprint.  Per-round planning latency
    therefore scales with the size of the disturbance, not the cluster.

    Returns ``(config, fallback_reason)``.  ``fallback_reason`` is None when
    the incremental path ran; otherwise the call transparently degraded to a
    full ``partial_reconfiguration`` because locality would change the
    answer:

    * ``"dirty-fraction"`` — the disturbance touches more than
      ``max_dirty_fraction`` of the live fleet (or there is no live fleet),
      so a cluster-wide re-plan is at least as cheap as stitching;
    * ``"job-straddle"`` — ``multi_task_aware`` and some affected task's job
      also has tasks on clean instances: the §4.4 job-RP penalty must see
      the whole job, so the sub-problem cannot be priced locally.

    When no job straddles the cut, the affected sub-problem's reservation
    prices and job-RP sums equal the system-wide ones (RP is per-task,
    catalog-only), so the incremental plan is bit-identical to the clean
    pass-through plus ``partial_reconfiguration`` on the affected subset —
    pinned by ``tests/test_incremental.py``.

    Caller contract (scheduler views satisfy it): ``live`` placements
    reference only tasks present in ``tasks``.  Clean instances are NOT
    trimmed of completed tasks here — that O(cluster) sweep is exactly what
    this path avoids.
    """
    evac = set(evacuate)
    dirty = set(dirty_ids) | evac
    affected = [i for i in live if i.instance_id in dirty]
    clean = [i for i in live if i.instance_id not in dirty]
    kw = dict(interference_aware=interference_aware,
              multi_task_aware=multi_task_aware, engine=engine,
              time_s=time_s, type_mask=type_mask, keep_bonus=keep_bonus,
              credit_horizon_s=credit_horizon_s)

    def _fallback(reason: str) -> Tuple[ClusterConfig, str]:
        kept_live = [(i.type_index, i.task_ids) for i in live
                     if i.instance_id not in evac]
        pend = set(pending_ids)
        for i in live:
            if i.instance_id in evac:
                pend |= set(i.task_ids)
        cfg = partial_reconfiguration(tasks, kept_live, pend, catalog,
                                      table, region_caps=region_caps, **kw)
        return cfg, reason

    if not live or len(affected) > max_dirty_fraction * len(live):
        return _fallback("dirty-fraction")

    pending = set(pending_ids) & set(tasks.ids.tolist()) \
        if pending_ids else set()
    evac_tasks: Set[int] = set()
    for i in affected:
        if i.instance_id in evac:
            evac_tasks |= set(i.task_ids)
    sub_ids = sorted({t for i in affected for t in i.task_ids} | pending)
    if not sub_ids:
        return (ClusterConfig([(i.type_index, i.task_ids) for i in clean]),
                None)
    if multi_task_aware:
        jobs, counts = np.unique(
            tasks.job_ids[[tasks.row(t) for t in sub_ids]],
            return_counts=True)
        for j, n in zip(jobs.tolist(), counts.tolist()):
            if tasks.job_size(j) != n:
                return _fallback("job-straddle")
    sub_caps = region_caps
    if region_caps is not None and catalog.region_ids is not None:
        clean_per_region = [0] * len(region_caps)
        for i in clean:
            clean_per_region[catalog.region_of(i.type_index)] += 1
        sub_caps = [None if c is None
                    else max(int(c) - clean_per_region[r], 0)
                    for r, c in enumerate(region_caps)]
    sub = tasks.subset(sub_ids)
    sub_live = [(i.type_index, i.task_ids) for i in affected
                if i.instance_id not in evac]
    cfg = partial_reconfiguration(sub, sub_live, pending | evac_tasks,
                                  catalog, table, region_caps=sub_caps, **kw)
    out = [(i.type_index, i.task_ids) for i in clean] + cfg.assignments
    return ClusterConfig(out), None
