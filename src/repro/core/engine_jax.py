"""Jitted packing engine (beyond-paper optimization).

Algorithm 1's inner argmax is reformulated incrementally so each add step is
O(W² + T) instead of O(|members| · T):

  TNRP(T ∪ {c}) = cur − Σ_m jobrp_m·tput_m·(1 − P[w_m, w_c])
                      + rp_c − (1 − Π_m P[w_c, w_m])·jobrp_c

The member sum collapses onto per-workload aggregates agg_w = Σ_{m:w_m=w}
jobrp_m·tput_m (updated in O(W) per add, queried via agg·P), and candidate
throughputs are maintained as running log-products.  The whole
instances×adds loop for one instance type runs as nested lax.while_loops in
a single jitted call; the 21-type outer loop stays in Python.

Single-task TNRP (tput·RP) is the multi-task formula with jobrp ≡ rp, so one
code path serves both.  This engine replaces the paper's 22 s / 8k-task
Python scheduler (Table 5) with a ~milliseconds-scale packing round.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .catalog import Catalog

_EPS = 1e-9
_NEG = -1e30


@functools.partial(jax.jit, static_argnames=())
def _pack_one_type(demand, workloads, rp, job_rp, logP, P, cap_full, cost,
                   avail0):
    """Pack instances of ONE type until the fill is not cost-efficient.

    demand: (T, R) on this type's family; workloads: (T,); rp/job_rp: (T,);
    logP/P: (W, W); cap_full: (R,); cost: scalar; avail0: (T,) bool.
    Returns (slot: (T,) int32 assignment for this type (-1 = none),
             n_slots, avail_after).
    """
    T = demand.shape[0]

    def fill_instance(avail):
        """Greedy-fill a fresh instance; returns (sel, tnrp)."""
        sel0 = jnp.zeros(T, bool)
        state = (sel0, cap_full, jnp.zeros(T), jnp.zeros(logP.shape[0]),
                 jnp.float64(0.0) if False else jnp.float32(0.0), False)

        def cond(s):
            return ~s[-1]

        def body(s):
            sel, capr, logtput, agg, cur, _ = s
            feas = avail & ~sel & jnp.all(demand <= capr[None] + _EPS, axis=1)
            vec = agg @ P  # (W,)
            cand_tput = jnp.exp(logtput)
            score = (cur - (agg.sum() - vec[workloads])
                     + rp - (1.0 - cand_tput) * job_rp)
            score = jnp.where(feas, score, _NEG)
            best = jnp.argmax(score)
            bv = score[best]
            ok = feas.any() & (bv >= cur - _EPS)

            wb = workloads[best]
            tput_b = cand_tput[best]
            new_sel = sel.at[best].set(True)
            new_capr = capr - demand[best]
            new_logtput = logtput + logP[workloads, wb]
            new_agg = agg * P[:, wb]
            new_agg = new_agg.at[wb].add(job_rp[best] * tput_b)

            sel = jnp.where(ok, new_sel, sel)
            capr = jnp.where(ok, new_capr, capr)
            logtput = jnp.where(ok, new_logtput, logtput)
            agg = jnp.where(ok, new_agg, agg)
            cur = jnp.where(ok, bv.astype(cur.dtype), cur)
            return (sel, capr, logtput, agg, cur, ~ok)

        sel, _, _, _, cur, _ = jax.lax.while_loop(cond, body, state)
        return sel, cur

    def outer_cond(s):
        return s[-1]

    def outer_body(s):
        slot_arr, n_slots, avail, _ = s
        sel, tnrp = fill_instance(avail)
        accept = sel.any() & (tnrp >= cost - _EPS)
        slot_arr = jnp.where(accept & sel, n_slots, slot_arr)
        avail = jnp.where(accept, avail & ~sel, avail)
        n_slots = n_slots + jnp.where(accept, 1, 0)
        return (slot_arr, n_slots, avail, accept)

    init = (jnp.full(T, -1, jnp.int32), jnp.int32(0), avail0, True)
    slot_arr, n_slots, avail, _ = jax.lax.while_loop(outer_cond, outer_body,
                                                     init)
    return slot_arr, n_slots, avail


def pack_jax(demand_by_family: np.ndarray, workloads: np.ndarray,
             rp: np.ndarray, job_rp: Optional[np.ndarray], catalog: Catalog,
             pairwise: np.ndarray) -> List[Tuple[int, List[int]]]:
    """Engine entry point (same contract as the numpy/python engines)."""
    T = demand_by_family.shape[0]
    if job_rp is None:
        job_rp = rp  # single-task TNRP == multi-task with jobrp = rp
    w = jnp.asarray(workloads, jnp.int32)
    rp_j = jnp.asarray(rp, jnp.float32)
    jr_j = jnp.asarray(job_rp, jnp.float32)
    P = jnp.asarray(pairwise, jnp.float32)
    logP = jnp.log(jnp.maximum(P, 1e-9))
    avail = jnp.ones(T, bool)
    out: List[Tuple[int, List[int]]] = []
    for k in catalog.order_desc.tolist():
        fam = catalog.family_ids[k]
        d = jnp.asarray(demand_by_family[:, fam, :], jnp.float32)
        slot_arr, n_slots, avail = _pack_one_type(
            d, w, rp_j, jr_j, logP, P,
            jnp.asarray(catalog.capacities[k], jnp.float32),
            jnp.float32(catalog.costs[k]), avail)
        ns = int(n_slots)
        if ns:
            sa = np.asarray(slot_arr)
            for s in range(ns):
                rows = np.nonzero(sa == s)[0].tolist()
                out.append((k, rows))
        if not bool(avail.any()):
            break
    return out
