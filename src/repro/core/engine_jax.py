"""Jitted packing engine (beyond-paper optimization): one fused multi-type
pass over *task classes* instead of tasks.

Algorithm 1's inner argmax is reformulated incrementally so each add step is
cheap:

  TNRP(T ∪ {c}) = cur − Σ_m jobrp_m·tput_m·(1 − P[w_m, w_c])
                      + rp_c − (1 − Π_m P[w_c, w_m])·jobrp_c

The member sum collapses onto per-workload aggregates agg_w = Σ_{m:w_m=w}
jobrp_m·tput_m (updated in O(W) per add, queried via agg·P), and candidate
throughputs are maintained as running log-products — exactly the formulation
the per-type engine used, with two fleet-scale upgrades:

* **Class collapse.**  Tasks with identical (workload, RP, job-RP, demand)
  are interchangeable to Algorithm 1, so the argmax runs over the C ≤ ~tens
  of distinct *classes* with multiplicity counts, not the T tasks — each
  greedy step is O(C + W²) regardless of fleet size.  When the pairwise
  matrix is all-ones (interference-oblivious packs) classes additionally
  merge across workloads with equal price/demand rows.
* **Single jitted multi-type pass.**  The whole descending-cost type loop —
  fills, cost-efficiency acceptance, per-region instance budgets — runs as
  nested ``lax.while_loop``s inside one ``lax.fori_loop`` in a single jitted
  call with donated count/budget buffers; Python only expands the returned
  fill records back to task rows.
* **Fill replication.**  A greedy fill whose argmax was unique at every step
  replays identically while every used class retains enough tasks, so it is
  emitted once with a replication factor ``rep = min_c ⌊count_c/used_c⌋``
  (capped by the region budget) instead of being recomputed per instance.
  Fills that broke an exact cross-class score tie are not replicated
  (``rep = 1``): the tie is resolved by the *current lowest task row* of
  each tied class — the same first-maximal-row rule the numpy engine uses —
  and that row pointer advances between fills.

Together the pass is pick-for-pick identical to the per-type task-level
engine (and tie-break-compatible with the numpy engine) while planning
10⁵–10⁶-task fleets in far less than numpy needs for 10⁴
(``benchmarks/bench_micro.py scaling``).

Single-task TNRP (tput·RP) is the multi-task formula with jobrp ≡ rp, so one
code path serves both.  Unlike the earlier per-type engine, ``pack_jax`` now
accepts ``type_mask`` and ``region_budget`` with the same contract as the
numpy/python packers (budget consumption is written back in place), so every
Full/Partial Reconfiguration path — masked, region-capped and overflow
re-packs included — can run jitted.

All floating-point state is kept in the canonical JAX float dtype
(float32 by default, float64 under ``jax_enable_x64``) with accumulators
built explicitly from that dtype, so enabling x64 changes precision, not
semantics.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import profiler as _prof
from .catalog import Catalog

_EPS = 1e-9
_NEG = -1e30
_BIG_I = np.int32(np.iinfo(np.int32).max // 2)  # headroom for decrements


def _collapse_classes(workloads: np.ndarray, rp: np.ndarray, jr: np.ndarray,
                      demand: np.ndarray, merge_workloads: bool):
    """Group interchangeable tasks into classes.

    Returns ``(inv, cw, crp, cjr, cdemand, counts)`` where ``inv`` maps each
    task row to its class.  Fast path: when price/demand vectors are constant
    per workload (the common case — demands come from the workload profile
    and RP is a function of demand), classes are just the workloads present
    (further merged across workloads when ``merge_workloads`` — i.e. the
    pairwise matrix is all-ones and workload identity is inert).
    """
    T = workloads.shape[0]
    d2 = np.ascontiguousarray(demand.reshape(T, -1), dtype=np.float64)
    cols = np.column_stack([rp.astype(np.float64), jr.astype(np.float64), d2])
    order = np.argsort(workloads, kind="stable")
    ws = workloads[order]
    starts = np.nonzero(np.concatenate([[True], ws[1:] != ws[:-1]]))[0]
    grouped = cols[order]
    lo = np.minimum.reduceat(grouped, starts, axis=0)
    hi = np.maximum.reduceat(grouped, starts, axis=0)
    if np.array_equal(lo, hi):
        present = ws[starts]  # distinct workloads, ascending
        remap = np.zeros(int(workloads.max()) + 1, dtype=np.int64)
        remap[present] = np.arange(present.size)
        inv = remap[workloads]
        keys, cw = lo, present.astype(np.int64)
        if merge_workloads:
            _, uidx, uinv = np.unique(keys, axis=0, return_index=True,
                                      return_inverse=True)
            inv = uinv.reshape(-1)[inv]
            cw = cw[uidx]
            keys = keys[uidx]
    else:  # per-workload keys vary (e.g. per-job RP sums): full row unique
        full = cols if merge_workloads else np.column_stack(
            [workloads.astype(np.float64), cols])
        _, uidx, inv = np.unique(full, axis=0, return_inverse=True)
        inv = inv.reshape(-1)
        cw = workloads[uidx].astype(np.int64)
        keys = cols[uidx]
    counts = np.bincount(inv).astype(np.int32)
    crp, cjr = keys[:, 0], keys[:, 1]
    cdemand = keys[:, 2:].reshape(len(counts), demand.shape[1],
                                  demand.shape[2])
    return inv, cw, crp, cjr, cdemand, counts


def _pow2(n: int, floor: int) -> int:
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


@functools.partial(jax.jit, static_argnames=("max_fills",),
                   donate_argnums=(12,))
def _pack_all_types(cdemand, cw, crp, cjr, counts0, rows_pad, P, logP,
                    costs, caps, fams, rids, budget, *, max_fills: int):
    """One fused pass over every (masked-in) type in descending-cost order.

    Shapes: cdemand (C,F,R) · cw/crp/cjr/counts0 (C,) · rows_pad (C,M) ·
    P/logP (W,W) · costs/fams/rids (K,) · caps (K,R) · budget (NR,).
    Returns the final budget plus ``max_fills``-bounded fill records
    (type position, replication, per-class composition) and an overflow
    flag (caller retries with a larger buffer — record count is bounded by
    the task count, so the retry always terminates).
    """
    C = cw.shape[0]
    W = P.shape[0]
    K = costs.shape[0]
    dt = crp.dtype
    arange_c = jnp.arange(C)
    # complement interference matrix: the multi-task member penalty is
    # Σ_w agg_w·(1 − P[w, c]) = (agg @ Q)[c], which is *exactly* zero when
    # interference is off (P ≡ 1) instead of carrying the reduction-order
    # residual of agg.sum() − (agg @ P)[c]
    Q = 1.0 - P
    # break-even acceptance: fills on a task's RP type sum exactly to the
    # instance cost under the catalog's linear pricing, so the gate needs a
    # tolerance matched to the accumulator dtype — f32 greedy sums drift
    # ~n·eps·cost over an n-task fill; under jax_enable_x64 the relative
    # term collapses below the absolute 1e-9 epsilon, matching numpy
    rtol = dt.type(256 * jnp.finfo(dt).eps)

    def fill_one(counts, d, cap0):
        """Greedy-fill one fresh instance; returns (used, tnrp, had_tie)."""
        def cond(s):
            return ~s[-1]

        def body(s):
            used, capr, logtput, agg, cur, tie, _ = s
            feas = ((counts - used) > 0) & jnp.all(
                d <= capr[None, :] + _EPS, axis=1)
            cand_tput = jnp.exp(logtput)
            qvec = agg @ Q
            score = cur - qvec[cw] + crp - (1.0 - cand_tput) * cjr
            masked = jnp.where(feas, score, dt.type(_NEG))
            mx = masked.max()
            ok = feas.any() & (mx >= cur - _EPS)
            at_max = feas & (masked == mx)
            crosstie = at_max.sum() > 1
            # current lowest task row per class = numpy's first-max tie-break
            ptr = counts0 - counts + used
            rowkey = rows_pad[arange_c,
                              jnp.minimum(ptr, rows_pad.shape[1] - 1)]
            best = jnp.argmin(jnp.where(at_max, rowkey, _BIG_I))
            wb = cw[best]
            tput_b = cand_tput[best]
            n_used = used.at[best].add(1)
            n_capr = capr - d[best]
            n_logtput = logtput + logP[cw, wb]
            n_agg = (agg * P[:, wb]).at[wb].add(cjr[best] * tput_b)
            used = jnp.where(ok, n_used, used)
            capr = jnp.where(ok, n_capr, capr)
            logtput = jnp.where(ok, n_logtput, logtput)
            agg = jnp.where(ok, n_agg, agg)
            cur = jnp.where(ok, mx, cur)
            tie = tie | (crosstie & ok)
            return (used, capr, logtput, agg, cur, tie, ~ok)

        init = (jnp.zeros(C, jnp.int32), cap0, jnp.zeros(C, dt),
                jnp.zeros(W, dt), jnp.zeros((), dt),
                jnp.asarray(False), jnp.asarray(False))
        used, _, _, _, cur, tie, _ = jax.lax.while_loop(cond, body, init)
        return used, cur, tie

    def type_body(t, st):
        cost = costs[t]
        cap0 = caps[t]
        rid = rids[t]
        d = jnp.take(cdemand, fams[t], axis=1)  # (C, R) on this family

        def fcond(s):
            return s[-1]

        def fbody(s):
            counts, budget, rt, rr, rc, n_rec, ovf, _ = s
            used, cur, had_tie = fill_one(counts, d, cap0)
            accept = ((used.sum() > 0)
                      & (cur >= cost - _EPS - rtol * cost)
                      & (budget[rid] > 0))
            rep_c = jnp.where(used > 0, counts // jnp.maximum(used, 1),
                              _BIG_I)
            rep = jnp.minimum(rep_c.min(), budget[rid])
            rep = jnp.where(had_tie, 1, rep).astype(jnp.int32)
            can = n_rec < max_fills
            idx = jnp.minimum(n_rec, max_fills - 1)
            wr = accept & can
            rt = rt.at[idx].set(jnp.where(wr, t.astype(jnp.int32), rt[idx]))
            rr = rr.at[idx].set(jnp.where(wr, rep, rr[idx]))
            rc = rc.at[idx].set(jnp.where(wr, used, rc[idx]))
            n_rec = n_rec + jnp.where(accept, 1, 0).astype(jnp.int32)
            ovf = ovf | (accept & ~can)
            counts = jnp.where(accept, counts - rep * used, counts)
            budget = jnp.where(accept, budget.at[rid].add(-rep), budget)
            go = accept & (counts > 0).any()
            return (counts, budget, rt, rr, rc, n_rec, ovf, go)

        counts = st[0]
        init = st + ((counts > 0).any(),)
        return jax.lax.while_loop(fcond, fbody, init)[:-1]

    rec_type = jnp.full((max_fills,), -1, jnp.int32)
    rec_rep = jnp.zeros((max_fills,), jnp.int32)
    rec_comp = jnp.zeros((max_fills, C), jnp.int32)
    st = (counts0, budget, rec_type, rec_rep, rec_comp,
          jnp.zeros((), jnp.int32), jnp.asarray(False))
    st = jax.lax.fori_loop(0, K, type_body, st)
    _, budget, rec_type, rec_rep, rec_comp, n_rec, overflow = st
    return budget, rec_type, rec_rep, rec_comp, n_rec, overflow


def pack_jax(demand_by_family: np.ndarray, workloads: np.ndarray,
             rp: np.ndarray, job_rp: Optional[np.ndarray], catalog: Catalog,
             pairwise: np.ndarray,
             type_mask: Optional[np.ndarray] = None,
             region_budget: Optional[np.ndarray] = None
             ) -> List[Tuple[int, List[int]]]:
    """Engine entry point (same contract as the numpy/python engines,
    including in-place ``region_budget`` consumption)."""
    T = demand_by_family.shape[0]
    if T == 0:
        return []
    jr = rp if job_rp is None else job_rp  # single-task == jobrp ≡ rp
    dt = jax.dtypes.canonicalize_dtype(np.float64)
    merge = bool(np.all(pairwise == 1.0))
    inv, cw, crp, cjr, cdemand, counts = _collapse_classes(
        np.asarray(workloads), np.asarray(rp), np.asarray(jr),
        np.asarray(demand_by_family), merge)
    C = counts.size
    order_rows = np.argsort(inv, kind="stable")  # ascending rows per class
    starts = np.concatenate([[0], np.cumsum(counts)])

    # pad class axis / row queues to power-of-two buckets so jit shapes (and
    # compilations) stay bounded as fleet composition changes round to round
    c_pad = _pow2(C, 4)
    m_cap = _pow2(int(counts.max()), 8)
    rows_pad = np.full((c_pad, m_cap), T, np.int32)
    for c in range(C):
        rows_pad[c, :counts[c]] = order_rows[starts[c]:starts[c + 1]]
    pad = c_pad - C
    counts_p = np.concatenate([counts, np.zeros(pad, np.int32)])
    cw_p = np.concatenate([cw, np.zeros(pad, np.int64)]).astype(np.int32)
    crp_p = np.concatenate([crp, np.zeros(pad)]).astype(dt)
    cjr_p = np.concatenate([cjr, np.zeros(pad)]).astype(dt)
    cdem_p = np.concatenate(
        [cdemand, np.zeros((pad,) + cdemand.shape[1:])]).astype(dt)

    ks = [k for k in catalog.order_desc.tolist()
          if type_mask is None or bool(np.asarray(type_mask)[k])]
    if not ks:
        return []
    costs = catalog.costs[ks].astype(dt)
    caps = catalog.capacities[ks].astype(dt)
    fams = catalog.family_ids[ks].astype(np.int32)
    if region_budget is not None:
        rids = catalog.region_ids[ks].astype(np.int32)
        budget0 = np.minimum(region_budget, _BIG_I).astype(np.int32)
    else:
        rids = np.zeros(len(ks), np.int32)
        budget0 = np.array([_BIG_I], np.int32)

    P = jnp.asarray(pairwise, dt)
    logP = jnp.log(jnp.maximum(P, 1e-9))
    max_fills = _pow2(max(256, T // 2 + 8), 256)
    cache_size = getattr(_pack_all_types, "_cache_size", lambda: -1)
    while True:  # record count ≤ T, so doubling always terminates
        n_cached = cache_size()
        # the module-level span hook is a shared nullcontext (sp is None)
        # unless a profiler was activated; the bool(overflow) host sync sits
        # inside the span so device time is part of the measurement
        with _prof.span("jax_pack") as sp:
            budget_out, rec_type, rec_rep, rec_comp, n_rec, overflow = \
                _pack_all_types(jnp.asarray(cdem_p), jnp.asarray(cw_p),
                                jnp.asarray(crp_p), jnp.asarray(cjr_p),
                                jnp.asarray(counts_p), jnp.asarray(rows_pad),
                                P, logP, jnp.asarray(costs),
                                jnp.asarray(caps), jnp.asarray(fams),
                                jnp.asarray(rids), jnp.asarray(budget0),
                                max_fills=max_fills)
            overflowed = bool(overflow)
        if sp is not None:  # jit-cache growth == this call compiled
            sp.tags["stage"] = ("compile" if cache_size() > n_cached
                                else "execute")
            sp.tags["max_fills"] = max_fills
            sp.tags["n_tasks"] = T
        if not overflowed:
            break
        max_fills *= 2

    nrec = int(n_rec)
    rt = np.asarray(rec_type[:nrec])
    rr = np.asarray(rec_rep[:nrec])
    rc = np.asarray(rec_comp[:nrec])
    ptr = starts[:-1].copy()
    out: List[Tuple[int, List[int]]] = []
    for i in range(nrec):
        k = ks[int(rt[i])]
        rep = int(rr[i])
        comp = rc[i]
        cls = np.nonzero(comp[:C])[0]
        chunks = []
        for c in cls:
            n = int(comp[c]) * rep
            chunks.append(order_rows[ptr[c]:ptr[c] + n]
                          .reshape(rep, int(comp[c])))
            ptr[c] += n
        allrows = np.concatenate(chunks, axis=1)
        for j in range(rep):
            out.append((k, allrows[j].tolist()))
    if region_budget is not None:
        consumed = budget0.astype(np.int64) - np.asarray(budget_out,
                                                         dtype=np.int64)
        region_budget -= consumed  # in place: callers track remaining budget
    return out
