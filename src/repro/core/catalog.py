"""Instance-type catalog: static specs, spot-price dynamics, and regions.

The paper evaluates 21 AWS EC2 instance types from 3 families (P3 GPU
instances, C7i compute-optimized, R7i memory-optimized).  We encode the real
published specs/prices (us-east-1, on-demand, 2024).  Resources are the
3-vector (GPU, CPU, RAM-GB) used throughout the paper.  ``example_catalog``
reproduces Table 3 of the paper and is used by unit tests to check the
Algorithm-1 walkthrough verbatim.

Public API (see docs/ARCHITECTURE.md for how it plugs into scheduling):

* ``InstanceType`` / ``Catalog`` / ``aws_catalog()`` / ``table3_catalog()`` —
  the vectorized (capacities, costs, descending-cost order) view every
  pricing and packing routine consumes.
* ``PriceModel`` (``static`` / ``mean_reverting`` / ``trace``) — maps (base
  on-demand costs, time) → current hourly prices; ``catalog.at(time_s)``
  returns a snapshot with current costs and the Algorithm-1 order recomputed.
  The static model is the identity — ``at`` returns the catalog itself — so
  on-demand behaviour is bit-for-bit unchanged.
* ``Region`` / ``TransferMatrix`` / ``multi_region_catalog()`` — the
  multi-region layer: each region carries its own price model, base-price
  scale, preemption-hazard scale and optional instance-count capacity, and
  the catalog is expanded to region-qualified types (``region-0/p3.2xlarge``)
  whose prices move with *their region's* market.  ``catalog.at(time_s)``
  then returns region-qualified snapshots, and the cross-region
  ``TransferMatrix`` (egress $/GB + inter-region bandwidth) prices the
  checkpoint-transfer penalty a cross-region migration pays.
  ``dispersed_demo_regions()`` builds the bundled 3-region staggered
  cheap-window market used by benchmarks and tests.
* ``CreditModel`` — the burstable (AWS T-family / CASH) layer: an instance
  type may carry a credit model (baseline fraction, accrual rate, cap,
  launch credits).  A burstable instance runs at full speed while its
  credit balance lasts and is throttled to ``baseline_fraction`` once it
  hits zero — while its *hourly price never changes*.  The catalog exposes
  the state-dependent economics: ``avg_speed_over(horizon_s, balances)``
  forecasts the mean effective throughput of each type over a horizon and
  ``credit_priced(horizon_s, balances)`` returns a planning snapshot whose
  costs are effective $/throughput (cost ÷ forecast speed) so reservation
  prices and Algorithm 1 see a burstable type as cheap only while its
  forecast credits last.  ``burstable_demo_catalog()`` bundles the demo
  market (on-demand AWS types + discounted burstable c7i variants) used by
  ``benchmarks/bench_credits.py`` and the credit tests.

* ``CommitmentModel`` / ``Provider`` / ``multi_provider_catalog()`` — the
  commitment-portfolio + multi-provider layer: a provider is a market
  ``Region`` (all base types, its own price model / cost scale / hazard
  scale / egress rate) plus one *pool* ``Region`` per commitment — a
  1yr/3yr-style reserved-capacity pool holding only the committed type at
  the discounted rate, bounded by ``max_instances = pool_size`` so the
  existing region-cap machinery (planner budgets + simulator launch
  denial) bounds the pool.  A committed pool bills its discounted rate
  for every pool slot whether used or idle (the simulator's standing
  pool bill); overflow rides the provider's market region at the spot /
  on-demand ``PriceModel``.  The provider-aware ``TransferMatrix`` prices
  intra-provider moves at zero egress and near-zero transfer time, and
  cross-provider moves at the *source* provider's egress rate — so the
  existing S·D̂ > ΔM machinery automatically prices inter-provider
  arbitrage.  ``MarketPriceModel`` generalizes ``RegionPriceModel`` to
  heterogeneous region blocks (21-type markets next to 1-type pools).

Single-region catalogs carry ``regions=None`` and take none of the
multi-region code paths: their behaviour is bit-for-bit the PR-1 catalog.
Catalogs without burstable types carry ``credit_models=None`` and take none
of the credit code paths (``credit_priced`` is the identity there).
Catalogs without commitment pools carry no ``Region.commitment`` and take
none of the commitment code paths; a single-provider, commitment-free
``multi_provider_catalog`` is decision-identical to the equivalent
``multi_region_catalog`` (pinned in ``tests/test_policies.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

RESOURCES = ("gpu", "cpu", "ram")
NUM_RESOURCES = len(RESOURCES)

# Instance families.  Per-family demand vectors (Table 7: CPU tasks need fewer
# vCPUs on C7i/R7i because of higher clocks) are indexed by these ids.
FAMILIES = ("p3", "c7i", "r7i")


@dataclasses.dataclass(frozen=True)
class CreditModel:
    """Burstable-instance credit dynamics (AWS T-family / CASH, Sharma 2020).

    Credits are measured in *full-speed hours*: one credit-hour buys one
    hour of full-throughput compute.  A busy instance drains its balance at
    ``duty − accrual_per_hour`` per hour (``duty`` is the busiest resident
    task's burst duty cycle, 1.0 by default); an idle one accrues at
    ``accrual_per_hour`` up to ``credit_cap_hours``.  At zero balance a
    busy instance is *throttled*: every resident task progresses at
    ``baseline_fraction`` of its normal rate while the hourly price is
    billed unchanged — the cost/throughput asymmetry credit-aware
    scheduling exploits.  While throttled the accrual is consumed by the
    baseline itself, so the balance stays pinned at zero until the
    instance goes idle.  Fresh instances start with
    ``launch_credit_hours`` (AWS T3 launch credits).

    ``accrual_per_hour`` defaults to ``baseline_fraction`` — the T-family
    identity (the baseline is exactly the sustainable duty).
    """

    baseline_fraction: float
    accrual_per_hour: Optional[float] = None
    credit_cap_hours: float = 2.0
    launch_credit_hours: float = 0.5

    def __post_init__(self):
        assert 0.0 < self.baseline_fraction < 1.0
        if self.accrual_per_hour is None:
            object.__setattr__(self, "accrual_per_hour",
                               self.baseline_fraction)

    @property
    def effective_launch_hours(self) -> float:
        """Launch balance actually granted: the cap bounds it, and planner
        (``Catalog.launch_balances``) and simulator must agree on it."""
        return min(self.launch_credit_hours, self.credit_cap_hours)

    def drain_per_hour(self, duty: float = 1.0) -> float:
        """Net balance change per busy hour (negative = accruing)."""
        return float(duty) - self.accrual_per_hour

    def burst_hours(self, balance_h: float, duty: float = 1.0) -> float:
        """Busy hours until a balance exhausts (inf for sustainable duty)."""
        d = self.drain_per_hour(duty)
        if d <= 0.0:
            return float("inf")
        return max(float(balance_h), 0.0) / d

    def speed(self, balance_h: float) -> float:
        """Instantaneous effective-throughput factor at a balance."""
        return 1.0 if balance_h > 1e-9 else self.baseline_fraction

    def avg_speed_over(self, balance_h: float, horizon_h: float,
                       duty: float = 1.0) -> float:
        """Forecast mean effective-throughput factor over ``horizon_h``
        busy hours starting from ``balance_h``: full speed while the
        balance lasts, ``baseline_fraction`` after."""
        if horizon_h <= 0.0:
            return self.speed(balance_h)
        t_full = self.burst_hours(balance_h, duty)
        if t_full >= horizon_h:
            return 1.0
        return (t_full + (horizon_h - t_full) * self.baseline_fraction) \
            / horizon_h


@dataclasses.dataclass(frozen=True)
class CommitmentModel:
    """A reserved-capacity commitment (1yr/3yr RI / savings-plan style).

    A commitment buys ``pool_size`` slots of one instance type at
    ``rate_fraction`` × the on-demand price.  The pool bills its
    discounted rate for *every* slot *every* hour, used or idle — the
    defining asymmetry of committed capacity: the marginal price of
    placing work on an already-paid slot is ≈ 0, while an idle slot is
    pure waste.  Overflow beyond the pool rides the provider's market
    (spot / on-demand ``PriceModel``).  Committed capacity is reserved:
    pool instances are never spot-preempted (the pool region carries
    ``hazard_scale = 0``).

    ``term_s`` is metadata for reporting (the nominal commitment term);
    billing inside a simulation run is per pool-hour regardless.
    """

    instance_type: str
    pool_size: int
    rate_fraction: float = 0.6
    term_s: float = 365.0 * 86400.0

    def __post_init__(self):
        assert self.pool_size >= 0
        assert 0.0 < self.rate_fraction <= 1.0
        assert self.term_s > 0.0

    def hourly_rate(self, on_demand_cost: float) -> float:
        """Committed $/hour for one pool slot of a type whose on-demand
        price is ``on_demand_cost``."""
        return float(on_demand_cost) * self.rate_fraction

    def standing_usd_per_hour(self, on_demand_cost: float) -> float:
        """The pool's standing bill: every slot, used or idle."""
        return self.pool_size * self.hourly_rate(on_demand_cost)


@dataclasses.dataclass(frozen=True)
class InstanceType:
    name: str
    family: str
    capacity: tuple  # (gpu, cpu, ram_gb)
    hourly_cost: float
    credit_model: Optional[CreditModel] = None  # burstable types only

    @property
    def family_id(self) -> int:
        return FAMILIES.index(self.family) if self.family in FAMILIES else 0


def _it(name, family, gpu, cpu, ram, cost):
    return InstanceType(name, family, (float(gpu), float(cpu), float(ram)), float(cost))


# 21 types: 3 P3 + 9 C7i + 9 R7i (matches the paper's setup).
AWS_CATALOG: tuple = (
    _it("p3.2xlarge", "p3", 1, 8, 61, 3.06),
    _it("p3.8xlarge", "p3", 4, 32, 244, 12.24),
    _it("p3.16xlarge", "p3", 8, 64, 488, 24.48),
    _it("c7i.large", "c7i", 0, 2, 4, 0.0893),
    _it("c7i.xlarge", "c7i", 0, 4, 8, 0.1785),
    _it("c7i.2xlarge", "c7i", 0, 8, 16, 0.357),
    _it("c7i.4xlarge", "c7i", 0, 16, 32, 0.714),
    _it("c7i.8xlarge", "c7i", 0, 32, 64, 1.428),
    _it("c7i.12xlarge", "c7i", 0, 48, 96, 2.142),
    _it("c7i.16xlarge", "c7i", 0, 64, 128, 2.856),
    _it("c7i.24xlarge", "c7i", 0, 96, 192, 4.284),
    _it("c7i.48xlarge", "c7i", 0, 192, 384, 8.568),
    _it("r7i.large", "r7i", 0, 2, 16, 0.1323),
    _it("r7i.xlarge", "r7i", 0, 4, 32, 0.2646),
    _it("r7i.2xlarge", "r7i", 0, 8, 64, 0.5292),
    _it("r7i.4xlarge", "r7i", 0, 16, 128, 1.0584),
    _it("r7i.8xlarge", "r7i", 0, 32, 256, 2.1168),
    _it("r7i.12xlarge", "r7i", 0, 48, 384, 3.1752),
    _it("r7i.16xlarge", "r7i", 0, 64, 512, 4.2336),
    _it("r7i.24xlarge", "r7i", 0, 96, 768, 6.3504),
    _it("r7i.48xlarge", "r7i", 0, 192, 1536, 12.7008),
)


def example_catalog() -> tuple:
    """Table 3(a) of the paper: it1..it4."""
    return (
        _it("it1", "p3", 4, 16, 244, 12.0),
        _it("it2", "p3", 1, 4, 61, 3.0),
        _it("it3", "c7i", 0, 8, 32, 0.8),
        _it("it4", "c7i", 0, 4, 16, 0.4),
    )


# --------------------------------------------------------------------------
# price models (spot-market layer)
# --------------------------------------------------------------------------
class PriceModel:
    """Maps (base on-demand costs, time) -> current hourly prices.

    The base class is the *static* on-demand model: prices never move and
    ``Catalog.at`` short-circuits to the catalog itself, so attaching
    ``PriceModel.static()`` is exactly equivalent to no model at all.

    Dynamic subclasses return a per-type multiplier vector that is a pure
    function of time (piecewise-constant on a precomputed grid), so scheduler
    and simulator always agree on the price at any instant and replays are
    deterministic regardless of event interleaving.
    """

    kind = "static"
    is_static = True
    mean_multiplier = 1.0

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        return np.ones(n_types)

    def prices_at(self, base_costs: np.ndarray, time_s: float) -> np.ndarray:
        return base_costs * self.multipliers_at(len(base_costs), time_s)

    def pressure_at(self, n_types: int, time_s: float) -> np.ndarray:
        """Price pressure: current multiplier relative to the long-run mean.
        > 1 means the market is tight (preemption hazard rises with it)."""
        return self.multipliers_at(n_types, time_s) / self.mean_multiplier

    # -- constructors -------------------------------------------------------
    @staticmethod
    def static() -> "PriceModel":
        return PriceModel()

    @staticmethod
    def mean_reverting(discount: float = 0.35, volatility: float = 0.10,
                       reversion: float = 0.05, step_s: float = 300.0,
                       horizon_s: float = 14 * 86400.0,
                       seed: int = 0) -> "MeanRevertingPriceModel":
        return MeanRevertingPriceModel(discount, volatility, reversion,
                                       step_s, horizon_s, seed)

    @staticmethod
    def trace(times_s: Sequence[float],
              multipliers: Sequence[float]) -> "TracePriceModel":
        return TracePriceModel(times_s, multipliers)


class MeanRevertingPriceModel(PriceModel):
    """Ornstein-Uhlenbeck log-price series around ``discount`` × on-demand.

    Each instance type gets an independent seeded path sampled once on a
    fixed ``step_s`` grid; queries step-interpolate (piecewise-constant) and
    hold the last value beyond ``horizon_s``.  Multipliers are clipped to
    [discount/10, 1.0] — AWS caps spot at the on-demand price.
    """

    kind = "mean-reverting"
    is_static = False

    def __init__(self, discount: float, volatility: float, reversion: float,
                 step_s: float, horizon_s: float, seed: int):
        assert 0.0 < discount <= 1.0
        self.discount = float(discount)
        self.volatility = float(volatility)
        self.reversion = float(reversion)
        self.step_s = float(step_s)
        self.horizon_s = float(horizon_s)
        self.seed = int(seed)
        self.mean_multiplier = float(discount)
        self._grids: Dict[int, np.ndarray] = {}  # n_types -> (N, K)

    def _grid(self, n_types: int) -> np.ndarray:
        g = self._grids.get(n_types)
        if g is None:
            rng = np.random.default_rng(self.seed)
            n_steps = int(self.horizon_s / self.step_s) + 1
            mu = np.log(self.discount)
            x = np.empty((n_steps, n_types))
            x[0] = mu
            eps = rng.standard_normal((n_steps - 1, n_types))
            for i in range(1, n_steps):
                x[i] = (x[i - 1] + self.reversion * (mu - x[i - 1])
                        + self.volatility * eps[i - 1])
            g = np.clip(np.exp(x), self.discount / 10.0, 1.0)
            self._grids[n_types] = g
        return g

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        g = self._grid(n_types)
        i = min(int(max(time_s, 0.0) / self.step_s), g.shape[0] - 1)
        return g[i]


class TracePriceModel(PriceModel):
    """Replay a recorded price trace: piecewise-constant multipliers.

    ``multipliers`` is (N,) for a market-wide series or (N, K) per-type.
    """

    kind = "trace"
    is_static = False

    def __init__(self, times_s: Sequence[float], multipliers: Sequence[float]):
        self.times_s = np.asarray(times_s, dtype=np.float64)
        self.multipliers = np.asarray(multipliers, dtype=np.float64)
        assert self.times_s.ndim == 1 and len(self.times_s) > 0
        assert self.multipliers.shape[0] == self.times_s.shape[0]
        assert np.all(np.diff(self.times_s) >= 0), "trace must be time-sorted"
        # per-type long-run mean for (N, K) traces so pressure (and hence the
        # preemption hazard) is unbiased for types whose own mean differs
        # from the market mean
        if self.multipliers.ndim == 2:
            self.mean_multiplier = self.multipliers.mean(axis=0)
        else:
            self.mean_multiplier = float(self.multipliers.mean())

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        i = int(np.searchsorted(self.times_s, time_s, side="right")) - 1
        i = max(i, 0)
        m = self.multipliers[i]
        if np.ndim(m) == 0:
            return np.full(n_types, float(m))
        return np.asarray(m)


# --------------------------------------------------------------------------
# regions (multi-region spot-arbitrage layer)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Region:
    """One cloud region (an independent spot market).

    price_model   : region-local price dynamics (None/static = on-demand)
    cost_scale    : multiplier on the base on-demand prices (static regional
                    price dispersion, e.g. us-west is 8 % dearer)
    hazard_scale  : multiplier on the preemption hazard of every instance in
                    the region — hazards are *region-correlated*: all types
                    in the region share the regional market's price pressure
                    scaled by this factor
    max_instances : per-region capacity (simultaneously alive instances);
                    None = unlimited.  The simulator denies launches beyond
                    it and the multi-region scheduler packs around full
                    regions.
    provider      : owning cloud provider (multi-provider catalogs only;
                    None = provider-less, the pre-commitment behaviour).
                    Regions of the same provider transfer data for free.
    commitment    : set on commitment-*pool* regions only: the pool bills
                    ``commitment.pool_size`` slots at the discounted rate
                    every hour regardless of use, and ``max_instances``
                    equals the pool size so the existing region-cap
                    machinery bounds it.  None = ordinary market region.
    """

    name: str
    price_model: Optional[PriceModel] = None
    cost_scale: float = 1.0
    hazard_scale: float = 1.0
    max_instances: Optional[int] = None
    provider: Optional[str] = None
    commitment: Optional[CommitmentModel] = None


@dataclasses.dataclass(frozen=True)
class TransferMatrix:
    """Cross-region data-movement cost model.

    egress_usd_per_gb : (R, R) — $/GB billed to the *source* region when a
                        checkpoint leaves it (diagonal is 0)
    bandwidth_gbps    : (R, R) — inter-region throughput in Gbit/s, used to
                        turn checkpoint size into transfer *time* (diagonal
                        is ignored: intra-region moves pay no transfer)
    """

    egress_usd_per_gb: np.ndarray
    bandwidth_gbps: np.ndarray

    @staticmethod
    def uniform(n_regions: int, egress_usd_per_gb: float = 0.02,
                bandwidth_gbps: float = 5.0) -> "TransferMatrix":
        """AWS-like defaults: $0.02/GB inter-region egress, ~5 Gbit/s per
        checkpoint stream."""
        e = np.full((n_regions, n_regions), float(egress_usd_per_gb))
        b = np.full((n_regions, n_regions), float(bandwidth_gbps))
        np.fill_diagonal(e, 0.0)
        return TransferMatrix(e, b)

    @staticmethod
    def for_providers(region_providers: Sequence[Optional[str]],
                      egress_usd_per_gb: Dict[str, float],
                      cross_bandwidth_gbps: float = 5.0,
                      intra_bandwidth_gbps: float = 50.0) -> "TransferMatrix":
        """Provider-aware transfer costs.

        Moves between regions of the *same* provider (a market and its
        commitment pools) pay zero egress over fat intra-provider links;
        cross-provider moves pay the **source** provider's egress rate
        (clouds bill data out, not in) over ``cross_bandwidth_gbps``.
        The S·D̂ > ΔM arbitrage machinery therefore prices inter-provider
        moves automatically through the existing ``task_move_cost`` path.
        """
        n = len(region_providers)
        e = np.zeros((n, n))
        b = np.full((n, n), float(intra_bandwidth_gbps))
        for i, p_i in enumerate(region_providers):
            for j, p_j in enumerate(region_providers):
                if i != j and p_i != p_j:
                    e[i, j] = float(egress_usd_per_gb.get(p_i, 0.0))
                    b[i, j] = float(cross_bandwidth_gbps)
        return TransferMatrix(e, b)

    def transfer_time_s(self, src: int, dst: int, size_gb: float) -> float:
        if src == dst:
            return 0.0
        return float(size_gb) * 8.0 / float(self.bandwidth_gbps[src, dst])

    def egress_usd(self, src: int, dst: int, size_gb: float) -> float:
        if src == dst:
            return 0.0
        return float(size_gb) * float(self.egress_usd_per_gb[src, dst])


class RegionPriceModel(PriceModel):
    """Composite price model for a region-expanded catalog.

    The expanded catalog lays types out as R consecutive blocks of
    ``n_base`` types; each block's multipliers come from that region's own
    model.  Preemption pressure is additionally scaled per region
    (``Region.hazard_scale``), which is what makes hazards
    region-correlated: every type in a region shares the regional market's
    pressure.
    """

    kind = "multi-region"

    def __init__(self, models: Sequence[PriceModel],
                 hazard_scales: Sequence[float], n_base: int):
        self.models = tuple(m if m is not None else PriceModel.static()
                            for m in models)
        self.hazard_scales = tuple(float(h) for h in hazard_scales)
        self.n_base = int(n_base)
        self.is_static = all(m.is_static for m in self.models)
        means = []
        for m in self.models:
            mm = np.asarray(m.mean_multiplier, dtype=np.float64)
            means.append(np.full(self.n_base, float(mm)) if mm.ndim == 0
                         else np.broadcast_to(mm, (self.n_base,)))
        self.mean_multiplier = np.concatenate(means)
        # the simulator samples prices no coarser than the finest sub-grid
        steps = [m.step_s for m in self.models if hasattr(m, "step_s")]
        if steps:
            self.step_s = min(steps)
        # trace sub-models are billed exactly at their own breakpoints
        times = sorted({float(t) for m in self.models
                        for t in np.asarray(getattr(m, "times_s", ()),
                                            dtype=np.float64).tolist()})
        if times:
            self.times_s = np.asarray(times, dtype=np.float64)

    def _check(self, n_types: int) -> None:
        assert n_types == self.n_base * len(self.models), \
            f"expected {self.n_base}x{len(self.models)} types, got {n_types}"

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        self._check(n_types)
        return np.concatenate([m.multipliers_at(self.n_base, time_s)
                               for m in self.models])

    def pressure_at(self, n_types: int, time_s: float) -> np.ndarray:
        self._check(n_types)
        return np.concatenate([m.pressure_at(self.n_base, time_s) * h
                               for m, h in zip(self.models,
                                               self.hazard_scales)])


class MarketPriceModel(PriceModel):
    """Composite price model for heterogeneous region blocks.

    Generalizes ``RegionPriceModel`` to catalogs whose regions hold
    *different* numbers of types — a provider's full 21-type market next
    to its 1-type commitment pools.  Block ``i`` covers ``counts[i]``
    consecutive types priced by ``models[i]`` with preemption pressure
    scaled by ``hazard_scales[i]`` (0 for reserved pools: committed
    capacity is never spot-preempted).

    Deliberately *not* a ``RegionPriceModel`` subclass: the forecaster
    dispatch (``PriceForecaster.for_model``) keys on the classes, and the
    uniform-block ``RegionForecaster`` cannot serve heterogeneous blocks.
    With one block this is numerically identical to a one-region
    ``RegionPriceModel`` (pinned in ``tests/test_policies.py``).
    """

    kind = "multi-provider"

    def __init__(self, models: Sequence[PriceModel],
                 hazard_scales: Sequence[float], counts: Sequence[int]):
        self.models = tuple(m if m is not None else PriceModel.static()
                            for m in models)
        self.hazard_scales = tuple(float(h) for h in hazard_scales)
        self.counts = tuple(int(c) for c in counts)
        assert len(self.models) == len(self.hazard_scales) \
            == len(self.counts)
        self.is_static = all(m.is_static for m in self.models)
        means = []
        for m, c in zip(self.models, self.counts):
            mm = np.asarray(m.mean_multiplier, dtype=np.float64)
            means.append(np.full(c, float(mm)) if mm.ndim == 0
                         else np.broadcast_to(mm, (c,)))
        self.mean_multiplier = np.concatenate(means)
        # same grid-propagation contract as RegionPriceModel: the simulator
        # samples no coarser than the finest sub-grid and exactly at trace
        # breakpoints
        steps = [m.step_s for m in self.models if hasattr(m, "step_s")]
        if steps:
            self.step_s = min(steps)
        times = sorted({float(t) for m in self.models
                        for t in np.asarray(getattr(m, "times_s", ()),
                                            dtype=np.float64).tolist()})
        if times:
            self.times_s = np.asarray(times, dtype=np.float64)

    def _check(self, n_types: int) -> None:
        assert n_types == sum(self.counts), \
            f"expected {sum(self.counts)} types in blocks {self.counts}, " \
            f"got {n_types}"

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        self._check(n_types)
        return np.concatenate([m.multipliers_at(c, time_s)
                               for m, c in zip(self.models, self.counts)])

    def pressure_at(self, n_types: int, time_s: float) -> np.ndarray:
        self._check(n_types)
        return np.concatenate([m.pressure_at(c, time_s) * h
                               for m, c, h in zip(self.models, self.counts,
                                                  self.hazard_scales)])


@dataclasses.dataclass(frozen=True)
class Catalog:
    """Vectorized view over a set of instance types.

    Attributes
    ----------
    capacities : (K, R) float64
    costs      : (K,)   float64 — current prices (== base for static models)
    order_desc : indices of types sorted by descending cost (Algorithm 1 order)
    price_model : optional time-varying price source; ``at(time_s)`` snapshots
    base_costs : on-demand reference prices (None until a snapshot is taken)
    regions    : multi-region catalogs only — tuple of ``Region``
    region_ids : (K,) int64 — region index of each type (None = single-region)
    base_index : (K,) int64 — index of each type in the un-expanded base
                 catalog (same base_index across regions = same hardware)
    transfer   : cross-region ``TransferMatrix`` (multi-region only)
    credit_models : burstable catalogs only — one ``Optional[CreditModel]``
                 per type (None entries = ordinary on-demand/spot types);
                 None when no type in the catalog is burstable
    """

    types: tuple
    capacities: np.ndarray
    costs: np.ndarray
    family_ids: np.ndarray
    order_desc: np.ndarray
    price_model: Optional[PriceModel] = None
    base_costs: Optional[np.ndarray] = None
    regions: Optional[tuple] = None
    region_ids: Optional[np.ndarray] = None
    base_index: Optional[np.ndarray] = None
    transfer: Optional[TransferMatrix] = None
    credit_models: Optional[tuple] = None

    @staticmethod
    def from_types(types: Sequence[InstanceType],
                   price_model: Optional[PriceModel] = None) -> "Catalog":
        types = tuple(types)
        caps = np.array([t.capacity for t in types], dtype=np.float64)
        costs = np.array([t.hourly_cost for t in types], dtype=np.float64)
        fam = np.array([t.family_id for t in types], dtype=np.int64)
        order = np.argsort(-costs, kind="stable")
        credits = None
        if any(t.credit_model is not None for t in types):
            credits = tuple(t.credit_model for t in types)
        return Catalog(types, caps, costs, fam, order, price_model,
                       credit_models=credits)

    def __len__(self) -> int:
        return len(self.types)

    def index_of(self, name: str) -> int:
        for i, t in enumerate(self.types):
            if t.name == name:
                return i
        raise KeyError(name)

    # -- regions -------------------------------------------------------------
    @property
    def is_multi_region(self) -> bool:
        return self.regions is not None

    def region_of(self, k: int) -> int:
        return int(self.region_ids[k])

    def region_index(self, name: str) -> int:
        for i, r in enumerate(self.regions):
            if r.name == name:
                return i
        raise KeyError(name)

    def region_type_mask(self, region: int) -> np.ndarray:
        """(K,) bool: which types live in ``region`` (index)."""
        return self.region_ids == int(region)

    # -- providers & commitments --------------------------------------------
    @property
    def has_commitments(self) -> bool:
        return self.regions is not None and \
            any(r.commitment is not None for r in self.regions)

    @property
    def has_providers(self) -> bool:
        return self.regions is not None and \
            any(r.provider is not None for r in self.regions)

    def commitment_pools(self) -> tuple:
        """((region_index, CommitmentModel), ...) over pool regions."""
        if self.regions is None:
            return ()
        return tuple((i, r.commitment) for i, r in enumerate(self.regions)
                     if r.commitment is not None)

    def commitment_type_mask(self) -> np.ndarray:
        """(K,) bool: types living in a commitment-pool region."""
        out = np.zeros(len(self), dtype=bool)
        for i, _cm in self.commitment_pools():
            out |= self.region_ids == i
        return out

    def provider_of(self, k: int) -> Optional[str]:
        """Owning provider of type ``k`` (None on provider-less catalogs)."""
        if self.regions is None or self.region_ids is None:
            return None
        return self.regions[int(self.region_ids[k])].provider

    def cheapest_copy(self, k: int,
                      type_mask: Optional[np.ndarray] = None) -> int:
        """Index of the cheapest same-hardware copy of type ``k`` across
        regions (``k`` itself on single-region catalogs or when every copy
        is masked out).  First-lowest-index tie-break."""
        if self.base_index is None:
            return int(k)
        cand = self.base_index == self.base_index[k]
        if type_mask is not None:
            cand = cand & np.asarray(type_mask)
        ks = np.nonzero(cand)[0]
        if ks.size == 0:
            return int(k)
        return int(ks[np.argmin(self.costs[ks])])

    # -- time-varying prices ------------------------------------------------
    def with_price_model(self, price_model: Optional[PriceModel]) -> "Catalog":
        return dataclasses.replace(self, price_model=price_model)

    def at(self, time_s: float) -> "Catalog":
        """Snapshot of the catalog priced at ``time_s``.

        Static (or absent) price models return ``self`` unchanged — the
        identity guarantees on-demand code paths stay bit-for-bit intact.
        """
        pm = self.price_model
        if pm is None or pm.is_static:
            return self
        base = self.base_costs if self.base_costs is not None else self.costs
        costs = pm.prices_at(base, time_s)
        order = np.argsort(-costs, kind="stable")
        return dataclasses.replace(self, costs=costs, order_desc=order,
                                   base_costs=base)

    def prices_between(self, t0: float, t1: Optional[float] = None) -> np.ndarray:
        """(K,) price vector in effect over the constant-price segment
        ``[t0, t1)``.

        Every price model here is piecewise-constant in time (OU grids,
        traces, and the region/market block compositions of both), so a
        caller that only crosses segment boundaries at its own PRICE_UPDATE
        events can bill a whole segment from one vector.  Unlike :meth:`at`,
        no catalog snapshot is built — no ``dataclasses.replace``, no
        re-sorted ``order_desc`` — which is what the simulator's billing
        path wants: the prices, nothing else.  ``t1`` documents the
        segment's intended extent; prices are evaluated at ``t0`` and the
        caller is responsible for not spanning a breakpoint (the simulator
        guarantees this by construction: PRICE_UPDATE events sit on every
        model step and trace breakpoint).

        With a static or absent model this returns ``self.costs`` itself —
        the same identity guarantee as :meth:`at`.
        """
        pm = self.price_model
        if pm is None or pm.is_static:
            return self.costs
        base = self.base_costs if self.base_costs is not None else self.costs
        return pm.prices_at(base, t0)

    # -- burstable credits --------------------------------------------------
    @property
    def is_burstable(self) -> bool:
        return self.credit_models is not None

    @property
    def launch_balances(self) -> np.ndarray:
        """(K,) launch-credit hours per type (0 for non-burstable types)."""
        if self.credit_models is None:
            return np.zeros(len(self))
        return np.array([0.0 if cm is None else cm.effective_launch_hours
                         for cm in self.credit_models])

    def avg_speed_over(self, horizon_s: float,
                       balances: Optional[np.ndarray] = None) -> np.ndarray:
        """(K,) forecast mean effective-throughput factor of each type over
        a ``horizon_s`` busy window: 1.0 for non-burstable types, the
        credit-adjusted average for burstable ones.  ``balances`` defaults
        to the launch-credit balance of a fresh instance of each type."""
        out = np.ones(len(self))
        if self.credit_models is None:
            return out
        bal = self.launch_balances if balances is None \
            else np.asarray(balances, dtype=np.float64)
        h = float(horizon_s) / 3600.0
        for k, cm in enumerate(self.credit_models):
            if cm is not None:
                out[k] = cm.avg_speed_over(float(bal[k]), h)
        return out

    def credit_priced(self, horizon_s: Optional[float],
                      balances: Optional[np.ndarray] = None) -> "Catalog":
        """Planning snapshot priced at effective $/throughput over a horizon.

        Each burstable type's cost is divided by its forecast mean speed
        (``avg_speed_over``), so a type whose credits will not last the
        horizon looks proportionally dearer to reservation prices and to
        Algorithm 1's descending-cost order — which is recomputed.  The
        identity for non-burstable catalogs (and ``horizon_s=None``), so
        on-demand/spot/multi-region paths are bit-for-bit unchanged.
        Billing always uses the *raw* costs: throttling never discounts
        the bill, which is the asymmetry this view prices in.
        """
        if self.credit_models is None or horizon_s is None:
            return self
        speed = self.avg_speed_over(horizon_s, balances)
        costs = self.costs / speed
        order = np.argsort(-costs, kind="stable")
        return dataclasses.replace(self, costs=costs, order_desc=order)


def aws_catalog(price_model: Optional[PriceModel] = None) -> Catalog:
    return Catalog.from_types(AWS_CATALOG, price_model)


def table3_catalog() -> Catalog:
    return Catalog.from_types(example_catalog())


# --------------------------------------------------------------------------
# multi-region construction
# --------------------------------------------------------------------------
def multi_region_catalog(regions: Sequence[Region],
                         base_types: Sequence[InstanceType] = AWS_CATALOG,
                         transfer: Optional[TransferMatrix] = None) -> Catalog:
    """Expand ``base_types`` across ``regions`` into a region-qualified catalog.

    Types are laid out as R consecutive blocks of the base catalog; names are
    qualified (``us-east/p3.2xlarge``), base prices are scaled by each
    region's ``cost_scale`` and move with its ``price_model`` (the composite
    ``RegionPriceModel`` keeps every region's market independent).  The
    default ``transfer`` is ``TransferMatrix.uniform(R)``.
    """
    regions = tuple(regions)
    base = tuple(base_types)
    assert regions, "need at least one region"
    types = []
    rids, bidx = [], []
    for r_i, region in enumerate(regions):
        for b_i, t in enumerate(base):
            types.append(InstanceType(f"{region.name}/{t.name}", t.family,
                                      t.capacity,
                                      t.hourly_cost * region.cost_scale,
                                      credit_model=t.credit_model))
            rids.append(r_i)
            bidx.append(b_i)
    pm: Optional[PriceModel] = None
    if any(r.price_model is not None for r in regions):
        pm = RegionPriceModel([r.price_model for r in regions],
                              [r.hazard_scale for r in regions], len(base))
    cat = Catalog.from_types(types, pm)
    if transfer is None:
        transfer = TransferMatrix.uniform(len(regions))
    return dataclasses.replace(
        cat, regions=regions,
        region_ids=np.asarray(rids, dtype=np.int64),
        base_index=np.asarray(bidx, dtype=np.int64), transfer=transfer)


# --------------------------------------------------------------------------
# multi-provider construction (commitment-portfolio layer)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Provider:
    """One cloud provider: a market plus an optional commitment portfolio.

    price_model / cost_scale / hazard_scale / max_instances configure the
    provider's *market* region exactly like ``Region``;
    ``egress_usd_per_gb`` is the rate billed when data leaves this
    provider; ``commitments`` is a tuple of ``CommitmentModel`` — each
    becomes a dedicated reserved-capacity pool region.
    """

    name: str
    price_model: Optional[PriceModel] = None
    cost_scale: float = 1.0
    hazard_scale: float = 1.0
    max_instances: Optional[int] = None
    egress_usd_per_gb: float = 0.02
    commitments: tuple = ()


def multi_provider_catalog(providers: Sequence[Provider],
                           base_types: Sequence[InstanceType] = AWS_CATALOG,
                           transfer: Optional[TransferMatrix] = None,
                           cross_bandwidth_gbps: float = 5.0,
                           intra_bandwidth_gbps: float = 50.0) -> Catalog:
    """Expand ``base_types`` across provider markets + commitment pools.

    Each provider contributes one *market* region holding every base type
    at ``cost_scale`` × on-demand moving with its ``price_model``, plus
    one single-type *pool* region per commitment: ``pool_size`` slots of
    the committed type at the discounted static rate, hazard 0 (reserved
    capacity is never preempted), ``max_instances = pool_size``.  Region
    blocks are heterogeneous, so the composite is a ``MarketPriceModel``;
    ``base_index`` maps every copy (market or pool, any provider) of the
    same hardware together, so ``cheapest_copy`` / the arbitrage repack
    shop across providers and pools transparently.  The default transfer
    matrix is ``TransferMatrix.for_providers`` (intra-provider free).

    Composes with the whole existing catalog algebra: ``at(time_s)``
    snapshots the market blocks (pool blocks are static), and
    ``credit_priced`` / forecast snapshots work unchanged.  A
    single-provider, commitment-free call is decision-identical to
    ``multi_region_catalog`` with one region.
    """
    providers = tuple(providers)
    base = tuple(base_types)
    assert providers, "need at least one provider"
    by_name = {t.name: t for t in base}
    regions, blocks = [], []  # blocks[i] = list of (InstanceType, base_idx)
    for p in providers:
        market = Region(p.name, price_model=p.price_model,
                        cost_scale=p.cost_scale, hazard_scale=p.hazard_scale,
                        max_instances=p.max_instances, provider=p.name)
        regions.append(market)
        blocks.append([
            (InstanceType(f"{p.name}/{t.name}", t.family, t.capacity,
                          t.hourly_cost * p.cost_scale,
                          credit_model=t.credit_model), b_i)
            for b_i, t in enumerate(base)])
        for cm in p.commitments:
            t = by_name[cm.instance_type]  # KeyError = unknown committed type
            pool = Region(f"{p.name}/commit-{cm.instance_type}",
                          cost_scale=p.cost_scale * cm.rate_fraction,
                          hazard_scale=0.0, max_instances=cm.pool_size,
                          provider=p.name, commitment=cm)
            regions.append(pool)
            blocks.append([
                (InstanceType(f"{pool.name}/{t.name}", t.family, t.capacity,
                              cm.hourly_rate(t.hourly_cost * p.cost_scale),
                              credit_model=t.credit_model),
                 base.index(t))])
    types, rids, bidx = [], [], []
    for r_i, block in enumerate(blocks):
        for t, b_i in block:
            types.append(t)
            rids.append(r_i)
            bidx.append(b_i)
    pm: Optional[PriceModel] = None
    if any(r.price_model is not None for r in regions):
        pm = MarketPriceModel([r.price_model for r in regions],
                              [r.hazard_scale for r in regions],
                              [len(block) for block in blocks])
    cat = Catalog.from_types(types, pm)
    if transfer is None:
        transfer = TransferMatrix.for_providers(
            [r.provider for r in regions],
            {p.name: p.egress_usd_per_gb for p in providers},
            cross_bandwidth_gbps=cross_bandwidth_gbps,
            intra_bandwidth_gbps=intra_bandwidth_gbps)
    return dataclasses.replace(
        cat, regions=tuple(regions),
        region_ids=np.asarray(rids, dtype=np.int64),
        base_index=np.asarray(bidx, dtype=np.int64), transfer=transfer)


# --------------------------------------------------------------------------
# burstable demo market
# --------------------------------------------------------------------------
# Burstable variants cover the c7i sizes the Table-7 CPU workloads actually
# fit on (T-family stops well short of the 24/48xlarge metal tiers).
_BURSTABLE_SIZES = ("large", "xlarge", "2xlarge", "4xlarge", "8xlarge",
                    "12xlarge", "16xlarge")


def burstable_demo_catalog(price_fraction: float = 0.42,
                           baseline_fraction: float = 0.2,
                           launch_credit_hours: float = 0.5,
                           credit_cap_hours: float = 2.0,
                           price_model: Optional[PriceModel] = None
                           ) -> Catalog:
    """The bundled burstable market (``bench_credits`` + credit tests).

    All 21 on-demand AWS types, plus burstable ``t7i.*`` twins of the c7i
    compute tier at ``price_fraction`` × the on-demand price, each carrying
    a shared ``CreditModel``: a fresh instance bursts at full speed for
    ``launch_credit_hours / (1 − accrual)`` busy hours, then throttles to
    ``baseline_fraction``.  The defaults make the trap concrete: a
    burstable instance is 58 % cheaper per hour, but once throttled its
    effective price is ``price_fraction / baseline_fraction`` = 2.1× the
    on-demand twin — credit-blind reservation prices anchor to the cheap
    hourly sticker and ride the throttle; credit-aware ones burst while
    the forecast balance lasts and migrate off when it runs out.
    """
    cm = CreditModel(baseline_fraction=baseline_fraction,
                     credit_cap_hours=credit_cap_hours,
                     launch_credit_hours=launch_credit_hours)
    types = list(AWS_CATALOG)
    by_name = {t.name: t for t in AWS_CATALOG}
    for size in _BURSTABLE_SIZES:
        base = by_name[f"c7i.{size}"]
        types.append(InstanceType(f"t7i.{size}", base.family, base.capacity,
                                  base.hourly_cost * price_fraction,
                                  credit_model=cm))
    return Catalog.from_types(types, price_model)


def dispersed_demo_regions(n_regions: int = 3, low: float = 0.25,
                           high: float = 0.85, period_s: float = 3 * 3600.0,
                           horizon_s: float = 14 * 86400.0) -> tuple:
    """The bundled dispersed-price multi-region market (benchmarks + tests).

    Each region replays a staggered square-wave price trace: exactly one
    region is in its cheap window (``low`` × on-demand) at any instant while
    the others sit at ``high`` × on-demand, rotating every
    ``period_s / n_regions``.  A single-region scheduler therefore pays
    ``low`` only 1/R of the time; a multi-region one can chase the cheap
    window continuously — the price dispersion spot-arbitrage exploits.
    """
    step = period_s / n_regions
    times = np.arange(0.0, horizon_s, step)
    regions = []
    for r in range(n_regions):
        mult = np.where(np.arange(len(times)) % n_regions == r, low, high)
        regions.append(Region(f"region-{r}",
                              price_model=PriceModel.trace(times, mult)))
    return tuple(regions)
