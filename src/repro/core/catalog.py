"""Instance-type catalog.

The paper evaluates 21 AWS EC2 instance types from 3 families (P3 GPU
instances, C7i compute-optimized, R7i memory-optimized).  We encode the real
published specs/prices (us-east-1, on-demand, 2024).  Resources are the
3-vector (GPU, CPU, RAM-GB) used throughout the paper.

``example_catalog`` reproduces Table 3 of the paper and is used by unit tests
to check the Algorithm-1 walkthrough verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

RESOURCES = ("gpu", "cpu", "ram")
NUM_RESOURCES = len(RESOURCES)

# Instance families.  Per-family demand vectors (Table 7: CPU tasks need fewer
# vCPUs on C7i/R7i because of higher clocks) are indexed by these ids.
FAMILIES = ("p3", "c7i", "r7i")


@dataclasses.dataclass(frozen=True)
class InstanceType:
    name: str
    family: str
    capacity: tuple  # (gpu, cpu, ram_gb)
    hourly_cost: float

    @property
    def family_id(self) -> int:
        return FAMILIES.index(self.family) if self.family in FAMILIES else 0


def _it(name, family, gpu, cpu, ram, cost):
    return InstanceType(name, family, (float(gpu), float(cpu), float(ram)), float(cost))


# 21 types: 3 P3 + 9 C7i + 9 R7i (matches the paper's setup).
AWS_CATALOG: tuple = (
    _it("p3.2xlarge", "p3", 1, 8, 61, 3.06),
    _it("p3.8xlarge", "p3", 4, 32, 244, 12.24),
    _it("p3.16xlarge", "p3", 8, 64, 488, 24.48),
    _it("c7i.large", "c7i", 0, 2, 4, 0.0893),
    _it("c7i.xlarge", "c7i", 0, 4, 8, 0.1785),
    _it("c7i.2xlarge", "c7i", 0, 8, 16, 0.357),
    _it("c7i.4xlarge", "c7i", 0, 16, 32, 0.714),
    _it("c7i.8xlarge", "c7i", 0, 32, 64, 1.428),
    _it("c7i.12xlarge", "c7i", 0, 48, 96, 2.142),
    _it("c7i.16xlarge", "c7i", 0, 64, 128, 2.856),
    _it("c7i.24xlarge", "c7i", 0, 96, 192, 4.284),
    _it("c7i.48xlarge", "c7i", 0, 192, 384, 8.568),
    _it("r7i.large", "r7i", 0, 2, 16, 0.1323),
    _it("r7i.xlarge", "r7i", 0, 4, 32, 0.2646),
    _it("r7i.2xlarge", "r7i", 0, 8, 64, 0.5292),
    _it("r7i.4xlarge", "r7i", 0, 16, 128, 1.0584),
    _it("r7i.8xlarge", "r7i", 0, 32, 256, 2.1168),
    _it("r7i.12xlarge", "r7i", 0, 48, 384, 3.1752),
    _it("r7i.16xlarge", "r7i", 0, 64, 512, 4.2336),
    _it("r7i.24xlarge", "r7i", 0, 96, 768, 6.3504),
    _it("r7i.48xlarge", "r7i", 0, 192, 1536, 12.7008),
)


def example_catalog() -> tuple:
    """Table 3(a) of the paper: it1..it4."""
    return (
        _it("it1", "p3", 4, 16, 244, 12.0),
        _it("it2", "p3", 1, 4, 61, 3.0),
        _it("it3", "c7i", 0, 8, 32, 0.8),
        _it("it4", "c7i", 0, 4, 16, 0.4),
    )


@dataclasses.dataclass(frozen=True)
class Catalog:
    """Vectorized view over a set of instance types.

    Attributes
    ----------
    capacities : (K, R) float64
    costs      : (K,)   float64
    order_desc : indices of types sorted by descending cost (Algorithm 1 order)
    """

    types: tuple
    capacities: np.ndarray
    costs: np.ndarray
    family_ids: np.ndarray
    order_desc: np.ndarray

    @staticmethod
    def from_types(types: Sequence[InstanceType]) -> "Catalog":
        types = tuple(types)
        caps = np.array([t.capacity for t in types], dtype=np.float64)
        costs = np.array([t.hourly_cost for t in types], dtype=np.float64)
        fam = np.array([t.family_id for t in types], dtype=np.int64)
        order = np.argsort(-costs, kind="stable")
        return Catalog(types, caps, costs, fam, order)

    def __len__(self) -> int:
        return len(self.types)

    def index_of(self, name: str) -> int:
        for i, t in enumerate(self.types):
            if t.name == name:
                return i
        raise KeyError(name)


def aws_catalog() -> Catalog:
    return Catalog.from_types(AWS_CATALOG)


def table3_catalog() -> Catalog:
    return Catalog.from_types(example_catalog())
