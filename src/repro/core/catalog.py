"""Instance-type catalog.

The paper evaluates 21 AWS EC2 instance types from 3 families (P3 GPU
instances, C7i compute-optimized, R7i memory-optimized).  We encode the real
published specs/prices (us-east-1, on-demand, 2024).  Resources are the
3-vector (GPU, CPU, RAM-GB) used throughout the paper.

``example_catalog`` reproduces Table 3 of the paper and is used by unit tests
to check the Algorithm-1 walkthrough verbatim.

Beyond the paper, the catalog supports *time-varying* prices through a
``PriceModel`` attached to the ``Catalog``: ``catalog.at(time_s)`` returns a
snapshot view with current costs (and the Algorithm-1 descending-cost order
recomputed), so reservation prices and packing decisions track spot-market
drift.  The static model is the identity — ``at`` returns the catalog itself —
so on-demand behaviour is bit-for-bit unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

RESOURCES = ("gpu", "cpu", "ram")
NUM_RESOURCES = len(RESOURCES)

# Instance families.  Per-family demand vectors (Table 7: CPU tasks need fewer
# vCPUs on C7i/R7i because of higher clocks) are indexed by these ids.
FAMILIES = ("p3", "c7i", "r7i")


@dataclasses.dataclass(frozen=True)
class InstanceType:
    name: str
    family: str
    capacity: tuple  # (gpu, cpu, ram_gb)
    hourly_cost: float

    @property
    def family_id(self) -> int:
        return FAMILIES.index(self.family) if self.family in FAMILIES else 0


def _it(name, family, gpu, cpu, ram, cost):
    return InstanceType(name, family, (float(gpu), float(cpu), float(ram)), float(cost))


# 21 types: 3 P3 + 9 C7i + 9 R7i (matches the paper's setup).
AWS_CATALOG: tuple = (
    _it("p3.2xlarge", "p3", 1, 8, 61, 3.06),
    _it("p3.8xlarge", "p3", 4, 32, 244, 12.24),
    _it("p3.16xlarge", "p3", 8, 64, 488, 24.48),
    _it("c7i.large", "c7i", 0, 2, 4, 0.0893),
    _it("c7i.xlarge", "c7i", 0, 4, 8, 0.1785),
    _it("c7i.2xlarge", "c7i", 0, 8, 16, 0.357),
    _it("c7i.4xlarge", "c7i", 0, 16, 32, 0.714),
    _it("c7i.8xlarge", "c7i", 0, 32, 64, 1.428),
    _it("c7i.12xlarge", "c7i", 0, 48, 96, 2.142),
    _it("c7i.16xlarge", "c7i", 0, 64, 128, 2.856),
    _it("c7i.24xlarge", "c7i", 0, 96, 192, 4.284),
    _it("c7i.48xlarge", "c7i", 0, 192, 384, 8.568),
    _it("r7i.large", "r7i", 0, 2, 16, 0.1323),
    _it("r7i.xlarge", "r7i", 0, 4, 32, 0.2646),
    _it("r7i.2xlarge", "r7i", 0, 8, 64, 0.5292),
    _it("r7i.4xlarge", "r7i", 0, 16, 128, 1.0584),
    _it("r7i.8xlarge", "r7i", 0, 32, 256, 2.1168),
    _it("r7i.12xlarge", "r7i", 0, 48, 384, 3.1752),
    _it("r7i.16xlarge", "r7i", 0, 64, 512, 4.2336),
    _it("r7i.24xlarge", "r7i", 0, 96, 768, 6.3504),
    _it("r7i.48xlarge", "r7i", 0, 192, 1536, 12.7008),
)


def example_catalog() -> tuple:
    """Table 3(a) of the paper: it1..it4."""
    return (
        _it("it1", "p3", 4, 16, 244, 12.0),
        _it("it2", "p3", 1, 4, 61, 3.0),
        _it("it3", "c7i", 0, 8, 32, 0.8),
        _it("it4", "c7i", 0, 4, 16, 0.4),
    )


# --------------------------------------------------------------------------
# price models (spot-market layer)
# --------------------------------------------------------------------------
class PriceModel:
    """Maps (base on-demand costs, time) -> current hourly prices.

    The base class is the *static* on-demand model: prices never move and
    ``Catalog.at`` short-circuits to the catalog itself, so attaching
    ``PriceModel.static()`` is exactly equivalent to no model at all.

    Dynamic subclasses return a per-type multiplier vector that is a pure
    function of time (piecewise-constant on a precomputed grid), so scheduler
    and simulator always agree on the price at any instant and replays are
    deterministic regardless of event interleaving.
    """

    kind = "static"
    is_static = True
    mean_multiplier = 1.0

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        return np.ones(n_types)

    def prices_at(self, base_costs: np.ndarray, time_s: float) -> np.ndarray:
        return base_costs * self.multipliers_at(len(base_costs), time_s)

    def pressure_at(self, n_types: int, time_s: float) -> np.ndarray:
        """Price pressure: current multiplier relative to the long-run mean.
        > 1 means the market is tight (preemption hazard rises with it)."""
        return self.multipliers_at(n_types, time_s) / self.mean_multiplier

    # -- constructors -------------------------------------------------------
    @staticmethod
    def static() -> "PriceModel":
        return PriceModel()

    @staticmethod
    def mean_reverting(discount: float = 0.35, volatility: float = 0.10,
                       reversion: float = 0.05, step_s: float = 300.0,
                       horizon_s: float = 14 * 86400.0,
                       seed: int = 0) -> "MeanRevertingPriceModel":
        return MeanRevertingPriceModel(discount, volatility, reversion,
                                       step_s, horizon_s, seed)

    @staticmethod
    def trace(times_s: Sequence[float],
              multipliers: Sequence[float]) -> "TracePriceModel":
        return TracePriceModel(times_s, multipliers)


class MeanRevertingPriceModel(PriceModel):
    """Ornstein-Uhlenbeck log-price series around ``discount`` × on-demand.

    Each instance type gets an independent seeded path sampled once on a
    fixed ``step_s`` grid; queries step-interpolate (piecewise-constant) and
    hold the last value beyond ``horizon_s``.  Multipliers are clipped to
    [discount/10, 1.0] — AWS caps spot at the on-demand price.
    """

    kind = "mean-reverting"
    is_static = False

    def __init__(self, discount: float, volatility: float, reversion: float,
                 step_s: float, horizon_s: float, seed: int):
        assert 0.0 < discount <= 1.0
        self.discount = float(discount)
        self.volatility = float(volatility)
        self.reversion = float(reversion)
        self.step_s = float(step_s)
        self.horizon_s = float(horizon_s)
        self.seed = int(seed)
        self.mean_multiplier = float(discount)
        self._grids: Dict[int, np.ndarray] = {}  # n_types -> (N, K)

    def _grid(self, n_types: int) -> np.ndarray:
        g = self._grids.get(n_types)
        if g is None:
            rng = np.random.default_rng(self.seed)
            n_steps = int(self.horizon_s / self.step_s) + 1
            mu = np.log(self.discount)
            x = np.empty((n_steps, n_types))
            x[0] = mu
            eps = rng.standard_normal((n_steps - 1, n_types))
            for i in range(1, n_steps):
                x[i] = (x[i - 1] + self.reversion * (mu - x[i - 1])
                        + self.volatility * eps[i - 1])
            g = np.clip(np.exp(x), self.discount / 10.0, 1.0)
            self._grids[n_types] = g
        return g

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        g = self._grid(n_types)
        i = min(int(max(time_s, 0.0) / self.step_s), g.shape[0] - 1)
        return g[i]


class TracePriceModel(PriceModel):
    """Replay a recorded price trace: piecewise-constant multipliers.

    ``multipliers`` is (N,) for a market-wide series or (N, K) per-type.
    """

    kind = "trace"
    is_static = False

    def __init__(self, times_s: Sequence[float], multipliers: Sequence[float]):
        self.times_s = np.asarray(times_s, dtype=np.float64)
        self.multipliers = np.asarray(multipliers, dtype=np.float64)
        assert self.times_s.ndim == 1 and len(self.times_s) > 0
        assert self.multipliers.shape[0] == self.times_s.shape[0]
        assert np.all(np.diff(self.times_s) >= 0), "trace must be time-sorted"
        # per-type long-run mean for (N, K) traces so pressure (and hence the
        # preemption hazard) is unbiased for types whose own mean differs
        # from the market mean
        if self.multipliers.ndim == 2:
            self.mean_multiplier = self.multipliers.mean(axis=0)
        else:
            self.mean_multiplier = float(self.multipliers.mean())

    def multipliers_at(self, n_types: int, time_s: float) -> np.ndarray:
        i = int(np.searchsorted(self.times_s, time_s, side="right")) - 1
        i = max(i, 0)
        m = self.multipliers[i]
        if np.ndim(m) == 0:
            return np.full(n_types, float(m))
        return np.asarray(m)


@dataclasses.dataclass(frozen=True)
class Catalog:
    """Vectorized view over a set of instance types.

    Attributes
    ----------
    capacities : (K, R) float64
    costs      : (K,)   float64 — current prices (== base for static models)
    order_desc : indices of types sorted by descending cost (Algorithm 1 order)
    price_model : optional time-varying price source; ``at(time_s)`` snapshots
    base_costs : on-demand reference prices (None until a snapshot is taken)
    """

    types: tuple
    capacities: np.ndarray
    costs: np.ndarray
    family_ids: np.ndarray
    order_desc: np.ndarray
    price_model: Optional[PriceModel] = None
    base_costs: Optional[np.ndarray] = None

    @staticmethod
    def from_types(types: Sequence[InstanceType],
                   price_model: Optional[PriceModel] = None) -> "Catalog":
        types = tuple(types)
        caps = np.array([t.capacity for t in types], dtype=np.float64)
        costs = np.array([t.hourly_cost for t in types], dtype=np.float64)
        fam = np.array([t.family_id for t in types], dtype=np.int64)
        order = np.argsort(-costs, kind="stable")
        return Catalog(types, caps, costs, fam, order, price_model)

    def __len__(self) -> int:
        return len(self.types)

    def index_of(self, name: str) -> int:
        for i, t in enumerate(self.types):
            if t.name == name:
                return i
        raise KeyError(name)

    # -- time-varying prices ------------------------------------------------
    def with_price_model(self, price_model: Optional[PriceModel]) -> "Catalog":
        return dataclasses.replace(self, price_model=price_model)

    def at(self, time_s: float) -> "Catalog":
        """Snapshot of the catalog priced at ``time_s``.

        Static (or absent) price models return ``self`` unchanged — the
        identity guarantees on-demand code paths stay bit-for-bit intact.
        """
        pm = self.price_model
        if pm is None or pm.is_static:
            return self
        base = self.base_costs if self.base_costs is not None else self.costs
        costs = pm.prices_at(base, time_s)
        order = np.argsort(-costs, kind="stable")
        return dataclasses.replace(self, costs=costs, order_desc=order,
                                   base_costs=base)


def aws_catalog(price_model: Optional[PriceModel] = None) -> Catalog:
    return Catalog.from_types(AWS_CATALOG, price_model)


def table3_catalog() -> Catalog:
    return Catalog.from_types(example_catalog())
