# The paper's primary contribution: Eva's cost-efficient cloud-based cluster
# scheduling — reservation-price provisioning (Algorithm 1), TNRP interference
# awareness, multi-task attribution, and the Full/Partial ensemble criterion.
from .catalog import (AWS_CATALOG, Catalog, CommitmentModel, CreditModel,
                      InstanceType, MarketPriceModel,
                      MeanRevertingPriceModel, PriceModel, Provider, Region,
                      RegionPriceModel, TracePriceModel, TransferMatrix,
                      aws_catalog, burstable_demo_catalog,
                      dispersed_demo_regions, multi_provider_catalog,
                      multi_region_catalog, table3_catalog)
from .cluster_types import (Assignment, ClusterConfig, Job, Task, TaskSet,
                            make_job, make_task)
from .ensemble import EventRateEstimator, choose, mean_time_to_full_reconfig
from .full_reconfig import evaluate_assignments, full_reconfiguration
from .partial_reconfig import (incremental_reconfiguration,
                               partial_reconfiguration)
from .plan import (LiveInstance, Plan, diff_configs, migration_cost,
                   task_move_cost)
from .reservation_price import (cheapest_type, feasibility_matrix, job_rp_sums,
                                regional_reservation_prices,
                                reservation_prices, tnrp)
from .scheduler import EvaScheduler, NoPackingScheduler, SchedulerBase, SchedulerView
from .serving import (RequestProfile, ServiceSpec, UtilityCurve,
                      p99_latency_ms)
from .throughput_table import ThroughputTable
from .workloads import (M_TRUE, NUM_BATCH_WORKLOADS, NUM_WORKLOADS, WORKLOADS,
                        checkpoint_size_gb, true_throughput)

__all__ = [
    "AWS_CATALOG", "Catalog", "CommitmentModel", "CreditModel",
    "InstanceType", "MarketPriceModel", "MeanRevertingPriceModel",
    "PriceModel", "Provider", "Region", "RegionPriceModel",
    "TracePriceModel",
    "TransferMatrix", "aws_catalog", "burstable_demo_catalog",
    "dispersed_demo_regions", "multi_provider_catalog",
    "multi_region_catalog", "table3_catalog",
    "Assignment", "ClusterConfig", "Job", "Task", "TaskSet", "make_job",
    "make_task", "EventRateEstimator", "choose", "mean_time_to_full_reconfig",
    "evaluate_assignments", "full_reconfiguration",
    "incremental_reconfiguration", "partial_reconfiguration",
    "LiveInstance", "Plan", "diff_configs", "migration_cost",
    "task_move_cost", "cheapest_type",
    "feasibility_matrix", "job_rp_sums", "regional_reservation_prices",
    "reservation_prices", "tnrp",
    "EvaScheduler", "NoPackingScheduler", "SchedulerBase", "SchedulerView",
    "RequestProfile", "ServiceSpec", "UtilityCurve", "p99_latency_ms",
    "ThroughputTable", "M_TRUE", "NUM_BATCH_WORKLOADS", "NUM_WORKLOADS",
    "WORKLOADS", "checkpoint_size_gb", "true_throughput",
]
