# Simulated cloud substrate: event-driven cluster simulator + trace generators.
from .simulator import Metrics, SimConfig, Simulator
from .traces import (alibaba_like_trace, burstable_trace, deferrable_trace,
                     physical_trace, portfolio_trace, serving_trace)

__all__ = ["Metrics", "SimConfig", "Simulator", "alibaba_like_trace",
           "burstable_trace", "deferrable_trace", "physical_trace",
           "portfolio_trace", "serving_trace"]
