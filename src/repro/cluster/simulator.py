"""High-fidelity event-driven simulator of a cloud-based cluster (paper §5).

Public API: ``Simulator(catalog, jobs, scheduler, SimConfig).run() ->
Metrics``.  The scheduler under test operates exactly as in a real
deployment: it sees only task demands, live placements and observed
throughputs (through the ThroughputMonitor hooks) and returns abstract
cluster configurations (docs/ARCHITECTURE.md walks through the full
scheduling-round data flow).  The simulated cloud models:

* instance acquisition + setup delays (Table 1; acquisition ~ 6+Exp(13) s
  clipped to [6, 83] (mean ≈ 19 s), setup ~ U[140, 251] s),
* per-workload checkpoint / launch migration delays (Table 7),
* co-location interference from the hidden ground-truth pairwise matrix
  (Figure 1 model) — tasks progress at the product of pairwise throughputs,
* data-parallel multi-task jobs progressing at the slowest task's rate,
* per-second billing from instance request to termination,
* optional instance failures (spot-style) for fault-tolerance experiments,
* an optional spot market (catalog with a dynamic ``PriceModel``): prices
  drift on a fixed update grid, billing integrates the current price, and
  instances face a per-type preemption hazard that rises with price pressure.
  A revocation arrives as a 2-minute notice (``preemption_notice_s``) visible
  to the scheduler via ``SchedulerView.revoked`` before the instance is
  reclaimed; whatever is still on the instance at reclaim time loses at most
  one checkpoint period of progress (same machinery as failures),
* an optional multi-region market (``core.catalog.multi_region_catalog``):
  billing is region-scoped (``Metrics.cost_by_region``), preemption hazards
  are region-correlated (every type shares its region's price pressure ×
  ``Region.hazard_scale``), a cross-region migration pays the checkpoint
  transfer time on top of the Table-7 checkpoint delay plus an egress fee
  billed exactly once per move (restoring a checkpoint stranded in another
  region after a reclaim/failure pays the same charge), and per-region
  ``max_instances`` capacity is enforced by denying launches into full
  regions (the tasks stay put / pending and are repacked next round),
* optional commitment pools (``core.catalog.multi_provider_catalog``):
  each pool region bills its discounted rate for every slot every hour —
  used or idle — as a standing bill integrated in ``_accrue`` (exactly
  once per pool-hour), while pool *instances* bill zero marginal; overflow
  rides the provider's market region at spot/on-demand prices.  Per-pool
  utilization/idle-waste integrals and per-provider ledgers
  (``Metrics.cost_by_provider``) account every dollar; the per-region
  launch caps bound pools, and a ``commitment_orders`` attribute on the
  scheduler (polled after every round, like ``admission``) grows pools
  monotonically mid-run — the inventory decision layered over the
  per-round RP decision,

* optional burstable instance types (catalog types carrying a
  ``core.catalog.CreditModel``): each burstable instance tracks a credit
  balance in full-speed hours — drained at ``duty − accrual`` per busy hour
  (``duty`` = the busiest resident RUNNING task's ``burst_duty``), accrued
  at ``accrual_per_hour`` while idle, capped.  When a busy instance's
  balance hits zero (a deterministic ``CREDIT_EXHAUST`` event — no RNG) it
  is *throttled*: every resident task progresses at ``baseline_fraction`` ×
  its interference-adjusted rate while billing continues at the unchanged
  hourly price — cost stays flat while throughput collapses, the asymmetry
  the credit-aware scheduler prices in.  Exhaustion is surfaced to the
  scheduler as a credit-pressure signal (``on_credit_pressure`` + an
  immediate extra round, mirroring spot revocation notices) and per-round
  via ``SchedulerView.instance_credits`` / ``SchedulerView.throttled``.
  Throughput observations from throttled instances are withheld from the
  monitor callbacks (credit state is cloud-visible à la CloudWatch, so the
  monitor can and does discard throttle-confounded samples instead of
  polluting the co-location interference table).  The executor never
  matches a *fresh* (zero-overlap) slot onto a throttled instance — asking
  for a new instance of a burstable type buys a new instance with launch
  credits, not someone's exhausted one.

* optional deferrable jobs (``Job.deferrable`` / ``Job.deadline_s``, the
  price-pressure autoscaling axis): an arrived job whose tasks a scheduler
  declines to place stays in a *pending* (not-admitted) state — zero
  billing, idle time accruing — until a config first assigns its tasks
  (the ARRIVE→PENDING→ADMIT transition, recorded per job).  The view
  surfaces ``SchedulerView.deferrable`` / ``deadline_s`` / ``pending``
  each round; a deterministic ``DEFER_DEADLINE`` event fires at each
  deferrable job's latest-start time (``repro.autoscale.latest_start_s``
  on its true duration) and — if the job is still pending — signals
  ``on_deadline_pressure`` plus an immediate extra round, the same
  pressure wiring spot notices and credit exhaustion use.  A scheduler
  re-deferring an admitted-but-unstarted job simply omits its tasks from
  the config: the executor *withdraws* the not-yet-launched placements
  (WAITING tasks only; launching/running tasks are never withdrawn).
  ``Metrics.deadline_misses`` / ``deferred_jobs`` / ``deferred_wait_s`` /
  ``withdrawals`` account for the axis.

* optional service jobs (``Job.service`` carrying a
  ``core.serving.ServiceSpec``, the online-serving axis): a service job is
  a fleet of interchangeable inference replicas running a fixed wall-clock
  window.  Its request load is a piecewise-constant profile (a
  deterministic ``RATE_UPDATE`` event fires at every breakpoint, so accrual
  segments never span a rate change); effective capacity is
  ``per_replica_rps`` × Σ replica throughputs (interference and credit
  throttling degrade serving exactly like batch iteration rates); each
  constant-rate segment bills ``λ·dt`` requests at the M/M/1-style p99
  ``base/(1 − λ/capacity)`` against the job's utility curve
  (``Metrics.slo_attainment`` / ``service_utility``).  When a job crosses
  into *utility risk* — load within the risk margin of its SLO-feasible
  utilization ceiling, or capacity short of load — an ``slo`` pressure
  signal fires on the rising edge through the shared wiring, and the view
  surfaces ``service`` / ``service_rps`` / ``service_capacity`` /
  ``slo_risk`` each round.

Every scheduler-visible pressure event — spot revocation notices, credit
exhaustion, deferral latest-start deadlines, serving utility risk —
travels one shared wiring: a ``PressureSignal`` published on the
simulator's ``PressureBus`` (``repro.policies.pressure``; delivered to
``scheduler.on_pressure`` exactly once) followed by an immediate extra
scheduling round, de-duplicated so coincident signals react in a single
round.

The spot, multi-region, credit, deferral and serving layers are strictly
additive: with a static (or absent) price model, a single-region catalog,
no burstable types, no deferrable/deadlined jobs and no service jobs no
extra events are scheduled and no extra RNG draws occur, so on-demand runs
are bit-for-bit identical to the seed simulator.  (The credit, deferral
and serving layers draw no randomness at all — each is a pure function of
the event trajectory.)

Progress accounting is lazy: every state change accrues Δt into cost /
allocation / idle-time integrals and re-projects job-completion events
(versioned to invalidate stale projections).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..autoscale.admission import latest_start_s
from ..core.catalog import Catalog, FAMILIES
from ..core.cluster_types import ClusterConfig, Job, TaskSet
from ..core.plan import LiveInstance, diff_configs
from ..core.scheduler import SchedulerBase, SchedulerView
from ..core.serving import p99_latency_ms_np, utility_np
from ..core.workloads import M_TRUE, WORKLOADS, checkpoint_size_gb
from ..obs import events as obs_ev
from ..policies.pressure import (CREDIT, DEADLINE, SLO, SPOT, PressureBus,
                                 PressureSignal)
from .fleet import SlotTable

# task states
PENDING, WAITING, CKPT, LAUNCH, RUNNING = range(5)


class _Col:
    """Descriptor for an entity attribute backed by a private slot and —
    while the entity is registered in a :class:`~repro.cluster.fleet.
    SlotTable` (vectorized mode) — by that table's column.

    ``through=True`` (accrual-integrated columns): sweeps advance the
    array only, so reads go through the table while registered and fall
    back to the private slot after deregistration (the table's ``remove``
    hands the final value back).  ``through=False`` (event-written
    columns): the private copy is always current, so reads stay cheap and
    writes mirror into the table for the sweeps to consume.
    """

    __slots__ = ("attr", "table_attr", "col", "through", "boolean")

    def __init__(self, attr: str, table_attr: str, col: str,
                 through: bool = True, boolean: bool = False):
        self.attr = attr
        self.table_attr = table_attr
        self.col = col
        self.through = through
        self.boolean = boolean

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        if self.through:
            t = getattr(obj, self.table_attr)
            if t is not None:
                return float(t.f[self.col][t.slot[obj._eid]])
        return getattr(obj, self.attr)

    def __set__(self, obj, v):
        setattr(obj, self.attr, v)
        t = getattr(obj, self.table_attr)
        if t is not None:
            cols = t.b if self.boolean else t.f
            cols[self.col][t.slot[obj._eid]] = v


@dataclasses.dataclass
class SimConfig:
    round_interval_s: float = 300.0
    migration_delay_scale: float = 1.0
    # override ground-truth interference: None -> M_TRUE; float x -> uniform
    # pairwise matrix with all off-diagonal entries x (Fig. 4 sweeps)
    uniform_interference: Optional[float] = None
    failure_mtbf_hours: float = 0.0  # 0 = no failures
    checkpoint_period_s: float = 600.0  # progress-loss bound on failure
    seed: int = 0
    max_time_s: float = 1e9
    # --- spot market (active only when the catalog has a dynamic PriceModel)
    price_update_interval_s: float = 300.0
    preemption_notice_s: float = 120.0  # revocation notice before reclaim
    preemption_hazard_per_hour: float = 0.0  # per-instance baseline; 0 = off


@dataclasses.dataclass
class _TaskState:
    task: object
    job_id: int
    workload: int
    state: int = PENDING
    src: Optional[int] = None  # instance where physically resident
    dst: Optional[int] = None  # instance assigned by the scheduler
    epoch: int = 0  # bumps invalidate in-flight ckpt/launch events
    migrations: int = 0
    placed_once: bool = False
    # multi-region: region where the durable checkpoint lives (for pricing a
    # cross-region restore after a reclaim/failure), and any pending restore
    # transfer time to add to the next launch
    ckpt_region: Optional[int] = None
    restore_transfer_s: float = 0.0


class _JobState:
    """Mutable per-job simulation state.

    The accrual-integrated accumulators (progress, idle/running time,
    served-request integrals) are :class:`_Col` attributes: in vectorized
    mode they live in the simulator's SoA job/service tables while the job
    is active, so sweeps advance whole columns at once and every reader —
    including tests inspecting ``js.iters_done`` mid-run — still sees
    current values.  In scalar mode (or once deregistered) they are plain
    attributes.
    """

    __slots__ = ("job", "version", "done_t", "arrived", "admitted_t",
                 "svc_risk", "svc_seg", "svc_times", "svc_rps",
                 "_rate", "_iters", "_idle", "_run_s", "_tputw",
                 "_svc_cap", "_svc_lam", "_req", "_ok", "_util",
                 "_jt", "_st", "_eid")

    # accrual-integrated: sweeps write the array, reads go through it
    iters_done = _Col("_iters", "_jt", "iters")
    idle_s = _Col("_idle", "_jt", "idle")
    running_s = _Col("_run_s", "_jt", "run_s")
    tput_weighted = _Col("_tputw", "_jt", "tputw")  # ∫ tput dt while running
    req_total = _Col("_req", "_st", "req")
    req_ok = _Col("_ok", "_st", "ok")
    util_integral = _Col("_util", "_st", "util")  # ∫ utility(p99) · λ dt
    # event-written: private copy always current, writes mirror to the table
    rate = _Col("_rate", "_jt", "rate", through=False)
    svc_capacity = _Col("_svc_cap", "_st", "cap", through=False)
    svc_lam = _Col("_svc_lam", "_st", "lam", through=False)

    def __init__(self, job: Job, arrived: bool = False):
        self.job = job
        self._eid = job.job_id
        self.version = 0
        self.done_t: Optional[float] = None
        self.arrived = arrived
        # deferral scenarios: instant a config first assigned this job's
        # tasks (the PENDING→ADMIT transition); None again if withdrawn
        self.admitted_t: Optional[float] = None
        # serving scenarios (jobs carrying a ServiceSpec): utility-risk
        # latch (SLO pressure fires on its rising edge), request-profile
        # segment cursor over the cached breakpoint arrays, current
        # effective fleet capacity / request rate, served-request integrals
        self.svc_risk = False
        self.svc_seg = -1
        self.svc_times: Optional[list] = None
        self.svc_rps: Optional[list] = None
        self._rate = 0.0
        self._iters = 0.0
        self._idle = 0.0
        self._run_s = 0.0
        self._tputw = 0.0
        self._svc_cap = 0.0
        self._svc_lam = 0.0
        self._req = 0.0
        self._ok = 0.0
        self._util = 0.0
        self._jt: Optional[SlotTable] = None
        self._st: Optional[SlotTable] = None


class _Instance:
    """Mutable per-instance simulation state; the burstable-credit balance
    is a :class:`_Col` backed by the simulator's credit table while the
    instance is alive in vectorized mode (see :class:`_JobState`)."""

    __slots__ = ("iid", "type_index", "request_t", "ready_t", "ready",
                 "terminated_t", "draining", "preempt_deadline", "assigned",
                 "residents", "alloc", "credit_seq",
                 "_credit", "_throttled", "_ct", "_eid")

    # burstable-credit state (types carrying a CreditModel only; the balance
    # is integrated lazily in _accrue, so it is current as of _last_accrue)
    credit_hours = _Col("_credit", "_ct", "bal")  # balance, full-speed hours
    # busy at zero balance -> baseline speed
    throttled = _Col("_throttled", "_ct", "throttled",
                     through=False, boolean=True)

    def __init__(self, iid: int, type_index: int,
                 request_t: float, ready_t: float):
        self.iid = iid
        self._eid = iid
        self.type_index = type_index
        self.request_t = request_t
        self.ready_t = ready_t
        self.ready = False
        self.terminated_t: Optional[float] = None
        self.draining = False
        self.preempt_deadline: Optional[float] = None  # revocation notice
        self.assigned: Set[int] = set()
        self.residents: Set[int] = set()  # outbound ckpt
        # running total of assigned tasks' demand on this instance's family,
        # maintained by Simulator._assign_task/_unassign_task so per-accrual
        # allocation accounting is O(alive instances), not O(alive tasks).
        # Demands are integer-valued, so incremental updates are float-exact.
        self.alloc = np.zeros(3)
        self._credit = 0.0
        self._throttled = False
        self.credit_seq = 0  # bumps invalidate in-flight CREDIT_EXHAUST
        self._ct: Optional[SlotTable] = None

    @property
    def alive(self) -> bool:
        return self.terminated_t is None


@dataclasses.dataclass
class Metrics:
    total_cost: float = 0.0
    instances_launched: int = 0
    migrations: int = 0
    n_tasks: int = 0
    n_jobs: int = 0
    jct_sum: float = 0.0
    idle_sum: float = 0.0
    running_sum: float = 0.0
    tput_weighted_sum: float = 0.0
    alloc_integral: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(3))
    cap_integral: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(3))
    ninst_integral: float = 0.0
    ntask_integral: float = 0.0
    failures: int = 0
    preemption_notices: int = 0
    preemptions: int = 0
    end_time: float = 0.0
    # multi-region accounting.  The ledgers are *always present* (empty
    # dicts on single-region runs, never None) and summary() gating is the
    # explicit has_regions flag — not dict truthiness, which conflated
    # "single-region run" with "multi-region run that spent nothing".
    has_regions: bool = False
    egress_cost: float = 0.0
    cross_region_migrations: int = 0
    capacity_denied: int = 0
    cost_by_region: Dict[str, float] = dataclasses.field(default_factory=dict)
    # provider/commitment accounting (multi-provider catalogs only; same
    # always-present, explicitly-gated contract as the region ledger)
    has_providers: bool = False
    cost_by_provider: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    has_commitments: bool = False
    commitment_cost: float = 0.0  # Σ standing pool bills (used or idle)
    commitment_idle_cost: float = 0.0  # unused pool-hours × discounted rate
    commitment_utilization: Dict[str, float] = dataclasses.field(
        default_factory=dict)  # pool region -> covered / capacity ∈ [0, 1]
    commitment_resizes: int = 0  # inventory-pass pool growths applied
    # burstable-credit accounting (populated only for burstable catalogs)
    has_credits: bool = False
    credit_exhaustions: int = 0
    throttled_s: float = 0.0  # Σ instance-seconds spent throttled
    # deferral accounting (populated only when some job is deferrable or
    # carries a deadline)
    has_deadlines: bool = False
    deadline_misses: int = 0
    deferred_jobs: int = 0  # admitted later than their first possible round
    deferred_wait_s: float = 0.0  # Σ arrival→admission wait, deferrable jobs
    withdrawals: int = 0  # re-deferred placements released before launch
    max_pending_jobs: int = 0  # peak not-yet-admitted deferrable queue length
    # serving accounting (populated only when some job carries a ServiceSpec)
    has_service: bool = False
    slo_requests_total: float = 0.0  # ∫ λ dt over service jobs
    slo_requests_ok: float = 0.0  # requests served with p99 ≤ target
    service_utility_sum: float = 0.0  # ∫ utility(p99) · λ dt
    slo_pressure_signals: int = 0  # utility-risk rising edges
    # flight-recorder event log (repro.obs.events.EventLog), set only when a
    # FlightRecorder was attached to the run; never enters summary()
    events: Optional[object] = None

    @property
    def slo_attainment(self) -> float:
        """Request-weighted fraction served with p99 at/below target."""
        return self.slo_requests_ok / max(self.slo_requests_total, 1e-9)

    @property
    def service_utility(self) -> float:
        """Request-weighted mean utility (1.0 = every request at full
        utility)."""
        return self.service_utility_sum / max(self.slo_requests_total, 1e-9)

    @property
    def avg_jct_hours(self) -> float:
        return self.jct_sum / max(self.n_jobs, 1) / 3600.0

    @property
    def avg_idle_hours(self) -> float:
        return self.idle_sum / max(self.n_jobs, 1) / 3600.0

    @property
    def norm_job_tput(self) -> float:
        return self.tput_weighted_sum / max(self.running_sum, 1e-9)

    @property
    def tasks_per_instance(self) -> float:
        return self.ntask_integral / max(self.ninst_integral, 1e-9)

    @property
    def migrations_per_task(self) -> float:
        return self.migrations / max(self.n_tasks, 1)

    def resource_allocation(self) -> Dict[str, float]:
        out = {}
        for i, r in enumerate(("gpu", "cpu", "ram")):
            out[r] = float(self.alloc_integral[i] / max(self.cap_integral[i], 1e-9))
        return out

    def summary(self) -> Dict[str, float]:
        d = {"total_cost": round(self.total_cost, 2),
             "avg_jct_hours": round(self.avg_jct_hours, 3),
             "avg_idle_hours": round(self.avg_idle_hours, 4),
             "norm_job_tput": round(self.norm_job_tput, 4),
             "tasks_per_instance": round(self.tasks_per_instance, 3),
             "migrations_per_task": round(self.migrations_per_task, 3),
             "instances_launched": self.instances_launched,
             "failures": self.failures,
             "preemptions": self.preemptions}
        d.update({f"alloc_{k}": round(v, 4)
                  for k, v in self.resource_allocation().items()})
        if self.has_regions:  # multi-region runs only
            d["egress_cost"] = round(self.egress_cost, 2)
            d["cross_region_migrations"] = self.cross_region_migrations
            d["capacity_denied"] = self.capacity_denied
            d.update({f"cost_{name}": round(v, 2)
                      for name, v in sorted(self.cost_by_region.items())})
        if self.has_providers:  # multi-provider runs only
            d.update({f"cost_provider_{name}": round(v, 2)
                      for name, v in sorted(self.cost_by_provider.items())})
        if self.has_commitments:  # commitment-pool runs only
            d["commitment_cost"] = round(self.commitment_cost, 2)
            d["commitment_idle_cost"] = round(self.commitment_idle_cost, 2)
            d["commitment_resizes"] = self.commitment_resizes
            d.update({f"util_{name}": round(v, 4) for name, v
                      in sorted(self.commitment_utilization.items())})
        if self.has_credits:  # burstable runs only
            d["credit_exhaustions"] = self.credit_exhaustions
            d["throttled_hours"] = round(self.throttled_s / 3600.0, 2)
        if self.has_deadlines:  # deferral/autoscale runs only
            d["deadline_misses"] = self.deadline_misses
            d["deferred_jobs"] = self.deferred_jobs
            d["deferred_wait_hours"] = round(self.deferred_wait_s / 3600.0, 2)
            d["withdrawals"] = self.withdrawals
            d["max_pending_jobs"] = self.max_pending_jobs
        if self.has_service:  # serving runs only
            d["slo_attainment"] = round(self.slo_attainment, 4)
            d["service_utility"] = round(self.service_utility, 4)
            d["served_requests"] = round(self.slo_requests_total)
            d["slo_signals"] = self.slo_pressure_signals
        return d


# event kinds (ordering within same timestamp: arrivals & completions before
# rounds so the round sees fresh state; price updates, preemption reclaims,
# credit exhaustions, deferral deadlines and serving rate updates also
# precede rounds so the scheduler reacts to current prices, notices,
# throttle state, latest-start signals and request load)
(ARRIVAL, INSTANCE_READY, CKPT_DONE, LAUNCH_DONE, JOB_DONE, FAILURE,
 PRICE_UPDATE, PREEMPT_FIRE, CREDIT_EXHAUST, DEFER_DEADLINE, RATE_UPDATE,
 ROUND) = range(12)

# Event kinds whose coincident bursts collapse into one accrual sweep in
# run(): their handlers never pop events themselves, never rebind the heap,
# and only push same-timestamp events of later-sorting kinds (ROUND) or
# strictly-future events — so handling the whole burst after a single
# _accrue is observably identical to the one-pop-one-accrue reference
# (the in-between accruals were dt=0 no-ops).  JOB_DONE is deliberately
# excluded: its handler can filter + re-heapify the event heap.
_COALESCE = frozenset((ARRIVAL, PRICE_UPDATE, RATE_UPDATE, DEFER_DEADLINE))


class Simulator:
    def __init__(self, catalog: Catalog, jobs: Sequence[Job],
                 scheduler: SchedulerBase, cfg: Optional[SimConfig] = None,
                 recorder=None, vectorized: bool = True):
        self.catalog = catalog
        # Vectorized accrual core (docs/ARCHITECTURE.md, "The simulator at
        # fleet scale").  vectorized=False keeps the original per-entity
        # scalar sweeps as the pinned reference: summaries agree exactly on
        # counters and within 1e-9 relative on reassociated float sums.
        self._vec = bool(vectorized)
        self.scheduler = scheduler
        self.cfg = cfg or SimConfig()
        # Flight recorder (repro.obs.FlightRecorder) — a pure observer: every
        # emission below is gated on self._ev, so recorder-less runs execute
        # the identical instruction stream (pinned by tests/test_obs.py).
        self._rec = recorder
        self._ev = None if recorder is None else recorder.events
        self._round_index = 0
        self.rng = np.random.default_rng(self.cfg.seed)
        self.jobs: Dict[int, _JobState] = {}
        self.tasks: Dict[int, _TaskState] = {}
        self.instances: Dict[int, _Instance] = {}
        # fleet-scale indices: the alive (insertion-ordered, so sweeps stay
        # bit-identical to filtering self.instances) and not-yet-done
        # subsets, plus per-region alive counts — long traces accumulate
        # dead instances/jobs and the per-event sweeps were O(history)
        self._alive: Dict[int, _Instance] = {}
        self._active_jobs: Dict[int, _JobState] = {}
        self._iid = itertools.count()
        self._seq = itertools.count()
        self._heap: List[Tuple[float, int, int, int, tuple]] = []
        self._seeding = True  # __init__ batches pushes, then heapifies once
        self._round_scheduled_at: float = -1.0
        self._pressure_round_at: float = -1.0  # immediate-round de-dup
        # One bus for every pressure wiring (spot / credit / deadline); the
        # scheduler's on_pressure fans the signal out to its policy stack
        # and the legacy per-kind hooks.
        self.pressure_bus = PressureBus()
        self.pressure_bus.subscribe(scheduler.on_pressure)
        self.now = 0.0
        self._last_accrue = 0.0
        self.metrics = Metrics()
        if self._ev is not None:
            self.metrics.events = self._ev
        if self.cfg.uniform_interference is not None:
            x = float(self.cfg.uniform_interference)
            self._m = np.full_like(M_TRUE, x)
            np.fill_diagonal(self._m, 1.0)
        else:
            self._m = M_TRUE
        # Spot market: active only with a dynamic price model on the catalog.
        # All spot randomness comes from a dedicated stream so the main RNG's
        # draw sequence (acquisition/setup/failures) is untouched.
        pm = catalog.price_model
        self._spot = pm is not None and not pm.is_static
        self._jobs_outstanding = len(jobs)
        # Multi-region: region-scoped billing, cross-region migration costs,
        # per-region capacity.  All gated on catalog.regions so single-region
        # runs take none of these paths.
        self._regions = catalog.regions
        if self._regions is not None:
            self._region_ids = catalog.region_ids
            self._region_name_of_type = [self._regions[r].name
                                         for r in self._region_ids.tolist()]
            self._provider_of_type = [self._regions[r].provider
                                      for r in self._region_ids.tolist()]
            self.metrics.has_regions = True
            self.metrics.cost_by_region = {r.name: 0.0 for r in self._regions}
            # mutable per-region launch limits: commitment re-sizes grow
            # pool caps at runtime (frozen Region.max_instances is only the
            # initial value)
            self._region_limits = [r.max_instances for r in self._regions]
            providers = [r.provider for r in self._regions]
            if any(p is not None for p in providers):
                self.metrics.has_providers = True
                self.metrics.cost_by_provider = {
                    p: 0.0 for p in dict.fromkeys(providers)
                    if p is not None}
        # Commitment pools: each pool region bills its discounted rate for
        # every slot every hour (standing bill, integrated in _accrue) while
        # its instances bill zero marginal — the pool-hour is paid exactly
        # once.  All paths gated on self._commit so commitment-free catalogs
        # are bit-for-bit untouched.
        self._pools = catalog.commitment_pools() \
            if self._regions is not None else ()
        self._commit = bool(self._pools)
        if self._commit:
            self.metrics.has_commitments = True
            self._pool_type = catalog.commitment_type_mask()
            self._pool_size: Dict[int, int] = {}
            self._pool_rate: Dict[int, float] = {}
            self._pool_covered_s: Dict[int, float] = {}
            self._pool_capacity_s: Dict[int, float] = {}
            for ri, cm in self._pools:
                ks = np.nonzero(catalog.region_ids == ri)[0]
                assert ks.size == 1, \
                    "a commitment pool region holds exactly one type"
                self._pool_size[ri] = int(cm.pool_size)
                self._pool_rate[ri] = float(catalog.costs[int(ks[0])])
                self._pool_covered_s[ri] = 0.0
                self._pool_capacity_s[ri] = 0.0
        # Burstable credits: active only when some catalog type carries a
        # CreditModel.  Deterministic (no RNG); all paths gated on
        # self._credits so other catalogs are bit-for-bit untouched.
        self._credit_models = catalog.credit_models
        self._credits = self._credit_models is not None
        if self._credits:
            self.metrics.has_credits = True
        # Deferrable jobs (price-pressure autoscaling): active only when the
        # trace carries deferrable or deadlined jobs.  Deterministic (no
        # RNG); all paths gated on self._deferrals so other traces are
        # bit-for-bit untouched.  Each deferrable deadlined job gets a
        # DEFER_DEADLINE event at its latest-start time — if still pending
        # then, the deadline-pressure signal fires (callback + immediate
        # round) so the admission bound is honoured between rounds.
        self._deferrals = any(j.deferrable or j.deadline_s is not None
                              for j in jobs)
        if self._deferrals:
            self.metrics.has_deadlines = True
            # the backstop must agree with the live controller's bound, so
            # read its (possibly customized) margin/overhead when present
            ctl = getattr(scheduler, "admission", None)
            ls_kw = {} if ctl is None else dict(
                margin=ctl.margin, overhead_s=ctl.overhead_s)
            for job in jobs:
                if job.deferrable and job.deadline_s is not None:
                    t = max(latest_start_s(job.deadline_s, job.duration_s,
                                           **ls_kw),
                            job.arrival_time)
                    if t <= self.cfg.max_time_s:
                        self._push(t, DEFER_DEADLINE, (job.job_id,))
        # Serving axis: active only when some job carries a ServiceSpec.
        # Deterministic (no RNG); all paths gated on self._serving so batch
        # traces are bit-for-bit untouched.  Each service job gets a
        # RATE_UPDATE event at every request-profile breakpoint inside its
        # window, so accrual segments never span a rate change and utility
        # risk is re-evaluated the instant load shifts.
        self._serving = any(j.service is not None for j in jobs)
        if self._serving:
            self.metrics.has_service = True
            # per-profile breakpoint arrays, materialized once: _svc_rate
            # advances a per-job cursor over these lists instead of
            # re-searching the piecewise representation on every accrual
            # segment (profiles are shared across jobs, hence keyed by id)
            self._profile_segs: Dict[int, Tuple[list, list]] = {}
            for job in jobs:
                if job.service is None:
                    continue
                prof = job.service.requests
                if id(prof) not in self._profile_segs:
                    t_arr, r_arr = prof.segments()
                    self._profile_segs[id(prof)] = (t_arr.tolist(),
                                                    r_arr.tolist())
                end = min(job.arrival_time + job.duration_s,
                          self.cfg.max_time_s)
                for t in prof.breakpoints_between(job.arrival_time, end):
                    self._push(float(t), RATE_UPDATE, (job.job_id,))
        # SoA fleet state for vectorized sweeps: per-type alive counts and
        # fleet-wide allocation totals (einsum inputs), plus swap-remove
        # tables holding the accrual-integrated columns of live entities.
        # Maintained unconditionally cheap at the event handlers; consumed
        # only by _accrue_vec.
        if self._vec:
            self._type_alive = np.zeros(len(catalog), dtype=np.int64)
            self._alloc_total = np.zeros(3)
            self._assigned_total = 0
            self._jtab = SlotTable(("rate", "iters", "idle", "run_s",
                                    "tputw"))
            self._ctab = SlotTable(("bal", "net", "cap_h"),
                                   ("throttled",)) if self._credits else None
            self._stab = SlotTable(("lam", "cap", "base_ms", "target_ms",
                                    "soft_ms", "floor", "req", "ok",
                                    "util")) if self._serving else None
        if self._spot:
            self._spot_rng = np.random.default_rng(self.cfg.seed + 0x5B07)
            self._cur_costs = pm.prices_at(catalog.costs, 0.0)
            self._last_price_update = 0.0
            # never sample coarser than the model's own grid (an OU model
            # with step_s below the configured interval would otherwise be
            # billed with prices up to one interval stale)
            self._price_interval = min(self.cfg.price_update_interval_s,
                                       getattr(pm, "step_s",
                                               self.cfg.price_update_interval_s))
            self._push(self._price_interval, PRICE_UPDATE, (True,))
            # trace models change price at their own breakpoints; bill those
            # exactly instead of lagging up to one update interval
            for t in np.asarray(getattr(pm, "times_s", ()), dtype=np.float64):
                if 0.0 < t <= self.cfg.max_time_s:
                    self._push(float(t), PRICE_UPDATE, (False,))
        for job in jobs:
            self._push(job.arrival_time, ARRIVAL, (job,))
        self.metrics.n_jobs = len(jobs)
        self.metrics.n_tasks = sum(j.n_tasks for j in jobs)
        if self._regions is not None:
            self._region_alive = [0] * len(self._regions)
        # one heapify over the seeded events instead of per-event pushes;
        # pop order is unchanged (the unique seq makes ordering total)
        heapq.heapify(self._heap)
        self._seeding = False

    # ------------------------------------------------------------------ util
    def _push(self, t: float, kind: int, payload: tuple):
        entry = (t, kind, next(self._seq), payload)
        if self._seeding:
            self._heap.append(entry)
        else:
            heapq.heappush(self._heap, entry)

    def _live_instances(self) -> List[_Instance]:
        return [i for i in self._alive.values() if not i.draining]

    def _task_demand(self, inst: _Instance, tid: int) -> np.ndarray:
        fam = FAMILIES[self.catalog.types[inst.type_index].family_id]
        return np.array(self.tasks[tid].task.demand_for_family(fam))

    def _assign_task(self, inst: _Instance, tid: int) -> None:
        if tid not in inst.assigned:
            inst.assigned.add(tid)
            d = self._task_demand(inst, tid)
            inst.alloc += d
            if self._vec and inst.alive:
                self._assigned_total += 1
                self._alloc_total += d

    def _unassign_task(self, inst: _Instance, tid: int) -> None:
        if tid in inst.assigned:
            inst.assigned.discard(tid)
            d = self._task_demand(inst, tid)
            inst.alloc -= d
            if self._vec and inst.alive:
                self._assigned_total -= 1
                self._alloc_total -= d

    # ------------------------------------------------------------ accounting
    def _bill_type(self, amt: float, k: int,
                   category: str = obs_ev.COST_INSTANCE) -> None:
        """Bill ``amt`` attributed to instance type ``k`` on every ledger
        (total, per-region, per-provider; plus the flight recorder's
        per-(category, key) cost ledger when one is attached)."""
        m = self.metrics
        m.total_cost += amt
        if self._regions is not None:
            m.cost_by_region[self._region_name_of_type[k]] += amt
            p = self._provider_of_type[k]
            if p is not None:
                m.cost_by_provider[p] += amt
        if self._ev is not None:
            key = (self._region_name_of_type[k] if self._regions is not None
                   else self.catalog.types[k].name)
            self._ev.record_cost(category, key, amt)

    def _bill_region(self, amt: float, ri: int,
                     category: str = obs_ev.COST_INSTANCE) -> None:
        """Bill ``amt`` attributed to region ``ri`` on every ledger."""
        m = self.metrics
        m.total_cost += amt
        m.cost_by_region[self._regions[ri].name] += amt
        p = self._regions[ri].provider
        if p is not None:
            m.cost_by_provider[p] += amt
        if self._ev is not None:
            self._ev.record_cost(category, self._regions[ri].name, amt)

    def _accrue(self, now: float):
        dt = now - self._last_accrue
        if dt <= 0:
            self._last_accrue = now
            return
        if self._vec:
            self._accrue_vec(dt)
        else:
            self._accrue_scalar(dt)
        self._last_accrue = now

    def _accrue_scalar(self, dt: float) -> None:
        """Reference accrual sweep: a Python loop over live entities.

        This is the pinned semantics the vectorized sweep must reproduce;
        the hot loops touch the private slots directly (``js._iters`` etc.
        — identical arithmetic, no descriptor dispatch) since in scalar
        mode the tables are absent and the privates are the truth.
        """
        m = self.metrics
        for inst in self._alive.values():
            m.ninst_integral += dt
            m.ntask_integral += len(inst.assigned) * dt
            m.cap_integral += self.catalog.capacities[inst.type_index] * dt
            m.alloc_integral += inst.alloc * dt
            if self._credits:  # integrate the credit balance (billing is NOT
                self._credit_integrate(inst, dt)  # touched: cost stays flat)
                if inst._throttled:
                    m.throttled_s += dt
            if self._spot and not (self._commit
                                   and self._pool_type[inst.type_index]):
                # integrate the piecewise-constant spot price; pool
                # instances bill zero marginal (the standing bill below
                # already paid their slot)
                amt = dt / 3600.0 * self._cur_costs[inst.type_index]
                self._bill_type(amt, inst.type_index)
        if self._commit:
            self._accrue_pools(dt)
        for js in self._active_jobs.values():
            if js._rate > 0:
                js._iters += js._rate * dt
                js._run_s += dt
                js._tputw += js._rate * dt
            else:
                js._idle += dt
            if self._serving and js.job.service is not None:
                # rate is constant on the segment (RATE_UPDATE events sit on
                # every profile breakpoint), so λ at the segment start holds
                self._svc_accrue(js, dt)

    def _accrue_vec(self, dt: float) -> None:
        """One accrual sweep as array programs over the SoA fleet state.

        Equivalent to :meth:`_accrue_scalar` up to float reassociation:
        fleet integrals and spot bills become per-type segment sums
        (count × price instead of repeated ``+=``) and metric totals
        become array reductions, which may drift by ~1 ulp per sweep
        (the documented ≤1e-9 relative tolerance), while credit balances
        and per-job progress advance with the *same elementwise
        arithmetic* as the scalar path and stay bit-identical — so every
        scheduling decision, and hence the event trajectory, matches the
        reference exactly.
        """
        m = self.metrics
        n = len(self._alive)
        if n:
            m.ninst_integral += n * dt
            m.ntask_integral += self._assigned_total * dt
            # per-type capacity integral in one (K,)·(K,3) contraction
            m.cap_integral += (self._type_alive
                               @ self.catalog.capacities) * dt
            m.alloc_integral += self._alloc_total * dt
            if self._credits and self._ctab.n:
                ct = self._ctab
                cn = ct.n
                thr = ct.b["throttled"][:cn]
                n_thr = int(np.count_nonzero(thr))
                if n_thr:
                    m.throttled_s += n_thr * dt
                # same min/max/fma chain as _credit_integrate, elementwise;
                # the `net` column is refreshed by _credit_reproject at
                # every RUNNING-set change, so it is current by invariant
                bal = ct.f["bal"][:cn]
                nb = np.minimum(
                    ct.f["cap_h"][:cn],
                    np.maximum(0.0, bal + ct.f["net"][:cn] * dt / 3600.0))
                np.copyto(bal, nb, where=~thr)
            if self._spot:
                counts = self._type_alive
                if self._commit:
                    counts = np.where(self._pool_type, 0, counts)
                amt = dt / 3600.0 * self._cur_costs
                for k in np.nonzero(counts)[0].tolist():
                    self._bill_type(float(counts[k]) * float(amt[k]), k)
        if self._commit:
            self._accrue_pools(dt)
        jt = self._jtab
        jn = jt.n
        if jn:
            r = jt.f["rate"][:jn]
            run = r > 0.0
            adv = np.where(run, r * dt, 0.0)  # adding +0.0 on idle lanes
            jt.f["iters"][:jn] += adv         # is bit-exact (values >= 0)
            jt.f["tputw"][:jn] += adv
            jt.f["run_s"][:jn] += np.where(run, dt, 0.0)
            jt.f["idle"][:jn] += np.where(run, 0.0, dt)
        if self._serving and self._stab.n:
            self._svc_accrue_vec(dt)

    def _accrue_pools(self, dt: float) -> None:
        """Standing pool bills: every slot, used or idle, exactly once per
        pool-hour — plus the utilization integrals.  Shared verbatim by
        both accrual paths (few pools, so the loop is already O(1)-ish)."""
        m = self.metrics
        hours = dt / 3600.0
        for ri, _cm in self._pools:
            size = self._pool_size[ri]
            amt = hours * size * self._pool_rate[ri]
            m.commitment_cost += amt
            self._bill_region(amt, ri, obs_ev.COST_COMMITMENT)
            self._pool_capacity_s[ri] += dt * size
            self._pool_covered_s[ri] += dt * min(
                self._region_alive[ri], size)

    def _svc_accrue(self, js: _JobState, dt: float) -> None:
        """Bill a constant-rate segment of served requests against the
        job's utility curve at the current capacity headroom.  ``js.
        svc_lam`` is maintained by _touch_service at arrival and at every
        RATE_UPDATE (one sits on each profile breakpoint), so it equals
        ``rate_at`` of the segment start without a search."""
        spec = js.job.service
        lam = js._svc_lam
        if lam <= 0.0:
            return
        lat = spec.p99_ms(lam, js._svc_cap)
        req = lam * dt
        m = self.metrics
        js._req += req
        m.slo_requests_total += req
        if lat <= spec.utility.target_p99_ms + 1e-9:
            js._ok += req
            m.slo_requests_ok += req
        u = spec.utility.utility(lat)
        js._util += u * req
        m.service_utility_sum += u * req

    def _svc_accrue_vec(self, dt: float) -> None:
        """Batched :meth:`_svc_accrue` across the whole service fleet: one
        latency/utility evaluation over the lam/cap columns.  Per-job
        integrals use the identical per-lane arithmetic (bit-exact); only
        the metric totals are array reductions (reassociated sums)."""
        st = self._stab
        sn = st.n
        lam = st.f["lam"][:sn]
        active = lam > 0.0
        if not active.any():
            return
        cap = st.f["cap"][:sn]
        target = st.f["target_ms"][:sn]
        pos = cap > 0.0
        # rho >= 1 on any lane with no capacity -> saturated -> inf latency,
        # matching ServiceSpec.p99_ms's capacity_rps <= 0 branch
        rho = np.where(pos, lam / np.where(pos, cap, 1.0), 2.0)
        lat = p99_latency_ms_np(st.f["base_ms"][:sn], rho)
        req = np.where(active, lam * dt, 0.0)
        ok = np.where(active & (lat <= target + 1e-9), req, 0.0)
        uq = utility_np(lat, target, st.f["soft_ms"][:sn],
                        st.f["floor"][:sn]) * req
        st.f["req"][:sn] += req
        st.f["ok"][:sn] += ok
        st.f["util"][:sn] += uq
        m = self.metrics
        m.slo_requests_total += float(req.sum())
        m.slo_requests_ok += float(ok.sum())
        m.service_utility_sum += float(uq.sum())

    # ----------------------------------------------------------- throughputs
    def _colocated_running(self, tid: int) -> List[int]:
        """Workloads of other RUNNING tasks resident on tid's instance."""
        ts = self.tasks[tid]
        if ts.state != RUNNING or ts.src is None:
            return []
        inst = self.instances[ts.src]
        out = []
        for other in inst.residents:
            if other == tid:
                continue
            if self.tasks[other].state == RUNNING:
                out.append(self.tasks[other].workload)
        return out

    def _task_tput(self, tid: int) -> float:
        ts = self.tasks[tid]
        if ts.state != RUNNING:
            return 0.0
        t = 1.0
        for w2 in self._colocated_running(tid):
            t *= self._m[ts.workload, w2]
        if self._credits and self.instances[ts.src].throttled:
            t *= self._credit_models[
                self.instances[ts.src].type_index].baseline_fraction
        return t

    # ------------------------------------------------------------- credits
    def _instance_duty(self, inst: _Instance) -> float:
        """Busy intensity of an instance: the largest burst duty cycle among
        its RUNNING resident tasks (0 when nothing runs)."""
        duty = 0.0
        for tid in inst.residents:
            if self.tasks[tid].state == RUNNING:
                d = WORKLOADS[self.tasks[tid].workload].burst_duty
                if d > duty:
                    duty = d
        return duty

    def _credit_integrate(self, inst: _Instance, dt: float) -> None:
        """Advance an instance's credit balance by ``dt`` seconds of the
        *current* (pre-event) duty.  Throttled instances stay pinned at
        zero: the accrual is consumed by the baseline itself."""
        cm = self._credit_models[inst.type_index]
        if cm is None or inst._throttled:
            return
        net = cm.accrual_per_hour - self._instance_duty(inst)  # per hour
        inst._credit = min(cm.credit_cap_hours,
                           max(0.0, inst._credit + net * dt / 3600.0))

    def _credit_reproject(self, inst: _Instance) -> None:
        """Recompute throttle state and (re)project the deterministic
        exhaustion event after any change to the instance's RUNNING set."""
        cm = self._credit_models[inst.type_index]
        if cm is None or not inst.alive:
            return
        inst.credit_seq += 1  # invalidate any in-flight projection
        duty = self._instance_duty(inst)
        drain = cm.drain_per_hour(duty)
        if self._vec and inst._ct is not None:
            # refresh the cached net accrual rate the vectorized sweep
            # integrates with; duty only changes when the RUNNING-resident
            # set changes, and every such change lands here
            inst._ct.f["net"][inst._ct.slot[inst.iid]] = \
                cm.accrual_per_hour - duty
        if duty <= 0.0 or drain <= 0.0:
            inst.throttled = False  # idle or sustainable duty: (re)accruing
            return
        if inst.credit_hours <= 1e-9:
            inst.credit_hours = 0.0
            if not inst.throttled:
                inst.throttled = True
                self._on_credit_exhausted(inst)
            return
        inst.throttled = False
        eta = self.now + inst.credit_hours / drain * 3600.0
        self._push(eta, CREDIT_EXHAUST, (inst.iid, inst.credit_seq))

    def _pressure_signal(self, kind: str, ids: Sequence[int]) -> None:
        """Shared forced-reaction wiring for every scheduler-visible
        pressure event — spot revocation notices, credit exhaustion and
        deferral latest-start deadlines: publish one ``PressureSignal`` on
        the bus (delivered to the scheduler exactly once), then fire an
        immediate extra round — unless one is already queued at this
        instant, so coincident signals (e.g. two deferral deadlines at the
        same latest-start time) react in a single round instead of
        double-firing the forced partial."""
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.PRESSURE, signal=kind,
                          ids=tuple(ids))
        self.pressure_bus.publish(PressureSignal(kind, tuple(ids), self.now))
        if (self._round_scheduled_at != self.now
                and self._pressure_round_at != self.now):
            self._pressure_round_at = self.now
            self._push(self.now, ROUND, ())

    def _on_credit_exhausted(self, inst: _Instance) -> None:
        """An instance just throttled: surface the credit-pressure signal."""
        self.metrics.credit_exhaustions += 1
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.CREDIT_THROTTLE,
                          instance_id=inst.iid)
        self._pressure_signal(CREDIT, [inst.iid])

    def _on_credit_exhaust_event(self, iid: int, seq: int) -> None:
        inst = self.instances.get(iid)
        if inst is None or not inst.alive or inst.credit_seq != seq:
            return  # stale projection
        self._touch_instance_jobs(iid)  # reprojects credits + job rates

    def _job_rate(self, jid: int) -> float:
        js = self.jobs[jid]
        rate = math.inf
        for task in js.job.tasks:
            rate = min(rate, self._task_tput(task.task_id))
        return 0.0 if not math.isfinite(rate) else rate

    def _touch_job(self, jid: int):
        """Recompute a job's rate and (re)project its completion event."""
        js = self.jobs.get(jid)
        if js is None or not js.arrived or js.done_t is not None:
            return
        if js.job.service is not None:
            # service jobs end at a fixed wall-clock instant (pushed at
            # arrival), never by progress projection
            self._touch_service(js)
            return
        js.rate = self._job_rate(jid)
        js.version += 1
        if js.rate > 0:
            remaining = js.job.total_iters - js.iters_done
            eta = self.now + max(remaining, 0.0) / js.rate
            self._push(eta, JOB_DONE, (jid, js.version))

    def _svc_rate(self, js: _JobState, t: float) -> float:
        """Request rate at ``t`` via the job's monotone segment cursor over
        the profile's precomputed breakpoint arrays (cached at __init__) —
        O(1) amortized instead of a binary search per call.  Callers only
        move forward in time, matching the simulator clock; values are the
        exact floats ``RequestProfile.rate_at`` would return."""
        times = js.svc_times
        seg = js.svc_seg
        n = len(times)
        while seg + 1 < n and times[seg + 1] <= t:
            seg += 1
        js.svc_seg = seg
        return js.svc_rps[seg] if seg >= 0 else 0.0

    def _touch_service(self, js: _JobState) -> None:
        """Recompute a service job's effective capacity and utility-risk
        state.  SLO pressure fires on the *rising edge* of risk — load
        within the risk margin of the SLO-feasible utilization ceiling, or
        capacity short of load — through the shared pressure wiring."""
        spec = js.job.service
        cap = 0.0
        for task in js.job.tasks:
            cap += self._task_tput(task.task_id)
        cap *= spec.per_replica_rps
        js.svc_capacity = cap
        # normalized fleet capacity stands in for the batch rate, so the
        # shared running/idle/tput accounting stays meaningful for services
        js.rate = cap / max(spec.per_replica_rps * js.job.n_tasks, 1e-9)
        lam = self._svc_rate(js, self.now)
        js.svc_lam = lam  # the segment rate _svc_accrue integrates with
        risk = spec.at_risk(lam, cap)
        if risk and not js.svc_risk:
            js.svc_risk = True
            self.metrics.slo_pressure_signals += 1
            if self._ev is not None:
                self._ev.emit(self.now, obs_ev.SLO_RISK,
                              job_id=js.job.job_id, edge="on",
                              load_rps=lam, capacity_rps=cap)
            self._pressure_signal(SLO, (js.job.job_id,))
        elif not risk:
            if self._ev is not None and js.svc_risk:
                self._ev.emit(self.now, obs_ev.SLO_RISK,
                              job_id=js.job.job_id, edge="off",
                              load_rps=lam, capacity_rps=cap)
            js.svc_risk = False

    def _touch_instance_jobs(self, iid: int):
        inst = self.instances.get(iid)
        if inst is None:
            return
        if self._credits and inst.alive:
            # throttle state first: job rates below depend on it
            self._credit_reproject(inst)
        jids = {self.tasks[t].job_id for t in inst.residents | inst.assigned}
        for j in jids:
            self._touch_job(j)

    # -------------------------------------------------------------- executor
    def _region_has_capacity(self, k: int) -> bool:
        """May a fresh instance of type k launch, or is its region at its
        ``max_instances`` cap?  Counts every alive instance (incl. draining:
        they still bill and occupy regional quota)."""
        if self._regions is None:
            return True
        r = int(self._region_ids[k])
        cap = self._region_limits[r]  # mutable: commitment re-sizes grow it
        if cap is None:
            return True
        return self._region_alive[r] < cap

    def _launch_or_deny(self, k: int) -> Optional[_Instance]:
        if self._region_has_capacity(k):
            return self._new_instance(k)
        self.metrics.capacity_denied += 1
        if self._ev is not None:  # denials only happen on capped regions
            self._ev.emit(self.now, obs_ev.CAPACITY_DENIED,
                          type=self.catalog.types[k].name,
                          region=self._region_name_of_type[k])
        return None  # slot unfilled: its tasks stay put / pending

    def _new_instance(self, k: int) -> _Instance:
        iid = next(self._iid)
        acq = float(np.clip(6.0 + self.rng.exponential(13.0), 6.0, 83.0))
        setup = float(self.rng.uniform(140.0, 251.0))
        inst = _Instance(iid, k, self.now, self.now + acq + setup)
        if self._credits:
            cm = self._credit_models[k]
            if cm is not None:
                inst.credit_hours = cm.effective_launch_hours
                if self._vec:
                    # fresh instance idles (duty 0) until its first launch,
                    # so the cached net rate starts at the full accrual
                    self._ctab.add(iid, bal=inst._credit,
                                   net=cm.accrual_per_hour,
                                   cap_h=cm.credit_cap_hours)
                    inst._ct = self._ctab
        self.instances[iid] = inst
        self._alive[iid] = inst
        if self._vec:
            self._type_alive[k] += 1
        if self._regions is not None:
            self._region_alive[int(self._region_ids[k])] += 1
        self.metrics.instances_launched += 1
        if self._ev is not None:
            kw = {"type": self.catalog.types[k].name,
                  "ready_t": inst.ready_t}
            if self._regions is not None:
                kw["region"] = self._region_name_of_type[k]
            self._ev.emit(self.now, obs_ev.PROVISION, instance_id=iid, **kw)
        self._push(inst.ready_t, INSTANCE_READY, (iid,))
        if self.cfg.failure_mtbf_hours > 0:
            dt = self.rng.exponential(self.cfg.failure_mtbf_hours * 3600.0)
            self._push(self.now + dt, FAILURE, (iid,))
        return inst

    def _terminate(self, inst: _Instance, reason: str = "released"):
        if not inst.alive:
            return
        inst.terminated_t = self.now
        self._alive.pop(inst.iid, None)
        if self._vec:
            self._type_alive[inst.type_index] -= 1
            # terminate does not clear `assigned` (drain bookkeeping still
            # reads it), so subtract the snapshot from the fleet totals here
            self._assigned_total -= len(inst.assigned)
            self._alloc_total -= inst.alloc
            if inst._ct is not None:
                fin = inst._ct.remove(inst.iid)
                inst._ct = None
                inst._credit = fin["bal"]
                inst._throttled = fin["throttled"]
        if self._regions is not None:
            self._region_alive[int(self._region_ids[inst.type_index])] -= 1
        billed = 0.0
        pool = self._commit and self._pool_type[inst.type_index]
        # pool slots bill the standing rate (never per instance); spot
        # billing is integrated in _accrue instead
        if not pool and not self._spot:
            billed = ((self.now - inst.request_t) / 3600.0
                      * self.catalog.costs[inst.type_index])
            self._bill_type(billed, inst.type_index)
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.TERMINATE, instance_id=inst.iid,
                          reason=reason,
                          lifetime_s=self.now - inst.request_t,
                          billed=billed)

    def _maybe_finish_drain(self, inst: _Instance):
        if inst.draining and inst.alive and not inst.residents and not inst.assigned:
            self._terminate(inst, "drained")

    def _start_launch(self, tid: int):
        """Task is checkpointed (or fresh) and assigned; launch when dst ready."""
        ts = self.tasks[tid]
        inst = self.instances[ts.dst]
        if not inst.alive:  # dst died meanwhile
            self._make_pending(tid)
            return
        if inst.ready:
            ts.state = LAUNCH
            w = WORKLOADS[ts.workload]
            delay = (w.launch_delay_s * self.cfg.migration_delay_scale
                     + ts.restore_transfer_s)
            ts.restore_transfer_s = 0.0
            self._push(self.now + delay, LAUNCH_DONE, (tid, ts.epoch))
        else:
            ts.state = WAITING

    def _cross_region_charge(self, workload: int, r_s: int, r_d: int) -> float:
        """Extra checkpoint-transfer delay for moving a checkpoint from
        region ``r_s`` to ``r_d`` (live migration *or* a restore after a
        reclaim); also bills the egress fee — exactly once per move, to the
        source region.  Returns 0 for intra-region moves."""
        if r_s == r_d:
            return 0.0
        gb = checkpoint_size_gb(workload)
        fee = self.catalog.transfer.egress_usd(r_s, r_d, gb)
        self._bill_region(fee, r_s, obs_ev.COST_EGRESS)
        self.metrics.egress_cost += fee
        self.metrics.cross_region_migrations += 1
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.EGRESS,
                          src=self._regions[r_s].name,
                          dst=self._regions[r_d].name, gb=gb, fee=fee)
        return (self.catalog.transfer.transfer_time_s(r_s, r_d, gb)
                * self.cfg.migration_delay_scale)

    def _make_pending(self, tid: int):
        ts = self.tasks[tid]
        ts.state = PENDING
        ts.src = None
        ts.dst = None
        ts.epoch += 1
        ts.restore_transfer_s = 0.0  # ckpt_region keeps the durable copy

    def _execute_config(self, config: ClusterConfig):
        if self._deferrals:
            self._withdraw_deferred(config)
        live = self._live_instances()
        live_view = [LiveInstance(i.iid, i.type_index, tuple(sorted(i.assigned)))
                     for i in live]
        plan = diff_configs(live_view, config)

        # map plan slots to concrete instances (reuse matched, launch fresh).
        # A revoked (spot notice) or throttled (exhausted credits) instance
        # may only be reused by a slot that keeps some of its current tasks
        # (a non-aware scheduler rides it out); a zero-overlap match would
        # land brand-new tasks on a doomed/baseline-pinned instance, so it
        # launches fresh instead — a fresh burstable instance comes with
        # launch credits, not someone's exhausted balance.
        slot_inst: Dict[int, Optional[_Instance]] = {}
        for slot, (k, tids, matched) in enumerate(plan.slots):
            if matched is not None:
                minst = self.instances[matched]
                doomed = ((self._spot and minst.preempt_deadline is not None)
                          or (self._credits and minst.throttled))
                if doomed and not (set(tids) & minst.assigned):
                    slot_inst[slot] = self._launch_or_deny(k)
                else:
                    slot_inst[slot] = minst
            else:
                slot_inst[slot] = self._launch_or_deny(k)

        # Migrations.  Tasks mid-flight (WAITING/CKPT/LAUNCH) are pinned: the
        # executor defers moving them until they are RUNNING again.
        for mig in plan.migrations:
            ts = self.tasks[mig.task_id]
            dst = slot_inst[mig.dst_slot]
            if dst is None:
                continue  # launch denied (region at capacity): task stays put
            if ts.state in (WAITING, CKPT, LAUNCH):
                continue  # pinned
            if ts.dst == dst.iid:
                continue  # no-op
            if ts.state == RUNNING:
                # leave src: checkpoint first
                src = self.instances[ts.src]
                self._unassign_task(src, mig.task_id)
                ts.epoch += 1
                ts.state = CKPT
                ts.dst = dst.iid
                self._assign_task(dst, mig.task_id)
                w = WORKLOADS[ts.workload]
                delay = w.checkpoint_delay_s * self.cfg.migration_delay_scale
                if self._regions is not None:
                    r_d = int(self._region_ids[dst.type_index])
                    delay += self._cross_region_charge(
                        ts.workload, int(self._region_ids[src.type_index]),
                        r_d)
                    ts.ckpt_region = r_d  # checkpoint lands at the destination
                self._push(self.now + delay, CKPT_DONE, (mig.task_id, ts.epoch))
                ts.migrations += 1
                self.metrics.migrations += 1
                if self._ev is not None:
                    self._ev.emit(self.now, obs_ev.MIGRATE,
                                  instance_id=dst.iid, job_id=ts.job_id,
                                  task_id=mig.task_id, src=src.iid,
                                  delay_s=delay)
                self._touch_instance_jobs(src.iid)
            else:  # PENDING -> fresh placement
                ts.epoch += 1
                ts.dst = dst.iid
                self._assign_task(dst, mig.task_id)
                if self._ev is not None:
                    self._ev.emit(self.now, obs_ev.PLACE,
                                  instance_id=dst.iid, job_id=ts.job_id,
                                  task_id=mig.task_id)
                if self._deferrals:  # PENDING -> ADMIT transition
                    js = self.jobs[ts.job_id]
                    if js.admitted_t is None:
                        js.admitted_t = self.now
                        if self._ev is not None:
                            self._ev.emit(
                                self.now, obs_ev.ADMIT, job_id=ts.job_id,
                                wait_s=self.now - js.job.arrival_time)
                if ts.placed_once:
                    ts.migrations += 1
                    self.metrics.migrations += 1
                ts.placed_once = True
                # restoring a checkpoint stranded in another region (e.g.
                # after a reclaim) pays the same transfer + egress as a live
                # cross-region migration
                if self._regions is not None and ts.ckpt_region is not None:
                    r_d = int(self._region_ids[dst.type_index])
                    ts.restore_transfer_s = self._cross_region_charge(
                        ts.workload, ts.ckpt_region, r_d)
                    ts.ckpt_region = r_d
                self._start_launch(mig.task_id)

        # Terminations: instances not matched by any slot.
        for iid in plan.terminations:
            inst = self.instances[iid]
            if inst.assigned:
                continue  # defensive: scheduler kept tasks here implicitly
            if inst.residents:
                inst.draining = True
            else:
                self._terminate(inst, "evicted")

        # Evacuated revoked instances stop billing as soon as they are empty
        # (terminate during the notice window) instead of idling to reclaim.
        if self._spot:
            for inst in list(self._alive.values()):
                if (inst.alive and inst.preempt_deadline is not None
                        and not inst.assigned and not inst.draining):
                    inst.draining = True
                    self._maybe_finish_drain(inst)

    # ----------------------------------------------------------- monitoring
    def _report_throughputs(self):
        for jid, js in self._active_jobs.items():
            tasks = js.job.tasks
            if self._serving and js.job.service is not None:
                # replicas serve independently, so each running replica is
                # its own single-task interference observation rather than
                # the data-parallel min over the fleet
                for t in tasks:
                    ts = self.tasks[t.task_id]
                    if ts.state != RUNNING:
                        continue
                    if self._credits and self.instances[ts.src].throttled:
                        continue  # throttle-confounded: withhold
                    colo = self._colocated_running(t.task_id)
                    if colo:
                        self.scheduler.observe_single(
                            ts.workload, tuple(sorted(colo)),
                            self._task_tput(t.task_id))
                continue
            states = [self.tasks[t.task_id] for t in tasks]
            if any(s.state != RUNNING for s in states):
                continue
            if self._credits and any(self.instances[s.src].throttled
                                     for s in states):
                # throttle-confounded sample: the observed slowdown is the
                # credit baseline, not co-location interference — withhold
                # it from the monitor (credit state is cloud-visible)
                continue
            placements = []
            tputs = []
            for t in tasks:
                colo = self._colocated_running(t.task_id)
                placements.append((self.tasks[t.task_id].workload,
                                   tuple(sorted(colo))))
                tputs.append(self._task_tput(t.task_id))
            value = min(tputs)
            if len(tasks) == 1:
                w, colo = placements[0]
                if colo:
                    self.scheduler.observe_single(w, colo, value)
            else:
                self.scheduler.observe_job(placements, value)

    # ------------------------------------------------------------ round
    def _live_task_ids(self) -> List[int]:
        out = []
        for js in self._active_jobs.values():
            out.extend(t.task_id for t in js.job.tasks)
        return sorted(out)

    def _run_round(self):
        self._report_throughputs()
        tids = self._live_task_ids()
        if not tids:
            # nothing to schedule; terminate any empty instances
            for inst in self._live_instances():
                if not inst.assigned and not inst.residents:
                    self._terminate(inst, "idle")
            return
        taskset = TaskSet([self.tasks[t].task for t in tids])
        pending = {t for t in tids if self.tasks[t].dst is None}
        live_view = [LiveInstance(i.iid, i.type_index, tuple(sorted(i.assigned)))
                     for i in self._live_instances()]
        remaining = {}
        if self.scheduler.needs_runtime_estimates:
            for t in tids:
                js = self.jobs[self.tasks[t].job_id]
                remaining[t] = max(js.job.total_iters - js.iters_done, 0.0)
        revoked = {i.iid for i in self._live_instances()
                   if i.preempt_deadline is not None}
        ckpt_region = None
        if self._regions is not None:
            ckpt_region = {t: self.tasks[t].ckpt_region for t in tids
                           if self.tasks[t].ckpt_region is not None}
        instance_credits = None
        throttled = None
        if self._credits:
            instance_credits, throttled = {}, set()
            for i in self._live_instances():
                if self._credit_models[i.type_index] is not None:
                    instance_credits[i.iid] = i.credit_hours
                    if i.throttled:
                        throttled.add(i.iid)
        deferrable = deadline = pending_jobs = None
        if self._deferrals:
            jids = {self.tasks[t].job_id for t in tids}
            deferrable = {j for j in jids if self.jobs[j].job.deferrable}
            deadline = {j: float(self.jobs[j].job.deadline_s) for j in jids
                        if self.jobs[j].job.deadline_s is not None}
            pending_jobs = {j for j in jids if self._job_pending(j)}
            # queue-stability accounting: deferrable jobs whose tasks no
            # config has admitted yet (the pending queue a stability-aware
            # policy bounds)
            queued = sum(1 for j in deferrable
                         if self.jobs[j].admitted_t is None)
            if queued > self.metrics.max_pending_jobs:
                self.metrics.max_pending_jobs = queued
        service = service_rps = service_cap = slo_risk = specs = None
        if self._serving:
            service, service_rps, service_cap = set(), {}, {}
            slo_risk, specs = set(), {}
            for jid, js in self._active_jobs.items():
                spec = js.job.service
                if spec is None:
                    continue
                service.add(jid)
                service_rps[jid] = self._svc_rate(js, self.now)
                service_cap[jid] = js.svc_capacity
                specs[jid] = spec
                if js.svc_risk:
                    slo_risk.add(jid)
        view = SchedulerView(
            time=self.now, tasks=taskset, pending_ids=pending, live=live_view,
            task_workload={t: self.tasks[t].workload for t in tids},
            remaining_s=remaining or None, revoked=revoked or None,
            task_ckpt_region=ckpt_region or None,
            instance_credits=instance_credits or None,
            throttled=throttled or None, deferrable=deferrable or None,
            deadline_s=deadline or None, pending=pending_jobs or None,
            service=service or None, service_rps=service_rps or None,
            service_capacity=service_cap or None, slo_risk=slo_risk or None,
            service_specs=specs or None)
        config = self.scheduler.schedule(view)
        if self._rec is not None:
            self._emit_round(len(tids), len(pending))
        self._round_index += 1
        if self._commit:
            self._apply_commitment_orders()
        self._execute_config(config)

    def _emit_round(self, n_tasks: int, n_pending: int) -> None:
        """ROUND event + the per-round gauge samples (flight recorder on)."""
        self._ev.emit(self.now, obs_ev.ROUND, round_index=self._round_index,
                      n_tasks=n_tasks, n_pending=n_pending,
                      n_instances=len(self._alive))
        reg = self._rec.metrics
        t, m = self.now, self.metrics
        reg.inc("rounds")
        reg.sample("cost_total", t, m.total_cost)
        reg.sample("instances_alive", t, len(self._alive))
        reg.sample("tasks_live", t, n_tasks)
        reg.sample("tasks_pending", t, n_pending)
        if m.has_regions:
            for name, v in m.cost_by_region.items():
                reg.sample(f"cost_region:{name}", t, v)
        if m.has_service:
            reg.sample("slo_risk_jobs", t, sum(
                1 for js in self._active_jobs.values() if js.svc_risk))

    def _apply_commitment_orders(self) -> None:
        """Poll the scheduler for commitment re-sizes (the inventory
        decision, polled like ``admission``) and grow pools monotonically:
        commitments can be bought mid-run but never un-bought, so orders
        below the current pool size are ignored."""
        orders = getattr(self.scheduler, "commitment_orders", None)
        if not orders:
            return
        for name, size in orders.items():
            try:
                ri = self.catalog.region_index(name)
            except KeyError:
                continue
            if self._regions[ri].commitment is None:
                continue
            size = int(size)
            if size > self._pool_size[ri]:
                if self._ev is not None:
                    self._ev.emit(self.now, obs_ev.POOL_RESIZE, region=name,
                                  old=self._pool_size[ri], new=size)
                self._pool_size[ri] = size
                self._region_limits[ri] = size
                self.metrics.commitment_resizes += 1

    def _schedule_next_round(self):
        interval = self.cfg.round_interval_s
        nxt = math.floor(self.now / interval + 1.0) * interval
        if nxt > self._round_scheduled_at:
            self._round_scheduled_at = nxt
            self._push(nxt, ROUND, ())

    # ------------------------------------------------------------- handlers
    def _on_arrival(self, job: Job):
        js = _JobState(job=job, arrived=True)
        self.jobs[job.job_id] = js
        self._active_jobs[job.job_id] = js
        if self._vec:
            self._jtab.add(job.job_id)
            js._jt = self._jtab
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.JOB_ARRIVE, job_id=job.job_id,
                          n_tasks=job.n_tasks)
        for t in job.tasks:
            self.tasks[t.task_id] = _TaskState(task=t, job_id=job.job_id,
                                               workload=t.workload)
        if self._serving and job.service is not None:
            spec = job.service
            js.svc_times, js.svc_rps = self._profile_segs[id(spec.requests)]
            if self._vec:
                u = spec.utility
                self._stab.add(job.job_id, base_ms=spec.base_latency_ms,
                               target_ms=u.target_p99_ms,
                               soft_ms=u.softness_ms, floor=u.floor)
                js._st = self._stab
            # fixed wall-clock serving window: the end event is pushed once
            # at arrival (version -1 marks it as the non-projected end), and
            # the initial risk check fires SLO pressure immediately if load
            # is already nonzero — latency traffic cannot wait for the next
            # grid round
            self._push(self.now + job.duration_s, JOB_DONE, (job.job_id, -1))
            self._touch_service(js)
        self.scheduler.on_event(self.now)
        self._schedule_next_round()

    def _on_instance_ready(self, iid: int):
        inst = self.instances.get(iid)
        if inst is None or not inst.alive:
            return
        inst.ready = True
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.READY, instance_id=iid,
                          acquisition_s=self.now - inst.request_t)
        for tid in sorted(inst.assigned):
            if self.tasks[tid].state == WAITING:
                self._start_launch(tid)

    def _on_ckpt_done(self, tid: int, epoch: int):
        ts = self.tasks[tid]
        if ts.epoch != epoch or ts.state != CKPT:
            return
        if ts.src is not None:
            src = self.instances[ts.src]
            src.residents.discard(tid)
            self._touch_instance_jobs(src.iid)
            self._maybe_finish_drain(src)
        ts.src = None
        self._start_launch(tid)

    def _on_launch_done(self, tid: int, epoch: int):
        ts = self.tasks[tid]
        if ts.epoch != epoch or ts.state != LAUNCH:
            return
        inst = self.instances[ts.dst]
        ts.state = RUNNING
        ts.src = inst.iid
        if self._regions is not None:  # checkpoints now written here
            ts.ckpt_region = int(self._region_ids[inst.type_index])
        inst.residents.add(tid)
        self._touch_instance_jobs(inst.iid)

    def _on_job_done(self, jid: int, version: int):
        js = self.jobs[jid]
        if js.done_t is not None:
            return
        if js.job.service is not None:
            if version != -1:
                return  # progress projections never complete a service job
        else:
            if js.version != version:
                return
            if js.iters_done < js.job.total_iters - 1e-6:
                return  # stale projection
        js.done_t = self.now
        js.job.completion_time = self.now
        if self._vec:
            # deregister from the SoA tables; remove() hands back the final
            # column values, which become the plain attributes every later
            # reader (metric folds below, summaries, tests) sees
            fin = self._jtab.remove(jid)
            js._jt = None
            js._iters = fin["iters"]
            js._idle = fin["idle"]
            js._run_s = fin["run_s"]
            js._tputw = fin["tputw"]
            js._rate = fin["rate"]
            if js._st is not None:
                sfin = self._stab.remove(jid)
                js._st = None
                js._req = sfin["req"]
                js._ok = sfin["ok"]
                js._util = sfin["util"]
                js._svc_lam = sfin["lam"]
                js._svc_cap = sfin["cap"]
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.JOB_DONE, job_id=jid,
                          jct_s=self.now - js.job.arrival_time)
        self._active_jobs.pop(jid, None)
        self._jobs_outstanding -= 1
        if self._deferrals:
            if (js.job.deadline_s is not None
                    and self.now > js.job.deadline_s):
                self.metrics.deadline_misses += 1
            if js.job.deferrable and js.admitted_t is not None:
                wait = max(js.admitted_t - js.job.arrival_time, 0.0)
                self.metrics.deferred_wait_s += wait
                if wait > self.cfg.round_interval_s:  # held past round 1
                    self.metrics.deferred_jobs += 1
        if (self._spot or self._credits or self._deferrals or self._serving) \
                and self._jobs_outstanding == 0:
            # drop remaining one-shot breakpoint / credit-exhaustion /
            # latest-start / rate-update events (a long price trace or a
            # far-out projection would otherwise no-op through the heap and
            # inflate end_time)
            self._heap = [e for e in self._heap
                          if e[1] not in (PRICE_UPDATE, CREDIT_EXHAUST,
                                          DEFER_DEADLINE, RATE_UPDATE)]
            heapq.heapify(self._heap)
        self.metrics.jct_sum += self.now - js.job.arrival_time
        self.metrics.idle_sum += js.idle_s
        self.metrics.running_sum += js.running_s
        self.metrics.tput_weighted_sum += js.tput_weighted
        for t in js.job.tasks:
            ts = self.tasks[t.task_id]
            for ref in (ts.src, ts.dst):
                if ref is not None and ref in self.instances:
                    inst = self.instances[ref]
                    self._unassign_task(inst, t.task_id)
                    inst.residents.discard(t.task_id)
                    self._touch_instance_jobs(inst.iid)
                    self._maybe_finish_drain(inst)
            ts.state = PENDING
            ts.src = ts.dst = None
            ts.epoch += 1
        # housekeeping: empty instances release immediately (applies equally
        # to all schedulers; non-empty ones wait for the next round)
        for inst in self._live_instances():
            if not inst.assigned and not inst.residents:
                self._terminate(inst, "idle")
        self.scheduler.on_event(self.now)

    def _kill_instance(self, inst: _Instance, rng, reason: str):
        """Reclaim an instance out from under its tasks (failure or spot
        preemption): victims lose up to one checkpoint period of progress and
        re-enter PENDING."""
        iid = inst.iid
        victims = set(inst.assigned) | set(inst.residents)
        self._terminate(inst, reason)
        jids = set()
        for tid in victims:
            ts = self.tasks[tid]
            jids.add(ts.job_id)
            # progress loss up to one checkpoint period
            js = self.jobs[ts.job_id]
            loss = js.rate * rng.uniform(0, self.cfg.checkpoint_period_s)
            js.iters_done = max(0.0, js.iters_done - loss)
            # clear any other reservation
            if ts.dst is not None and ts.dst in self.instances and ts.dst != iid:
                self._unassign_task(self.instances[ts.dst], tid)
            self._make_pending(tid)
        for j in jids:
            self._touch_job(j)
        self._schedule_next_round()

    def _on_failure(self, iid: int):
        inst = self.instances.get(iid)
        if inst is None or not inst.alive:
            return
        self.metrics.failures += 1
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.FAILURE, instance_id=iid,
                          victims=len(inst.assigned | inst.residents))
        self._kill_instance(inst, self.rng, "failure")

    # --------------------------------------------------------- spot handlers
    def _on_price_update(self, periodic: bool = True):
        pm = self.catalog.price_model
        # segment price vector for [now, next update): same floats at(now)
        # would yield, without materializing a catalog snapshot per update
        self._cur_costs = self.catalog.prices_between(
            self.now, self.now + self._price_interval)
        dt = self.now - self._last_price_update  # actual elapsed exposure
        self._last_price_update = self.now
        noticed: List[int] = []
        if self.cfg.preemption_hazard_per_hour > 0 and dt > 0:
            pressure = pm.pressure_at(len(self.catalog), self.now)
            for iid in sorted(self._alive):
                inst = self._alive[iid]
                if inst.preempt_deadline is not None:
                    continue
                lam = (self.cfg.preemption_hazard_per_hour / 3600.0
                       * float(pressure[inst.type_index]))
                if self._spot_rng.uniform() < 1.0 - math.exp(-lam * dt):
                    inst.preempt_deadline = self.now + self.cfg.preemption_notice_s
                    self.metrics.preemption_notices += 1
                    self._push(inst.preempt_deadline, PREEMPT_FIRE, (iid,))
                    noticed.append(iid)
                    if self._ev is not None:
                        self._ev.emit(self.now, obs_ev.NOTICE,
                                      instance_id=iid,
                                      deadline=inst.preempt_deadline)
        if noticed:
            # immediate reaction so the scheduler can evacuate within the
            # notice window
            self._pressure_signal(SPOT, noticed)
        # only the periodic chain self-perpetuates; breakpoint events are
        # one-shots scheduled up-front
        if periodic and self._jobs_outstanding > 0:
            self._push(self.now + self._price_interval, PRICE_UPDATE, (True,))

    def _on_preempt_fire(self, iid: int):
        inst = self.instances.get(iid)
        if inst is None or not inst.alive:
            return  # evacuated and terminated before the deadline
        self.metrics.preemptions += 1
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.PREEMPT, instance_id=iid,
                          victims=len(inst.assigned | inst.residents))
        self._kill_instance(inst, self._spot_rng, "preempt")

    # ----------------------------------------------------- deferral handlers
    def _job_pending(self, jid: int) -> bool:
        """No task of the job has started (running or mid-launch): the job
        is still in the pending state — cheap to defer or re-defer."""
        return all(self.tasks[t.task_id].state in (PENDING, WAITING)
                   for t in self.jobs[jid].job.tasks)

    def _on_defer_deadline(self, jid: int):
        """A deferrable job's latest-start time arrived.  If the scheduler
        is still holding it, signal deadline pressure (callback + immediate
        extra round — the shared pressure wiring) so it can be admitted in
        this very instant rather than up to a round interval late."""
        js = self.jobs.get(jid)
        if js is None or not js.arrived or js.done_t is not None:
            return
        if not self._job_pending(jid):
            return  # already admitted and under way
        if self._ev is not None:
            self._ev.emit(self.now, obs_ev.DEFER_DEADLINE, job_id=jid)
        self._pressure_signal(DEADLINE, [jid])

    # ------------------------------------------------------ serving handlers
    def _on_rate_update(self, jid: int) -> None:
        """A service job's request rate just stepped to a new level
        (profile breakpoint): re-evaluate utility risk against the already
        up-to-date capacity (the accrual up to this instant used the old
        rate)."""
        js = self.jobs.get(jid)
        if js is None or not js.arrived or js.done_t is not None:
            return
        self._touch_service(js)

    def _withdraw_deferred(self, config: ClusterConfig) -> None:
        """Release reserved-but-unstarted placements of re-deferred jobs:
        the config omits their tasks, so any WAITING task (assigned to an
        instance that is still acquiring / not yet launched on) of a
        deferrable job returns to PENDING and its slot reservation is
        dropped before the plan diff — the vacated instance then terminates
        or is re-matched like any other.  Tasks that are launching, running
        or checkpointing are never withdrawn."""
        cfg_tids = {t for _, tids in config.assignments for t in tids}
        for inst in self._live_instances():
            for tid in sorted(inst.assigned):
                ts = self.tasks[tid]
                if (tid in cfg_tids or ts.state != WAITING
                        or not self.jobs[ts.job_id].job.deferrable):
                    continue
                self._unassign_task(inst, tid)
                self._make_pending(tid)
                self.metrics.withdrawals += 1
                if self._ev is not None:
                    self._ev.emit(self.now, obs_ev.WITHDRAW,
                                  instance_id=inst.iid, job_id=ts.job_id,
                                  task_id=tid)
                if self._job_pending(ts.job_id):
                    self.jobs[ts.job_id].admitted_t = None  # back to PENDING

    # ----------------------------------------------------------------- main
    def _dispatch(self, kind: int, payload: tuple) -> None:
        if kind == ARRIVAL:
            self._on_arrival(*payload)
        elif kind == INSTANCE_READY:
            self._on_instance_ready(*payload)
        elif kind == CKPT_DONE:
            self._on_ckpt_done(*payload)
        elif kind == LAUNCH_DONE:
            self._on_launch_done(*payload)
        elif kind == JOB_DONE:
            self._on_job_done(*payload)
        elif kind == FAILURE:
            self._on_failure(*payload)
        elif kind == PRICE_UPDATE:
            self._on_price_update(*payload)
        elif kind == PREEMPT_FIRE:
            self._on_preempt_fire(*payload)
        elif kind == CREDIT_EXHAUST:
            self._on_credit_exhaust_event(*payload)
        elif kind == DEFER_DEADLINE:
            self._on_defer_deadline(*payload)
        elif kind == RATE_UPDATE:
            self._on_rate_update(*payload)
        elif kind == ROUND:
            self._run_round()
            if self._live_task_ids():
                self._schedule_next_round()

    def run(self) -> Metrics:
        while self._heap:
            t, kind, _, payload = heapq.heappop(self._heap)
            if t > self.cfg.max_time_s:
                break
            self._accrue(t)
            self.now = t
            self._dispatch(kind, payload)
            if kind in _COALESCE:
                # Coincident bursts of the same kind (RATE_UPDATE fan-outs
                # over a shared profile grid, simultaneous arrival waves,
                # periodic + breakpoint price updates) run under a single
                # accrual sweep.  Safe because these handlers only push
                # same-timestamp events of later-sorting kinds (ROUND) or
                # strictly-future events, so batch order equals pop order —
                # and the dt<=0 re-accrual between them was already a no-op.
                # Reference self._heap afresh each pop: handlers may rebind
                # it (none of the coalesced kinds do, but stay defensive).
                while (self._heap and self._heap[0][0] == t
                       and self._heap[0][1] == kind):
                    self._dispatch(kind, heapq.heappop(self._heap)[3])
        # drain any leftover instances at the end
        for inst in list(self._alive.values()):
            self._terminate(inst, "end_of_run")
        if self._commit:  # finalize the pool ledgers
            for ri, _cm in self._pools:
                cap_s = self._pool_capacity_s[ri]
                cov_s = self._pool_covered_s[ri]
                self.metrics.commitment_utilization[
                    self._regions[ri].name] = \
                    cov_s / cap_s if cap_s > 0.0 else 0.0
                self.metrics.commitment_idle_cost += \
                    (cap_s - cov_s) / 3600.0 * self._pool_rate[ri]
        if self._deferrals:  # deadlines blown by never finishing count too
            for js in self.jobs.values():
                if (js.done_t is None and js.job.deadline_s is not None
                        and self.now > js.job.deadline_s):
                    self.metrics.deadline_misses += 1
        self.metrics.end_time = self.now
        return self.metrics
