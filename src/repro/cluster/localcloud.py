"""Physical-mode harness: Eva scheduling REAL JAX training jobs.

The analogue of the paper's EC2 deployment (§6.2), scaled to one machine:
"instances" are slots billed by wall-clock uptime, tasks are genuine JAX
training loops (reduced architecture configs) executed by worker threads,
task migration checkpoints params via repro.train.checkpoint and restarts
the loop on the destination instance, and the ThroughputMonitor reports the
observed steps/s back to the scheduler — co-location interference emerges
from real CPU contention between co-resident workers.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from ..core.catalog import Catalog
from ..core.cluster_types import ClusterConfig, Task, TaskSet
from ..core.plan import LiveInstance, diff_configs
from ..core.scheduler import SchedulerBase, SchedulerView
from ..data.pipeline import SyntheticTokens
from ..models.steps import init_train_state, make_train_step
from ..train.checkpoint import restore_checkpoint, save_checkpoint
from ..train.optimizer import OptConfig


@dataclasses.dataclass
class LocalJob:
    job_id: int
    workload: int
    arch_cfg: object  # reduced ArchConfig
    total_steps: int
    demand: tuple  # (gpu, cpu, ram)
    steps_done: int = 0
    standalone_sps: Optional[float] = None  # steps/s solo (calibration)
    done: bool = False


class _Worker(threading.Thread):
    """Runs one task's training loop until stopped; counts steps."""

    def __init__(self, job: LocalJob, ckpt_dir: str):
        super().__init__(daemon=True)
        self.job = job
        self.ckpt_dir = ckpt_dir
        self.stop_flag = threading.Event()
        self.steps_this_run = 0
        self.window: List[float] = []  # recent step timestamps

    def run(self):
        cfg = self.job.arch_cfg
        try:
            state, step0, _ = restore_checkpoint(self.ckpt_dir)
        except FileNotFoundError:
            state = init_train_state(cfg, jax.random.PRNGKey(self.job.job_id))
            step0 = 0
        step_fn = jax.jit(make_train_step(cfg, OptConfig(total_steps=max(
            self.job.total_steps, 10))))
        src = SyntheticTokens(cfg.vocab, 2, 32, seed=self.job.job_id,
                              start_step=step0)
        step = step0
        while not self.stop_flag.is_set() and step < self.job.total_steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in src.next_batch().items()}
            state, _ = step_fn(state, batch)
            jax.block_until_ready(state["params"])
            step += 1
            now = time.time()
            self.window.append(now)
            self.window = [t for t in self.window if now - t < 10.0]
        save_checkpoint(self.ckpt_dir, state, step)
        self.job.steps_done = step
        if step >= self.job.total_steps:
            self.job.done = True

    def throughput(self) -> float:
        w = [t for t in self.window if time.time() - t < 10.0]
        if len(w) < 2:
            return 0.0
        return (len(w) - 1) / max(w[-1] - w[0], 1e-6)


class LocalCloud:
    """Drives a SchedulerBase against real threaded jobs."""

    def __init__(self, catalog: Catalog, scheduler: SchedulerBase,
                 jobs: List[LocalJob], round_s: float = 4.0,
                 workdir: Optional[str] = None):
        self.catalog = catalog
        self.scheduler = scheduler
        self.jobs = {j.job_id: j for j in jobs}
        self.round_s = round_s
        self.workdir = workdir or tempfile.mkdtemp(prefix="evalocal-")
        self._iid = itertools.count()
        # instance id -> (type_index, start_time, task ids)
        self.instances: Dict[int, dict] = {}
        self.workers: Dict[int, _Worker] = {}  # task id -> worker
        self.task_of_job: Dict[int, Task] = {}
        self.cost = 0.0
        self.migrations = 0
        for j in jobs:
            t = Task(task_id=j.job_id, job_id=j.job_id, workload=j.workload,
                     demands={"p3": tuple(map(float, j.demand))})
            self.task_of_job[j.job_id] = t

    def _ckpt_dir(self, tid: int) -> str:
        return os.path.join(self.workdir, f"task-{tid}")

    def _live_view(self):
        return [LiveInstance(i, inst["type"], tuple(sorted(inst["tasks"])))
                for i, inst in self.instances.items()]

    def _stop_worker(self, tid: int):
        w = self.workers.pop(tid, None)
        if w is not None:
            w.stop_flag.set()
            w.join(timeout=60)

    def _start_worker(self, tid: int):
        job = self.jobs[tid]
        if job.done:
            return
        w = _Worker(job, self._ckpt_dir(tid))
        self.workers[tid] = w
        w.start()

    def step_round(self, now: float):
        # monitor: report observed normalized throughput
        for tid, w in list(self.workers.items()):
            job = self.jobs[tid]
            sps = w.throughput()
            if sps > 0 and job.standalone_sps:
                inst = next((i for i in self.instances.values()
                             if tid in i["tasks"]), None)
                if inst:
                    colo = [self.jobs[o].workload for o in inst["tasks"]
                            if o != tid]
                    if colo:
                        self.scheduler.observe_single(
                            job.workload, colo,
                            min(sps / job.standalone_sps, 1.0))
        live = [t for t, j in self.jobs.items() if not j.done]
        taskset = TaskSet([self.task_of_job[t] for t in live])
        placed = {t for i in self.instances.values() for t in i["tasks"]}
        view = SchedulerView(
            time=now, tasks=taskset,
            pending_ids={t for t in live if t not in placed},
            live=self._live_view(),
            task_workload={t: self.jobs[t].workload for t in live})
        config = self.scheduler.schedule(view)
        plan = diff_configs(self._live_view(), config)

        slot_inst = {}
        for slot, (k, tids, matched) in enumerate(plan.slots):
            if matched is not None:
                slot_inst[slot] = matched
            else:
                iid = next(self._iid)
                self.instances[iid] = {"type": k, "start": now, "tasks": set()}
                slot_inst[slot] = iid
        for mig in plan.migrations:
            tid = mig.task_id
            if mig.src_instance is not None:
                self._stop_worker(tid)  # checkpoint happens in worker exit
                self.instances[mig.src_instance]["tasks"].discard(tid)
                self.migrations += 1
            self.instances[slot_inst[mig.dst_slot]]["tasks"].add(tid)
            self._start_worker(tid)
        for iid in plan.terminations:
            inst = self.instances.pop(iid, None)
            if inst is not None:
                self.cost += (now - inst["start"]) / 3600.0 \
                    * self.catalog.costs[inst["type"]]

    def reap_done(self, now: float):
        for tid, job in self.jobs.items():
            if job.done and tid in self.workers:
                self._stop_worker(tid)
            if job.done:
                for inst in self.instances.values():
                    inst["tasks"].discard(tid)
        self.scheduler.on_event(now)

    def run(self, timeout_s: float = 600.0) -> dict:
        t0 = time.time()
        while time.time() - t0 < timeout_s:
            now = time.time()
            self.reap_done(now)
            if all(j.done for j in self.jobs.values()):
                break
            self.step_round(now)
            time.sleep(self.round_s)
        # final billing
        now = time.time()
        for iid, inst in list(self.instances.items()):
            self.cost += (now - inst["start"]) / 3600.0 \
                * self.catalog.costs[inst["type"]]
        for tid in list(self.workers):
            self._stop_worker(tid)
        return {"cost": self.cost, "migrations": self.migrations,
                "steps": {t: j.steps_done for t, j in self.jobs.items()},
                "all_done": all(j.done for j in self.jobs.values())}
