"""Workload trace generation (§6.1).

* ``physical_trace`` — synthetic traces like the paper's physical experiments:
  N jobs sampled from the 10 Table-7 workloads, durations U[0.5, 3] h,
  Poisson arrivals with 20-min mean inter-arrival.
* ``alibaba_like_trace`` — the Alibaba production trace
  (cluster-trace-gpu-v2023) is not redistributable offline, so we synthesize
  a 6,274-job trace matching its published statistics: GPU-demand mix from
  Table 8, job durations matching Table 9's quantiles (mean 9.1 h, median
  0.2 h, P80 1.0 h, P95 5.2 h) or the Gavel duration model (10^x minutes,
  x ~ U[1.5,3] w.p. 0.8 else U[3,4]).  Each job is mapped to a Table-7
  workload for its migration delays and interference behaviour, while
  keeping the trace's own resource demands — exactly the paper's procedure.
* knobs for §6.6-6.8: multi-GPU composition (5:4:1 of 2/4/8-GPU jobs),
  multi-task share (1:1 of 2-/4-task jobs), arrival-rate scaling.
* ``burstable_trace`` — CPU-only jobs (the Table-7 workloads burstable
  T-family instances can host) with durations long enough to outlast a
  fresh instance's launch credits; the bundled trace for
  ``benchmarks/bench_credits.py`` and the credit tests.
* ``deferrable_trace`` — every job deferrable with a completion deadline, a
  mixed population of deadline-*tight* jobs (almost no slack beyond the
  latest-start margin: admission is deadline-forced nearly immediately) and
  deadline-*loose* ones (hours of slack to wait out dear markets); the
  bundled trace for ``benchmarks/bench_autoscale.py`` and the autoscale
  tests.
* ``portfolio_trace`` — the commitment-portfolio axis: a steady base of
  horizon-long jobs shaped to fill reserved capacity exactly, plus bursty
  waves of short jobs that overflow onto the spot/on-demand markets; the
  bundled trace for ``benchmarks/bench_portfolio.py`` and the portfolio
  tests.
* ``serving_trace`` — the online-serving axis: diurnal million-user request
  load with surge windows split across two inference fleets (GPU llm-serve,
  CPU embed-serve) that run for the whole horizon, plus batch filler jobs;
  the bundled trace for ``benchmarks/bench_serving.py`` and the SLO tests.
"""
from __future__ import annotations

import itertools
import math
from typing import List, Optional

import numpy as np

from ..autoscale.admission import ADMIT_OVERHEAD_S, RUNTIME_MARGIN
from ..core.catalog import FAMILIES
from ..core.cluster_types import Job, Task
from ..core.serving import RequestProfile, ServiceSpec, UtilityCurve
from ..core.workloads import (NUM_BATCH_WORKLOADS, WORKLOAD_INDEX, WORKLOADS)

# Batch samplers draw from the Table-7 block only (service workloads are
# placed explicitly by serving_trace), keeping pre-serving traces
# bit-identical to the 10-workload table.
_GPU_WORKLOADS = [i for i, w in enumerate(WORKLOADS[:NUM_BATCH_WORKLOADS])
                  if w.demands["p3"][0] > 0]
_CPU_WORKLOADS = [i for i, w in enumerate(WORKLOADS[:NUM_BATCH_WORKLOADS])
                  if w.demands["p3"][0] == 0]

_job_ids = itertools.count(1)
_task_ids = itertools.count(1_000_000)


def _table7_job(rng, workload: int, arrival: float, duration: float) -> Job:
    prof = WORKLOADS[workload]
    job_id = next(_job_ids)
    # workload-profile autoscaling defaults (deadline_s is arrival-relative
    # on the profile, absolute on the job); per-job overrides come later
    job = Job(job_id=job_id, workload=workload, arrival_time=arrival,
              duration_s=duration, n_tasks=prof.n_tasks,
              deferrable=prof.deferrable,
              deadline_s=None if prof.deadline_s is None
              else arrival + prof.deadline_s)
    for _ in range(prof.n_tasks):
        demands = {f: prof.demand_for_family(f) for f in FAMILIES}
        job.tasks.append(Task(next(_task_ids), job_id, workload, demands))
    return job


def _custom_job(workload: int, arrival: float, duration: float,
                demand, n_tasks: int) -> Job:
    job_id = next(_job_ids)
    job = Job(job_id=job_id, workload=workload, arrival_time=arrival,
              duration_s=duration, n_tasks=n_tasks)
    d = {f: tuple(map(float, demand)) for f in FAMILIES}
    for _ in range(n_tasks):
        job.tasks.append(Task(next(_task_ids), job_id, workload, d))
    return job


def physical_trace(n_jobs: int = 120, seed: int = 0,
                   mean_interarrival_s: float = 1200.0,
                   duration_range_h=(0.5, 3.0)) -> List[Job]:
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for _ in range(n_jobs):
        t += rng.exponential(mean_interarrival_s)
        w = int(rng.integers(NUM_BATCH_WORKLOADS))
        dur = rng.uniform(*duration_range_h) * 3600.0
        jobs.append(_table7_job(rng, w, t, dur))
    return jobs


def burstable_trace(n_jobs: int = 16, seed: int = 11,
                    mean_interarrival_s: float = 900.0,
                    duration_range_h=(0.6, 1.5)) -> List[Job]:
    """CPU-only trace for the burstable-credit scenario: jobs drawn from the
    Table-7 CPU workloads (gcn / a3c / diamond / openfoam — the shapes a
    T-family instance can host), with durations that outlast the bundled
    demo catalog's launch credits so credit-blind schedulers actually hit
    the throttle mid-job."""
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for _ in range(n_jobs):
        t += rng.exponential(mean_interarrival_s)
        w = int(rng.choice(_CPU_WORKLOADS))
        dur = rng.uniform(*duration_range_h) * 3600.0
        jobs.append(_table7_job(rng, w, t, dur))
    return jobs


def deferrable_trace(n_jobs: int = 24, seed: int = 13,
                     mean_interarrival_s: float = 900.0,
                     duration_range_h=(0.3, 0.8),
                     loose_fraction: float = 0.7,
                     loose_window_h=(3.0, 9.0),
                     tight_window_h=(0.0, 0.5),
                     cpu_only: bool = False) -> List[Job]:
    """Mixed deadline-tight / deadline-loose trace for the autoscaling axis.

    Every job is deferrable and carries a completion deadline
    ``arrival + RUNTIME_MARGIN x duration + ADMIT_OVERHEAD_S + window``, so
    its latest-*start* slack is exactly ``window``: loose jobs
    (``loose_fraction`` of the trace) get hours of slack to wait out dear
    markets, tight ones are deadline-forced almost immediately — the
    admission controller must treat them differently for the deadlines to
    hold.  ``cpu_only=True`` restricts to the Table-7 CPU workloads (for
    composing with the burstable market, whose T-family twins only host
    CPU shapes)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for _ in range(n_jobs):
        t += rng.exponential(mean_interarrival_s)
        w = int(rng.choice(_CPU_WORKLOADS)) if cpu_only \
            else int(rng.integers(NUM_BATCH_WORKLOADS))
        dur = rng.uniform(*duration_range_h) * 3600.0
        job = _table7_job(rng, w, t, dur)
        window_h = loose_window_h if rng.uniform() < loose_fraction \
            else tight_window_h
        job.deferrable = True
        job.deadline_s = (t + RUNTIME_MARGIN * dur + ADMIT_OVERHEAD_S
                          + rng.uniform(*window_h) * 3600.0)
        jobs.append(job)
    return jobs


# ---------------------------------------------------------------- durations
# piecewise log-linear inverse CDF through Table 9's Alibaba quantiles, with
# a log-uniform tail beyond P95 on [5.2 h, 900 h]: E[tail] = Δ/ln-ratio ≈
# 174 h, so the overall mean lands at 0.95·0.31 + 0.05·174 ≈ 9 h (Table 9
# reports mean 9.1 h, median 0.2 h — the mass is in week-long trainings).
_ALI_ANCHORS_P = np.array([0.0, 0.25, 0.50, 0.80, 0.95])
_ALI_ANCHORS_H = np.array([0.003, 0.05, 0.20, 1.00, 5.20])
_ALI_TAIL_MAX_H = 900.0


def sample_alibaba_duration_h(rng, n: int) -> np.ndarray:
    u = rng.uniform(0, 1, size=n)
    out = np.empty(n)
    body = u < 0.95
    out[body] = np.exp(np.interp(u[body], _ALI_ANCHORS_P,
                                 np.log(_ALI_ANCHORS_H)))
    k = (~body).sum()
    if k:
        out[~body] = np.exp(rng.uniform(np.log(5.2), np.log(_ALI_TAIL_MAX_H),
                                        size=k))
    return out


def sample_gavel_duration_h(rng, n: int) -> np.ndarray:
    lo = rng.uniform(1.5, 3.0, size=n)
    hi = rng.uniform(3.0, 4.0, size=n)
    x = np.where(rng.uniform(0, 1, size=n) < 0.8, lo, hi)
    return (10.0 ** x) / 60.0  # minutes -> hours


# Table 8 GPU-demand mix.
_GPU_MIX = [(0, 0.1341), (1, 0.8617), (2, 0.0020), (4, 0.0018), (8, 0.0004)]


def alibaba_like_trace(n_jobs: int = 6274, seed: int = 0,
                       duration_model: str = "alibaba",
                       mean_interarrival_s: float = 1200.0,
                       multi_gpu_fraction: Optional[float] = None,
                       multi_task_fraction: float = 0.0) -> List[Job]:
    """Synthesize the paper's simulation trace.

    multi_gpu_fraction: if set, overrides the share of GPU jobs that are
    multi-GPU, keeping a 5:4:1 ratio among 2-/4-/8-GPU jobs (§6.6).
    multi_task_fraction: share of jobs duplicated into 2- or 4-task jobs,
    1:1 mix (§6.7).
    """
    rng = np.random.default_rng(seed)
    sampler = {"alibaba": sample_alibaba_duration_h,
               "gavel": sample_gavel_duration_h}[duration_model]
    durations = sampler(rng, n_jobs) * 3600.0

    gpus, probs = zip(*_GPU_MIX)
    gpu_demand = rng.choice(gpus, size=n_jobs, p=probs)
    if multi_gpu_fraction is not None:
        # rewrite GPU jobs: fraction f multi-GPU at ratio 5:4:1 (2:4:8 GPUs)
        is_gpu = gpu_demand > 0
        idx = np.nonzero(is_gpu)[0]
        multi = rng.uniform(0, 1, size=idx.size) < multi_gpu_fraction
        kinds = rng.choice([2, 4, 8], size=idx.size, p=[0.5, 0.4, 0.1])
        gpu_demand[idx] = np.where(multi, kinds, 1)

    t = 0.0
    jobs: List[Job] = []
    for i in range(n_jobs):
        t += rng.exponential(mean_interarrival_s)
        g = int(gpu_demand[i])
        if g > 0:
            # ~55 % of GPU tasks request CPU/RAM beyond their GPU-count's
            # instance tier ("straddle" demands): a 1-GPU task asking for
            # 16 vCPU / 100 GB forces a p3.8xlarge on its own — the
            # fragmentation Eva exploits.  The real cluster-trace-gpu-v2023
            # comes from Alibaba's GPU-sharing cluster with exactly this
            # demand pattern; the fraction is calibrated so the No-Packing
            # per-job cost matches Table 13 (≈ $76/job ≈ $8.4/job-hour).
            w = int(rng.choice(_GPU_WORKLOADS))
            if rng.uniform() < 0.55 and 8 * g < 64:
                cpu = float(rng.integers(8 * g + 1, min(24 * g, 64) + 1))
                ram = float(np.round(rng.uniform(61.0 * g,
                                                 min(200.0 * g, 488.0)), 1))
            else:
                cpu = float(rng.integers(1, 8 * g + 1))
                ram = float(np.round(rng.uniform(2.0, 55.0 * g), 1))
        else:
            w = int(rng.choice(_CPU_WORKLOADS))
            cpu = float(np.round(np.exp(rng.uniform(0.0, np.log(32.0)))))
            ram = float(np.round(np.exp(rng.uniform(np.log(2.0), np.log(256.0))), 1))
        n_tasks = 1
        if multi_task_fraction > 0 and rng.uniform() < multi_task_fraction:
            n_tasks = int(rng.choice([2, 4]))
        jobs.append(_custom_job(w, t, float(durations[i]), (g, cpu, ram),
                                n_tasks))
    return jobs


def portfolio_trace(n_steady: int = 6, n_burst: int = 10, seed: int = 23,
                    horizon_h: float = 8.0, steady_demand=(0.0, 7.0, 14.0),
                    steady_start_h: float = 0.1, steady_span: float = 0.88,
                    burst_waves=((0.30, 0.40), (0.60, 0.72)),
                    burst_duration_h=(0.3, 0.7)) -> List[Job]:
    """Steady committed base + bursty spot overflow (the commitment story).

    ``n_steady`` horizon-long single-task jobs arrive near t=0 with a
    demand (``steady_demand``, default 7 vCPU / 14 GB) sized so each fills
    one c7i.2xlarge — the hardware ``benchmarks/bench_portfolio.py``
    commits — and runs for ``steady_span`` of the horizon: the persistent
    base a commitment pool should absorb at the discounted rate.
    ``n_burst`` short CPU jobs arrive in waves (horizon fractions in
    ``burst_waves``) on top: transient demand that should overflow to the
    spot market, *not* grow the commitment.  A portfolio policy beats both
    pure-spot (the base pays spot prices all day) and pure-commit (pools
    sized for the burst peak idle between waves) on this trace."""
    rng = np.random.default_rng(seed)
    horizon_s = horizon_h * 3600.0
    jobs: List[Job] = []
    for _ in range(n_steady):
        t = steady_start_h * 3600.0 * rng.uniform(0.2, 1.0)
        w = int(rng.choice(_CPU_WORKLOADS))
        jobs.append(_custom_job(w, t, steady_span * horizon_s,
                                steady_demand, n_tasks=1))
    waves = [w for w in burst_waves]
    for i in range(n_burst):
        f0, f1 = waves[i % len(waves)]
        t = rng.uniform(f0, f1) * horizon_s
        w = int(rng.choice(_CPU_WORKLOADS))
        dur = rng.uniform(*burst_duration_h) * 3600.0
        jobs.append(_custom_job(w, t, dur, steady_demand, n_tasks=1))
    jobs.sort(key=lambda j: j.arrival_time)
    return jobs


def _service_job(workload: int, arrival: float, duration: float,
                 n_replicas: int, spec: ServiceSpec) -> Job:
    prof = WORKLOADS[workload]
    job_id = next(_job_ids)
    job = Job(job_id=job_id, workload=workload, arrival_time=arrival,
              duration_s=duration, n_tasks=n_replicas, service=spec)
    for _ in range(n_replicas):
        demands = {f: prof.demand_for_family(f) for f in FAMILIES}
        job.tasks.append(Task(next(_task_ids), job_id, workload, demands))
    return job


def serving_trace(n_batch: int = 10, seed: int = 17, horizon_h: float = 8.0,
                  users: float = 1_000_000, req_per_user_day: float = 20.0,
                  llm_share: float = 0.25, peak_hour: float = 5.0,
                  trough: float = 0.35, surge_mult: float = 1.7,
                  surge_windows=((0.35, 0.45), (0.70, 0.80)),
                  util_target: float = 0.6, step_s: float = 900.0,
                  batch_duration_h=(0.4, 1.2)) -> List[Job]:
    """Diurnal serving trace with surge windows, next to batch filler.

    Two service fleets (a GPU ``llm-serve`` and a CPU ``embed-serve``, see
    ``core.workloads.SERVICE_WORKLOADS``) arrive at t=0 and run for the whole
    ``horizon_h`` window.  The request load is a ``users``-population diurnal
    curve (``req_per_user_day`` requests per user per day, split
    ``llm_share`` / ``1 - llm_share`` between the fleets) on a ``step_s``
    grid, climbing toward ``peak_hour``, with multiplicative surge windows
    given as horizon fractions and snapped to the grid.  Each fleet is sized
    so the *surge* peak sits at ``util_target`` utilization when every
    replica runs undegraded — i.e. the SLO is comfortably feasible at full
    capacity, and misses can only come from lost or interference-degraded
    replicas.  ``n_batch`` Table-7 batch jobs arrive throughout for
    co-location pressure.
    """
    rng = np.random.default_rng(seed)
    horizon_s = horizon_h * 3600.0
    snap = lambda f: round(f * horizon_s / step_s) * step_s  # noqa: E731
    surges = tuple((snap(f0), snap(f1), surge_mult) for f0, f1 in surge_windows)
    avg_rps = users * req_per_user_day / 86400.0
    jobs: List[Job] = []
    for name, share in (("llm-serve", llm_share),
                        ("embed-serve", 1.0 - llm_share)):
        w = WORKLOAD_INDEX[name]
        prof = WORKLOADS[w]
        # diurnal peak ≈ 1.6x the population's mean rate (surges on top)
        profile = RequestProfile.diurnal(
            share * avg_rps * 1.6, start_s=0.0, duration_s=horizon_s,
            step_s=step_s, trough=trough, peak_hour=peak_hour, surges=surges)
        n_replicas = max(2, math.ceil(
            profile.peak_rps() / (prof.per_replica_rps * util_target)))
        spec = ServiceSpec(
            requests=profile,
            utility=UtilityCurve(prof.target_p99_ms,
                                 softness_ms=prof.target_p99_ms / 3.0),
            per_replica_rps=prof.per_replica_rps,
            base_latency_ms=prof.base_latency_ms)
        jobs.append(_service_job(w, 0.0, horizon_s, n_replicas, spec))
    t = 0.0
    mean_gap = horizon_s * 0.7 / max(n_batch, 1)
    for _ in range(n_batch):
        t += rng.exponential(mean_gap)
        w = int(rng.integers(NUM_BATCH_WORKLOADS))
        dur = rng.uniform(*batch_duration_h) * 3600.0
        jobs.append(_table7_job(rng, w, t, dur))
    return jobs
