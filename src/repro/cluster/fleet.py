"""Structure-of-arrays fleet state for the vectorized simulator core.

The event-driven simulator keeps rich per-entity objects (``_Instance``,
``_JobState``) for control flow, but its accrual hot path — executed at
every event pop — only needs a handful of numeric columns per entity:
credit balances, net drain rates, job progress rates, service request
rates.  :class:`SlotTable` holds those columns as parallel numpy arrays
over *compact slots* so a billing sweep is a few elementwise array ops
instead of a Python loop over the fleet.

Layout contract
---------------
* Rows live in slots ``[0, n)`` of pre-allocated, capacity-doubling
  arrays; ``table.f[col][:table.n]`` is the live view a sweep operates on.
* ``add``/``remove`` are O(1): removal swaps the last row into the hole
  (swap-remove), so slot order is *not* stable — per-entity access always
  goes through ``slot[entity_id]``, which the swap keeps current.
* Sweeps write columns in place; entity objects that expose one of these
  columns as an attribute read through the table while registered and
  receive the final value back on ``remove`` (the simulator's properties
  handle that hand-off).

Determinism: swap-remove order is a pure function of the event trajectory
(no hashing, no randomness), so vectorized runs are exactly reproducible.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

__all__ = ["SlotTable"]

_INITIAL_CAPACITY = 64


class SlotTable:
    """Compact swap-remove table of float64 / bool columns keyed by an
    integer entity id (instance iid or job id).

    Attributes
    ----------
    n : int
        Number of live rows; every column's live data is ``col[:n]``.
    f / b : dict of name -> ndarray
        Float64 and bool column storage (full capacity, not just ``[:n]``).
    slot : dict of entity id -> row index
        Kept current across swap-removes.
    ids : ndarray
        Entity id of each slot (int64), for reverse lookups on swap.
    """

    def __init__(self, float_cols: Sequence[str],
                 bool_cols: Sequence[str] = ()) -> None:
        cap = _INITIAL_CAPACITY
        self.n = 0
        self._cap = cap
        self.ids = np.zeros(cap, dtype=np.int64)
        self.f: Dict[str, np.ndarray] = {
            c: np.zeros(cap, dtype=np.float64) for c in float_cols}
        self.b: Dict[str, np.ndarray] = {
            c: np.zeros(cap, dtype=bool) for c in bool_cols}
        self.slot: Dict[int, int] = {}

    def __len__(self) -> int:
        return self.n

    def __contains__(self, eid: int) -> bool:
        return eid in self.slot

    def _grow(self) -> None:
        new_cap = self._cap * 2
        self.ids = np.resize(self.ids, new_cap)
        for cols in (self.f, self.b):
            for name, arr in cols.items():
                grown = np.zeros(new_cap, dtype=arr.dtype)
                grown[:self._cap] = arr
                cols[name] = grown
        self._cap = new_cap

    def add(self, eid: int, **values) -> int:
        """Register ``eid`` in a fresh slot; unnamed columns start at 0."""
        if eid in self.slot:
            raise ValueError(f"entity {eid} already registered")
        if self.n == self._cap:
            self._grow()
        s = self.n
        self.n += 1
        self.ids[s] = eid
        self.slot[eid] = s
        for name, v in values.items():
            (self.f if name in self.f else self.b)[name][s] = v
        # columns not named in `values` must not inherit a stale row left
        # behind by an earlier swap-remove
        for name, arr in self.f.items():
            if name not in values:
                arr[s] = 0.0
        for name, arr in self.b.items():
            if name not in values:
                arr[s] = False
        return s

    def remove(self, eid: int) -> Dict[str, float]:
        """Drop ``eid``'s row (swap-remove) and return its final column
        values, so the owner can fold them back into the entity object."""
        s = self.slot.pop(eid)
        final = {name: float(arr[s]) for name, arr in self.f.items()}
        final.update({name: bool(arr[s]) for name, arr in self.b.items()})
        last = self.n - 1
        if s != last:
            moved = int(self.ids[last])
            self.ids[s] = moved
            for arr in self.f.values():
                arr[s] = arr[last]
            for arr in self.b.values():
                arr[s] = arr[last]
            self.slot[moved] = s
        self.n = last
        return final

    # -- per-entity scalar access (slow path; sweeps use the arrays) -------
    def get(self, eid: int, col: str):
        s = self.slot[eid]
        if col in self.f:
            return float(self.f[col][s])
        return bool(self.b[col][s])

    def set(self, eid: int, col: str, value) -> None:
        s = self.slot[eid]
        (self.f if col in self.f else self.b)[col][s] = value

    def live(self, col: str) -> np.ndarray:
        """View of the live rows of one column (``col[:n]``)."""
        return (self.f[col] if col in self.f else self.b[col])[:self.n]

    def items(self) -> Tuple[np.ndarray, int]:
        """(ids_view, n) for callers that iterate entities with slots."""
        return self.ids[:self.n], self.n
