"""Forecast-driven admission control for deferrable jobs.

``AdmissionController`` scales the *job population itself* against price
pressure (the last axis on the ``PriceModel`` stack): deferrable batch
jobs are held in a pending queue while the market is dear and admitted
when it is cheap — bounded by per-job deadlines.  Mechanics, per
scheduling round (the controller runs before Algorithm 1 ever sees the
task set):

* every *deferrable, not-yet-started* job (``SchedulerView.deferrable`` ∩
  ``SchedulerView.pending``) is reviewed;
* its **strike test** compares the forecast effective $/throughput of
  running it over its estimated duration D̂_j (``PriceForecaster.
  forecast_catalog(...).credit_priced(...)`` — spot, region and credit
  axes all priced in) against ``strike`` × the same reservation price
  under the market's *long-run anchor* prices.  Below the strike the
  market is cheap *for this job's feasible types*: admit; above: hold;
* its **latest-start time** ``deadline − margin · D̂_j − overhead`` is the
  unconditional bound: once it arrives the job is admitted regardless of
  price (``forced``), so deadlines are met even on markets that never
  dip.  The simulator mirrors the same bound with a ``DEFER_DEADLINE``
  event that fires an immediate extra round (the shared pressure-signal
  wiring spot notices and credit exhaustion use), so a latest-start
  falling between rounds is not missed;
* an admitted-but-unstarted job is **re-deferred** when prices spike: if
  its forecast rises above the strike by more than ``hold_hysteresis``
  (hysteresis, because withdrawing an in-flight placement wastes the
  already-billed acquisition time), it returns to the pending queue and
  the executor withdraws its not-yet-launched placement.  Started jobs
  are never touched.

Duration estimates D̂_j come from ``SchedulerView.remaining_s`` (the same
runtime-estimate channel Stratus uses); jobs without one fall back to the
ensemble's D̂ horizon.  ``margin`` covers interference slowdown and
``ADMIT_OVERHEAD_S`` the instance acquisition + setup + one scheduling
round of latency, so "admit at latest start" still meets the deadline.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.catalog import Catalog
from ..core.reservation_price import reservation_prices
from ..core.workloads import INSTANCE_ACQUISITION_S, INSTANCE_SETUP_S
from .forecast import PriceForecaster

# Latest-start defaults: margin stretches the standalone duration estimate
# for interference slowdown; the overhead covers acquisition + setup + one
# round interval + launch latency.  The simulator's DEFER_DEADLINE backstop
# reads the live controller's margin/overhead (falling back to these
# defaults for controller-less schedulers) so the two sides agree on the
# bound even when the knobs are customized.
RUNTIME_MARGIN = 2.0
ADMIT_OVERHEAD_S = INSTANCE_ACQUISITION_S + INSTANCE_SETUP_S + 300.0 + 120.0


def latest_start_s(deadline_s: float, est_duration_s: float,
                   margin: float = RUNTIME_MARGIN,
                   overhead_s: float = ADMIT_OVERHEAD_S) -> float:
    """Last instant a job can be admitted and still meet its deadline."""
    return deadline_s - margin * max(est_duration_s, 0.0) - overhead_s


class AdmissionController:
    """Pending queue + strike test + deadline bound for deferrable jobs."""

    def __init__(self, catalog: Catalog,
                 forecaster: Optional[PriceForecaster] = None, *,
                 strike: float = 1.0, margin: float = RUNTIME_MARGIN,
                 overhead_s: float = ADMIT_OVERHEAD_S,
                 hold_hysteresis: float = 0.25,
                 min_horizon_s: float = 600.0,
                 type_mask: Optional[np.ndarray] = None):
        assert strike > 0.0 and margin >= 1.0 and hold_hysteresis >= 0.0
        self.catalog = catalog
        self.forecaster = forecaster or PriceForecaster.for_catalog(catalog)
        # restrict the strike test to the types the scheduler may actually
        # pack on (e.g. a region pin) — otherwise another region's cheap
        # window would admit a job the packer cannot place there
        self.type_mask = type_mask
        self.strike = float(strike)
        self.margin = float(margin)
        self.overhead_s = float(overhead_s)
        self.hold_hysteresis = float(hold_hysteresis)
        self.min_horizon_s = float(min_horizon_s)
        self._admitted: Set[int] = set()  # admitted, possibly unstarted
        self._force: Set[int] = set()  # deadline-pressure signals
        # per-job held-round backlog (the queue drift term stability-aware
        # subclasses weigh against the price premium)
        self._held_rounds: Dict[int, int] = {}
        # observability
        self.admissions = 0
        self.forced_admissions = 0
        self.re_deferrals = 0
        self.held_job_rounds = 0

    # -- signals -------------------------------------------------------------
    def note_deadline(self, job_ids: Sequence[int]) -> None:
        """A ``DEFER_DEADLINE`` signal arrived: these jobs' latest-start
        time has passed — admit them unconditionally at the next review."""
        self._force |= set(job_ids)

    # -- per-job pieces ------------------------------------------------------
    def _estimates(self, view) -> Dict[int, float]:
        """Job id -> estimated standalone duration (max over its tasks)."""
        est: Dict[int, float] = {}
        if view.remaining_s:
            ids = view.tasks.ids.tolist()
            jids = view.tasks.job_ids.tolist()
            for tid, jid in zip(ids, jids):
                r = view.remaining_s.get(tid)
                if r is not None:
                    est[jid] = max(est.get(jid, 0.0), float(r))
        return est

    def _job_rp(self, view, job_ids, cat: Catalog) -> float:
        sub = view.tasks.subset(job_ids)
        return float(reservation_prices(sub, cat,
                                        type_mask=self.type_mask).sum())

    # -- the admit/hold decision (subclass points) ---------------------------
    def queue_rounds(self, jid: int) -> int:
        """Rounds this job has been held so far (its share of the
        ``held_job_rounds`` queue backlog)."""
        return self._held_rounds.get(jid, 0)

    def _hold(self, jid: int, held: Set[int]) -> None:
        held.add(jid)
        self.held_job_rounds += 1
        self._held_rounds[jid] = self._held_rounds.get(jid, 0) + 1

    def _admit_now(self, jid: int, rp_f: float, rp_a: float) -> bool:
        """The strike test: admit while the forecast reservation price
        sits at or below ``strike`` × the long-run anchor.  Stability-aware
        subclasses extend this with a queue-drift term."""
        return rp_f <= self.strike * rp_a + 1e-12

    def _re_defer(self, jid: int, rp_f: float, rp_a: float) -> bool:
        """Re-deferral test for admitted-but-unstarted jobs: hysteresis,
        because withdrawing an in-flight placement wastes the already
        billed acquisition time — only a real spike re-defers."""
        return rp_f > self.strike * rp_a * (1.0 + self.hold_hysteresis) \
            + 1e-12

    # -- the round review ----------------------------------------------------
    def review(self, view, d_hat_s: float) -> Tuple[Set[int], Set[int]]:
        """Review every deferrable unstarted job at ``view.time``.

        Returns ``(held, forced)``: job ids to keep out of this round's
        task set, and jobs force-admitted by their latest-start bound this
        round (the scheduler routes those through its forced-partial
        path).  Jobs that started running are dropped from tracking.
        """
        if not view.deferrable:
            self._admitted.clear()
            self._force.clear()
            return set(), set()
        pending = view.pending if view.pending is not None else set()
        live_jobs = set(view.tasks.job_ids.tolist())
        # intersect with the jobs actually present in the view: an earlier
        # admission layer in a policy stack may already have stripped some
        # held jobs' tasks, and those are no longer this review's to judge.
        # Service jobs are never deferral candidates — holding a latency
        # job for a price dip forfeits utility it can never earn back.
        candidates = (set(view.deferrable) & pending & live_jobs
                      - set(view.service or ()))
        self._admitted &= live_jobs & pending  # started/done jobs drop out
        self._force &= live_jobs
        self._held_rounds = {j: r for j, r in self._held_rounds.items()
                             if j in live_jobs}
        if not candidates:
            return set(), set()

        now = view.time
        est = self._estimates(view)
        deadlines = view.deadline_s or {}
        job_tasks: Dict[int, list] = {}
        for tid, jid in zip(view.tasks.ids.tolist(),
                            view.tasks.job_ids.tolist()):
            job_tasks.setdefault(jid, []).append(tid)

        held: Set[int] = set()
        forced: Set[int] = set()
        # per-horizon cache of both sides of the strike comparison
        cache: Dict[float, Tuple[Catalog, Catalog]] = {}
        anchor = self.forecaster.anchor_catalog(self.catalog, now)
        for jid in sorted(candidates):
            dur = est.get(jid, d_hat_s)
            dl = deadlines.get(jid)
            if jid in self._force or (
                    dl is not None
                    and now >= latest_start_s(dl, dur, self.margin,
                                              self.overhead_s)):
                # the deadline bound: admit regardless of price
                if jid not in self._admitted:
                    self.forced_admissions += 1
                    self.admissions += 1
                    forced.add(jid)
                self._admitted.add(jid)
                self._force.discard(jid)
                continue
            h = max(dur, self.min_horizon_s)
            pair = cache.get(h)
            if pair is None:
                pair = (self.forecaster.forecast_catalog(
                    self.catalog, now, h).credit_priced(h),
                    anchor.credit_priced(h))
                cache[h] = pair
            rp_f = self._job_rp(view, job_tasks[jid], pair[0])
            rp_a = self._job_rp(view, job_tasks[jid], pair[1])
            if jid in self._admitted:
                if self._re_defer(jid, rp_f, rp_a):
                    self._admitted.discard(jid)
                    self.re_deferrals += 1
                    self._hold(jid, held)
                continue
            if self._admit_now(jid, rp_f, rp_a):
                self._admitted.add(jid)
                self.admissions += 1
            else:
                self._hold(jid, held)
        return held, forced
