# Price-pressure autoscaling: horizon price forecasts + forecast-driven
# admission control / deadline-bounded deferral of the job population.
from .admission import (ADMIT_OVERHEAD_S, RUNTIME_MARGIN, AdmissionController,
                        latest_start_s)
from .forecast import (OUForecaster, PersistenceForecaster, PriceForecaster,
                       RegionForecaster, TraceForecaster)

__all__ = ["ADMIT_OVERHEAD_S", "RUNTIME_MARGIN", "AdmissionController",
           "latest_start_s", "OUForecaster", "PersistenceForecaster",
           "PriceForecaster", "RegionForecaster", "TraceForecaster"]
