"""Horizon price forecasts from any ``core.catalog.PriceModel``.

``PriceForecaster`` answers the planning question admission control needs:
*what will this market cost, on average, over the next H seconds* — and
*what does it cost in the long run* (the anchor a strike price is derived
from).  One forecaster per price-model kind, mirroring the ``PriceModel``
hierarchy (Gao 2020's predictive-autoscaler horizon forecasts are the
reference design):

* ``PriceForecaster`` (static passthrough) — prices never move, so the
  forecast is *exact*: forecast == anchor == base costs.
* ``OUForecaster`` — closed-form mean reversion of the discrete OU
  log-price process the ``MeanRevertingPriceModel`` samples:
  ``E[x_k] = mu + (x_0 - mu)(1 - r)^k``; the horizon forecast averages the
  median path ``exp(E[x_k])`` over the horizon steps (clipped to the
  model's own price band) and converges to the stationary mean
  (``discount`` x on-demand) as the horizon grows.
* ``TraceForecaster`` — *lookahead-free* empirical forecast for replayed
  traces: only breakpoints at times <= now are consulted (the future of
  the trace is exactly what a deployed forecaster would not have).  The
  current multiplier is assumed to persist for the median observed
  holding time, then revert to an empirical quantile (default the median)
  of the history.
* ``RegionForecaster`` — block-composition over a ``RegionPriceModel``:
  each region's sub-model is forecast by its own forecaster.
* ``MarketForecaster`` — the same composition over a ``MarketPriceModel``
  (heterogeneous blocks: provider markets next to commitment pools).

All forecasters compose with the catalog exactly like ``catalog.at``:
``forecast_catalog(catalog, now_s, horizon_s)`` returns a snapshot whose
costs are the forecast mean hourly prices (Algorithm-1 order recomputed),
so downstream ``credit_priced`` / ``reservation_prices`` stack unchanged —
on a burstable market ``forecast_catalog(...).credit_priced(horizon_s)``
prices the *forecast effective $/throughput* of running over the horizon.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from ..core.catalog import (Catalog, MarketPriceModel,
                            MeanRevertingPriceModel, PriceModel,
                            RegionPriceModel, TracePriceModel)


class PriceForecaster:
    """Static passthrough base: prices never move, the forecast is exact."""

    kind = "static"

    def mean_multipliers(self, n_types: int, now_s: float,
                         horizon_s: float) -> np.ndarray:
        """(K,) forecast mean price multiplier over [now, now + horizon]."""
        return np.ones(n_types)

    def anchor_multipliers(self, n_types: int, now_s: float) -> np.ndarray:
        """(K,) long-run mean multiplier as estimable *at* ``now`` (the
        reservation-price anchor strike prices are derived from).  Never
        uses information past ``now``."""
        return np.ones(n_types)

    # -- catalog composition -------------------------------------------------
    def _snapshot(self, catalog: Catalog, mult: np.ndarray) -> Catalog:
        base = catalog.base_costs if catalog.base_costs is not None \
            else catalog.costs
        costs = base * mult
        order = np.argsort(-costs, kind="stable")
        return dataclasses.replace(catalog, costs=costs, order_desc=order,
                                   base_costs=base)

    def forecast_catalog(self, catalog: Catalog, now_s: float,
                         horizon_s: float) -> Catalog:
        """Snapshot priced at the forecast mean over [now, now + horizon].
        Composes with ``credit_priced`` for burstable catalogs."""
        if self.kind == "static":
            return catalog  # exact: the identity, like Catalog.at
        return self._snapshot(catalog, self.mean_multipliers(
            len(catalog), now_s, horizon_s))

    def anchor_catalog(self, catalog: Catalog, now_s: float) -> Catalog:
        """Snapshot priced at the long-run mean (strike-price anchor)."""
        if self.kind == "static":
            return catalog
        return self._snapshot(catalog,
                              self.anchor_multipliers(len(catalog), now_s))

    # -- constructors --------------------------------------------------------
    @staticmethod
    def for_model(pm: Optional[PriceModel]) -> "PriceForecaster":
        if pm is None or pm.is_static:
            return PriceForecaster()
        if isinstance(pm, MarketPriceModel):
            return MarketForecaster(pm)
        if isinstance(pm, RegionPriceModel):
            return RegionForecaster(pm)
        if isinstance(pm, MeanRevertingPriceModel):
            return OUForecaster(pm)
        if isinstance(pm, TracePriceModel):
            return TraceForecaster(pm)
        return PersistenceForecaster(pm)

    @staticmethod
    def for_catalog(catalog: Catalog) -> "PriceForecaster":
        return PriceForecaster.for_model(catalog.price_model)


class PersistenceForecaster(PriceForecaster):
    """Fallback for unknown dynamic models: the current price persists, the
    anchor is the model's declared long-run mean."""

    kind = "persistence"

    def __init__(self, pm: PriceModel):
        self.pm = pm

    def mean_multipliers(self, n_types, now_s, horizon_s):
        return np.asarray(self.pm.multipliers_at(n_types, now_s), dtype=float)

    def anchor_multipliers(self, n_types, now_s):
        mm = np.asarray(self.pm.mean_multiplier, dtype=np.float64)
        return np.full(n_types, float(mm)) if mm.ndim == 0 \
            else np.broadcast_to(mm, (n_types,)).copy()


class OUForecaster(PriceForecaster):
    """Closed-form forecast of the mean-reverting (OU) log-price model.

    The model samples ``x_{i+1} = x_i + r (mu - x_i) + sigma eps``, so the
    conditional mean after k steps is ``mu + (x_0 - mu)(1 - r)^k`` — no
    simulation needed.  The horizon forecast averages the median path
    ``exp(E[x_k])`` over the horizon's steps, clipped to the model's price
    band, and the anchor is the stationary mean ``exp(mu) = discount``.
    """

    kind = "ou"

    def __init__(self, pm: MeanRevertingPriceModel):
        self.pm = pm

    def mean_multipliers(self, n_types, now_s, horizon_s):
        pm = self.pm
        x0 = np.log(pm.multipliers_at(n_types, now_s))
        mu = math.log(pm.discount)
        n_steps = max(int(math.ceil(max(horizon_s, 0.0) / pm.step_s)), 1)
        decay = (1.0 - pm.reversion) ** np.arange(n_steps)  # (S,)
        paths = np.exp(mu + np.outer(decay, x0 - mu))  # (S, K) median path
        return np.clip(paths, pm.discount / 10.0, 1.0).mean(axis=0)

    def anchor_multipliers(self, n_types, now_s):
        return np.full(n_types, self.pm.discount)


class TraceForecaster(PriceForecaster):
    """Lookahead-free empirical forecast of a replayed price trace.

    Consults only breakpoints at times <= now — never the trace's future.
    The current multiplier is assumed to persist for the median holding
    time observed so far, then revert to the ``quantile`` (default median)
    of the multipliers seen so far; the horizon forecast is the
    time-weighted blend of the two.  The anchor is the same empirical
    quantile, so both sides of the strike comparison are causal.
    """

    kind = "trace"

    def __init__(self, pm: TracePriceModel, quantile: float = 0.5):
        self.pm = pm
        assert 0.0 <= quantile <= 1.0
        self.quantile = float(quantile)

    def _history(self, now_s: float):
        """(times, values) of breakpoints at or before ``now`` (at least the
        first one, matching ``multipliers_at``'s clamp below the trace)."""
        pm = self.pm
        idx = int(np.searchsorted(pm.times_s, now_s, side="right"))
        idx = max(idx, 1)
        return pm.times_s[:idx], pm.multipliers[:idx]

    def _per_type(self, vals: np.ndarray, n_types: int) -> np.ndarray:
        if vals.ndim == 1:
            return np.broadcast_to(vals[:, None], (len(vals), n_types))
        return vals

    def mean_multipliers(self, n_types, now_s, horizon_s):
        times, vals = self._history(now_s)
        vals = self._per_type(np.asarray(vals, dtype=np.float64), n_types)
        current = vals[-1]
        anchor = np.quantile(vals, self.quantile, axis=0)
        holds = np.diff(times)
        persist_s = float(np.median(holds)) if holds.size else float("inf")
        # the current breakpoint has already been held for now - times[-1]
        persist_left = max(persist_s - (now_s - float(times[-1])), 0.0)
        h = max(float(horizon_s), 1e-9)
        w = min(persist_left, h) / h
        return w * current + (1.0 - w) * anchor

    def anchor_multipliers(self, n_types, now_s):
        _, vals = self._history(now_s)
        vals = self._per_type(np.asarray(vals, dtype=np.float64), n_types)
        return np.quantile(vals, self.quantile, axis=0)


class MarketForecaster(PriceForecaster):
    """Composite forecaster for heterogeneous region blocks
    (``MarketPriceModel``, the multi-provider catalog): block ``i`` covers
    ``counts[i]`` types forecast by its own sub-model's forecaster (static
    for commitment pools)."""

    kind = "multi-provider"

    def __init__(self, pm: MarketPriceModel,
                 subs: Optional[Sequence[PriceForecaster]] = None):
        self.pm = pm
        self.counts = pm.counts
        self.subs = tuple(subs) if subs is not None else tuple(
            PriceForecaster.for_model(m) for m in pm.models)

    def mean_multipliers(self, n_types, now_s, horizon_s):
        assert n_types == sum(self.counts)
        return np.concatenate([
            np.asarray(f.mean_multipliers(c, now_s, horizon_s),
                       dtype=np.float64)
            for f, c in zip(self.subs, self.counts)])

    def anchor_multipliers(self, n_types, now_s):
        assert n_types == sum(self.counts)
        return np.concatenate([
            np.asarray(f.anchor_multipliers(c, now_s), dtype=np.float64)
            for f, c in zip(self.subs, self.counts)])


class RegionForecaster(PriceForecaster):
    """Composite forecaster for a region-expanded catalog: each region's
    block is forecast by its own sub-model's forecaster."""

    kind = "multi-region"

    def __init__(self, pm: RegionPriceModel,
                 subs: Optional[Sequence[PriceForecaster]] = None):
        self.pm = pm
        self.n_base = pm.n_base
        self.subs = tuple(subs) if subs is not None else tuple(
            PriceForecaster.for_model(m) for m in pm.models)

    def _concat(self, fn) -> np.ndarray:
        return np.concatenate([np.asarray(fn(f), dtype=np.float64)
                               for f in self.subs])

    def mean_multipliers(self, n_types, now_s, horizon_s):
        assert n_types == self.n_base * len(self.subs)
        return self._concat(lambda f: f.mean_multipliers(
            self.n_base, now_s, horizon_s))

    def anchor_multipliers(self, n_types, now_s):
        assert n_types == self.n_base * len(self.subs)
        return self._concat(lambda f: f.anchor_multipliers(
            self.n_base, now_s))
