"""Wall-clock span profiler with an inert module-level hook.

``Profiler`` records named spans (start, duration, tags) — plan rounds,
jit warmup vs steady-state execution, simulator sweeps.  Hot paths that
cannot thread a recorder argument (``core/engine_jax.py``) call the
module-level ``span`` context manager, which is a shared ``nullcontext``
unless a profiler has been activated with ``activate`` — one attribute
read and one ``is None`` branch when off, so profiling-disabled runs pay
nothing measurable.

Spans nest; each records its wall-clock duration via
``time.perf_counter``.  The profiler is wall-clock-only by design: it
never touches sim time, RNG or decisions.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Iterator, List, Optional


@dataclasses.dataclass
class Span:
    name: str
    start_s: float           # perf_counter-relative to profiler creation
    duration_s: float = 0.0
    tags: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {"name": self.name, "start_s": round(self.start_s, 6),
             "duration_s": round(self.duration_s, 6)}
        if self.tags:
            d["tags"] = self.tags
        return d


class Profiler:
    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self.spans: List[Span] = []

    @contextlib.contextmanager
    def span(self, name: str, **tags) -> Iterator[Span]:
        s = Span(name, time.perf_counter() - self._t0, tags=dict(tags))
        t0 = time.perf_counter()
        try:
            yield s
        finally:
            s.duration_s = time.perf_counter() - t0
            self.spans.append(s)

    def totals(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def to_dicts(self) -> List[dict]:
        return [s.to_dict() for s in self.spans]


# --- module-level hook for hot paths that can't thread a profiler ----------
_ACTIVE: Optional[Profiler] = None
_NULL = contextlib.nullcontext()


def activate(profiler: Optional[Profiler]) -> None:
    """Install (or, with ``None``, remove) the process-global profiler."""
    global _ACTIVE
    _ACTIVE = profiler


def active() -> Optional[Profiler]:
    return _ACTIVE


def span(name: str, **tags):
    """Span on the active profiler; a shared no-op context when inactive."""
    if _ACTIVE is None:
        return _NULL
    return _ACTIVE.span(name, **tags)
