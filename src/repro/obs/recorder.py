"""``FlightRecorder`` — the bundle threaded through simulator + scheduler.

One recorder per run holds the four observability surfaces:

* ``events``   — :class:`repro.obs.events.EventLog` (lifecycle + cost)
* ``decisions``— :class:`repro.obs.trace.DecisionTrace` (planner explain)
* ``metrics``  — :class:`repro.obs.metrics.MetricsRegistry` (time series)
* ``profiler`` — :class:`repro.obs.profiler.Profiler` (wall-clock spans)

Attach it to both ends of a run::

    rec = FlightRecorder(meta={"bench": "spot", "scheduler": "eva-spot"})
    sched = EvaScheduler(cat, policies=[...], recorder=rec)
    m = Simulator(cat, jobs, sched, cfg, recorder=rec).run()
    rec.save("results/traces/run.jsonl")

and replay it offline with ``tools/explain.py``.  The JSONL layout is one
object per line, discriminated by ``rec``: a ``meta`` header, then
``event`` / ``cost`` / ``decision`` / ``series`` / ``span`` records.
``FlightRecorder.load`` round-trips the artifact.

The recorder is a pure observer — the hard invariant of the subsystem:
with no recorder attached the hot paths are bit-identical to the seed,
and with one attached decisions are unchanged (both pinned by
``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from .events import EventLog
from .metrics import MetricsRegistry
from .profiler import Profiler
from .trace import DecisionRecord, DecisionTrace

FORMAT_VERSION = 1


class FlightRecorder:
    def __init__(self, meta: Optional[dict] = None):
        self.meta = dict(meta or {})
        self.events = EventLog()
        self.decisions = DecisionTrace()
        self.metrics = MetricsRegistry()
        self.profiler = Profiler()

    # -- serialization ------------------------------------------------------
    def to_jsonl(self) -> str:
        lines = [json.dumps({"rec": "meta", "version": FORMAT_VERSION,
                             **self.meta})]
        for e in self.events:
            lines.append(json.dumps({"rec": "event", **e.to_dict()}))
        for (cat, key), amt in self.events.costs.items():
            lines.append(json.dumps({"rec": "cost", "category": cat,
                                     "key": key, "amount": amt}))
        for r in self.decisions:
            lines.append(json.dumps({"rec": "decision", **r.to_dict()}))
        md = self.metrics.to_dict()
        if any(md.values()):
            lines.append(json.dumps({"rec": "series", **md}))
        for s in self.profiler.to_dicts():
            lines.append(json.dumps({"rec": "span", **s}))
        return "\n".join(lines) + "\n"

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path

    @classmethod
    def load(cls, path: str) -> "FlightRecorder":
        rec = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                kind = d.pop("rec", None)
                if kind == "meta":
                    d.pop("version", None)
                    rec.meta = d
                elif kind == "event":
                    from .events import Event
                    ev = Event.from_dict(d)
                    # JSON round-trips tuples as lists; re-freeze id payloads
                    ev = Event(ev.t, ev.kind, ev.instance_id, ev.job_id,
                               tuple((k, tuple(v) if isinstance(v, list)
                                      else v) for k, v in ev.fields))
                    rec.events.events.append(ev)
                elif kind == "cost":
                    rec.events.record_cost(d["category"], d["key"],
                                           float(d["amount"]))
                elif kind == "decision":
                    rec.decisions.append(DecisionRecord.from_dict(d))
                elif kind == "series":
                    rec.metrics = MetricsRegistry.from_dict(d)
                elif kind == "span":
                    from .profiler import Span
                    rec.profiler.spans.append(Span(
                        d["name"], float(d["start_s"]),
                        float(d["duration_s"]), d.get("tags", {})))
        return rec
