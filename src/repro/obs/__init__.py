# Flight recorder: structured decision telemetry, timeline event log and
# per-layer cost attribution across planner, policy stack and simulator.
# Pure observer by contract — recording off is bit-identical, recording on
# is decision-identical (tests/test_obs.py pins both).
from . import events, profiler
from .events import (COST_COMMITMENT, COST_EGRESS, COST_INSTANCE, Event,
                     EventLog)
from .metrics import Histogram, MetricsRegistry, Series
from .profiler import Profiler, Span
from .recorder import FlightRecorder
from .report import Reporter
from .trace import DecisionRecord, DecisionTrace, KeepEntry

__all__ = [
    "events", "profiler",
    "COST_COMMITMENT", "COST_EGRESS", "COST_INSTANCE", "Event", "EventLog",
    "Histogram", "MetricsRegistry", "Series",
    "Profiler", "Span",
    "FlightRecorder",
    "Reporter",
    "DecisionRecord", "DecisionTrace", "KeepEntry",
]
