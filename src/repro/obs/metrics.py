"""Counter / gauge / histogram time-series registry with ring buffers.

``MetricsRegistry`` is the flight recorder's numeric surface: named
counters (monotone totals), gauges sampled into bounded ring buffers
(cost burn rate per region, queue depth, SLO risk, credit balances), and
fixed-bucket histograms.  Everything serializes to the JSONL artifact and
to Prometheus text exposition format (``prom_text``), so a run can be
scraped or diffed with standard tooling.

Ring buffers keep the artifact bounded on long runs: each gauge retains
the most recent ``maxlen`` (default 4096) samples; ``dropped`` counts
what scrolled off, so downsampling is explicit, never silent.
"""
from __future__ import annotations

import bisect
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

_DEFAULT_BUCKETS = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0, float("inf"))


class Series:
    """One gauge's (t, value) ring buffer."""

    def __init__(self, maxlen: int = 4096):
        self.samples: Deque[Tuple[float, float]] = deque(maxlen=maxlen)
        self.dropped = 0

    def add(self, t: float, value: float) -> None:
        if len(self.samples) == self.samples.maxlen:
            self.dropped += 1
        self.samples.append((t, float(value)))

    @property
    def last(self) -> Optional[Tuple[float, float]]:
        return self.samples[-1] if self.samples else None

    def values(self) -> List[float]:
        return [v for _, v in self.samples]


class Histogram:
    """Fixed cumulative buckets (Prometheus convention: le upper bounds)."""

    def __init__(self, buckets: Sequence[float] = _DEFAULT_BUCKETS):
        self.bounds = tuple(sorted(buckets))
        self.counts = [0] * len(self.bounds)
        self.total = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        self.total += 1
        self.sum += value
        i = bisect.bisect_left(self.bounds, value)
        if i < len(self.counts):
            self.counts[i] += 1

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


class MetricsRegistry:
    def __init__(self, maxlen: int = 4096):
        self.maxlen = maxlen
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, Series] = {}
        self.histograms: Dict[str, Histogram] = {}

    # -- emission -----------------------------------------------------------
    def inc(self, name: str, amount: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + amount

    def sample(self, name: str, t: float, value: float) -> None:
        s = self.gauges.get(name)
        if s is None:
            s = self.gauges[name] = Series(self.maxlen)
        s.add(t, value)

    def observe(self, name: str, value: float,
                buckets: Sequence[float] = _DEFAULT_BUCKETS) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(buckets)
        h.observe(value)

    # -- export -------------------------------------------------------------
    @staticmethod
    def _prom_name(name: str) -> str:
        """metric{label="x"} spelling for dotted/slashed series names."""
        if ":" in name:
            base, label = name.split(":", 1)
            base = base.replace(".", "_").replace("-", "_").replace("/", "_")
            return f'{base}{{key="{label}"}}'
        return name.replace(".", "_").replace("-", "_").replace("/", "_")

    def prom_text(self) -> str:
        """Prometheus text exposition of counters, last gauge samples and
        histograms (one scrape = the run's final state)."""
        lines: List[str] = []
        for name in sorted(self.counters):
            pn = self._prom_name(name)
            lines.append(f"# TYPE {pn.split('{', 1)[0]} counter")
            lines.append(f"{pn} {self.counters[name]:g}")
        for name in sorted(self.gauges):
            last = self.gauges[name].last
            if last is None:
                continue
            pn = self._prom_name(name)
            lines.append(f"# TYPE {pn.split('{', 1)[0]} gauge")
            lines.append(f"{pn} {last[1]:g}")
        for name in sorted(self.histograms):
            h = self.histograms[name]
            base = self._prom_name(name).split("{", 1)[0]
            lines.append(f"# TYPE {base} histogram")
            for bound, acc in zip(h.bounds, h.cumulative()):
                le = "+Inf" if bound == float("inf") else f"{bound:g}"
                lines.append(f'{base}_bucket{{le="{le}"}} {acc}')
            lines.append(f"{base}_sum {h.sum:g}")
            lines.append(f"{base}_count {h.total}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_dict(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": {n: {"samples": list(s.samples), "dropped": s.dropped}
                       for n, s in self.gauges.items()},
            "histograms": {n: {"bounds": ["inf" if b == float("inf") else b
                                          for b in h.bounds],
                               "counts": h.counts, "sum": h.sum,
                               "total": h.total}
                           for n, h in self.histograms.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MetricsRegistry":
        reg = cls()
        reg.counters = {k: float(v) for k, v in d.get("counters", {}).items()}
        for n, sd in d.get("gauges", {}).items():
            s = reg.gauges[n] = Series(reg.maxlen)
            for t, v in sd["samples"]:
                s.samples.append((float(t), float(v)))
            s.dropped = int(sd.get("dropped", 0))
        for n, hd in d.get("histograms", {}).items():
            bounds = tuple(float("inf") if b == "inf" else float(b)
                           for b in hd["bounds"])
            h = reg.histograms[n] = Histogram(bounds)
            h.counts = [int(c) for c in hd["counts"]]
            h.sum = float(hd["sum"])
            h.total = int(hd["total"])
        return reg
