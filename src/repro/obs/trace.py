"""Per-round planner explain records — why each adopt/evict/veto happened.

``DecisionTrace`` collects one ``DecisionRecord`` per scheduling round,
emitted by ``EvaScheduler.schedule`` when a ``FlightRecorder`` is
attached.  Each record snapshots, at the moment the decision was made:

* the reservation-price landscape (count/min/mean/max over the round's
  planning catalog) and the D̂ horizon the ensemble used;
* the per-instance **keep table**: TNRP saving S, hourly cost ΔM, the
  summed ``keep_bonus`` slack *decomposed by contributing layer*, and the
  resulting keep/evict margin — the S·D̂ > ΔM test made attributable;
* ``type_mask`` / ``region_caps`` provenance (which layer contributed);
* the ensemble arithmetic (S_f, M_f, S_p, M_p, adopt_full) or, for a
  pressure round, the forced-partial context (evacuated instances,
  resumed jobs, incremental dirty set + fallback reason);
* per-layer counter deltas across ``refine`` (arbitrage moves, SLO move
  vetoes, ...), so post-pass rewrites are attributable to their layer.

The trace is a pure observer: the scheduler computes records from the
same inputs the decision used (re-running only pure evaluation helpers),
so recording cannot change a decision — pinned by ``tests/test_obs.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class KeepEntry:
    """One live instance through the keep test."""

    instance_id: int
    type_index: int
    saving: float            # S: TNRP saving of keeping the set ($/h)
    cost: float              # ΔM stand-in: the instance's hourly cost
    bonus: float             # summed keep_bonus slack ($/h)
    bonus_by_layer: Dict[str, float]
    kept: bool               # S >= ΔM - bonus (the planner's keep test)

    @property
    def margin(self) -> float:
        """Positive = kept with room; negative = evicted by this much."""
        return self.saving - (self.cost - self.bonus)

    def to_dict(self) -> dict:
        return {"instance_id": self.instance_id,
                "type_index": self.type_index,
                "saving": self.saving, "cost": self.cost,
                "bonus": self.bonus, "bonus_by_layer": self.bonus_by_layer,
                "margin": self.margin, "kept": self.kept}


@dataclasses.dataclass
class DecisionRecord:
    t: float
    round_index: int
    kind: str                    # "ensemble" | "full-only" | "partial-only"
    #                            # | "forced-partial"
    d_hat_s: float
    n_tasks: int = 0
    n_pending: int = 0
    rp_min: float = 0.0
    rp_mean: float = 0.0
    rp_max: float = 0.0
    keep_table: List[KeepEntry] = dataclasses.field(default_factory=list)
    mask_layers: Tuple[str, ...] = ()      # type_mask provenance
    caps_layer: Optional[str] = None       # region_caps provenance
    # ensemble rounds
    s_full: Optional[float] = None
    m_full: Optional[float] = None
    s_partial: Optional[float] = None
    m_partial: Optional[float] = None
    adopt_full: Optional[bool] = None
    # forced-partial rounds
    evacuated: Tuple[int, ...] = ()
    resumed_jobs: Tuple[int, ...] = ()
    dirty: Tuple[int, ...] = ()
    incremental_fallback: Optional[str] = None
    # per-layer counter deltas across refine (vetoes, arbitrage moves, ...)
    refine_deltas: Dict[str, float] = dataclasses.field(default_factory=dict)

    def keep_entry(self, iid: int) -> Optional[KeepEntry]:
        for e in self.keep_table:
            if e.instance_id == iid:
                return e
        return None

    def to_dict(self) -> dict:
        d = {"t": self.t, "round_index": self.round_index, "kind": self.kind,
             "d_hat_s": self.d_hat_s, "n_tasks": self.n_tasks,
             "n_pending": self.n_pending, "rp_min": self.rp_min,
             "rp_mean": self.rp_mean, "rp_max": self.rp_max,
             "keep_table": [e.to_dict() for e in self.keep_table],
             "mask_layers": list(self.mask_layers),
             "caps_layer": self.caps_layer}
        if self.kind == "forced-partial":
            d["evacuated"] = list(self.evacuated)
            d["resumed_jobs"] = list(self.resumed_jobs)
            d["dirty"] = list(self.dirty)
            d["incremental_fallback"] = self.incremental_fallback
        if self.adopt_full is not None:
            d.update({"s_full": self.s_full, "m_full": self.m_full,
                      "s_partial": self.s_partial,
                      "m_partial": self.m_partial,
                      "adopt_full": self.adopt_full})
        if self.refine_deltas:
            d["refine_deltas"] = self.refine_deltas
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionRecord":
        keep = [KeepEntry(instance_id=e["instance_id"],
                          type_index=e["type_index"], saving=e["saving"],
                          cost=e["cost"], bonus=e["bonus"],
                          bonus_by_layer=dict(e.get("bonus_by_layer", {})),
                          kept=e["kept"])
                for e in d.get("keep_table", [])]
        return cls(t=float(d["t"]), round_index=int(d["round_index"]),
                   kind=d["kind"], d_hat_s=float(d["d_hat_s"]),
                   n_tasks=int(d.get("n_tasks", 0)),
                   n_pending=int(d.get("n_pending", 0)),
                   rp_min=float(d.get("rp_min", 0.0)),
                   rp_mean=float(d.get("rp_mean", 0.0)),
                   rp_max=float(d.get("rp_max", 0.0)),
                   keep_table=keep,
                   mask_layers=tuple(d.get("mask_layers", ())),
                   caps_layer=d.get("caps_layer"),
                   s_full=d.get("s_full"), m_full=d.get("m_full"),
                   s_partial=d.get("s_partial"),
                   m_partial=d.get("m_partial"),
                   adopt_full=d.get("adopt_full"),
                   evacuated=tuple(d.get("evacuated", ())),
                   resumed_jobs=tuple(d.get("resumed_jobs", ())),
                   dirty=tuple(d.get("dirty", ())),
                   incremental_fallback=d.get("incremental_fallback"),
                   refine_deltas=dict(d.get("refine_deltas", {})))


class DecisionTrace:
    """Append-only list of per-round decision records."""

    def __init__(self) -> None:
        self.records: List[DecisionRecord] = []

    def append(self, rec: DecisionRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    def at_or_before(self, t: float) -> Optional[DecisionRecord]:
        """Latest record with timestamp <= t (the round that decided the
        state in force at ``t``)."""
        best = None
        for r in self.records:
            if r.t <= t:
                best = r
        return best

    def last_keep_entry(self, iid: int, before_t: float
                        ) -> Tuple[Optional[DecisionRecord],
                                   Optional[KeepEntry]]:
        """Most recent round at/before ``before_t`` whose keep table saw
        instance ``iid`` — the round that decided its fate."""
        for r in reversed(self.records):
            if r.t > before_t:
                continue
            e = r.keep_entry(iid)
            if e is not None:
                return r, e
        return None, None
