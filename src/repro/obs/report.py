"""Structured run reporter: greppable ``key=value`` lines + JSON verdicts.

Replaces the ad-hoc ``print`` reporting in ``benchmarks/run.py`` and
``tools/bench_compare.py``.  Each ``emit`` prints one line

    [scope] event key=value key=value ...

(values with whitespace are quoted) and appends the record to an
in-memory list, so a CI step can both grep the log and write the whole
run as machine-readable JSON (``--json``) — e.g. the perf gate's
per-cell verdicts.
"""
from __future__ import annotations

import json
import sys
from typing import List, Optional, TextIO


def _fmt(v) -> str:
    if isinstance(v, float):
        s = f"{v:.6g}"
    elif isinstance(v, bool):
        s = "true" if v else "false"
    else:
        s = str(v)
    if any(c.isspace() for c in s) or s == "":
        return json.dumps(s)
    return s


class Reporter:
    def __init__(self, scope: str, stream: Optional[TextIO] = None):
        self.scope = scope
        self.stream = stream if stream is not None else sys.stdout
        self.records: List[dict] = []

    def emit(self, event: str, **kv) -> dict:
        rec = {"event": event, **kv}
        self.records.append(rec)
        line = " ".join([f"[{self.scope}]", event]
                        + [f"{k}={_fmt(v)}" for k, v in kv.items()])
        print(line, file=self.stream)
        return rec

    def of(self, event: str) -> List[dict]:
        return [r for r in self.records if r["event"] == event]

    def write_json(self, path: str, **extra) -> None:
        out = {"scope": self.scope, **extra, "records": self.records}
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
