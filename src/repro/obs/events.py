"""Typed, append-only structured event log — the flight recorder's spine.

Every lifecycle transition the simulator already models becomes one
``Event`` record: provision/terminate, migrate (+ egress), spot notices
and reclaims, credit throttles, defer/admit transitions, pool resizes,
SLO-risk edges and pressure-bus deliveries.  Records are sim-time-stamped
and carry only plain scalars (ints/floats/strings/short tuples), so the
log serializes losslessly to JSONL and replays deterministically.

The log is a *pure observer*: nothing in the simulator reads it back, it
draws no randomness, and with no log attached the emitting code paths are
bit-identical to the seed simulator (pinned by ``tests/test_obs.py``).

Cost attribution rides the same log: every dollar the simulator bills
flows through ``record_cost`` with a category (``instance`` / ``egress``
/ ``commitment``) and a ledger key (region or type name), aggregated into
running per-key sums — the event-cost conservation law
(``tests/test_invariants.py``) pins ``sum(log.costs.values()) ==
Metrics.total_cost`` on randomly composed traces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

# --- event vocabulary (docs/OBSERVABILITY.md documents each kind) ---------
PROVISION = "provision"          # instance requested (iid, type, region)
READY = "ready"                  # instance finished acquisition + setup
TERMINATE = "terminate"          # instance released (lifetime, billed $)
MIGRATE = "migrate"              # task checkpointed toward a new instance
PLACE = "place"                  # pending task assigned a fresh slot
EGRESS = "egress"                # cross-region checkpoint transfer billed
NOTICE = "notice"                # spot revocation notice (reclaim imminent)
PREEMPT = "preempt"              # spot reclaim fired
FAILURE = "failure"              # instance failure (MTBF model)
CAPACITY_DENIED = "capacity_denied"  # launch refused: region at its cap
CREDIT_THROTTLE = "credit_throttle"  # burstable credits exhausted
DEFER_DEADLINE = "defer_deadline"    # deferrable job hit latest-start
ADMIT = "admit"                  # pending job first assigned (PENDING->ADMIT)
WITHDRAW = "withdraw"            # re-deferred placement released pre-launch
POOL_RESIZE = "pool_resize"      # commitment pool grown mid-run
SLO_RISK = "slo_risk"            # service utility risk edge (on/off)
PRESSURE = "pressure"            # PressureBus delivery (kind + ids)
JOB_ARRIVE = "job_arrive"
JOB_DONE = "job_done"
ROUND = "round"                  # scheduling round ran (decision indexed)

KINDS = (PROVISION, READY, TERMINATE, MIGRATE, PLACE, EGRESS, NOTICE,
         PREEMPT, FAILURE, CAPACITY_DENIED, CREDIT_THROTTLE, DEFER_DEADLINE,
         ADMIT, WITHDRAW, POOL_RESIZE, SLO_RISK, PRESSURE, JOB_ARRIVE,
         JOB_DONE, ROUND)

# cost-ledger categories (every billed dollar lands in exactly one)
COST_INSTANCE = "instance"       # per-second / spot-integrated instance bill
COST_EGRESS = "egress"           # cross-region checkpoint transfer fees
COST_COMMITMENT = "commitment"   # standing pool bills (used or idle)


@dataclasses.dataclass(frozen=True)
class Event:
    """One sim-time-stamped lifecycle record.

    ``instance_id`` / ``job_id`` are set when the event concerns one
    (``None`` otherwise); everything else lives in ``fields`` as plain
    scalars so the record round-trips through JSON unchanged.
    """

    t: float
    kind: str
    instance_id: Optional[int] = None
    job_id: Optional[int] = None
    fields: Tuple[Tuple[str, object], ...] = ()

    def get(self, key: str, default=None):
        for k, v in self.fields:
            if k == key:
                return v
        return default

    def to_dict(self) -> dict:
        d = {"t": self.t, "kind": self.kind}
        if self.instance_id is not None:
            d["instance_id"] = self.instance_id
        if self.job_id is not None:
            d["job_id"] = self.job_id
        d.update(dict(self.fields))
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Event":
        core = {"t", "kind", "instance_id", "job_id"}
        return cls(t=float(d["t"]), kind=d["kind"],
                   instance_id=d.get("instance_id"),
                   job_id=d.get("job_id"),
                   fields=tuple((k, v) for k, v in d.items()
                                if k not in core))


class EventLog:
    """Append-only event store + aggregated cost ledger.

    Query helpers are deliberately simple linear scans: the log is an
    offline analysis artifact (``tools/explain.py``), not a hot-path
    index.
    """

    def __init__(self) -> None:
        self.events: List[Event] = []
        # (category, key) -> running billed total; insertion-ordered, so
        # summing the values replays the accrual order deterministically
        self.costs: Dict[Tuple[str, str], float] = {}
        self.cost_entries = 0  # micro-charges folded into the ledger

    # -- emission -----------------------------------------------------------
    def emit(self, t: float, kind: str, *, instance_id: Optional[int] = None,
             job_id: Optional[int] = None, **fields) -> None:
        self.events.append(Event(t, kind, instance_id, job_id,
                                 tuple(sorted(fields.items()))))

    def record_cost(self, category: str, key: str, amount: float) -> None:
        """Fold one billed amount into the (category, key) ledger cell.

        Aggregation (not per-charge append) keeps the artifact bounded:
        spot billing accrues at every simulator event, which would
        otherwise dominate the log with micro-charges.
        """
        cell = (category, key)
        self.costs[cell] = self.costs.get(cell, 0.0) + amount
        self.cost_entries += 1

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[Event]:
        return iter(self.events)

    def of_kind(self, *kinds: str) -> List[Event]:
        return [e for e in self.events if e.kind in kinds]

    def for_instance(self, iid: int) -> List[Event]:
        """Events naming the instance directly, plus pressure signals whose
        id payload contains it."""
        out = []
        for e in self.events:
            if e.instance_id == iid:
                out.append(e)
            elif e.kind == PRESSURE and iid in (e.get("ids") or ()):
                out.append(e)
        return out

    def for_job(self, jid: int) -> List[Event]:
        return [e for e in self.events if e.job_id == jid]

    def between(self, t0: float, t1: float) -> List[Event]:
        return [e for e in self.events if t0 <= e.t <= t1]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def total_cost(self) -> float:
        return sum(self.costs.values())

    def cost_by(self, axis: str = "category") -> Dict[str, float]:
        """Aggregate the ledger along ``category`` or ``key``."""
        i = 0 if axis == "category" else 1
        out: Dict[str, float] = {}
        for cell, v in self.costs.items():
            out[cell[i]] = out.get(cell[i], 0.0) + v
        return out
