"""Stratus (SoCC'18) adapted per §6.1: runtime-binned packing, migration-
averse.  Tasks are co-located only with tasks of a similar remaining-runtime
class (log2 bins), so instances drain together and are released promptly.
Per the paper's best-case comparison, Stratus receives oracle runtime
estimates (total iterations / standalone throughput)."""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

from ..core.catalog import Catalog
from ..core.cluster_types import ClusterConfig
from ..core.scheduler import SchedulerBase, SchedulerView
from .common import (cheapest_fitting_type, fits, preserved_assignments,
                     used_capacity)


def _bin(remaining_s: float) -> int:
    return max(0, math.ceil(math.log2(max(remaining_s, 1.0) / 60.0)))


class StratusScheduler(SchedulerBase):
    name = "stratus"
    needs_runtime_estimates = True

    def schedule(self, view: SchedulerView) -> ClusterConfig:
        rem = view.remaining_s or {}
        assignments = preserved_assignments(view, self.catalog)
        placed = {t for _, tids in assignments for t in tids}
        pending = sorted((t for t in view.tasks.ids.tolist() if t not in placed),
                         key=lambda t: -rem.get(t, 0.0))
        # per-assignment spare capacity + runtime bin (max remaining on board)
        used = [used_capacity(tids, view.tasks, self.catalog, k)
                for k, tids in assignments]
        bins = [max((_bin(rem.get(t, 0.0)) for t in tids), default=0)
                for _, tids in assignments]
        for t in pending:
            row = view.tasks.row(t)
            b = _bin(rem.get(t, 0.0))
            best, best_left = -1, np.inf
            for i, (k, tids) in enumerate(assignments):
                if bins[i] != b:
                    continue
                if not fits(view.tasks, row, self.catalog, k, used[i]):
                    continue
                cap = self.catalog.capacities[k]
                d = view.tasks.demand_by_family[row, self.catalog.family_ids[k], :]
                left = float(((cap - used[i] - d) / np.maximum(cap, 1.0)).sum())
                if left < best_left:
                    best, best_left = i, left
            if best >= 0:
                k = assignments[best][0]
                assignments[best][1].append(t)
                used[best] += view.tasks.demand_by_family[
                    row, self.catalog.family_ids[k], :]
                bins[best] = max(bins[best], b)
            else:
                k = cheapest_fitting_type(view.tasks, row, self.catalog)
                assignments.append((k, [t]))
                used.append(used_capacity([t], view.tasks, self.catalog, k))
                bins.append(b)
        return ClusterConfig([(k, tuple(tids)) for k, tids in assignments])
