"""Shared helpers for the baseline schedulers."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.catalog import Catalog, FAMILIES
from ..core.cluster_types import ClusterConfig, TaskSet
from ..core.scheduler import SchedulerView


def demand_on_type(tasks: TaskSet, row: int, catalog: Catalog, k: int) -> np.ndarray:
    return tasks.demand_by_family[row, catalog.family_ids[k], :]


def used_capacity(tids: Sequence[int], tasks: TaskSet, catalog: Catalog,
                  k: int) -> np.ndarray:
    u = np.zeros(catalog.capacities.shape[1])
    for t in tids:
        u += demand_on_type(tasks, tasks.row(t), catalog, k)
    return u


def fits(tasks: TaskSet, row: int, catalog: Catalog, k: int,
         used: np.ndarray) -> bool:
    d = demand_on_type(tasks, row, catalog, k)
    return bool(np.all(used + d <= catalog.capacities[k] + 1e-9))


def cheapest_fitting_type(tasks: TaskSet, row: int, catalog: Catalog) -> int:
    fam = catalog.family_ids
    d = tasks.demand_by_family[row, fam, :]  # (K, R)
    ok = np.all(d <= catalog.capacities + 1e-9, axis=1)
    costs = np.where(ok, catalog.costs, np.inf)
    return int(costs.argmin())


def cheapest_type_for_set(tids: Sequence[int], tasks: TaskSet,
                          catalog: Catalog) -> Optional[int]:
    """Cheapest type fitting all of ``tids`` together (None if impossible)."""
    fam = catalog.family_ids
    d = np.zeros((len(catalog), catalog.capacities.shape[1]))
    for t in tids:
        d += tasks.demand_by_family[tasks.row(t), fam, :]
    ok = np.all(d <= catalog.capacities + 1e-9, axis=1)
    if not ok.any():
        return None
    costs = np.where(ok, catalog.costs, np.inf)
    return int(costs.argmin())


def preserved_assignments(view: SchedulerView, catalog: Optional[Catalog] = None,
                          downsize: bool = True) -> List[Tuple[int, List[int]]]:
    """Existing placements with completed tasks dropped.

    With ``downsize`` (and a catalog), instances whose surviving tenants fit a
    strictly cheaper type are consolidated onto that type — the minimal
    autoscaler policy that keeps migration-averse schedulers from stranding
    long-running tasks on oversized instances after co-tenants depart.
    """
    system = set(view.tasks.ids.tolist())
    out = []
    for inst in view.live:
        alive = [t for t in inst.task_ids if t in system]
        if not alive:
            continue
        k = inst.type_index
        if downsize and catalog is not None:
            k2 = cheapest_type_for_set(alive, view.tasks, catalog)
            if k2 is not None and catalog.costs[k2] < catalog.costs[k] - 1e-9:
                k = k2
        out.append((k, alive))
    return out
