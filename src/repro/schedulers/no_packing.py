"""No-Packing Scheduler (§6.1): one task per instance, each on its
reservation-price type — the strategy of most existing cloud cluster
managers, and the cost-normalization baseline for all experiments."""
from ..core.scheduler import NoPackingScheduler

__all__ = ["NoPackingScheduler"]
