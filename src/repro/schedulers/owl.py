"""Owl (SoCC'22) adapted per §6.1: interference-minimizing pair co-location.

Owl profiles all pairwise co-location throughputs in advance; the paper
provides this profile exclusively to Owl, so the simulator's ground-truth
matrix is injected at construction.  Pairs are considered in descending
ratio of pair TNRP to the cost of the cheapest instance type accommodating
both, and only low-interference pairs (min pairwise throughput ≥ threshold)
are co-located; everything else runs solo.  No migrations."""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..core.catalog import Catalog
from ..core.cluster_types import ClusterConfig
from ..core.reservation_price import reservation_prices
from ..core.scheduler import SchedulerBase, SchedulerView
from .common import cheapest_fitting_type, preserved_assignments


class OwlScheduler(SchedulerBase):
    name = "owl"
    needs_true_profile = True

    def __init__(self, catalog: Catalog, profile: np.ndarray,
                 min_pair_tput: float = 0.9):
        super().__init__(catalog)
        self.profile = profile
        self.min_pair_tput = min_pair_tput

    def _pair_type(self, r1: int, r2: int, view: SchedulerView) -> Optional[int]:
        fam = self.catalog.family_ids
        d = (view.tasks.demand_by_family[r1, fam, :]
             + view.tasks.demand_by_family[r2, fam, :])
        ok = np.all(d <= self.catalog.capacities + 1e-9, axis=1)
        if not ok.any():
            return None
        costs = np.where(ok, self.catalog.costs, np.inf)
        return int(costs.argmin())

    def schedule(self, view: SchedulerView) -> ClusterConfig:
        rp = reservation_prices(view.tasks, self.catalog)
        assignments = preserved_assignments(view, self.catalog)
        placed = {t for _, tids in assignments for t in tids}
        pending = [t for t in view.tasks.ids.tolist() if t not in placed]

        # candidate pairs: pending×pending (fresh right-sized instance) and
        # pending×running-solo (join the solo task's existing instance if the
        # pair fits it) — Owl continuously fills servers with low-
        # interference pairs; no migrations.
        solos = [(i, k, tids[0]) for i, (k, tids) in enumerate(assignments)
                 if len(tids) == 1]
        cands = []
        for a in range(len(pending)):
            r1 = view.tasks.row(pending[a])
            w1 = view.tasks.workloads[r1]
            for b in range(a + 1, len(pending)):
                r2 = view.tasks.row(pending[b])
                w2 = view.tasks.workloads[r2]
                t12, t21 = self.profile[w1, w2], self.profile[w2, w1]
                if min(t12, t21) < self.min_pair_tput:
                    continue
                k = self._pair_type(r1, r2, view)
                if k is None:
                    continue
                pair_tnrp = t12 * rp[r1] + t21 * rp[r2]
                if pair_tnrp < self.catalog.costs[k] - 1e-9:
                    continue
                cands.append((pair_tnrp / self.catalog.costs[k],
                              pending[a], pending[b], k, None))
            for slot, k, other in solos:
                r2 = view.tasks.row(other)
                w2 = view.tasks.workloads[r2]
                t12, t21 = self.profile[w1, w2], self.profile[w2, w1]
                if min(t12, t21) < self.min_pair_tput:
                    continue
                fam = self.catalog.family_ids[k]
                d = (view.tasks.demand_by_family[r1, fam, :]
                     + view.tasks.demand_by_family[r2, fam, :])
                if not np.all(d <= self.catalog.capacities[k] + 1e-9):
                    continue
                pair_tnrp = t12 * rp[r1] + t21 * rp[r2]
                if pair_tnrp < self.catalog.costs[k] - 1e-9:
                    continue
                cands.append((pair_tnrp / self.catalog.costs[k],
                              pending[a], other, k, slot))
        cands.sort(key=lambda x: -x[0])
        taken, used_slots = set(), set()
        for _, t1, t2, k, slot in cands:
            if t1 in taken or t2 in taken or (slot is not None and slot in used_slots):
                continue
            if slot is None:
                assignments.append((k, [t1, t2]))
            else:
                assignments[slot][1].append(t1)
                used_slots.add(slot)
            taken |= {t1, t2}
        for t in pending:
            if t in taken:
                continue
            k = cheapest_fitting_type(view.tasks, view.tasks.row(t), self.catalog)
            assignments.append((k, [t]))
        return ClusterConfig([(k, tuple(tids)) for k, tids in assignments])
