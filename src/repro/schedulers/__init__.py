from .no_packing import NoPackingScheduler
from .stratus import StratusScheduler
from .synergy import SynergyScheduler
from .owl import OwlScheduler

__all__ = ["NoPackingScheduler", "StratusScheduler", "SynergyScheduler",
           "OwlScheduler"]
