"""Synergy (OSDI'22) adapted per §6.1: best-fit packing to minimize resource
fragmentation, launching the lowest-cost instance type accommodating a task
when nothing fits, enhanced to be interference-aware via TNRP (online
throughput table, same monitor feed as Eva)."""
from __future__ import annotations

from typing import List

import numpy as np

from ..core.catalog import Catalog
from ..core.cluster_types import ClusterConfig
from ..core.reservation_price import reservation_prices
from ..core.scheduler import SchedulerBase, SchedulerView
from ..core.throughput_table import ThroughputTable
from ..core.workloads import NUM_WORKLOADS
from .common import (cheapest_fitting_type, fits, preserved_assignments,
                     used_capacity)


class SynergyScheduler(SchedulerBase):
    name = "synergy"

    def __init__(self, catalog: Catalog, default_t: float = 0.95):
        super().__init__(catalog)
        self.table = ThroughputTable(NUM_WORKLOADS, default=default_t)

    def observe_single(self, workload, colocated, value):
        self.table.observe_single(workload, colocated, value)

    def observe_job(self, placements, value):
        self.table.observe_job(placements, value)

    def _set_tnrp(self, rows: List[int], view: SchedulerView,
                  rp: np.ndarray) -> float:
        ws = view.tasks.workloads[rows]
        total = 0.0
        for i, r in enumerate(rows):
            others = np.delete(ws, i).tolist()
            total += self.table.lookup(int(ws[i]), others) * rp[r]
        return total

    def schedule(self, view: SchedulerView) -> ClusterConfig:
        rp = reservation_prices(view.tasks, self.catalog)
        assignments = preserved_assignments(view, self.catalog)
        placed = {t for _, tids in assignments for t in tids}
        pending = sorted((t for t in view.tasks.ids.tolist() if t not in placed),
                         key=lambda t: -rp[view.tasks.row(t)])
        used = [used_capacity(tids, view.tasks, self.catalog, k)
                for k, tids in assignments]
        for t in pending:
            row = view.tasks.row(t)
            best, best_left = -1, np.inf
            for i, (k, tids) in enumerate(assignments):
                if not fits(view.tasks, row, self.catalog, k, used[i]):
                    continue
                rows = [view.tasks.row(x) for x in tids] + [row]
                if self._set_tnrp(rows, view, rp) < self.catalog.costs[k] - 1e-9:
                    continue  # would make the instance cost-inefficient
                cap = self.catalog.capacities[k]
                d = view.tasks.demand_by_family[row, self.catalog.family_ids[k], :]
                left = float(((cap - used[i] - d) / np.maximum(cap, 1.0)).sum())
                if left < best_left:
                    best, best_left = i, left
            if best >= 0:
                k = assignments[best][0]
                assignments[best][1].append(t)
                used[best] += view.tasks.demand_by_family[
                    row, self.catalog.family_ids[k], :]
            else:
                k = cheapest_fitting_type(view.tasks, row, self.catalog)
                assignments.append((k, [t]))
                used.append(used_capacity([t], view.tasks, self.catalog, k))
        return ClusterConfig([(k, tuple(tids)) for k, tids in assignments])
