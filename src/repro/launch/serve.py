"""Serving launcher: continuous-batch prefill+decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --requests 8

Serves synthetic requests with a shared KV-cache budget: each round admits
up to --batch requests, prefills them together, then decodes all sequences
in lockstep until completion (length sampled per request) — the standard
static-batch serving loop; the dry-run's prefill/decode cells are exactly
these two program shapes at production scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..models.lm import init_params
from ..models.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch].reduced() if args.reduced else ARCHS[args.arch]
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    cache_len = args.prompt_len + args.max_new
    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=1)

    done = 0
    total_tokens = 0
    t0 = time.time()
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len))
        lengths = rng.integers(4, args.max_new + 1, size=args.batch)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.enc_dec:
            batch["enc_embeds"] = jnp.zeros(
                (args.batch, cfg.enc_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for i in range(int(lengths.max()) - 1):
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        jax.block_until_ready(tok)
        total_tokens += int(lengths[:n].sum())
        done += n
        print(f"[serve] round done: {done}/{args.requests} requests, "
              f"{total_tokens} tokens, "
              f"{total_tokens / (time.time() - t0):.1f} tok/s")
    print(f"[serve] complete in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
