"""ShapeDtypeStruct input stand-ins + shardings for every (arch × shape)
dry-run cell: weak-type-correct, shardable, zero device allocation."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models import lm
from ..models.params import abstract_tree, spec_tree
from ..models.sharding import spec_for
from ..train.optimizer import OptConfig


def batch_spec(mesh) -> P:
    names = [n for n in ("pod", "data") if n in mesh.shape]
    return P(tuple(names) if len(names) > 1 else (names[0] if names else None))


def _shard(mesh, tree, specs):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        tree, specs)


def _batched(mesh, shape: Tuple[int, ...], dtype, profile: str = "2d"):
    from ..models.sharding import PROFILES
    spec = spec_for(shape, ("batch",) + (None,) * (len(shape) - 1), mesh,
                    rules=PROFILES[profile][1])
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def train_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh, profile: str = "2d"):
    """(state, batch) abstract inputs for train_step."""
    params = lm.abstract_params(cfg)
    pspecs = lm.param_pspecs(cfg, mesh, profile)
    params = _shard(mesh, params, pspecs)
    opt = {"m": params, "v": params,
           "step": jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=NamedSharding(mesh, P()))}
    state = {"params": params, "opt": opt}
    B, S = shape.batch, shape.seq
    batch = {"tokens": _batched(mesh, (B, S), jnp.int32, profile),
             "labels": _batched(mesh, (B, S), jnp.int32, profile)}
    if cfg.enc_dec:
        batch["enc_embeds"] = _batched(mesh, (B, cfg.enc_seq, cfg.d_model),
                                       jnp.dtype(cfg.compute_dtype), profile)
    return state, batch


def prefill_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh, profile: str = "2d"):
    params = _shard(mesh, lm.abstract_params(cfg), lm.param_pspecs(cfg, mesh, profile))
    B, S = shape.batch, shape.seq
    batch = {"tokens": _batched(mesh, (B, S), jnp.int32, profile)}
    if cfg.enc_dec:
        batch["enc_embeds"] = _batched(mesh, (B, cfg.enc_seq, cfg.d_model),
                                       jnp.dtype(cfg.compute_dtype), profile)
    return params, batch


def decode_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh, profile: str = "2d"):
    """(params, cache, tokens, pos) for decode_step: one new token against a
    KV cache / state of shape.seq context."""
    params = _shard(mesh, lm.abstract_params(cfg), lm.param_pspecs(cfg, mesh, profile))
    B, S = shape.batch, shape.seq
    cache = _shard(mesh, lm.abstract_cache(cfg, B, S),
                   lm.cache_pspecs(cfg, B, S, mesh, profile))
    tokens = _batched(mesh, (B, 1), jnp.int32, profile)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return params, cache, tokens, pos


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh, profile: str = "2d"):
    if shape.kind == "train":
        return train_inputs(cfg, shape, mesh, profile)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape, mesh, profile)
    return decode_inputs(cfg, shape, mesh, profile)
