"""Roofline synthesis: combine dry-run artifacts (per-device HLO FLOPs +
collective bytes) with an analytic HBM-traffic model and analytic
MODEL_FLOPS.

Why analytic memory: on the CPU dry-run, HLO "bytes accessed" reflects the
CPU buffer plan — flash-attention/fusion intermediates that stay in VMEM on
the TPU target would be counted as HBM traffic.  The analytic model counts
what actually crosses TPU HBM per step:

 train:  params f32 read (fwd+bwd) + grad write + Adam m/v read+write +
         param write  (= 32·P_dev bytes)  + remat-boundary activations
         (write fwd, read bwd + recompute rw ≈ 6·L·B·S·D·bf16)  + CE logits
         chunk traffic + token embedding reads.
 prefill: params read + activations once + cache write.
 decode:  params read + full KV-cache/state read + one-slot write
          (the classic bandwidth-bound regime).

MODEL_FLOPS = 6·N·D (dense; N_active for MoE) + 12·L·S²·d_attn causal
attention term for the ratio against HLO FLOPs.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from ..models import lm

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 2 ** 30


def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: shared + top-k routed experts)."""
    total = lm.num_params(cfg)
    if not cfg.moe:
        return total
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    per_expert = 3 * cfg.d_model * cfg.expert_d_ff
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def attention_flops_per_layer(cfg: ArchConfig, S: int, B: int) -> float:
    """Causal self-attention matmul FLOPs per layer (2·QK + 2·PV halved for
    causality)."""
    if cfg.ssm:
        # SSD: intra-chunk "attention" within chunk Q + state updates
        d_inner = cfg.ssm_expand * cfg.d_model
        q = cfg.ssd_chunk
        return 2.0 * B * S * (q * d_inner + 2 * d_inner * cfg.ssm_state)
    hd, H = cfg.hd, cfg.n_heads
    window = cfg.local_window if cfg.attn_kind == "local" else None
    n_attn = sum(1 for k in lm.layer_kinds(cfg) if k not in ("ssm", "rglru"))
    frac = n_attn / max(cfg.n_layers, 1)
    eff_S = min(S, window) if window else S
    return frac * 2.0 * B * S * eff_S * H * hd * 2 * 0.5


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Analytic useful FLOPs per step (global, fwd+bwd for train)."""
    B, S = shape.batch, shape.seq
    n_act = active_params(cfg)
    if shape.kind == "train":
        dense = 6.0 * n_act * B * S
        attn = 3.0 * attention_flops_per_layer(cfg, S, B) * cfg.n_layers
        return dense + attn
    if shape.kind == "prefill":
        dense = 2.0 * n_act * B * S
        attn = attention_flops_per_layer(cfg, S, B) * cfg.n_layers
        return dense + attn
    # decode: one token; attention is a matvec over the cache
    dense = 2.0 * n_act * B
    if cfg.ssm:
        d_inner = cfg.ssm_expand * cfg.d_model
        attn = 4.0 * B * d_inner * cfg.ssm_state * cfg.n_layers
    else:
        window = cfg.local_window if cfg.attn_kind == "local" else None
        eff_S = min(S, window) if window else S
        n_attn = sum(1 for k in lm.layer_kinds(cfg)
                     if k not in ("ssm", "rglru"))
        attn = 4.0 * B * eff_S * cfg.n_heads * cfg.hd * n_attn
    return dense + attn


def cache_bytes(cfg: ArchConfig, batch: int, ctx: int) -> int:
    tree = lm.abstract_cache(cfg, batch, ctx)
    import jax
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def analytic_memory_bytes(cfg: ArchConfig, shape: ShapeSpec,
                          n_chips: int) -> float:
    """Per-device HBM bytes per step (TPU-target model, see module doc)."""
    B, S = shape.batch, shape.seq
    P_dev = lm.num_params(cfg) / n_chips
    act_dev = cfg.n_layers * B * S * cfg.d_model * 2 / n_chips  # bf16
    if shape.kind == "train":
        param_traffic = 32.0 * P_dev
        act_traffic = 6.0 * act_dev
        # chunked CE keeps logits tiles fused on TPU; HBM sees the hidden
        # states + embedding rows, not the (B,S,V) logits
        ce = 6.0 * B * S * cfg.d_model / n_chips
        return param_traffic + act_traffic + ce
    if shape.kind == "prefill":
        return 4.0 * P_dev + 2.0 * act_dev + cache_bytes(cfg, B, S) / n_chips
    # decode
    return 4.0 * P_dev + 1.5 * cache_bytes(cfg, B, S) / n_chips


def roofline_row(cell: Dict, cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    n_chips = cell.get("n_chips", 256)
    t_comp = cell.get("hlo_flops", 0.0) / PEAK_FLOPS
    mem = analytic_memory_bytes(cfg, shape, n_chips)
    t_mem = mem / HBM_BW
    t_coll = cell.get("collective_bytes", 0) / ICI_BW
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_chips
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())
    # roofline fraction: useful work rate vs peak, at the bound implied time
    mfu_bound = mf_dev / PEAK_FLOPS / max(t_bound, 1e-12)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_global": mf,
        "hlo_flops_dev": cell.get("hlo_flops", 0.0),
        "useful_ratio": mf_dev / max(cell.get("hlo_flops", 0.0), 1e-9),
        "roofline_frac": min(mfu_bound, 1.0),
        "mem_bytes_dev": mem,
        "coll_bytes_dev": cell.get("collective_bytes", 0),
    }
