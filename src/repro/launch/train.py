"""Training launcher: ``python -m repro.launch.train --arch smollm-135m
--reduced --steps 200``.

Supports every assigned architecture, reduced or full configs, optional
(data, model) meshes, periodic async checkpointing with restart-resume
(fault tolerance), and deterministic data so a restart reproduces the run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..data.pipeline import Prefetcher, SyntheticTokens, shard_batch
from ..models import lm
from ..models.sharding import mesh_context
from ..models.steps import init_train_state, make_train_step
from ..train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..train.optimizer import OptConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="structure-preserving small config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2 for a (data,model) mesh")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    key = jax.random.PRNGKey(args.seed)
    start_step = 0
    state = init_train_state(cfg, key)
    if args.checkpoint_dir and latest_step(args.checkpoint_dir) is not None:
        state, start_step, _ = restore_checkpoint(args.checkpoint_dir)
        print(f"[train] resumed from step {start_step}")

    oc = OptConfig(lr=args.lr, total_steps=max(args.steps, 1000))
    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=0)
    src = SyntheticTokens(cfg.vocab, args.batch, args.seq, seed=args.seed,
                          start_step=start_step)
    ckpt = AsyncCheckpointer(args.checkpoint_dir) if args.checkpoint_dir else None

    n_params = lm.num_params(cfg)
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")
    tok_per_step = args.batch * args.seq
    t0 = time.time()
    with mesh_context(mesh):
        for step in range(start_step, args.steps):
            batch = shard_batch(src.next_batch(), mesh)
            if cfg.enc_dec:
                batch["enc_embeds"] = jnp.zeros(
                    (args.batch, cfg.enc_seq, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
            state, metrics = step_fn(state, batch)
            if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                tps = tok_per_step * (step + 1 - start_step) / max(dt, 1e-9)
                print(f"[train] step={step + 1} loss={loss:.4f} "
                      f"tok/s={tps:,.0f}")
                assert np.isfinite(loss), "loss diverged"
            if ckpt and (step + 1) % args.checkpoint_every == 0:
                ckpt.save(state, step + 1)
    if ckpt:
        ckpt.save(state, args.steps)
        ckpt.wait()
        print(f"[train] checkpointed at {args.checkpoint_dir}")
    return state


if __name__ == "__main__":
    main()
