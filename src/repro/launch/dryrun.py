import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, and extract the roofline terms.

MUST be executed as a standalone process (``python -m repro.launch.dryrun``):
the XLA_FLAGS line above runs before any other import — including jax —
because jax locks the device count on first init.  Results are cached per
cell in a JSON file so interrupted sweeps resume for free.

Per cell we record:
  * per-device bytes from compiled.memory_analysis() (proves it fits HBM),
  * HLO FLOPs / bytes from compiled.cost_analysis(),
  * collective bytes parsed from the partitioned HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
  * the three roofline terms against TPU v5e constants.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import ARCHS, SHAPES, shape_applicable  # noqa: E402
from ..models import lm  # noqa: E402
from ..models.sharding import mesh_context  # noqa: E402
from ..models.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                            make_train_step)
from .mesh import make_production_mesh  # noqa: E402
from .specs import input_specs  # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link
HBM_BYTES = 16 * 2 ** 30

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO shape string like 'bf16[256,4096]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo_text: str):
    """Split HLO text into {computation_name: [lines]}."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\))? ?->", line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line.strip())
    return comps


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in partitioned HLO,
    multiplying ops inside while-loop bodies (scan-over-layers, CE chunks)
    by their trip counts.  Trip counts are recovered from the largest
    integer constant in the loop's condition computation — exact for
    scan-lowered loops.  Returns (total_bytes, per_kind, op_count)."""
    comps = _parse_computations(hlo_text)

    # while ops: (parent_comp, body_name, cond_name)
    whiles = []
    for cname, lines in comps.items():
        for s in lines:
            m = re.search(r"\bwhile\(.*?\), condition=%?([\w\.\-]+), "
                          r"body=%?([\w\.\-]+)", s)
            if m:
                whiles.append((cname, m.group(2), m.group(1)))

    def trip_count(cond_name: str) -> int:
        best = 1
        for s in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", s):
                best = max(best, int(m.group(1)))
        return best

    # multiplier per computation (nested whiles compose)
    mult = {c: 1 for c in comps}
    changed = True
    iters = 0
    while changed and iters < 10:
        changed = False
        iters += 1
        for parent, body, cond in whiles:
            want = mult.get(parent, 1) * trip_count(cond)
            if mult.get(body, 1) != want:
                mult[body] = want
                changed = True

    per = {k: 0 for k in _COLLECTIVES}
    count = 0
    for cname, lines in comps.items():
        m_c = mult.get(cname, 1)
        for s in lines:
            m = re.match(r"(?:ROOT )?%?[\w\.\-]+ = (.+?) (\w[\w\-]*)\(", s)
            if not m:
                continue
            shape_str, opname = m.group(1), m.group(2)
            for kind in _COLLECTIVES:
                if opname == kind or opname.startswith(kind + "-start"):
                    per[kind] += _shape_bytes(shape_str) * m_c
                    count += m_c
                    break
    return sum(per.values()), per, count


def step_fn_and_inputs(arch: str, shape_name: str, mesh, profile: str = "2d"):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    inputs = input_specs(cfg, shape, mesh, profile)
    if shape.kind == "train":
        fn = make_train_step(cfg)
        in_shardings = jax.tree.map(lambda s: s.sharding, inputs)
        donate = (0,)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        in_shardings = jax.tree.map(lambda s: s.sharding, inputs)
        donate = ()
    else:
        fn = make_decode_step(cfg)
        in_shardings = jax.tree.map(lambda s: s.sharding, inputs)
        donate = (1,)  # cache donated
    return fn, inputs, in_shardings, donate


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             extract_roofline: bool = True, profile: str = "2d",
             mesh_shape=None):
    if mesh_shape is not None:  # logical re-mesh of the same 256-chip pod
        mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    cfg = ARCHS[arch]
    t0 = time.time()
    with mesh_context(mesh, profile=profile):
        fn, inputs, in_shardings, donate = step_fn_and_inputs(
            arch, shape_name, mesh, profile)
        jfn = jax.jit(fn, in_shardings=None, donate_argnums=donate)
        lowered = jfn.lower(*inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    out = {"arch": arch, "shape": shape_name, "profile": profile,
           "mesh": "multi_pod" if multi_pod else "single_pod",
           "n_chips": n_chips, "ok": True,
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}

    try:
        ma = compiled.memory_analysis()
        out["bytes_per_device"] = int(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0))
        out["temp_bytes"] = int(getattr(ma, "temp_size_in_bytes", 0))
        out["arg_bytes"] = int(getattr(ma, "argument_size_in_bytes", 0))
    except Exception as e:  # CPU backend may not support it
        out["memory_analysis_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        out["hlo_flops"] = float(ca.get("flops", 0.0))
        out["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:
        out["cost_analysis_error"] = str(e)

    if extract_roofline:
        try:
            from .hlo_analysis import analyze
            text = compiled.as_text()
            res = analyze(text)
            out["hlo_flops"] = res["flops"]  # loop-aware (overrides XLA's
            out["hlo_bytes"] = res["traffic_bytes"]  # once-per-loop counts)
            out["collective_bytes"] = res["collective_bytes"]
            out["collective_ops"] = res["collective_ops"]
            out["collective_by_kind"] = res["collective_by_kind"]
        except Exception as e:
            out["collective_error"] = str(e)

    # roofline terms (per-device quantities / per-chip rates)
    if "hlo_flops" in out:
        out["t_compute_s"] = out["hlo_flops"] / PEAK_FLOPS
        out["t_memory_s"] = out.get("hlo_bytes", 0.0) / HBM_BW
        out["t_collective_s"] = out.get("collective_bytes", 0) / ICI_BW
        terms = {"compute": out["t_compute_s"], "memory": out["t_memory_s"],
                 "collective": out["t_collective_s"]}
        out["bottleneck"] = max(terms, key=terms.get)
    return out


def cells(archs=None, shapes=None):
    for a in sorted(archs or ARCHS):
        for s in (shapes or SHAPES):
            if shape_applicable(ARCHS[a], SHAPES[s]):
                yield a, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single_pod", "multi_pod", "both"],
                    default="both")
    ap.add_argument("--profile", default="2d",
                    choices=["2d", "fsdp", "inference-tp"])
    ap.add_argument("--mesh-shape", default=None,
                    help="logical DxM re-mesh of the 256-chip pod, e.g. 64x4")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):  # --force re-runs cells but never drops data
        with open(args.out) as f:
            results = json.load(f)

    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])
    todo = [(a, s, m) for a, s in cells(args.arch, args.shape)
            for m in meshes]
    print(f"dry-run: {len(todo)} cells, devices={len(jax.devices())}")
    mesh_shape = None
    if args.mesh_shape:
        mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x"))
    for a, s, m in todo:
        key = f"{a}|{s}|{m}" + ("" if args.profile == "2d"
                                else f"|{args.profile}")
        if mesh_shape:
            key += f"|mesh{args.mesh_shape}"
        if key in results and results[key].get("ok") and not args.force:
            print(f"[cached] {key}")
            continue
        print(f"[run]    {key} ...", flush=True)
        try:
            r = run_cell(a, s, multi_pod=(m == "multi_pod"),
                         profile=args.profile, mesh_shape=mesh_shape)
        except Exception as e:
            r = {"arch": a, "shape": s, "mesh": m, "ok": False,
                 "error": f"{type(e).__name__}: {e}",
                 "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAILED: {r['error']}")
        results[key] = r
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if r.get("ok"):
            print(f"  ok: compile={r.get('compile_s')}s "
                  f"flops={r.get('hlo_flops', 0):.3g} "
                  f"coll={r.get('collective_bytes', 0):.3g}B "
                  f"bottleneck={r.get('bottleneck')}")
    bad = [k for k, v in results.items() if not v.get("ok")]
    print(f"done: {len(results) - len(bad)} ok, {len(bad)} failed")
    for k in bad:
        print(f"  FAIL {k}: {results[k].get('error')}")


if __name__ == "__main__":
    main()
