"""Loop-aware analysis of partitioned HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
undercounts scan-over-layers models by ~n_layers.  This module parses
``compiled.as_text()`` into computations, recovers each while loop's trip
count from its ``backend_config={"known_trip_count":{"n":...}}`` (falling
back to the largest constant in the loop condition), propagates multipliers
through nested loops, and then accumulates:

  * matmul FLOPs        — 2 · prod(output dims) · contracted size per dot
  * collective bytes    — output bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute
  * HBM traffic proxy   — Σ (output bytes + operand bytes) over non-trivial
                          ops (fusion roots, dots, convs, scatters/gathers)

All quantities are PER DEVICE (the text is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8,
                "u4": 1, "s4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# shape group is non-greedy "anything" because tuple shapes embed
# /*index=N*/ comments; the op is the first word directly before a '('.
_INSTR_RE = re.compile(
    r"^(?:ROOT )?%?([\w\.\-]+) = (.*?) ([\w\-]+)\((.*)$")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    rest: str


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        if raw and not raw[0].isspace():
            m = re.match(r"(?:ENTRY )?%?([\w\.\-]+)", raw)
            if m and ("->" in raw or raw.rstrip().endswith("{")):
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        s = raw.strip()
        m = _INSTR_RE.match(s)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


def loop_multipliers(comps: Dict[str, List[Instr]]) -> Dict[str, int]:
    whiles = []  # (parent, body, cond, trip)
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op != "while":
                continue
            mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
            mb = re.search(r"body=%?([\w\.\-]+)", ins.rest)
            mt = re.search(r'known_trip_count[^\d]*(\d+)', ins.rest)
            trip = int(mt.group(1)) if mt else None
            whiles.append((cname, mb.group(1) if mb else None,
                           mc.group(1) if mc else None, trip))

    def cond_trip(cond: Optional[str]) -> int:
        best = 1
        for ins in comps.get(cond or "", []):
            for m in re.finditer(r"constant\((\d+)\)", ins.rest):
                best = max(best, int(m.group(1)))
        return best

    # map called computations (fusions/calls) to parents as multiplier 1;
    # while bodies get trip multipliers; iterate to fixpoint for nesting.
    parent_of: Dict[str, List[Tuple[str, int]]] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            for m in re.finditer(r"(?:calls|body|to_apply)=%?([\w\.\-]+)",
                                 ins.rest):
                trip = 1
                if ins.op == "while":
                    mt = re.search(r'known_trip_count[^\d]*(\d+)', ins.rest)
                    mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                    trip = int(mt.group(1)) if mt else cond_trip(
                        mc.group(1) if mc else None)
                parent_of.setdefault(m.group(1), []).append((cname, trip))

    mult: Dict[str, int] = {}

    def resolve(c: str, depth=0) -> int:
        if c in mult:
            return mult[c]
        if depth > 50 or c not in parent_of:
            mult[c] = 1
            return 1
        best = 1
        for parent, trip in parent_of[c]:
            if parent == c:
                continue
            best = max(best, resolve(parent, depth + 1) * trip)
        mult[c] = best
        return best

    for c in comps:
        resolve(c)
    return mult


def analyze(text: str) -> Dict[str, object]:
    comps = parse_computations(text)
    mult = loop_multipliers(comps)

    # instruction shapes per computation, for dot contraction sizes
    flops = 0.0
    coll = {k: 0 for k in COLLECTIVES}
    coll_ops = 0
    traffic = 0.0
    for cname, instrs in comps.items():
        m_c = mult.get(cname, 1)
        shapes = {ins.name: ins.shape_str for ins in instrs}
        for ins in instrs:
            out_b = _shape_bytes(ins.shape_str)
            if ins.op in ("dot", "dot_general", "convolution"):
                dims = _shape_dims(ins.shape_str)
                out_n = _numel(dims[0][1]) if dims else 0
                k = 1
                mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                ops = re.findall(r"%([\w\.\-]+)", ins.rest)
                if mlhs and ops:
                    lhs_shape = shapes.get(ops[0])
                    if lhs_shape:
                        ldims = _shape_dims(lhs_shape)
                        if ldims:
                            for ci in (mlhs.group(1).split(",")
                                       if mlhs.group(1) else []):
                                idx = int(ci)
                                if idx < len(ldims[0][1]):
                                    k *= ldims[0][1][idx]
                flops += 2.0 * out_n * max(k, 1) * m_c
                traffic += out_b * 2.0 * m_c  # output + ~operands
            elif any(ins.op == c or ins.op.startswith(c + "-start")
                     for c in COLLECTIVES):
                for c in COLLECTIVES:
                    if ins.op == c or ins.op.startswith(c + "-start"):
                        coll[c] += out_b * m_c
                        coll_ops += m_c
                        break
                traffic += out_b * 2.0 * m_c
            elif ins.op in ("fusion", "gather", "scatter", "reduce",
                            "dynamic-slice", "dynamic-update-slice", "copy",
                            "transpose", "reshape", "broadcast", "concatenate",
                            "sort", "custom-call"):
                traffic += out_b * 1.5 * m_c  # output + amortized reads

    return {"flops": flops,
            "collective_bytes": int(sum(coll.values())),
            "collective_by_kind": {k: int(v) for k, v in coll.items() if v},
            "collective_ops": int(coll_ops),
            "traffic_bytes": float(traffic)}
