"""RG-LRU recurrent block (RecurrentGemma): dual-branch with causal conv and
a gated linear recurrence:

    i_t = σ(x_t W_i),  r_t = σ(x_t W_r)
    a_t = exp(−c · softplus(Λ) · r_t),   c = 8
    h_t = a_t h_{t−1} + sqrt(1 − a_t²) · (i_t ⊙ x_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.rglru_scan.ops import rglru_scan
from .params import ParamDef
from .sharding import constrain

_C = 8.0


def rglru_defs(cfg: ArchConfig):
    D = cfg.d_model
    R = cfg.rnn_width or D
    W = cfg.conv_width
    return {
        "wx": ParamDef((D, R), ("embed", "inner"), fan_in=D),
        "wgate": ParamDef((D, R), ("embed", "inner"), fan_in=D),
        "conv_w": ParamDef((W, R), ("conv", "inner"), fan_in=W),
        "conv_b": ParamDef((R,), ("inner",), init="zeros"),
        "w_i": ParamDef((R, R), ("inner", None), fan_in=R),
        "b_i": ParamDef((R,), ("inner",), init="zeros"),
        "w_r": ParamDef((R, R), ("inner", None), fan_in=R),
        "b_r": ParamDef((R,), ("inner",), init="zeros"),
        "lam": ParamDef((R,), ("inner",), init="ones"),
        "out": ParamDef((R, D), ("inner", "embed"), fan_in=R),
    }


def rglru_cache_defs(cfg: ArchConfig, batch: int):
    R = cfg.rnn_width or cfg.d_model
    return {
        "conv": ParamDef((batch, cfg.conv_width - 1, R),
                         ("batch", None, "inner"), init="zeros"),
        "h": ParamDef((batch, R), ("batch", "inner"), init="zeros",
                      dtype="float32"),
    }


def _gates(p, xc):
    i = jax.nn.sigmoid(xc @ p["w_i"].astype(xc.dtype) + p["b_i"].astype(xc.dtype))
    r = jax.nn.sigmoid(xc @ p["w_r"].astype(xc.dtype) + p["b_r"].astype(xc.dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, u


def rglru_block(p, x, cfg: ArchConfig, mode: str, cache=None, impl="auto"):
    """x: (B, S, D). Returns (y, new_cache | None)."""
    B, S, D = x.shape
    W = cfg.conv_width
    xb = x @ p["wx"].astype(x.dtype)
    xb = constrain(xb, "batch", None, "inner")
    gate = jax.nn.gelu(x @ p["wgate"].astype(x.dtype))

    if mode in ("train", "prefill"):
        pad = jnp.pad(xb, ((0, 0), (W - 1, 0), (0, 0)))
        xc = jnp.zeros_like(xb)
        for i in range(W):
            xc = xc + pad[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
        xc = xc + p["conv_b"].astype(x.dtype)
        a, u = _gates(p, xc)
        hs, h_final = rglru_scan(a, u, h0=None, impl=impl)
        y = hs.astype(x.dtype)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": xb[:, -(W - 1):, :], "h": h_final}
    else:  # decode
        xb_full = jnp.concatenate([cache["conv"].astype(xb.dtype), xb], axis=1)
        xc = jnp.einsum("bwc,wc->bc", xb_full, p["conv_w"].astype(x.dtype))
        xc = (xc + p["conv_b"].astype(x.dtype))[:, None, :]
        a, u = _gates(p, xc)
        h = a[:, 0] * cache["h"] + u[:, 0]
        y = h[:, None, :].astype(x.dtype)
        new_cache = {"conv": xb_full[:, 1:, :], "h": h}

    y = y * gate
    y = constrain(y, "batch", None, "inner")
    return y @ p["out"].astype(x.dtype), new_cache
