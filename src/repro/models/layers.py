"""Core transformer layers: norms, RoPE, GQA attention (full/local/cross,
qk-norm, KV caches), gated MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.flash_attention.ops import flash_attention
from .params import ParamDef
from .sharding import constrain


# ------------------------------------------------------------------- norms
def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def norm_defs(d_model: int) -> ParamDef:
    return ParamDef((d_model,), (None,), init="ones")


# -------------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (S,) or scalar broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (S, half)
    cos = jnp.cos(ang)[..., None, :]  # (S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    half = d_model // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------- attention
def attn_defs(cfg: ArchConfig, cross: bool = False):
    D, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    d = {
        "wq": ParamDef((D, H, hd), ("embed", "heads", "head_dim"), fan_in=D),
        "wk": ParamDef((D, KH, hd), ("embed", "kv_heads", "head_dim"), fan_in=D),
        "wv": ParamDef((D, KH, hd), ("embed", "kv_heads", "head_dim"), fan_in=D),
        "wo": ParamDef((H, hd, D), ("heads", "head_dim", "embed"), fan_in=H * hd),
    }
    if cfg.use_bias:
        d["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        d["bv"] = ParamDef((KH, hd), ("kv_heads", "head_dim"), init="zeros")
        d["bo"] = ParamDef((D,), (None,), init="zeros")
    if cfg.qk_norm and not cross:
        d["qn"] = ParamDef((hd,), (None,), init="ones")
        d["kn"] = ParamDef((hd,), (None,), init="ones")
    return d


def _proj_qkv(p, xq, xkv, cfg: ArchConfig, positions_q, positions_k,
              use_rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(xq.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(xkv.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(xkv.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        v = v + p["bv"].astype(v.dtype)
    if "qn" in p:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    if use_rope and cfg.rope_theta > 0:
        q = rope(q, positions_q, cfg.rope_theta)
        k = rope(k, positions_k, cfg.rope_theta)
    return q, k, v


def _out_proj(p, o, dtype):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dtype))
    if "bo" in p:
        y = y + p["bo"].astype(dtype)
    return y


def _heads_shardable(cfg: ArchConfig) -> bool:
    from .sharding import current_mesh, current_profile
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return True
    if current_profile() == "fsdp":
        return True  # no TP axis in use
    return cfg.n_heads % mesh.shape["model"] == 0


def attention_ctx_parallel(q, k, v, *, causal: bool, window: Optional[int]):
    """Context-parallel attention: the query SEQUENCE dim is sharded on the
    `model` axis (K/V replicated), so score blocks shard 16-way even when the
    head count doesn't divide the mesh (e.g. smollm's 9 heads).  One big
    masked einsum — per-device score memory is S²/model_shards.
    [Perf iteration 3 — see EXPERIMENTS.md §Perf.]"""
    B, Sq, H, hd = q.shape
    q = constrain(q, "batch", "qseq", None, None)
    qf = q.astype(jnp.float32).reshape(B, Sq, k.shape[2], H // k.shape[2], hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s = s / float(hd) ** 0.5
    s = constrain(s, "batch", None, None, "qseq", None)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p_ = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p_, v.astype(jnp.float32))
    o = o.reshape(B, Sq, H, hd).astype(q.dtype)
    return constrain(o, "batch", "qseq", None, None)


def attention_full_seq(p, x, cfg: ArchConfig, *, causal: bool,
                       window: Optional[int], impl: str = "auto"):
    """Train / encoder path: self-attention over the full sequence."""
    S = x.shape[1]
    pos = jnp.arange(S)
    q, k, v = _proj_qkv(p, x, x, cfg, pos, pos, use_rope=True)
    if not _heads_shardable(cfg) and S >= 1024:
        o = attention_ctx_parallel(q, k, v, causal=causal, window=window)
    else:
        q = constrain(q, "batch", None, "heads", None)
        o = flash_attention(q, k, v, causal=causal, window=window, impl=impl)
        o = constrain(o, "batch", None, "heads", None)
    return _out_proj(p, o, x.dtype), (k, v)


def attn_cache_defs(cfg: ArchConfig, batch: int, ctx: int):
    KH, hd = cfg.n_kv_heads, cfg.hd
    cap = min(ctx, cfg.local_window) if cfg.attn_kind == "local" else ctx
    return {
        "k": ParamDef((batch, cap, KH, hd), ("batch", None, "kv_heads", None),
                      init="zeros"),
        "v": ParamDef((batch, cap, KH, hd), ("batch", None, "kv_heads", None),
                      init="zeros"),
        "pos": ParamDef((cap,), (None,), init="zeros", dtype="int32"),
    }


def attention_prefill_cache(k, v, cfg: ArchConfig, ctx: int):
    """Trim prefill K/V to the cache capacity (ring tail for local attn)."""
    S = k.shape[1]
    cap = min(ctx, cfg.local_window) if cfg.attn_kind == "local" else ctx
    if cfg.attn_kind == "local" and S > cap:
        # ring layout: slot = pos % cap
        start = S - cap
        k_t, v_t = k[:, start:], v[:, start:]
        pos = jnp.arange(start, S)
        slots = pos % cap
        order = jnp.argsort(slots)
        return {"k": k_t[:, order], "v": v_t[:, order], "pos": pos[order]}
    pad = cap - S
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.concatenate([jnp.arange(S), jnp.full((pad,), -1, jnp.int32)])
    else:
        pos = jnp.arange(cap)
    return {"k": k, "v": v, "pos": pos}


def attention_decode(p, x, cfg: ArchConfig, cache, pos, *,
                     window: Optional[int]):
    """One-token self-attention against a (ring) KV cache.

    x: (B, 1, D); pos: scalar int32 (position of the new token);
    cache: {"k": (B, cap, KH, hd), "v": ..., "pos": (cap,)}.
    """
    cap = cache["k"].shape[1]
    q, k_new, v_new = _proj_qkv(p, x, x, cfg, pos[None], pos[None],
                                use_rope=True)
    slot = pos % cap if (window is not None) else jnp.minimum(pos, cap - 1)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(cache["pos"],
                                        pos[None].astype(jnp.int32), (slot,))
    o = flash_attention(q, k, v, causal=True, window=window,
                        q_positions=pos[None], k_positions=kpos,
                        impl="reference")
    y = _out_proj(p, o, x.dtype)
    return y, {"k": k, "v": v, "pos": kpos}


def cross_attention(p, x, cfg: ArchConfig, enc_kv=None, enc_out=None):
    """Decoder cross-attention; K/V from encoder output (train/prefill) or
    precomputed in the cache (decode)."""
    if enc_kv is None:
        t = jnp.arange(enc_out.shape[1])
        k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(enc_out.dtype))
        if "bv" in p:
            v = v + p["bv"].astype(v.dtype)
        enc_kv = (k, v)
    k, v = enc_kv
    pos = jnp.arange(x.shape[1])
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    o = flash_attention(q, k, v, causal=False, impl="reference")
    return _out_proj(p, o, x.dtype), enc_kv


# ---------------------------------------------------------------------- MLP
def mlp_defs(cfg: ArchConfig, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    d = {
        "w_in": ParamDef((D, F), ("embed", "ffn"), fan_in=D),
        "w_out": ParamDef((F, D), ("ffn", "embed"), fan_in=F),
    }
    if cfg.gated_mlp:
        d["w_gate"] = ParamDef((D, F), ("embed", "ffn"), fan_in=D)
    if cfg.use_bias:
        d["b_in"] = ParamDef((F,), ("ffn",), init="zeros")
        d["b_out"] = ParamDef((D,), (None,), init="zeros")
    return d


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_apply(p, x, cfg: ArchConfig):
    h = x @ p["w_in"].astype(x.dtype)
    if "b_in" in p:
        h = h + p["b_in"].astype(x.dtype)
    if "w_gate" in p:
        h = _act(h, cfg.act) * (x @ p["w_gate"].astype(x.dtype))
    else:
        h = _act(h, cfg.act)
    h = constrain(h, "batch", None, "ffn")
    y = h @ p["w_out"].astype(x.dtype)
    if "b_out" in p:
        y = y + p["b_out"].astype(x.dtype)
    return y
