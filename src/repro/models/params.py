"""Parameter/cache definition trees.

Components describe their parameters once as nested dicts of ``ParamDef``
(shape + logical sharding axes + init); the same tree materializes as
initialized arrays, ShapeDtypeStructs (dry-run), or PartitionSpecs (mesh
sharding) — so shapes, inits and shardings can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import PARAM_RULES, spec_for


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple
    init: str = "normal"  # normal | zeros | ones
    fan_in: Optional[int] = None  # for normal init scale 1/sqrt(fan_in)
    dtype: Optional[str] = None  # override tree dtype (e.g. f32 states)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn, tree):
    if is_def(tree):
        return fn(tree)
    return {k: map_defs(fn, v) for k, v in tree.items()}


def stack_defs(tree, n: int):
    """Prepend a stacked-layers dim (unsharded) to every def."""
    def f(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, shape=(n,) + tuple(d.shape),
                                   axes=(None,) + tuple(d.axes))
    return map_defs(f, tree)


def init_tree(tree, key: jax.Array, dtype):
    leaves = []

    def collect(t):
        if is_def(t):
            leaves.append(t)
        else:
            for v in t.values():
                collect(v)

    collect(tree)
    keys = iter(jax.random.split(key, max(len(leaves), 1)))

    def make(d: ParamDef):
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        k = next(keys)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "a_log":  # mamba A_log init: log(uniform[1,16])
            h = d.shape[-1] if d.shape else 1
            return jnp.broadcast_to(
                jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
                d.shape).astype(dt)
        fan = d.fan_in or (d.shape[0] if d.shape else 1)
        return (jax.random.normal(k, d.shape, jnp.float32)
                / np.sqrt(fan)).astype(dt)

    return map_defs(make, tree)


def abstract_tree(tree, dtype):
    def make(d: ParamDef):
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        return jax.ShapeDtypeStruct(tuple(d.shape), dt)
    return map_defs(make, tree)


def spec_tree(tree, mesh, rules=PARAM_RULES):
    return map_defs(lambda d: spec_for(d.shape, d.axes, mesh, rules), tree)


def count_params(tree) -> int:
    n = 0

    def f(d: ParamDef):
        nonlocal n
        n += int(np.prod(d.shape)) if d.shape else 1
        return d

    map_defs(f, tree)
    return n
