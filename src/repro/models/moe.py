"""Mixture-of-Experts layer (DeepSeekMoE-style: shared + routed top-k).

Dispatch is sort-based with fixed per-expert capacity: token→expert
assignments are sorted by expert id, positions beyond capacity are dropped
(standard GShard-style token dropping), expert FFNs run as one batched
einsum over the (E, C, D) buffer with experts sharded on the `model` axis
(expert parallelism), and outputs scatter back weighted by router gates.
All shapes are static — no ragged ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import _act, mlp_defs, mlp_apply
from .params import ParamDef
from .sharding import constrain


def moe_defs(cfg: ArchConfig):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    d = {
        "router": ParamDef((D, E), ("embed", "experts"), fan_in=D),
        "w_in": ParamDef((E, D, F), ("experts", "embed", "ffn"), fan_in=D),
        "w_gate": ParamDef((E, D, F), ("experts", "embed", "ffn"), fan_in=D),
        "w_out": ParamDef((E, F, D), ("experts", "ffn", "embed"), fan_in=F),
    }
    if cfg.n_shared_experts:
        d["shared"] = mlp_defs(cfg, d_ff=cfg.n_shared_experts * cfg.expert_d_ff)
    return d


def moe_apply(p, x, cfg: ArchConfig):
    """x: (B, S, D) -> (B, S, D).

    Dispatch is per-sequence-row: each batch row sorts its own S·K
    (token, expert) assignments and packs them into an (E, C_row, D) buffer.
    Because the batch dim is data-sharded and every op here maps over B,
    dispatch is entirely shard-local under SPMD — no collectives are needed
    until the expert einsum (experts on `model`) and the standard TP
    all-reduce of the combined output.  [Perf iteration 1: a global
    argsort/scatter formulation lowered to ~4.3 TB/device of all-reduces;
    this row-local form removes them — see EXPERIMENTS.md §Perf.]
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if S == 1:
        # decode: a handful of tokens — the dense per-token path is exact
        # (no capacity drops) and cheap at S == 1.
        return moe_apply_oracle(p, x, cfg)
    cap = int(max(1, (S * K / E) * cfg.capacity_factor))

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # flatten assignments within each row and sort by expert (row-local)
    e_flat = expert_idx.reshape(B, S * K)
    t_flat = jnp.broadcast_to(jnp.arange(S)[:, None], (S, K)).reshape(S * K)
    g_flat = gate_vals.reshape(B, S * K)
    order = jnp.argsort(e_flat, axis=1)
    e_s = jnp.take_along_axis(e_flat, order, axis=1)
    g_s = jnp.take_along_axis(g_flat, order, axis=1)
    t_s = t_flat[order]  # (B, S*K)
    # position within expert = global sorted position - segment start
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(e_flat)
    seg_start = jnp.cumsum(counts, axis=1) - counts  # (B, E)
    pos_in_e = jnp.arange(S * K)[None, :] - jnp.take_along_axis(
        seg_start, e_s, axis=1)
    keep = pos_in_e < cap
    slot = e_s * cap + jnp.minimum(pos_in_e, cap - 1)  # (B, S*K)

    gathered = jnp.where(keep[..., None],
                         jnp.take_along_axis(x, t_s[..., None], axis=1), 0)
    buf = jnp.zeros((B, E * cap, D), x.dtype)
    buf = jax.vmap(lambda b, s, g: b.at[s].add(g))(buf, slot, gathered)
    buf = constrain(buf.reshape(B, E, cap, D), "batch", "experts", None, None)

    h = jnp.einsum("becd,edf->becf", buf, p["w_in"].astype(x.dtype))
    h = _act(h, cfg.act) * jnp.einsum("becd,edf->becf", buf,
                                      p["w_gate"].astype(x.dtype))
    h = constrain(h, "batch", "experts", None, "ffn")
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_out"].astype(x.dtype))
    out_buf = out_buf.reshape(B, E * cap, D)

    contrib = jnp.take_along_axis(out_buf, slot[..., None], axis=1) \
        * (g_s * keep).astype(x.dtype)[..., None]
    y = jnp.zeros((B, S, D), x.dtype)
    y = jax.vmap(lambda y_, t, c: y_.at[t].add(c))(y, t_s, contrib)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y


def moe_apply_oracle(p, x, cfg: ArchConfig):
    """Per-token dense oracle (no capacity drops) for unit tests."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(-1, D)
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # compute every expert for every token, then select
    h = jnp.einsum("nd,edf->nef", xf, p["w_in"].astype(x.dtype))
    h = _act(h, cfg.act) * jnp.einsum("nd,edf->nef", xf,
                                      p["w_gate"].astype(x.dtype))
    all_out = jnp.einsum("nef,efd->ned", h, p["w_out"].astype(x.dtype))
    sel = jnp.take_along_axis(all_out, expert_idx[:, :, None], axis=1)
    y = (sel * gate_vals[:, :, None].astype(x.dtype)).sum(axis=1)
    y = y.reshape(B, S, D)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y
