"""Logical-axis sharding: params and activations carry logical axis names;
resolution against the active mesh picks the first candidate whose size
divides the dimension (so e.g. a 51,865-entry vocab falls back to feature-dim
sharding instead of failing on a 16-way model axis).

Param FSDP dim ("embed") shards on `data`; tensor dims ("vocab", "heads",
"ffn", "experts", "inner") shard on `model`; everything is replicated over
`pod` (pure cross-pod DP).  Activations: "batch" -> (pod, data), tensor dims
-> model.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# candidate mesh axes per logical axis, in priority order; entries may be
# tuples (sharded over several mesh axes jointly).
PARAM_RULES = {
    "batch": [("pod", "data"), "data"],  # caches / batched state
    "vocab": ["model"],
    "embed": ["data"],
    "embed+": ["data", "model"],  # embedding feature dim (vocab fallback)
    "heads": ["model"],
    "kv_heads": ["model"],
    "ffn": ["model"],
    "experts": ["model"],
    "inner": ["model"],
    "head_dim": [],
    "conv": [],
    None: [],
}

ACT_RULES = {
    "batch": [("pod", "data"), "data"],
    "heads": ["model"],
    "kv_heads": ["model"],
    "ffn": ["model"],
    "experts": ["model"],
    "inner": ["model"],
    "embed": [],
    "seq": [],
    "qseq": ["model"],  # context-parallel attention (unshardable heads)
    "vocab": ["model"],
    None: [],
}

# --- sharding profiles (perf iterations, see EXPERIMENTS.md §Perf) ---------
# "fsdp": no tensor parallelism — batch and parameters shard across the
# combined (data, model) axes; collectives become overlappable weight
# all-gathers + gradient reduce-scatters instead of per-layer activation
# all-reduces.  Best for big dense training at batch >= n_chips.
_FSDP_PARAM_RULES = {
    "batch": [("pod", "data", "model"), ("data", "model"), "data"],
    "vocab": [("data", "model"), "data", "model"],
    "embed": [("data", "model"), "data"],
    "embed+": [("data", "model"), "data", "model"],
    "heads": [],
    "kv_heads": [],
    "ffn": [("data", "model"), "data"],
    "experts": [("data", "model"), "data", "model"],
    "inner": [("data", "model"), "data"],
    "head_dim": [], "conv": [], None: [],
}
_FSDP_ACT_RULES = {
    "batch": [("pod", "data", "model"), ("data", "model"), "data"],
    "heads": [], "kv_heads": [], "ffn": [], "experts": [], "inner": [],
    "embed": [], "seq": [], "qseq": [], "vocab": [], None: [],
}
# "inference-tp": weights live model-sharded and data-replicated — zero
# per-step weight all-gathers (decode is bandwidth-bound; FSDP gathers
# dominate otherwise).
_INF_PARAM_RULES = dict(PARAM_RULES, embed=[], inner=["model"])

PROFILES = {
    "2d": (PARAM_RULES, ACT_RULES),
    "fsdp": (_FSDP_PARAM_RULES, _FSDP_ACT_RULES),
    "inference-tp": (_INF_PARAM_RULES, ACT_RULES),
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    profile: str = "2d"


_ctx = _Ctx()


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], profile: str = "2d"):
    prev = (_ctx.mesh, getattr(_ctx, "profile", "2d"))
    _ctx.mesh = mesh
    _ctx.profile = profile
    try:
        yield
    finally:
        _ctx.mesh, _ctx.profile = prev


def current_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def current_profile() -> str:
    return getattr(_ctx, "profile", "2d")


def _axis_size(mesh: Mesh, cand) -> int:
    names = (cand,) if isinstance(cand, str) else tuple(cand)
    size = 1
    for n in names:
        if n not in mesh.shape:
            return 0  # axis not present in this mesh
        size *= mesh.shape[n]
    return size


def _resolve_dim(dim: int, logical, mesh: Mesh, taken: set, rules) -> Optional[tuple]:
    for cand in rules.get(logical, []):
        names = (cand,) if isinstance(cand, str) else tuple(cand)
        if any(n in taken for n in names):
            continue
        size = _axis_size(mesh, cand)
        if size <= 1 or dim % size != 0:
            continue
        taken.update(names)
        return names
    return None


def spec_for(shape: Sequence[int], axes: Sequence, mesh: Mesh,
             rules=PARAM_RULES) -> P:
    assert len(shape) == len(axes), (shape, axes)
    taken: set = set()
    out = []
    for dim, ax in zip(shape, axes):
        names = _resolve_dim(int(dim), ax, mesh, taken, rules)
        if names is None:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return P(*out)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Activation sharding constraint (no-op outside a mesh context)."""
    mesh = _ctx.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh, rules=ACT_RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape, axes, mesh: Mesh, rules=PARAM_RULES) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))
