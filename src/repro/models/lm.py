"""Model assembly: every assigned architecture as one composable LM.

Layers are grouped into *superblocks* (one period of the temporal pattern —
a single layer for uniform stacks, (rglru, rglru, attn) for RecurrentGemma)
and stacked with ``jax.lax.scan`` (+ per-superblock remat in training), which
keeps the HLO small, compiles fast, and bounds activation memory.  Caches
for decode are stacked along the same leading dimension and threaded through
the scan as per-step xs/ys.

Modes: "train" (full seq, no cache), "prefill" (full seq, returns cache),
"decode" (one token against the cache).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (attn_cache_defs, attn_defs, attention_decode,
                     attention_full_seq, attention_prefill_cache,
                     cross_attention, mlp_apply, mlp_defs, norm_defs, rmsnorm,
                     sinusoidal_embedding)
from .moe import moe_apply, moe_defs
from .params import (ParamDef, abstract_tree, count_params, init_tree,
                     map_defs, spec_tree, stack_defs)
from .rglru import rglru_block, rglru_cache_defs, rglru_defs
from .sharding import constrain
from .ssm import ssm_block, ssm_cache_defs, ssm_defs


# --------------------------------------------------------------- structure
def layer_kinds(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.enc_dec:
        return ("xdense",) * cfg.n_layers
    return cfg.layer_kinds


def structure(cfg: ArchConfig):
    """(pre_kinds, superblock_kinds, n_super, tail_kinds)."""
    kinds = layer_kinds(cfg)
    if cfg.block_pattern:
        p = len(cfg.block_pattern)
        n_super = cfg.n_layers // p
        return (), tuple(cfg.block_pattern), n_super, kinds[n_super * p:]
    pre = kinds[:cfg.first_dense_layers]
    rest = kinds[cfg.first_dense_layers:]
    assert all(k == rest[0] for k in rest), "non-pattern stack must be uniform"
    return pre, (rest[0],), len(rest), ()


def block_defs(cfg: ArchConfig, kind: str, d_ff_override: Optional[int] = None):
    D = cfg.d_model
    if kind == "ssm":
        return {"ln1": norm_defs(D), "ssm": ssm_defs(cfg)}
    if kind == "rglru":
        return {"ln1": norm_defs(D), "rec": rglru_defs(cfg),
                "ln2": norm_defs(D), "mlp": mlp_defs(cfg)}
    d = {"ln1": norm_defs(D), "attn": attn_defs(cfg), "ln2": norm_defs(D)}
    if kind == "moe":
        d["moe"] = moe_defs(cfg)
    else:
        d["mlp"] = mlp_defs(cfg, d_ff=d_ff_override)
    if kind == "xdense":
        d["lnx"] = norm_defs(D)
        d["xattn"] = attn_defs(cfg, cross=True)
    return d


def block_cache_defs(cfg: ArchConfig, kind: str, batch: int, ctx: int):
    if kind == "ssm":
        return ssm_cache_defs(cfg, batch)
    if kind == "rglru":
        return rglru_cache_defs(cfg, batch)
    d = attn_cache_defs(cfg, batch, ctx)
    if kind == "xdense":
        KH, hd = cfg.n_kv_heads, cfg.hd
        d["xk"] = ParamDef((batch, cfg.enc_seq, KH, hd),
                           ("batch", None, "kv_heads", None), init="zeros")
        d["xv"] = ParamDef((batch, cfg.enc_seq, KH, hd),
                           ("batch", None, "kv_heads", None), init="zeros")
    return d


def model_defs(cfg: ArchConfig):
    D, V = cfg.d_model, cfg.vocab
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed+"), fan_in=D),
        "final_norm": norm_defs(D),
    }
    pre, sb_kinds, n_super, tail = structure(cfg)
    dec = {}
    for i, k in enumerate(pre):
        dec[f"pre{i}"] = block_defs(cfg, "dense",
                                    d_ff_override=cfg.first_dense_d_ff or None)
    sb = {f"b{j}": block_defs(cfg, kind) for j, kind in enumerate(sb_kinds)}
    dec["stack"] = stack_defs(sb, n_super)
    for i, k in enumerate(tail):
        dec[f"tail{i}"] = block_defs(cfg, k)
    defs["dec"] = dec
    if cfg.enc_dec:
        enc_sb = {"b0": block_defs(cfg, "enc")}
        defs["enc"] = {"stack": stack_defs(enc_sb, cfg.n_enc_layers)}
        defs["enc_norm"] = norm_defs(D)
    return defs


def cache_defs(cfg: ArchConfig, batch: int, ctx: int):
    pre, sb_kinds, n_super, tail = structure(cfg)
    dec = {}
    for i, k in enumerate(pre):
        dec[f"pre{i}"] = block_cache_defs(cfg, k, batch, ctx)
    sb = {f"b{j}": block_cache_defs(cfg, kind, batch, ctx)
          for j, kind in enumerate(sb_kinds)}
    dec["stack"] = stack_defs(sb, n_super)
    for i, k in enumerate(tail):
        dec[f"tail{i}"] = block_cache_defs(cfg, k, batch, ctx)
    return {"dec": dec}


# ---------------------------------------------------------------- builders
def init_params(cfg: ArchConfig, key: jax.Array):
    return init_tree(model_defs(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ArchConfig):
    return abstract_tree(model_defs(cfg), jnp.dtype(cfg.param_dtype))


def param_pspecs(cfg: ArchConfig, mesh, profile: str = "2d"):
    from .sharding import PROFILES
    return spec_tree(model_defs(cfg), mesh, rules=PROFILES[profile][0])


def init_cache(cfg: ArchConfig, batch: int, ctx: int):
    return init_tree(cache_defs(cfg, batch, ctx), jax.random.PRNGKey(0),
                     jnp.dtype(cfg.compute_dtype))


def abstract_cache(cfg: ArchConfig, batch: int, ctx: int):
    return abstract_tree(cache_defs(cfg, batch, ctx),
                         jnp.dtype(cfg.compute_dtype))


def cache_pspecs(cfg: ArchConfig, batch: int, ctx: int, mesh,
                 profile: str = "2d"):
    from .sharding import PROFILES
    return spec_tree(cache_defs(cfg, batch, ctx), mesh,
                     rules=PROFILES[profile][0])


def num_params(cfg: ArchConfig) -> int:
    return count_params(model_defs(cfg))


# ------------------------------------------------------------------ blocks
def block_apply(p, x, cfg: ArchConfig, kind: str, mode: str, cache, pos,
                enc_out, impl: str):
    """Returns (x, cache_out)."""
    window = cfg.local_window if (kind == "attn" or cfg.attn_kind == "local") \
        else None
    cache_out = None
    if kind == "ssm":
        h, cache_out = ssm_block(p["ssm"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                                 cfg, mode, cache, impl=impl)
        return x + h, cache_out
    if kind == "rglru":
        h, cache_out = rglru_block(p["rec"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                                   cfg, mode, cache, impl=impl)
        x = x + h
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
        return x, cache_out

    # attention families
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if mode == "decode":
        ao, cache_out = attention_decode(p["attn"], h, cfg, cache, pos,
                                         window=window)
    else:
        causal = kind != "enc"
        ao, kv = attention_full_seq(p["attn"], h, cfg, causal=causal,
                                    window=window, impl=impl)
        if mode == "prefill":
            ctx = cache  # int: cache capacity threaded through
            cache_out = attention_prefill_cache(kv[0], kv[1], cfg, ctx)
    x = x + ao
    if kind == "xdense":
        h = rmsnorm(x, p["lnx"], cfg.norm_eps)
        if mode == "decode":
            xo, _ = cross_attention(p["xattn"], h, cfg,
                                    enc_kv=(cache["xk"], cache["xv"]))
            cache_out["xk"], cache_out["xv"] = cache["xk"], cache["xv"]
        else:
            xo, enc_kv = cross_attention(p["xattn"], h, cfg, enc_out=enc_out)
            if mode == "prefill":
                cache_out["xk"], cache_out["xv"] = enc_kv
        x = x + xo
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        x = x + moe_apply(p["moe"], h, cfg)
    else:
        x = x + mlp_apply(p["mlp"], h, cfg)
    return x, cache_out


def _superblock_apply(sb_params, x, cfg, sb_kinds, mode, sb_cache, pos,
                      enc_out, impl, ctx):
    cache_out = {}
    for j, kind in enumerate(sb_kinds):
        name = f"b{j}"
        if mode == "prefill":
            c = ctx
        elif mode == "decode":
            c = sb_cache[name]
        else:
            c = None
        x, co = block_apply(sb_params[name], x, cfg, kind, mode, c, pos,
                            enc_out, impl)
        if co is not None:
            cache_out[name] = co
    return x, cache_out


# ----------------------------------------------------------------- forward
def forward(params, cfg: ArchConfig, tokens=None, *, mode: str = "train",
            cache=None, pos=None, enc_embeds=None, embeds=None,
            impl: str = "auto", cache_len=None):
    """Returns (hidden (B,S,D), new_cache | None).

    tokens: (B, S) int32 (S == 1 for decode); enc_embeds: (B, T_enc, D)
    precomputed frontend features (whisper stub); pos: scalar int32 decode
    position; cache: pytree from init_cache/prefill.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    pre, sb_kinds, n_super, tail = structure(cfg)

    enc_out = None
    if cfg.enc_dec and mode != "decode":
        e = enc_embeds.astype(cdt)
        e = e + sinusoidal_embedding(jnp.arange(e.shape[1]),
                                     cfg.d_model).astype(cdt)

        def enc_body(carry, p_i):
            y, _ = block_apply(p_i["b0"], carry, cfg, "enc", "train", None,
                               None, None, impl)
            return y, None

        if cfg.remat and mode == "train":
            enc_body = jax.checkpoint(enc_body)
        e, _ = jax.lax.scan(enc_body, e, params["enc"]["stack"])
        enc_out = rmsnorm(e, params["enc_norm"], cfg.norm_eps)

    if embeds is not None:
        x = embeds.astype(cdt)
    else:
        x = params["embed"].astype(cdt)[tokens]
    x = constrain(x, "batch", "seq", "embed")
    if cfg.rope_theta == 0.0:  # absolute sinusoidal positions (whisper)
        if mode == "decode":
            x = x + sinusoidal_embedding(pos[None], cfg.d_model).astype(cdt)
        else:
            x = x + sinusoidal_embedding(jnp.arange(x.shape[1]),
                                         cfg.d_model).astype(cdt)

    ctx = None
    if mode == "prefill":
        ctx = cache_len or (tokens.shape[1] if tokens is not None
                            else x.shape[1])
    dec_p = params["dec"]
    new_cache = {}

    for i, k in enumerate(pre):
        c = (cache["dec"][f"pre{i}"] if mode == "decode" else
             (ctx if mode == "prefill" else None))
        x, co = block_apply(dec_p[f"pre{i}"], x, cfg, k, mode, c, pos,
                            enc_out, impl)
        if co is not None:
            new_cache[f"pre{i}"] = co

    def body(carry, xs):
        if mode == "decode":
            p_i, c_i = xs
        else:
            p_i, c_i = xs, None
        y, co = _superblock_apply(p_i, carry, cfg, sb_kinds, mode,
                                  c_i, pos, enc_out, impl, ctx)
        return y, (co if co else None)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, policy=None)
    xs = (dec_p["stack"], cache["dec"]["stack"]) if mode == "decode" \
        else dec_p["stack"]
    x, stack_cache = jax.lax.scan(body, x, xs)
    if mode in ("prefill", "decode") and stack_cache is not None:
        new_cache["stack"] = stack_cache

    for i, k in enumerate(tail):
        c = (cache["dec"][f"tail{i}"] if mode == "decode" else
             (ctx if mode == "prefill" else None))
        x, co = block_apply(dec_p[f"tail{i}"], x, cfg, k, mode, c, pos,
                            enc_out, impl)
        if co is not None:
            new_cache[f"tail{i}"] = co

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, ({"dec": new_cache} if mode in ("prefill", "decode") else None)


def logits_from_hidden(params, h, cfg: ArchConfig):
    """Tied-embedding LM head (full logits; training uses the chunked CE)."""
    return jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                      params["embed"].astype(jnp.float32))
