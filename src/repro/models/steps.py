"""Step functions: training (AdamW + sequence-chunked cross-entropy),
prefill, and single-token decode — the objects the dry-run lowers."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..train.optimizer import OptConfig, adamw_update, init_opt_state
from .lm import forward, logits_from_hidden
from .sharding import constrain


def chunked_ce_loss(params, h, labels, cfg: ArchConfig):
    """Cross-entropy without materializing (B, S, V) logits: scan over
    sequence chunks, rematerializing each chunk's logits in backward."""
    B, S, D = h.shape
    C = min(cfg.ce_chunk, S)
    if S % C:
        C = S  # fallback: single chunk
    n = S // C
    hc = jnp.swapaxes(h.reshape(B, n, C, D), 0, 1)  # (n, B, C, D)
    lc = jnp.swapaxes(labels.reshape(B, n, C), 0, 1)
    emb = params["embed"]

    def chunk_fn(carry, xs):
        hh, ll = xs
        logits = jnp.einsum("bcd,vd->bcv", hh.astype(jnp.float32),
                            emb.astype(jnp.float32))
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk_fn), jnp.zeros((), jnp.float32),
                            (hc, lc))
    return total / (B * S)


def make_train_step(cfg: ArchConfig, oc: Optional[OptConfig] = None,
                    impl: str = "auto", grad_compression: str = "none"):
    """grad_compression="int8" enables error-feedback int8 gradient
    compression (4× DP/pod gradient traffic; state["gerr"] holds the
    feedback accumulator)."""
    oc = oc or OptConfig()

    def train_step(state, batch):
        def loss_fn(params):
            h, _ = forward(params, cfg, batch.get("tokens"), mode="train",
                           enc_embeds=batch.get("enc_embeds"), impl=impl)
            return chunked_ce_loss(params, h, batch["labels"], cfg)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        new_state = {}
        if grad_compression == "int8":
            from ..train.compression import compress_grads
            grads, gerr = compress_grads(grads, state.get("gerr"))
            new_state["gerr"] = gerr
        new_params, new_opt, gn = adamw_update(state["params"], grads,
                                               state["opt"], oc)
        new_state.update({"params": new_params, "opt": new_opt})
        return new_state, {"loss": loss, "grad_norm": gn}

    return train_step


def make_prefill_step(cfg: ArchConfig, impl: str = "auto", cache_len=None):
    def prefill_step(params, batch):
        h, cache = forward(params, cfg, batch.get("tokens"), mode="prefill",
                           enc_embeds=batch.get("enc_embeds"), impl=impl,
                           cache_len=cache_len)
        logits = logits_from_hidden(params, h[:, -1:], cfg)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, impl: str = "auto"):
    def decode_step(params, cache, tokens, pos):
        h, new_cache = forward(params, cfg, tokens, mode="decode",
                               cache=cache, pos=pos, impl=impl)
        logits = logits_from_hidden(params, h, cfg)
        return logits, new_cache

    return decode_step


def init_train_state(cfg: ArchConfig, key):
    from .lm import init_params
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params)}
