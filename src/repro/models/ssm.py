"""Mamba2 (SSD) block: projections + causal depthwise conv + selective state
space scan + gated RMSNorm output."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.ssd_scan.ops import ssd, ssd_decode_step
from .layers import rmsnorm
from .params import ParamDef
from .sharding import constrain


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    G, N, W = cfg.ssm_groups, cfg.ssm_state, cfg.conv_width
    conv_ch = d_inner + 2 * G * N
    return d_inner, H, G, N, W, conv_ch


def ssm_defs(cfg: ArchConfig):
    D = cfg.d_model
    d_inner, H, G, N, W, conv_ch = _dims(cfg)
    return {
        "wz": ParamDef((D, d_inner), ("embed", "inner"), fan_in=D),
        "wx": ParamDef((D, d_inner), ("embed", "inner"), fan_in=D),
        "wB": ParamDef((D, G * N), ("embed", None), fan_in=D),
        "wC": ParamDef((D, G * N), ("embed", None), fan_in=D),
        "wdt": ParamDef((D, H), ("embed", "heads"), fan_in=D),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "conv_w": ParamDef((W, conv_ch), ("conv", "inner"), fan_in=W),
        "conv_b": ParamDef((conv_ch,), ("inner",), init="zeros"),
        "A_log": ParamDef((H,), ("heads",), init="a_log"),
        "D": ParamDef((H,), ("heads",), init="ones"),
        "norm": ParamDef((d_inner,), ("inner",), init="ones"),
        "out": ParamDef((d_inner, D), ("inner", "embed"), fan_in=d_inner),
    }


def ssm_cache_defs(cfg: ArchConfig, batch: int):
    d_inner, H, G, N, W, conv_ch = _dims(cfg)
    return {
        "conv": ParamDef((batch, W - 1, conv_ch), ("batch", None, "inner"),
                         init="zeros"),
        "state": ParamDef((batch, H, cfg.ssm_headdim, N),
                          ("batch", "heads", None, None), init="zeros",
                          dtype="float32"),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv over (B, S, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(u)
    for i in range(W):
        y = y + pad[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
    return jax.nn.silu(y + b.astype(u.dtype))


def _projections(p, x, cfg: ArchConfig):
    d_inner, H, G, N, W, conv_ch = _dims(cfg)
    dt_raw = x @ p["wdt"].astype(x.dtype)
    z = x @ p["wz"].astype(x.dtype)
    u = jnp.concatenate([x @ p["wx"].astype(x.dtype),
                         x @ p["wB"].astype(x.dtype),
                         x @ p["wC"].astype(x.dtype)], axis=-1)
    return z, u, dt_raw


def _split_conv(cu, cfg: ArchConfig, batch_shape):
    d_inner, H, G, N, _, _ = _dims(cfg)
    xc = cu[..., :d_inner]
    Bc = cu[..., d_inner:d_inner + G * N].reshape(*batch_shape, G, N)
    Cc = cu[..., d_inner + G * N:].reshape(*batch_shape, G, N)
    return xc, Bc, Cc


def ssm_block(p, x, cfg: ArchConfig, mode: str, cache=None, impl="auto"):
    """x: (B, S, D) (S == 1 for decode). Returns (y, new_cache | None)."""
    B, S, D = x.shape
    d_inner, H, G, N, W, conv_ch = _dims(cfg)
    z, u, dt_raw = _projections(p, x, cfg)
    z = constrain(z, "batch", None, "inner")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Dskip = p["D"].astype(jnp.float32)

    if mode in ("train", "prefill"):
        cu = _causal_conv(u, p["conv_w"], p["conv_b"])
        xc, Bc, Cc = _split_conv(cu, cfg, (B, S))
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        xh = xc.reshape(B, S, H, cfg.ssm_headdim)
        y, h_final = ssd(xh, dt, A, Bc, Cc, Dskip, chunk=cfg.ssd_chunk,
                         impl=impl)
        y = y.reshape(B, S, d_inner)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": u[:, -(W - 1):, :], "state": h_final}
    else:  # decode
        u_full = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        cu = jnp.einsum("bwc,wc->bc", u_full, p["conv_w"].astype(u.dtype))
        cu = jax.nn.silu(cu + p["conv_b"].astype(u.dtype))[:, None, :]
        xc, Bc, Cc = _split_conv(cu[:, 0], cfg, (B,))
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        xh = xc.reshape(B, H, cfg.ssm_headdim)
        y, h_new = ssd_decode_step(cache["state"], xh, dt, A, Bc, Cc, Dskip)
        y = y.reshape(B, 1, d_inner)
        new_cache = {"conv": u_full[:, 1:, :], "state": h_new}

    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"], cfg.norm_eps)
    y = constrain(y, "batch", None, "inner")
    return y @ p["out"].astype(x.dtype), new_cache
