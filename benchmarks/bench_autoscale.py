"""Price-pressure autoscaling scenario benchmark (beyond the paper).

Runs the bundled mixed deadline-tight / deadline-loose deferrable trace
(``cluster/traces.deferrable_trace``) through admission-controlled and
always-admit regimes:

* ``eva-autoscale`` — policy stack ``[SpotLayer(), AutoscaleLayer()]``:
  deferrable jobs are held pending while the forecast effective
  $/throughput over their estimated duration sits above their
  reservation-price-derived strike, and admitted when the OU market dips
  (or unconditionally at their latest-start deadline bound).
* ``eva-spot``      — same spot market, always-admit: every job is placed
  at its first round regardless of the current price.
* ``eva``           — on-demand static catalog (the price-blind anchor).

The acceptance invariant (also enforced in CI): eva-autoscale is strictly
cheaper than always-admit eva-spot on the bundled OU market *with zero
deadline misses* — deferral only counts if the deadlines still hold.  A
strike sweep shows the cost/latency dial, and a composed run (deferrable
CPU jobs on a burstable two-region spot market with dead phases where
*every* region is dear) shows the axis stacking on all three price layers:
a deferrable job picks the cheapest *time*, not just the cheapest
instance/region.

    PYTHONPATH=src python -m benchmarks.run --quick --only autoscale
"""
from __future__ import annotations

import numpy as np

from repro.cluster import SimConfig, deferrable_trace
from repro.core import (PriceModel, Region, aws_catalog,
                        burstable_demo_catalog, multi_region_catalog)

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "market", "total_cost", "avg_jct_hours",
        "deadline_misses", "deferred_jobs", "deferred_wait_hours",
        "admissions", "forced_admissions", "wall_s"]

STRIKE = 0.9  # headline strike: admit ≥10% below the long-run anchor


def _trace(n_jobs, seed=13, cpu_only=False):
    return deferrable_trace(n_jobs=n_jobs, seed=seed, cpu_only=cpu_only)


def autoscale_vs_always_admit(quick=False, n_jobs=None, hazard=0.3, seed=5):
    n_jobs = n_jobs or (24 if quick else 96)
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    spot_cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
    rows = []
    for name, cat, cfg, kw in (
            ("eva-autoscale", aws_catalog(price_model=pm), spot_cfg,
             dict(strike=STRIKE)),
            ("eva-spot", aws_catalog(price_model=pm), spot_cfg, {}),
            ("eva", aws_catalog(), SimConfig(seed=seed), {})):
        out = run_sim(name, _trace(n_jobs), cfg, catalog=cat, **kw)
        out["scheduler"] = name
        out["market"] = "spot (OU)" if cat.price_model is not None \
            else "on-demand"
        rows.append(out)
    print_table("Autoscaling: admission-controlled Eva vs always-admit "
                "eva-spot vs on-demand Eva", rows, COLS)
    by = {r["scheduler"]: r for r in rows}
    saving = 1.0 - by["eva-autoscale"]["total_cost"] / by["eva-spot"]["total_cost"]
    print(f"eva-autoscale saving vs always-admit eva-spot: {saving:.1%} "
          f"({by['eva-autoscale']['deadline_misses']} deadline misses)")
    assert by["eva-autoscale"]["total_cost"] < by["eva-spot"]["total_cost"], \
        "admission-controlled Eva must beat always-admit eva-spot on cost"
    assert by["eva-autoscale"]["deadline_misses"] == 0, \
        "deferral must not blow deadlines"
    return rows


def strike_sweep(quick=False, n_jobs=None, hazard=0.3, seed=5):
    """Cost/JCT vs the strike: 1.0 admits whenever the forecast is no worse
    than the long-run anchor, lower strikes hold out for deeper dips —
    cost falls then flattens (deadline-forced admissions cap the patience)
    while JCT stretches toward the deadline slack."""
    n_jobs = n_jobs or (16 if quick else 64)
    strikes = (1.0, 0.9, 0.8) if quick else (1.0, 0.95, 0.9, 0.85, 0.8, 0.7)
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    rows = []
    for strike in strikes:
        cat = aws_catalog(price_model=pm)
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
        out = run_sim("eva-autoscale", _trace(n_jobs), cfg, catalog=cat,
                      strike=strike)
        out["scheduler"] = "eva-autoscale"
        out["market"] = f"strike={strike:g}"
        rows.append(out)
    print_table("Autoscaling: strike sweep", rows, COLS)
    return rows


def _composed_catalog(low=0.3, high=0.9, phase_s=3600.0,
                      horizon_s=14 * 86400.0):
    """Two-region burstable spot market with *dead phases*: each region is
    cheap one hour in four (staggered), and for two hours of every four
    both are dear — a market where arbitrage alone cannot help and only
    waiting can."""
    times = np.arange(0.0, horizon_s, phase_s)
    k = np.arange(len(times)) % 4
    regions = (
        Region("r0", price_model=PriceModel.trace(
            times, np.where(k == 0, low, high))),
        Region("r1", price_model=PriceModel.trace(
            times, np.where(k == 1, low, high))))
    return multi_region_catalog(regions,
                                base_types=burstable_demo_catalog().types)


def composed_market(quick=False, n_jobs=None, hazard=0.3, seed=5):
    """All four axes at once: deferrable CPU jobs on a burstable two-region
    spot market.  The admission controller composes with the region and
    credit layers (``RegionForecaster`` + ``credit_priced``), so a job is
    held through the dead phases and admitted into a cheap window of
    *either* region — the cheapest time, not just the cheapest instance."""
    n_jobs = n_jobs or (16 if quick else 48)
    rows = []
    for name, kw in (
            ("eva-autoscale", dict(multi_region=True, credit_aware=True,
                                   autoscale=True, strike=STRIKE)),
            ("eva-multiregion", dict(multi_region=True, credit_aware=True))):
        cat = _composed_catalog()
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
        out = run_sim("eva-autoscale" if name == "eva-autoscale"
                      else "eva-multiregion", _trace(n_jobs, cpu_only=True),
                      cfg, catalog=cat, **kw)
        out["scheduler"] = name
        out["market"] = "2-region burstable spot w/ dead phases"
        rows.append(out)
    print_table("Autoscaling: composed market (spot x region x credit x "
                "deferral)", rows, COLS)
    by = {r["scheduler"]: r for r in rows}
    saving = 1.0 - (by["eva-autoscale"]["total_cost"]
                    / by["eva-multiregion"]["total_cost"])
    print(f"composed eva-autoscale saving vs always-admit: {saving:.1%}")
    assert by["eva-autoscale"]["deadline_misses"] == 0, \
        "composed deferral must not blow deadlines"
    return rows


def run(quick=False, full=False):
    n = 200 if full else None
    out = {"autoscale_vs_always_admit":
           autoscale_vs_always_admit(quick=quick, n_jobs=n),
           "strike_sweep": strike_sweep(quick=quick),
           "composed_market": composed_market(quick=quick)}
    save_results("bench_autoscale", out)
    return out


if __name__ == "__main__":
    run()
