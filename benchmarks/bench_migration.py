"""Figure 5: impact of migration overhead.  Sweep the migration-delay scale;
(a) Eva's Full-Reconfiguration adoption rate and migrations per task,
(b) total cost of Eva (ensemble) vs Eva full-only vs Stratus."""
from __future__ import annotations

from repro.cluster import SimConfig, alibaba_like_trace

from .common import print_table, run_sim, save_results


def run(quick=False, n_jobs=None):
    n = n_jobs or (150 if quick else 500)
    scales = (1.0, 4.0) if quick else (1.0, 2.0, 4.0, 8.0)
    rows = []
    for scale in scales:
        for sched in ("stratus", "eva-full-only", "eva"):
            jobs = alibaba_like_trace(n_jobs=n, seed=9)
            m = run_sim(sched, jobs,
                        SimConfig(seed=4, migration_delay_scale=scale))
            rows.append({"delay_scale": scale, "scheduler": sched,
                         "total_cost": m["total_cost"],
                         "migrations_per_task": m["migrations_per_task"],
                         "full_adoption": m.get("full_adoption", "")})
    for scale in scales:
        base = next(r["total_cost"] for r in rows
                    if r["delay_scale"] == scale and r["scheduler"] == "eva")
        for r in rows:
            if r["delay_scale"] == scale:
                r["cost_vs_eva_pct"] = round(100 * r["total_cost"] / base, 1)
    print_table("Figure 5: migration-delay sweep", rows,
                ["delay_scale", "scheduler", "cost_vs_eva_pct",
                 "migrations_per_task", "full_adoption"])
    save_results("bench_migration", rows)
    return rows


if __name__ == "__main__":
    run()
