"""Tables 13 & 14: end-to-end simulation on the Alibaba-like trace with both
duration models and all five schedulers."""
from __future__ import annotations

from repro.cluster import SimConfig, alibaba_like_trace

from .common import print_table, run_sim, save_results

SCHEDULERS = ("no-packing", "stratus", "synergy", "owl", "eva")


def run(quick=False, full=False, n_jobs=None, seeds=(7,)):
    n = n_jobs or (200 if quick else (6274 if full else 800))
    out = {}
    for model, table in (("alibaba", "Table 13"), ("gavel", "Table 14")):
        rows = []
        for sched in SCHEDULERS:
            agg = None
            for seed in seeds:
                jobs = alibaba_like_trace(n_jobs=n, seed=seed,
                                          duration_model=model)
                m = run_sim(sched, jobs, SimConfig(seed=1))
                if agg is None:
                    agg = {k: [v] for k, v in m.items()
                           if isinstance(v, (int, float))}
                else:
                    for k in agg:
                        agg[k].append(m[k])
            row = {k: round(sum(v) / len(v), 3) for k, v in agg.items()}
            row["scheduler"] = sched
            rows.append(row)
        base = rows[0]["total_cost"]
        for r in rows:
            r["norm_cost_pct"] = round(100 * r["total_cost"] / base, 1)
        print_table(f"{table}: end-to-end ({model} durations, {n} jobs)",
                    rows, ["scheduler", "total_cost", "norm_cost_pct",
                           "tasks_per_instance", "norm_job_tput",
                           "avg_jct_hours", "avg_idle_hours",
                           "migrations_per_task", "wall_s"])
        out[model] = rows
    save_results("bench_endtoend", out)
    return out


if __name__ == "__main__":
    run()
