"""Spot-market scenario benchmark (beyond the paper).

Runs the same trace through three provisioning regimes:

* ``eva-spot``    — spot catalog (mean-reverting prices, preemption hazard),
  Eva with ``spot_aware=True``: reservation prices re-evaluated against
  current prices each round, revocation notices force a partial
  reconfiguration that evacuates the doomed instances.
* ``eva``         — on-demand-only Eva: static catalog at base prices.
* ``no-packing``  — on-demand baseline, one task per reservation-price type.

Reports total cost, average JCT, migrations and preemption counts; a second
sweep varies the preemption hazard to show the cost/stability trade-off
(Voorsluys et al.; stability-vs-cost scheduling literature).

    PYTHONPATH=src python -m benchmarks.run --quick --only spot
"""
from __future__ import annotations

from repro.cluster import SimConfig, physical_trace
from repro.core import PriceModel, aws_catalog

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "market", "total_cost", "avg_jct_hours",
        "migrations_per_task", "preemptions", "instances_launched", "wall_s"]


def _trace(n_jobs, seed=11, durations=(0.3, 0.8)):
    return physical_trace(n_jobs=n_jobs, seed=seed, duration_range_h=durations)


def spot_vs_ondemand(quick=False, n_jobs=None, hazard=0.3, seed=5):
    n_jobs = n_jobs or (24 if quick else 120)
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    spot_cat = aws_catalog(price_model=pm)
    spot_cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
    rows = []
    for name, cat, cfg in (
            ("eva-spot", spot_cat, spot_cfg),
            ("eva", aws_catalog(), SimConfig(seed=seed)),
            ("no-packing", aws_catalog(), SimConfig(seed=seed))):
        out = run_sim(name, _trace(n_jobs), cfg, catalog=cat)
        out["scheduler"] = name
        out["market"] = "spot" if cat.price_model is not None else "on-demand"
        rows.append(out)
    print_table("Spot market: Eva-spot vs on-demand Eva vs No-Packing",
                rows, COLS)
    by = {r["scheduler"]: r for r in rows}
    saving = 1.0 - by["eva-spot"]["total_cost"] / by["eva"]["total_cost"]
    print(f"eva-spot cost saving vs on-demand eva: {saving:.1%}")
    assert by["eva-spot"]["total_cost"] < by["eva"]["total_cost"], \
        "spot-aware Eva must beat on-demand Eva on cost"
    return rows


def hazard_sweep(quick=False, n_jobs=None, seed=5):
    """Cost/JCT vs preemption pressure: spot stays cheaper until revocations
    dominate; JCT degrades gracefully (checkpoint-bounded losses)."""
    n_jobs = n_jobs or (16 if quick else 60)
    hazards = (0.0, 0.3, 1.0) if quick else (0.0, 0.1, 0.3, 1.0, 3.0)
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    rows = []
    for hz in hazards:
        cat = aws_catalog(price_model=pm)
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hz)
        out = run_sim("eva-spot", _trace(n_jobs), cfg, catalog=cat)
        out["scheduler"] = "eva-spot"
        out["market"] = f"spot hz={hz}/h"
        rows.append(out)
    print_table("Spot market: preemption-hazard sweep", rows, COLS)
    return rows


def run(quick=False, full=False):
    n = 200 if full else None
    out = {"spot_vs_ondemand": spot_vs_ondemand(quick=quick, n_jobs=n),
           "hazard_sweep": hazard_sweep(quick=quick)}
    save_results("bench_spot", out)
    return out


if __name__ == "__main__":
    run()
