"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.cluster import SimConfig, Simulator, alibaba_like_trace, physical_trace
from repro.core import EvaScheduler, NoPackingScheduler, aws_catalog
from repro.core.workloads import M_TRUE
from repro.schedulers import OwlScheduler, StratusScheduler, SynergyScheduler

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def scheduler_factory(name: str, catalog, simcfg: SimConfig, **kw):
    if name == "no-packing":
        return NoPackingScheduler(catalog)
    if name == "stratus":
        return StratusScheduler(catalog)
    if name == "synergy":
        return SynergyScheduler(catalog)
    if name == "owl":
        profile = M_TRUE
        if simcfg.uniform_interference is not None:
            profile = np.full_like(M_TRUE, simcfg.uniform_interference)
            np.fill_diagonal(profile, 1.0)
        return OwlScheduler(catalog, profile)
    if name.startswith("eva"):
        opts = dict(migration_delay_scale=simcfg.migration_delay_scale)
        if name == "eva-rp":
            opts["interference_aware"] = False
        if name == "eva-single":
            opts["multi_task_aware"] = False
        if name == "eva-full-only":
            opts["mode"] = "full-only"
        if name == "eva-partial-only":
            opts["mode"] = "partial-only"
        if name == "eva-spot":
            opts["spot_aware"] = True
        if name == "eva-multiregion":
            opts["multi_region"] = True
        if name == "eva-credit":
            opts["credit_aware"] = True
        if name == "eva-autoscale":
            opts["spot_aware"] = True
            opts["autoscale"] = True
        opts.update(kw)
        return EvaScheduler(catalog, **opts)
    raise KeyError(name)


def run_sim(sched_name: str, jobs, simcfg: SimConfig | None = None,
            catalog=None, **kw):
    simcfg = simcfg or SimConfig()
    cat = catalog if catalog is not None else aws_catalog()
    sched = scheduler_factory(sched_name, cat, simcfg, **kw)
    t0 = time.time()
    sim = Simulator(cat, jobs, sched, simcfg)
    m = sim.run()
    out = m.summary()
    out["wall_s"] = round(time.time() - t0, 1)
    if hasattr(sched, "full_adoption_rate"):
        out["full_adoption"] = round(sched.full_adoption_rate, 3)
    if getattr(sched, "multi_region", False):
        out["arbitrage_moves"] = sched.arbitrage_moves
    if getattr(sched, "credit_aware", False):
        out["credit_drains"] = sched.credit_drains
        out["credit_signals"] = sched.credit_signals
    if getattr(sched, "admission", None) is not None:
        out["admissions"] = sched.admission.admissions
        out["forced_admissions"] = sched.admission.forced_admissions
        out["re_deferrals"] = sched.admission.re_deferrals
        out["held_job_rounds"] = sched.admission.held_job_rounds
    return out


def save_results(name: str, data) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(data, f, indent=1)


def print_table(title: str, rows, cols):
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
