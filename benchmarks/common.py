"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.cluster import SimConfig, Simulator, alibaba_like_trace, physical_trace
from repro.core import EvaScheduler, NoPackingScheduler, aws_catalog
from repro.core.workloads import M_TRUE
from repro.obs import FlightRecorder
from repro.policies import stack_from_flags
from repro.schedulers import OwlScheduler, StratusScheduler, SynergyScheduler

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# when set (benchmarks.run --obs), every run_sim attaches a FlightRecorder
# and saves its JSONL trace here, named <scheduler>_<seq>.jsonl
TRACE_DIR: str | None = None
_trace_seq = 0

# scenario-axis flags consumed by stack_from_flags (benchmarks address the
# axes by these names; the factory translates them into an explicit policy
# stack so no deprecated boolean-flag path is exercised)
_AXIS_KW = ("spot_aware", "multi_region", "credit_aware", "autoscale",
            "stability", "slo", "portfolio", "region", "admission", "strike",
            "v")


def scheduler_factory(name: str, catalog, simcfg: SimConfig, **kw):
    if name == "no-packing":
        return NoPackingScheduler(catalog)
    if name == "stratus":
        return StratusScheduler(catalog)
    if name == "synergy":
        return SynergyScheduler(catalog)
    if name == "owl":
        profile = M_TRUE
        if simcfg.uniform_interference is not None:
            profile = np.full_like(M_TRUE, simcfg.uniform_interference)
            np.fill_diagonal(profile, 1.0)
        return OwlScheduler(catalog, profile)
    if name.startswith("eva"):
        opts = dict(migration_delay_scale=simcfg.migration_delay_scale)
        if name == "eva-rp":
            opts["interference_aware"] = False
        if name == "eva-single":
            opts["multi_task_aware"] = False
        if name == "eva-full-only":
            opts["mode"] = "full-only"
        if name == "eva-partial-only":
            opts["mode"] = "partial-only"
        axes = {k: kw.pop(k) for k in _AXIS_KW if k in kw}
        if name == "eva-spot":
            axes["spot_aware"] = True
        if name == "eva-multiregion":
            axes["multi_region"] = True
        if name == "eva-credit":
            axes["credit_aware"] = True
        if name == "eva-autoscale":
            axes.setdefault("spot_aware", True)
            axes["autoscale"] = True
        if name == "eva-stability":
            axes.setdefault("spot_aware", True)
            axes["stability"] = True
        if name == "eva-slo":
            axes.setdefault("spot_aware", True)
            axes["slo"] = True
        if name == "eva-portfolio":
            axes.setdefault("spot_aware", True)
            axes.setdefault("multi_region", True)
            axes["portfolio"] = True
        opts.update(kw)
        if axes and "policies" not in opts:
            opts["policies"] = stack_from_flags(**axes)
        return EvaScheduler(catalog, **opts)
    raise KeyError(name)


def run_sim(sched_name: str, jobs, simcfg: SimConfig | None = None,
            catalog=None, recorder=None, **kw):
    global _trace_seq
    simcfg = simcfg or SimConfig()
    cat = catalog if catalog is not None else aws_catalog()
    trace_path = None
    if recorder is None and TRACE_DIR is not None:
        recorder = FlightRecorder(meta={"scheduler": sched_name,
                                        "n_jobs": len(jobs)})
        trace_path = os.path.join(TRACE_DIR,
                                  f"{sched_name}_{_trace_seq:03d}.jsonl")
        _trace_seq += 1
    if recorder is not None and sched_name.startswith("eva"):
        kw = dict(kw, recorder=recorder)
    sched = scheduler_factory(sched_name, cat, simcfg, **kw)
    t0 = time.time()
    sim = Simulator(cat, jobs, sched, simcfg, recorder=recorder)
    m = sim.run()
    if trace_path is not None:
        recorder.save(trace_path)
    out = m.summary()
    out["wall_s"] = round(time.time() - t0, 1)
    if hasattr(sched, "full_adoption_rate"):
        out["full_adoption"] = round(sched.full_adoption_rate, 3)
    # per-layer counters (arbitrage moves, credit drains, admission stats,
    # stability queue peaks, ...) come from the policy stack itself — no
    # flag sniffing
    stack = getattr(sched, "stack", None)
    if stack is not None:
        out.update(stack.summary())
    return out


def save_results(name: str, data) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(data, f, indent=1)


def print_table(title: str, rows, cols):
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
