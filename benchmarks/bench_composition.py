"""Figure 6: impact of workload composition — share of multi-GPU jobs
(5:4:1 mix of 2-/4-/8-GPU) vs cost; includes Eva partial-only to show Full
Reconfiguration's contribution."""
from __future__ import annotations

from repro.cluster import SimConfig, alibaba_like_trace

from .common import print_table, run_sim, save_results


def run(quick=False, n_jobs=None):
    n = n_jobs or (150 if quick else 400)
    fracs = (0.0, 0.4) if quick else (0.0, 0.2, 0.4, 0.6)
    rows = []
    for f in fracs:
        for sched in ("no-packing", "stratus", "synergy", "eva-partial-only",
                      "eva"):
            jobs = alibaba_like_trace(n_jobs=n, seed=13, multi_gpu_fraction=f)
            m = run_sim(sched, jobs, SimConfig(seed=6))
            rows.append({"multi_gpu_frac": f, "scheduler": sched,
                         "total_cost": m["total_cost"]})
    for f in fracs:
        base = next(r["total_cost"] for r in rows
                    if r["multi_gpu_frac"] == f and r["scheduler"] == "no-packing")
        for r in rows:
            if r["multi_gpu_frac"] == f:
                r["norm_cost_pct"] = round(100 * r["total_cost"] / base, 1)
    print_table("Figure 6: multi-GPU composition sweep", rows,
                ["multi_gpu_frac", "scheduler", "norm_cost_pct"])
    save_results("bench_composition", rows)
    return rows


if __name__ == "__main__":
    run()
