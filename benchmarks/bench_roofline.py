"""Roofline table (§Roofline): one row per (arch × shape × mesh) from the
dry-run artifact + analytic terms.  Requires results/dryrun.json (produced
by ``python -m repro.launch.dryrun``)."""
from __future__ import annotations

import json
import os

from repro.configs import ARCHS, SHAPES

from .common import RESULTS_DIR, print_table, save_results


def run(quick=False, dryrun_path=None):
    path = dryrun_path or os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(path):
        print(f"[roofline] {path} missing — run `python -m repro.launch.dryrun`"
              " first; skipping")
        return []
    from repro.launch.roofline import roofline_row
    with open(path) as f:
        cells = json.load(f)
    rows = []
    for key, cell in sorted(cells.items()):
        if not cell.get("ok"):
            rows.append({"arch": cell.get("arch"), "shape": cell.get("shape"),
                         "mesh": cell.get("mesh"), "bottleneck": "FAILED"})
            continue
        if cell["mesh"] != "single_pod":
            continue  # roofline table is single-pod; multi-pod proves sharding
        cfg = ARCHS[cell["arch"]]
        shape = SHAPES[cell["shape"]]
        r = roofline_row(cell, cfg, shape)
        for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
            r[k] = round(r[k], 5)
        r["useful_ratio"] = round(r["useful_ratio"], 3)
        r["roofline_frac"] = round(r["roofline_frac"], 3)
        rows.append(r)
    print_table("Roofline (single-pod 16x16, per-device terms)", rows,
                ["arch", "shape", "t_compute_s", "t_memory_s",
                 "t_collective_s", "bottleneck", "useful_ratio",
                 "roofline_frac"])
    save_results("bench_roofline", rows)
    return rows


if __name__ == "__main__":
    run()
