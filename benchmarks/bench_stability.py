"""Stability-vs-cost scenario benchmark (beyond the paper; arXiv 2201.09050).

Runs the bundled mixed deadline-tight / deadline-loose deferrable trace
(``cluster/traces.deferrable_trace``) on the bundled OU spot market through
three admission regimes:

* ``eva-stability`` — ``StabilityLayer`` on the policy stack:
  drift-plus-penalty admission (queue backlog vs price premium, dial
  ``V``) plus warm-keep pricing of live instances while jobs are queued.
  The first scenario axis written purely against the policy-layer API.
* ``eva-autoscale`` (always-defer) — pure strike-price chasing with a deep
  strike: every deferrable job is held until the market dips below 0.7 ×
  its anchor reservation price (or its latest-start deadline forces it).
  Cheap, but the pending queue grows with every dear phase.
* ``eva-spot`` — always-admit on the same market (the queue-free anchor).

The acceptance invariant (also enforced in CI): eva-stability holds the
**max pending-queue length strictly below** the always-defer chaser at a
total cost **within 5 %** — bounded queues may not be bought with
runaway spending, and deferral still may not blow deadlines.  A ``V``
sweep shows the cost/stability dial between the two regimes.

    PYTHONPATH=src python -m benchmarks.run --quick --only stability
"""
from __future__ import annotations

from repro.cluster import SimConfig, deferrable_trace
from repro.core import PriceModel, aws_catalog

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "market", "total_cost", "avg_jct_hours",
        "deadline_misses", "max_pending_jobs", "held_job_rounds",
        "admissions", "forced_admissions", "wall_s"]

CHASER_STRIKE = 0.7  # the always-defer baseline: hold out for deep dips
COST_SLACK = 1.05  # stability may cost at most 5 % over the chaser


def _trace(n_jobs, seed=13):
    return deferrable_trace(n_jobs=n_jobs, seed=seed)


def stability_vs_chasing(quick=False, n_jobs=None, hazard=0.3, seed=5):
    n_jobs = n_jobs or (24 if quick else 96)
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
    rows = []
    for name, kw in (
            ("eva-stability", {}),
            ("eva-autoscale", dict(strike=CHASER_STRIKE)),
            ("eva-spot", {})):
        out = run_sim(name, _trace(n_jobs), cfg,
                      catalog=aws_catalog(price_model=pm), **kw)
        out["scheduler"] = name if name != "eva-autoscale" \
            else f"eva-autoscale (strike={CHASER_STRIKE:g})"
        out["market"] = "spot (OU)"
        rows.append(out)
    print_table("Stability: drift-plus-penalty admission vs always-defer "
                "strike chasing vs always-admit", rows, COLS)
    stab, chase, _ = rows
    ratio = stab["total_cost"] / chase["total_cost"]
    print(f"eva-stability queue peak {stab['max_pending_jobs']} vs chaser "
          f"{chase['max_pending_jobs']} at {ratio:.1%} of its cost "
          f"({stab['deadline_misses']} vs {chase['deadline_misses']} "
          f"deadline misses)")
    assert stab["max_pending_jobs"] < chase["max_pending_jobs"], \
        "stability must bound the pending queue below the strike chaser"
    assert stab["total_cost"] <= COST_SLACK * chase["total_cost"], \
        "bounded queues may cost at most 5% over strike chasing"
    assert stab["deadline_misses"] == 0, \
        "stability-admission must not blow deadlines"
    return rows


def v_sweep(quick=False, n_jobs=None, hazard=0.3, seed=5):
    """The drift-plus-penalty dial: V = rounds of queueing tolerated per
    unit of relative price premium.  V = 0 admits after one held round
    (pure stability), large V approaches strike chasing — cost falls,
    queue grows."""
    n_jobs = n_jobs or (16 if quick else 64)
    vs = (0.0, 32.0, 128.0) if quick else (0.0, 8.0, 32.0, 128.0, 512.0)
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    rows = []
    for v in vs:
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
        out = run_sim("eva-stability", _trace(n_jobs), cfg,
                      catalog=aws_catalog(price_model=pm), v=v)
        out["scheduler"] = "eva-stability"
        out["market"] = f"V={v:g}"
        rows.append(out)
    print_table("Stability: V sweep (queue patience per unit premium)",
                rows, COLS)
    return rows


def run(quick=False, full=False):
    n = 200 if full else None
    out = {"stability_vs_chasing": stability_vs_chasing(quick=quick,
                                                        n_jobs=n),
           "v_sweep": v_sweep(quick=quick)}
    save_results("bench_stability", out)
    return out


if __name__ == "__main__":
    run()
