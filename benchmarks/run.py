"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full] [--only NAME]

Emits CSV-style tables to stdout and JSON artifacts under results/.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (CI-scale)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale end-to-end (6,274 jobs)")
    ap.add_argument("--only", default=None,
                    help="run a single bench: micro|endtoend|multitask|"
                         "interference|migration|composition|arrival|"
                         "roofline|spot|multiregion|credits|autoscale|"
                         "stability|serving|portfolio")
    args = ap.parse_args()

    from . import (bench_arrival, bench_autoscale, bench_composition,
                   bench_credits, bench_endtoend, bench_interference,
                   bench_micro, bench_migration, bench_multiregion,
                   bench_multitask, bench_portfolio, bench_roofline,
                   bench_serving, bench_spot, bench_stability)
    benches = {
        "micro": lambda: bench_micro.run(quick=args.quick),
        "endtoend": lambda: bench_endtoend.run(quick=args.quick,
                                               full=args.full),
        "multitask": lambda: bench_multitask.run(quick=args.quick),
        "interference": lambda: bench_interference.run(quick=args.quick),
        "migration": lambda: bench_migration.run(quick=args.quick),
        "composition": lambda: bench_composition.run(quick=args.quick),
        "arrival": lambda: bench_arrival.run(quick=args.quick),
        "roofline": lambda: bench_roofline.run(quick=args.quick),
        "spot": lambda: bench_spot.run(quick=args.quick, full=args.full),
        "multiregion": lambda: bench_multiregion.run(quick=args.quick,
                                                     full=args.full),
        "credits": lambda: bench_credits.run(quick=args.quick,
                                             full=args.full),
        "autoscale": lambda: bench_autoscale.run(quick=args.quick,
                                                 full=args.full),
        "stability": lambda: bench_stability.run(quick=args.quick,
                                                 full=args.full),
        "serving": lambda: bench_serving.run(quick=args.quick,
                                             full=args.full),
        "portfolio": lambda: bench_portfolio.run(quick=args.quick,
                                                 full=args.full),
    }
    todo = [args.only] if args.only else list(benches)
    t0 = time.time()
    for name in todo:
        t1 = time.time()
        print(f"\n#### bench: {name} " + "#" * 40)
        benches[name]()
        print(f"#### bench {name} done in {time.time() - t1:.1f}s")
    print(f"\nall benches done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
