"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full] [--only NAME]
        [--obs] [--trace-dir DIR] [--results-dir DIR] [--json PATH]

Emits CSV-style tables to stdout, greppable ``[bench] event key=value``
progress lines, and JSON artifacts under results/.  With ``--obs`` every
simulated run attaches a flight recorder and saves its JSONL trace under
``results/traces/`` (or ``--trace-dir``) for offline replay with
``tools/explain.py``.
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (CI-scale)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale end-to-end (6,274 jobs)")
    ap.add_argument("--only", default=None,
                    help="run a single bench: micro|endtoend|multitask|"
                         "interference|migration|composition|arrival|"
                         "roofline|spot|multiregion|credits|autoscale|"
                         "stability|serving|portfolio|sim")
    ap.add_argument("--obs", action="store_true",
                    help="attach a flight recorder to every simulated run "
                         "and save JSONL traces (tools/explain.py replays "
                         "them)")
    ap.add_argument("--trace-dir", default=None,
                    help="trace output dir (implies --obs; default "
                         "results/traces)")
    ap.add_argument("--results-dir", default=None,
                    help="override the results/ artifact directory (the "
                         "perf-overhead gate writes recording-on results "
                         "to a separate dir)")
    ap.add_argument("--json", default=None,
                    help="write the run report (per-bench timings) as JSON")
    args = ap.parse_args()

    from repro.obs import Reporter

    from . import (bench_arrival, bench_autoscale, bench_composition,
                   bench_credits, bench_endtoend, bench_interference,
                   bench_micro, bench_migration, bench_multiregion,
                   bench_multitask, bench_portfolio, bench_roofline,
                   bench_serving, bench_sim, bench_spot, bench_stability,
                   common)

    if args.results_dir:
        common.RESULTS_DIR = args.results_dir
    if args.obs or args.trace_dir:
        common.TRACE_DIR = args.trace_dir or os.path.join(
            common.RESULTS_DIR, "traces")
        os.makedirs(common.TRACE_DIR, exist_ok=True)

    benches = {
        "micro": lambda: bench_micro.run(quick=args.quick),
        "endtoend": lambda: bench_endtoend.run(quick=args.quick,
                                               full=args.full),
        "multitask": lambda: bench_multitask.run(quick=args.quick),
        "interference": lambda: bench_interference.run(quick=args.quick),
        "migration": lambda: bench_migration.run(quick=args.quick),
        "composition": lambda: bench_composition.run(quick=args.quick),
        "arrival": lambda: bench_arrival.run(quick=args.quick),
        "roofline": lambda: bench_roofline.run(quick=args.quick),
        "spot": lambda: bench_spot.run(quick=args.quick, full=args.full),
        "multiregion": lambda: bench_multiregion.run(quick=args.quick,
                                                     full=args.full),
        "credits": lambda: bench_credits.run(quick=args.quick,
                                             full=args.full),
        "autoscale": lambda: bench_autoscale.run(quick=args.quick,
                                                 full=args.full),
        "stability": lambda: bench_stability.run(quick=args.quick,
                                                 full=args.full),
        "serving": lambda: bench_serving.run(quick=args.quick,
                                             full=args.full),
        "portfolio": lambda: bench_portfolio.run(quick=args.quick,
                                                 full=args.full),
        "sim": lambda: bench_sim.run(quick=args.quick, full=args.full),
    }
    todo = [args.only] if args.only else list(benches)
    rep = Reporter("bench")
    t0 = time.time()
    for name in todo:
        t1 = time.time()
        rep.emit("start", bench=name)
        benches[name]()
        rep.emit("done", bench=name, wall_s=round(time.time() - t1, 1))
    rep.emit("all_done", benches=len(todo),
             wall_s=round(time.time() - t0, 1),
             trace_dir=common.TRACE_DIR or "")
    if args.json:
        rep.write_json(args.json, quick=args.quick, full=args.full)


if __name__ == "__main__":
    main()
