"""Burstable-credit (CASH) scenario benchmark (beyond the paper).

Runs the bundled CPU trace through three provisioning regimes on the
``burstable_demo_catalog`` market (all 21 on-demand AWS types + ``t7i.*``
burstable twins of the c7i tier at 42 % of the on-demand price, throttling
to a 20 % baseline once their credit balance runs out):

* ``eva-credit``   — ``EvaScheduler(credit_aware=True)``: reservation
  prices against credit-adjusted effective throughput over the D̂ horizon,
  balance-decayed keep test, credit-pressure drains onto steady types.
* ``eva`` (blind)  — same burstable catalog, credit-blind Eva: reservation
  prices anchor to the cheap burstable sticker price and the jobs ride the
  throttle at baseline speed while billing continues unchanged.
* ``eva-ondemand`` — plain AWS catalog (no burstable types): the steady
  baseline a credit-aware scheduler must also beat for the axis to matter.

The acceptance invariant (also enforced in CI) is that eva-credit is
strictly cheaper than BOTH the credit-blind run and the on-demand run:
bursting is only worth it if you harvest the cheap full-speed window *and*
escape the throttle.  A second sweep scales the launch-credit budget to
show the axis closing: with no launch credits a burstable type is never
worth provisioning, with generous ones the whole trace fits in the burst.

    PYTHONPATH=src python -m benchmarks.run --quick --only credits
"""
from __future__ import annotations

from repro.cluster import SimConfig, burstable_trace
from repro.core import aws_catalog, burstable_demo_catalog

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "market", "total_cost", "avg_jct_hours",
        "migrations_per_task", "credit_exhaustions", "throttled_hours",
        "credit_drains", "wall_s"]


def _trace(n_jobs, seed=11, durations=(0.6, 1.5)):
    return burstable_trace(n_jobs=n_jobs, seed=seed,
                           duration_range_h=durations)


def credit_vs_blind_vs_ondemand(quick=False, n_jobs=None, seed=5):
    n_jobs = n_jobs or (16 if quick else 80)
    rows = []
    for name, cat, market in (
            ("eva-credit", burstable_demo_catalog(), "burstable (aware)"),
            ("eva", burstable_demo_catalog(), "burstable (blind)"),
            ("eva", aws_catalog(), "on-demand")):
        out = run_sim(name, _trace(n_jobs), SimConfig(seed=seed), catalog=cat)
        out["scheduler"] = "eva-ondemand" if market == "on-demand" else name
        out["market"] = market
        rows.append(out)
    print_table("Burstable credits: credit-aware Eva vs credit-blind Eva "
                "vs on-demand Eva", rows, COLS)
    by = {r["scheduler"]: r for r in rows}
    save_blind = 1.0 - by["eva-credit"]["total_cost"] / by["eva"]["total_cost"]
    save_od = (1.0 - by["eva-credit"]["total_cost"]
               / by["eva-ondemand"]["total_cost"])
    print(f"eva-credit saving vs credit-blind eva: {save_blind:.1%}; "
          f"vs on-demand eva: {save_od:.1%}")
    assert by["eva-credit"]["total_cost"] < by["eva"]["total_cost"], \
        "credit-aware Eva must beat credit-blind Eva on cost"
    assert by["eva-credit"]["total_cost"] < by["eva-ondemand"]["total_cost"], \
        "credit-aware Eva must beat always-on-demand Eva on cost"
    return rows


def launch_credit_sweep(quick=False, n_jobs=None, seed=5):
    """Cost vs launch-credit budget: with zero launch credits the burstable
    discount is unreachable (fresh instances throttle immediately, so the
    credit-adjusted RP prices them above on-demand and eva-credit converges
    to the on-demand cost); as the budget grows, more of each job fits in
    the cheap full-speed window and the cost falls toward
    ``price_fraction`` × on-demand."""
    n_jobs = n_jobs or (12 if quick else 48)
    budgets = (0.0, 0.5, 2.0) if quick else (0.0, 0.25, 0.5, 1.0, 2.0)
    rows = []
    for b in budgets:
        cat = burstable_demo_catalog(launch_credit_hours=b,
                                     credit_cap_hours=max(b, 2.0))
        out = run_sim("eva-credit", _trace(n_jobs), SimConfig(seed=seed),
                      catalog=cat)
        out["scheduler"] = "eva-credit"
        out["market"] = f"launch={b:g}h"
        rows.append(out)
    print_table("Burstable credits: launch-credit sweep", rows, COLS)
    return rows


def run(quick=False, full=False):
    n = 160 if full else None
    out = {"credit_vs_blind_vs_ondemand":
           credit_vs_blind_vs_ondemand(quick=quick, n_jobs=n),
           "launch_credit_sweep": launch_credit_sweep(quick=quick)}
    save_results("bench_credits", out)
    return out


if __name__ == "__main__":
    run()
