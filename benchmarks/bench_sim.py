"""Simulator-core throughput benchmark: vectorized vs scalar accrual.

The PR this pins rewrote the simulator's accrual/billing hot path as array
programs over structure-of-arrays fleet state (``cluster/fleet.SlotTable``
+ per-type aggregates; see docs/ARCHITECTURE.md, "The simulator at fleet
scale").  This bench measures the end-to-end win on two axes and gates it
in CI through ``tools/bench_compare.py``:

* ``sim_scenarios`` — serving-class and portfolio-class fleets (diurnal
  SLO traffic next to batch filler on an OU spot market; commitment pool +
  two provider markets), sized so the accrual sweep dominates the scalar
  runtime the way it does on any long-horizon fleet.  Acceptance:
  vectorized ≥ 10× scalar end-to-end on both cells, in quick mode.
* ``sim_population`` — task-population scaling sweep (10³ → 10⁵ in quick
  mode, 10⁶ vectorized-only with ``--full``: the million-task trace
  sweeps in minutes).  Acceptance: vectorized ≥ 5× scalar at the 10⁵
  cell.

Both modes run the *identical* event trajectory (the vectorized core is
pinned bit-identical on decisions, ≤1e-9 relative on reassociated sums —
tests/test_invariants.py), so each cell also cross-checks ``total_cost``
between modes and reports the relative error.

The fleet is driven by a bench-local launch-and-hold scheduler (one job
per instance, packed to fill it) so the measured time is the simulator
core, not planner work: EvaScheduler's own planning cost has its own
bench + gate (bench_micro's scaling curve).

    PYTHONPATH=src python -m benchmarks.run --quick --only sim
"""
from __future__ import annotations

import time

from repro.cluster import SimConfig, Simulator
from repro.core import (CommitmentModel, PriceModel, Provider,
                        RequestProfile, ServiceSpec, UtilityCurve,
                        aws_catalog, make_job, multi_provider_catalog)
from repro.core.cluster_types import ClusterConfig
from repro.core.scheduler import SchedulerBase
from repro.core.workloads import WORKLOAD_INDEX

from .common import print_table, save_results

BATCH = WORKLOAD_INDEX["a3c"]        # (4 vCPU, 8 GB) on c7i
SERVE = WORKLOAD_INDEX["embed-serve"]  # (6 vCPU, 16 GB) on c7i
POOL_W = WORKLOAD_INDEX["diamond"]   # (8 vCPU, 16 GB): 4 fill a c7i.8xlarge
GCP_W = WORKLOAD_INDEX["openfoam"]   # (6 vCPU, 8 GB): 5 per c7i.8xlarge
TASKS_PER_JOB = 8        # 8 × a3c exactly fills a c7i.8xlarge (32 vCPU)
REPLICAS = 24            # 24 × embed-serve fills a c7i.48xlarge (384 GB)

#: scalar cells above this population are skipped ('' in the table): the
#: reference path is the thing this PR made obsolete at fleet scale
SCALAR_CAP = 100_000

SCEN_COLS = ["scenario", "n_tasks", "scalar_s", "vectorized_s",
             "speedup", "cost_rel_err"]
POP_COLS = ["n_tasks", "scalar_s", "vectorized_s", "speedup",
            "cost_rel_err"]


class _HoldScheduler(SchedulerBase):
    """Launch-and-hold: place each job's tasks together on one instance of
    a fixed per-workload type, then keep the assignment for the rest of
    the run.  Rounds after the first re-emit the live placement, so the
    executor diffs to a no-op and the simulator core dominates wall time.
    """

    name = "hold"

    def __init__(self, catalog, type_of_workload):
        super().__init__(catalog)
        self._kmap = type_of_workload

    def schedule(self, view) -> ClusterConfig:
        system_ids = set(view.tasks.ids.tolist())
        assignments, placed = [], set()
        for inst in view.live:
            alive = tuple(t for t in inst.task_ids if t in system_ids)
            if alive:
                assignments.append((inst.type_index, alive))
                placed.update(alive)
        by_job = {}
        for tid, jid, w in zip(view.tasks.ids.tolist(),
                               view.tasks.job_ids.tolist(),
                               view.tasks.workloads.tolist()):
            if tid not in placed:
                by_job.setdefault(jid, (w, []))[1].append(tid)
        for jid in sorted(by_job):
            w, tids = by_job[jid]
            assignments.append((self._kmap[w], tuple(sorted(tids))))
        return ClusterConfig(assignments)


def _type_index(cat, name):
    return next(i for i, t in enumerate(cat.types) if t.name == name)


def _batch_jobs(n_tasks, horizon_s, start_id=0, arrival=0.0,
                workload=BATCH, tasks_per_job=TASKS_PER_JOB):
    """Long-lived batch filler: one-instance jobs that outlast the horizon,
    so the fleet stays at full population the whole run."""
    return [make_job(job_id=start_id + i, workload=workload,
                     arrival_time=arrival, duration_s=horizon_s * 10.0,
                     n_tasks=tasks_per_job)
            for i in range(max(n_tasks // tasks_per_job, 1))]


def _service_jobs(n_fleets, horizon_s, start_id):
    """Diurnal SLO fleets (one instance each): a 900 s profile grid keeps
    a steady RATE_UPDATE stream next to the 300 s price grid."""
    jobs = []
    for i in range(n_fleets):
        prof = RequestProfile.diurnal(
            peak_rps=6000.0, duration_s=horizon_s, step_s=900.0,
            peak_hour=5.0 + 3.0 * i)
        spec = ServiceSpec(requests=prof, utility=UtilityCurve(100.0),
                           per_replica_rps=400.0, base_latency_ms=25.0)
        jobs.append(make_job(job_id=start_id + i, workload=SERVE,
                             arrival_time=0.0, duration_s=horizon_s * 10.0,
                             n_tasks=REPLICAS, service=spec))
    return jobs


def _measure(cat, jobs, cfg, kmap, vectorized):
    sched = _HoldScheduler(cat, kmap)
    t0 = time.time()
    sim = Simulator(cat, jobs, sched, cfg, vectorized=vectorized)
    m = sim.run()
    return time.time() - t0, m


def _cell(cat, jobs, cfg, kmap, run_scalar=True):
    """One table cell: vectorized (always) vs scalar (unless capped)."""
    vec_s, mv = _measure(cat, jobs, cfg, kmap, vectorized=True)
    if not run_scalar:
        return {"scalar_s": "", "vectorized_s": round(vec_s, 3),
                "speedup": "", "cost_rel_err": ""}
    sca_s, ms = _measure(cat, jobs, cfg, kmap, vectorized=False)
    denom = max(abs(ms.total_cost), 1e-12)
    rel = abs(mv.total_cost - ms.total_cost) / denom
    return {"scalar_s": round(sca_s, 3), "vectorized_s": round(vec_s, 3),
            "speedup": round(sca_s / max(vec_s, 1e-9), 1),
            "cost_rel_err": float(f"{rel:.2e}")}


def scenarios(quick=False):
    """Serving-class and portfolio-class cells (the ≥10× acceptance)."""
    rows = []
    horizon = (84.0 if quick else 168.0) * 3600.0
    # --- serving-class: diurnal SLO fleets + batch filler on an OU market
    n_batch = 20_000
    cat = aws_catalog(
        price_model=PriceModel.mean_reverting(discount=0.35, seed=7))
    jobs = (_batch_jobs(n_batch, horizon)
            + _service_jobs(4, horizon, start_id=900_000))
    kmap = {BATCH: _type_index(cat, "c7i.8xlarge"),
            SERVE: _type_index(cat, "c7i.48xlarge")}
    cfg = SimConfig(seed=3, max_time_s=horizon, round_interval_s=6 * 3600.0)
    n_tasks = n_batch + 4 * REPLICAS
    row = {"scenario": "serving", "n_tasks": n_tasks}
    row.update(_cell(cat, jobs, cfg, kmap))
    rows.append(row)
    # --- portfolio-class: commitment pool (kept exactly full) + two
    # provider spot markets, steady base at t=0 plus burst arrival waves
    # mid-horizon (the arrival-coalescing path)
    n_market, n_pool, n_gcp, n_burst = 7_200, 2_400, 2_400, 2_400
    cm = CommitmentModel(instance_type="c7i.8xlarge",
                         pool_size=n_pool // 4, rate_fraction=0.55)
    pcat = multi_provider_catalog([
        Provider(name="aws",
                 price_model=PriceModel.mean_reverting(discount=0.4,
                                                       seed=11),
                 commitments=(cm,)),
        Provider(name="gcp", cost_scale=1.03,
                 price_model=PriceModel.mean_reverting(discount=0.45,
                                                       seed=12))])
    pjobs = (_batch_jobs(n_market, horizon)
             + _batch_jobs(n_pool, horizon, start_id=200_000,
                           workload=POOL_W, tasks_per_job=4)
             + _batch_jobs(n_gcp, horizon, start_id=300_000,
                           workload=GCP_W, tasks_per_job=5))
    for wave, t in enumerate((0.3, 0.6)):
        pjobs += _batch_jobs(n_burst // 2, horizon,
                             start_id=400_000 + 50_000 * wave,
                             arrival=t * horizon)
    pkmap = {BATCH: _type_index(pcat, "aws/c7i.8xlarge"),
             POOL_W: _type_index(pcat,
                                 "aws/commit-c7i.8xlarge/c7i.8xlarge"),
             GCP_W: _type_index(pcat, "gcp/c7i.8xlarge")}
    pcfg = SimConfig(seed=5, max_time_s=horizon,
                     round_interval_s=6 * 3600.0)
    row = {"scenario": "portfolio",
           "n_tasks": n_market + n_pool + n_gcp + n_burst}
    row.update(_cell(pcat, pjobs, pcfg, pkmap))
    rows.append(row)
    print_table("sim_scenarios: vectorized vs scalar accrual (end-to-end)",
                rows, SCEN_COLS)
    return rows


def population(quick=False, full=False):
    """Task-population scaling sweep (the ≥5× floor at 10⁵)."""
    rows = []
    ns = [1_000, 10_000, 100_000]
    if full:
        ns.append(1_000_000)
    horizon = 24.0 * 3600.0
    cat = aws_catalog(
        price_model=PriceModel.mean_reverting(discount=0.35, seed=7))
    kmap = {BATCH: _type_index(cat, "c7i.8xlarge")}
    for n in ns:
        jobs = _batch_jobs(n, horizon)
        cfg = SimConfig(seed=1, max_time_s=horizon,
                        round_interval_s=6 * 3600.0)
        row = {"n_tasks": n}
        row.update(_cell(cat, jobs, cfg, kmap, run_scalar=n <= SCALAR_CAP))
        rows.append(row)
    print_table("sim_population: accrual scaling with fleet size",
                rows, POP_COLS)
    return rows


def run(quick=False, full=False):
    out = {
        "sim_scenarios": scenarios(quick=quick),
        "sim_population": population(quick=quick, full=full),
    }
    save_results("bench_sim", out)
    return out


if __name__ == "__main__":
    run(quick=True)
