"""Multi-region spot-arbitrage benchmark (beyond the paper).

Runs the same trace through three provisioning regimes on the bundled
3-region dispersed-price market (``dispersed_demo_regions``: staggered
square-wave traces — exactly one region is in its cheap window at any
instant):

* ``eva-multiregion`` — region-expanded catalog, ``EvaScheduler(
  multi_region=True)``: cross-region reservation prices, migration-costed
  region arbitrage, region-correlated hazards.
* ``eva-spot``        — single-region spot baseline: the same price process
  as region-0 only (what a scheduler locked to its home region pays).
* ``eva``             — on-demand-only Eva: static catalog at base prices.

The acceptance invariant (also enforced in CI) is that eva-multiregion is
strictly cheaper than eva-spot: a single-market scheduler only enjoys the
cheap window 1/3 of the time, while the multi-region one chases it across
markets and pays egress for the privilege.  A second sweep scales the egress
price to show the arbitrage shutting down as transfer costs dominate
(Voorsluys et al.-style cross-market provisioning).

    PYTHONPATH=src python -m benchmarks.run --quick --only multiregion
"""
from __future__ import annotations

from repro.cluster import SimConfig, physical_trace
from repro.core import (TransferMatrix, aws_catalog, dispersed_demo_regions,
                        multi_region_catalog)

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "market", "total_cost", "avg_jct_hours",
        "migrations_per_task", "preemptions", "cross_region_migrations",
        "egress_cost", "arbitrage_moves", "wall_s"]

N_REGIONS = 3


def _trace(n_jobs, seed=11, durations=(0.3, 0.8)):
    return physical_trace(n_jobs=n_jobs, seed=seed, duration_range_h=durations)


def multiregion_vs_single(quick=False, n_jobs=None, hazard=0.3, seed=5):
    n_jobs = n_jobs or (24 if quick else 120)
    regions = dispersed_demo_regions(N_REGIONS)
    rows = []
    for name, cat, cfg in (
            ("eva-multiregion", multi_region_catalog(regions),
             SimConfig(seed=seed, preemption_hazard_per_hour=hazard)),
            ("eva-spot", aws_catalog(price_model=regions[0].price_model),
             SimConfig(seed=seed, preemption_hazard_per_hour=hazard)),
            ("eva", aws_catalog(), SimConfig(seed=seed))):
        out = run_sim(name, _trace(n_jobs), cfg, catalog=cat)
        out["scheduler"] = name
        out["market"] = ("3-region dispersed" if name == "eva-multiregion"
                         else "region-0 only" if name == "eva-spot"
                         else "on-demand")
        rows.append(out)
    print_table("Multi-region: Eva-multiregion vs single-region Eva-spot "
                "vs on-demand Eva", rows, COLS)
    by = {r["scheduler"]: r for r in rows}
    saving = 1.0 - by["eva-multiregion"]["total_cost"] / by["eva-spot"]["total_cost"]
    print(f"eva-multiregion cost saving vs single-region eva-spot: {saving:.1%}")
    assert by["eva-multiregion"]["total_cost"] < by["eva-spot"]["total_cost"], \
        "multi-region Eva must beat single-region spot Eva on cost"
    return rows


def egress_sweep(quick=False, n_jobs=None, hazard=0.3, seed=5):
    """Cost vs egress price: with cheap transfer the scheduler chases the
    cheap window hard; as egress grows each move gets dearer and the
    migration-costed keep test retains more instances in place, so total
    cost climbs from well below toward the single-market spot cost."""
    n_jobs = n_jobs or (16 if quick else 60)
    scales = (0.0, 1.0, 25.0) if quick else (0.0, 1.0, 5.0, 25.0, 100.0)
    regions = dispersed_demo_regions(N_REGIONS)
    rows = []
    for s in scales:
        transfer = TransferMatrix.uniform(N_REGIONS,
                                          egress_usd_per_gb=0.02 * s)
        cat = multi_region_catalog(regions, transfer=transfer)
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
        out = run_sim("eva-multiregion", _trace(n_jobs), cfg, catalog=cat)
        out["scheduler"] = "eva-multiregion"
        out["market"] = f"egress x{s:g}"
        rows.append(out)
    print_table("Multi-region: egress-price sweep", rows, COLS)
    return rows


def run(quick=False, full=False):
    n = 200 if full else None
    out = {"multiregion_vs_single": multiregion_vs_single(quick=quick, n_jobs=n),
           "egress_sweep": egress_sweep(quick=quick)}
    save_results("bench_multiregion", out)
    return out


if __name__ == "__main__":
    run()
