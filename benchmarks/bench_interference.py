"""Figure 4: impact of co-location interference.  Sweep uniform pairwise
throughput {1.0, 0.95, 0.9, 0.85, 0.8}; Eva-TNRP vs Eva-RP vs Owl vs
No-Packing."""
from __future__ import annotations

from repro.cluster import SimConfig, alibaba_like_trace

from .common import print_table, run_sim, save_results


def run(quick=False, n_jobs=None):
    n = n_jobs or (150 if quick else 500)
    levels = (1.0, 0.9, 0.8) if quick else (1.0, 0.95, 0.9, 0.85, 0.8)
    rows = []
    for tput in levels:
        cfgk = dict(seed=2, uniform_interference=tput)
        for sched in ("no-packing", "owl", "eva-rp", "eva"):
            jobs = alibaba_like_trace(n_jobs=n, seed=5)
            m = run_sim(sched, jobs, SimConfig(**cfgk))
            rows.append({"pair_tput": tput, "scheduler": sched,
                         "total_cost": m["total_cost"],
                         "jct_hours": m["avg_jct_hours"],
                         "job_tput": m["norm_job_tput"]})
    for tput in levels:
        base = next(r["total_cost"] for r in rows
                    if r["pair_tput"] == tput and r["scheduler"] == "no-packing")
        for r in rows:
            if r["pair_tput"] == tput:
                r["norm_cost_pct"] = round(100 * r["total_cost"] / base, 1)
    print_table("Figure 4: interference sweep", rows,
                ["pair_tput", "scheduler", "norm_cost_pct", "jct_hours",
                 "job_tput"])
    save_results("bench_interference", rows)
    return rows


if __name__ == "__main__":
    run()
