"""Tables 4 & 5: provisioning-cost micro-benchmark (No-Packing vs Full
Reconfiguration vs ILP), Full-Reconfiguration runtime scaling (plus the
beyond-paper jitted JAX engine), and the fleet-scale planning curve
(10³→10⁶ tasks: numpy vs single-pass jit vs incremental repack)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (LiveInstance, TaskSet, aws_catalog, cheapest_type,
                        full_reconfiguration, incremental_reconfiguration,
                        make_task, reservation_prices)
from repro.core.catalog import FAMILIES, NUM_RESOURCES
from repro.core.ilp import cost_lower_bound, solve_ilp
from repro.core.workloads import NUM_WORKLOADS, WORKLOADS
from repro.obs import profiler as _prof

from . import common
from .common import print_table, save_results


def _random_tasks(n, rng):
    return TaskSet([make_task(job_id=i, workload=int(rng.integers(NUM_WORKLOADS)))
                    for i in range(n)])


def _fleet(n, rng):
    """Array-built fleet (single-task jobs): the (W, F, R) profile matrix is
    gathered per task, so construction stays O(n) with no Python loop."""
    prof = np.zeros((NUM_WORKLOADS, len(FAMILIES), NUM_RESOURCES))
    for wi, w in enumerate(WORKLOADS):
        for fi, fam in enumerate(FAMILIES):
            prof[wi, fi] = w.demand_for_family(fam)
    wl = rng.integers(NUM_WORKLOADS, size=n).astype(np.int64)
    ids = np.arange(n, dtype=np.int64)
    return TaskSet.from_arrays(ids, ids, wl, prof[wl])


def table4(trials=5, n_tasks=200, ilp_time_limit=30.0, quick=False):
    """Provisioning cost for a static task set (paper: ILP ~1×, Full
    Reconfig 1.01×, No-Packing 1.56×; Gurobi timed out at 30 min)."""
    if quick:
        trials, n_tasks, ilp_time_limit = 3, 60, 10.0
    cat = aws_catalog()
    rows = []
    ratios_np, ratios_fr, gaps = [], [], []
    t_fr = t_ilp = 0.0
    for t in range(trials):
        rng = np.random.default_rng(1000 + t)
        tasks = _random_tasks(n_tasks, rng)
        rp = reservation_prices(tasks, cat)
        no_packing = float(rp.sum())
        t0 = time.time()
        cfg = full_reconfiguration(tasks, cat, table=None,
                                   interference_aware=False,
                                   multi_task_aware=False)
        t_fr += time.time() - t0
        fr_cost = cfg.total_hourly_cost(cat)
        t0 = time.time()
        ilp = solve_ilp(tasks, cat, time_limit_s=ilp_time_limit)
        t_ilp += time.time() - t0
        base = min(ilp.cost, fr_cost) if ilp.config else fr_cost
        lb = max(cost_lower_bound(tasks, cat), ilp.lower_bound)
        ratios_np.append(no_packing / base)
        ratios_fr.append(fr_cost / base)
        gaps.append(base / max(lb, 1e-9))
    rows.append({"scheduler": "No-Packing",
                 "norm_cost": f"{np.mean(ratios_np):.2f}±{np.std(ratios_np):.2f}",
                 "runtime_ms": "<1"})
    rows.append({"scheduler": "Full-Reconfig",
                 "norm_cost": f"{np.mean(ratios_fr):.3f}±{np.std(ratios_fr):.3f}",
                 "runtime_ms": round(t_fr / trials * 1e3, 1)})
    rows.append({"scheduler": f"ILP(HiGHS,{ilp_time_limit:.0f}s)",
                 "norm_cost": "1.00 (best found)",
                 "runtime_ms": round(t_ilp / trials * 1e3, 1)})
    rows.append({"scheduler": "LP/resource lower bound",
                 "norm_cost": f"best/LB={np.mean(gaps):.3f}",
                 "runtime_ms": ""})
    print_table("Table 4: provisioning-cost micro-benchmark", rows,
                ["scheduler", "norm_cost", "runtime_ms"])
    return rows


def table5(sizes=(1000, 2000, 4000, 8000), quick=False):
    """Full Reconfiguration runtime scaling.  Paper (Python): 0.4 / 1.5 /
    5.5 / 22.1 s.  Ours: vectorized numpy engine + jitted JAX engine."""
    if quick:
        sizes = (500, 1000)
    cat = aws_catalog()
    rows = []
    for n in sizes:
        rng = np.random.default_rng(n)
        tasks = _random_tasks(n, rng)
        t0 = time.time()
        c_np = full_reconfiguration(tasks, cat, table=None, engine="numpy",
                                    interference_aware=False,
                                    multi_task_aware=False)
        dt_np = time.time() - t0
        # jax engine: warm up once (compile), then time
        t0 = time.time()
        full_reconfiguration(tasks, cat, table=None, engine="jax",
                             interference_aware=False, multi_task_aware=False)
        dt_warm = time.time() - t0
        t0 = time.time()
        c_jx = full_reconfiguration(tasks, cat, table=None, engine="jax",
                                    interference_aware=False,
                                    multi_task_aware=False)
        dt_jx = time.time() - t0
        rows.append({"n_tasks": n,
                     "paper_python_s": {1000: 0.40, 2000: 1.50, 4000: 5.53,
                                        8000: 22.06}.get(n, "n/a"),
                     "numpy_s": round(dt_np, 3),
                     "jax_jit_s": round(dt_jx, 3),
                     "jax_warmup_s": round(dt_warm, 3),
                     "cost_numpy": round(c_np.total_hourly_cost(cat), 1),
                     "cost_jax": round(c_jx.total_hourly_cost(cat), 1)})
    print_table("Table 5: Full Reconfiguration runtime", rows,
                ["n_tasks", "paper_python_s", "numpy_s", "jax_jit_s",
                 "jax_warmup_s", "cost_numpy", "cost_jax"])
    return rows


#: numpy engine is O(T·K·fills) in Python-visible work; past this it takes
#: minutes per row, so larger rows report the jit/incremental columns only.
NUMPY_CAP = 10_000


def scaling_curve(sizes=(1000, 10_000, 100_000, 1_000_000), quick=False):
    """Fleet-scale planning curve: single-pass jitted engine vs numpy, plus
    incremental repack latency for a single-instance disturbance.

    Columns: ``numpy_s`` (capped at NUMPY_CAP tasks), ``jax_s`` (warm jitted
    full re-plan), ``jax_warmup_s`` (first call: compile + shape-bucket
    retraces), ``jax_compile_s`` (the jit-compile share of warmup, from the
    engine's ``jax_pack`` profiler spans; measured only when recording is
    on), ``incremental_s`` (one evacuated instance, dirty-set repack), and
    the two speedup ratios the CI gate pins.
    """
    if quick:
        sizes = (1000, 10_000, 100_000)
    cat = aws_catalog()
    kw = dict(interference_aware=False, multi_task_aware=True)
    # the profiler rides along only when recording is on (--obs): the
    # perf-smoke overhead gate compares this mode against the bare run
    prof = _prof.Profiler() if common.TRACE_DIR is not None else None
    _prof.activate(prof)
    try:
        rows = _scaling_rows(sizes, cat, kw, prof)
    finally:
        _prof.activate(None)
    print_table("Fleet-scale planning curve", rows,
                ["n_tasks", "numpy_s", "jax_s", "jax_warmup_s",
                 "jax_compile_s", "incremental_s", "jit_speedup",
                 "incr_speedup", "instances", "fallback"])
    return rows


def _scaling_rows(sizes, cat, kw, prof):
    rows = []
    for n in sizes:
        tasks = _fleet(n, np.random.default_rng(n))
        dt_np = None
        if n <= NUMPY_CAP:
            t0 = time.time()
            full_reconfiguration(tasks, cat, table=None, engine="numpy", **kw)
            dt_np = time.time() - t0
        # warm up (jit compile + shape-bucket retraces), then time.  The
        # engine's jax_pack spans land on the active profiler; the
        # stage=compile share of the warmup call becomes jax_compile_s.
        n_spans = len(prof.spans) if prof is not None else 0
        t0 = time.time()
        full_reconfiguration(tasks, cat, table=None, engine="jax", **kw)
        dt_warm = time.time() - t0
        dt_compile = (sum(s.duration_s for s in prof.spans[n_spans:]
                          if s.tags.get("stage") == "compile")
                      if prof is not None else None)
        t0 = time.time()
        cfg = full_reconfiguration(tasks, cat, table=None, engine="jax", **kw)
        dt_jx = time.time() - t0
        # single-instance disturbance: evacuate the first instance and repack
        # only its tasks (the dirty set) instead of re-planning the fleet.
        live = [LiveInstance(i, k, tuple(tids))
                for i, (k, tids) in enumerate(cfg.assignments)]
        evac = [live[0].instance_id]
        incremental_reconfiguration(tasks, live, set(), set(), cat, None,
                                    evacuate=evac, engine="jax", **kw)
        t0 = time.time()
        _, fb = incremental_reconfiguration(tasks, live, set(), set(), cat,
                                            None, evacuate=evac, engine="jax",
                                            **kw)
        dt_inc = time.time() - t0
        rows.append({"n_tasks": n,
                     "numpy_s": round(dt_np, 3) if dt_np is not None else "",
                     "jax_s": round(dt_jx, 4),
                     "jax_warmup_s": round(dt_warm, 3),
                     "jax_compile_s": (round(dt_compile, 3)
                                       if dt_compile is not None else ""),
                     "incremental_s": round(dt_inc, 4),
                     "jit_speedup": (round(dt_np / dt_jx, 1)
                                     if dt_np is not None else ""),
                     "incr_speedup": round(dt_jx / max(dt_inc, 1e-9), 1),
                     "instances": len(cfg.assignments),
                     "fallback": fb or ""})
    return rows


def run(quick=False):
    out = {"table4": table4(quick=quick), "table5": table5(quick=quick),
           "scaling": scaling_curve(quick=quick)}
    save_results("bench_micro", out)
    return out


if __name__ == "__main__":
    run()
