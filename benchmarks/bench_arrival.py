"""Figure 8: impact of job arrival rate (mean inter-arrival sweep)."""
from __future__ import annotations

from repro.cluster import SimConfig, alibaba_like_trace

from .common import print_table, run_sim, save_results


def run(quick=False, n_jobs=None):
    n = n_jobs or (150 if quick else 400)
    inter = (1200.0,) if quick else (600.0, 1200.0, 2400.0)
    rows = []
    for ia in inter:
        for sched in ("no-packing", "stratus", "synergy", "eva"):
            jobs = alibaba_like_trace(n_jobs=n, seed=17,
                                      mean_interarrival_s=ia)
            m = run_sim(sched, jobs, SimConfig(seed=8))
            rows.append({"interarrival_min": ia / 60, "scheduler": sched,
                         "total_cost": m["total_cost"]})
    for ia in inter:
        base = next(r["total_cost"] for r in rows
                    if r["interarrival_min"] == ia / 60
                    and r["scheduler"] == "no-packing")
        for r in rows:
            if r["interarrival_min"] == ia / 60:
                r["norm_cost_pct"] = round(100 * r["total_cost"] / base, 1)
    print_table("Figure 8: arrival-rate sweep", rows,
                ["interarrival_min", "scheduler", "norm_cost_pct"])
    save_results("bench_arrival", rows)
    return rows


if __name__ == "__main__":
    run()
