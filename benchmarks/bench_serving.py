"""Online serving scenario benchmark (beyond the paper; arXiv 2010.05049).

Runs the bundled diurnal million-user serving trace
(``cluster/traces.serving_trace``: two inference fleets with latency-utility
SLO curves plus Table-7 batch filler, surge windows on an OU spot market)
through three regimes:

* ``eva-slo`` — ``SLOLayer`` on the policy stack: standing CPU/RAM headroom
  for replicas, warm-keep exemption from the S·D̂ > ΔM evict test while
  utility is at risk, and risk-damped planning prices.  The second scenario
  axis written purely against the policy-layer API.
* ``eva-spot`` (headroom-blind) — the same market and trace with no
  serving awareness: replicas are packed and evicted like batch tasks, so
  spot churn and co-location interference eat the capacity margin exactly
  when the surge needs it.
* ``eva-spot`` on the batch-only subset — the cost anchor: what the same
  cluster spends with no inference fleet at all, pricing the serving
  premium.

The acceptance invariant (also enforced in CI): eva-slo holds p99-SLO
attainment at or above ``SLO_TARGET`` while the headroom-blind stack
misses it, at a cost premium over batch-only that the table documents.  A
headroom sweep shows the attainment-vs-cost dial.

    PYTHONPATH=src python -m benchmarks.run --quick --only serving
"""
from __future__ import annotations

from repro.cluster import SimConfig, serving_trace
from repro.core import PriceModel, aws_catalog
from repro.policies import SLOLayer, stack_from_flags

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "trace", "total_cost", "slo_attainment",
        "service_utility", "served_requests", "slo_signals",
        "migrations_per_task", "preemptions", "wall_s"]

SLO_TARGET = 0.95  # fleet-wide p99-SLO attainment floor for eva-slo


def _trace(quick, n_batch=None, seed=17):
    return serving_trace(n_batch=n_batch or (8 if quick else 32),
                         horizon_h=6.0 if quick else 24.0, seed=seed)


def _market():
    return PriceModel.mean_reverting(discount=0.35, seed=7)


def serving_vs_blind(quick=False, n_batch=None, hazard=0.25, seed=5):
    cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
    jobs = _trace(quick, n_batch)
    batch_only = [j for j in jobs if not j.is_service]
    rows = []
    for name, trace, label in (
            ("eva-slo", jobs, "serving+batch"),
            ("eva-spot", jobs, "serving+batch (blind)"),
            ("eva-spot", batch_only, "batch-only")):
        out = run_sim(name, trace, cfg, catalog=aws_catalog(
            price_model=_market()))
        out["scheduler"] = name
        out["trace"] = label
        rows.append(out)
    print_table("Serving: SLO-aware headroom vs headroom-blind vs "
                "batch-only anchor", rows, COLS)
    slo, blind, anchor = rows
    premium_anchor = slo["total_cost"] / anchor["total_cost"] - 1.0
    premium_blind = slo["total_cost"] / blind["total_cost"] - 1.0
    print(f"eva-slo attainment {slo['slo_attainment']:.4f} vs blind "
          f"{blind['slo_attainment']:.4f} (target {SLO_TARGET}); serving "
          f"premium {premium_anchor:+.1%} over batch-only, "
          f"{premium_blind:+.1%} over the blind stack")
    assert slo["slo_attainment"] >= SLO_TARGET, \
        "SLO-aware stack must keep fleet p99 attainment at the target"
    assert blind["slo_attainment"] < SLO_TARGET, \
        "the headroom-blind stack should miss the target (else the " \
        "scenario exerts no pressure and the comparison is vacuous)"
    assert slo["slo_attainment"] > blind["slo_attainment"], \
        "serving awareness must strictly improve attainment"
    return rows


def headroom_sweep(quick=False, hazard=0.25, seed=5):
    """The provisioning dial: headroom = planning-demand inflation for
    replicas.  1.0 disables the standing margin (warm-keep and risk
    damping still act); larger values buy attainment with co-location
    room."""
    heads = (1.0, 1.3, 1.6) if quick else (1.0, 1.15, 1.3, 1.45, 1.6)
    jobs_fn = lambda: _trace(quick)  # noqa: E731
    rows = []
    for h in heads:
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
        stack = stack_from_flags(spot_aware=True,
                                 extra=[SLOLayer(headroom=h)])
        out = run_sim("eva", jobs_fn(), cfg,
                      catalog=aws_catalog(price_model=_market()),
                      policies=stack)
        out["scheduler"] = "eva-slo"
        out["trace"] = f"headroom={h:g}"
        rows.append(out)
    print_table("Serving: headroom sweep (attainment vs cost dial)",
                rows, COLS)
    return rows


def run(quick=False, full=False):
    n = 64 if full else None
    out = {"serving_vs_blind": serving_vs_blind(quick=quick, n_batch=n),
           "headroom_sweep": headroom_sweep(quick=quick)}
    save_results("bench_serving", out)
    return out


if __name__ == "__main__":
    run()
