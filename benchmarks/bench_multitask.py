"""Table 6 + Figure 7: multi-task jobs — Eva-Multi vs Eva-Single vs
No-Packing, and the multi-task-share sweep over the Alibaba-like trace."""
from __future__ import annotations

import numpy as np

from repro.cluster import SimConfig, alibaba_like_trace
from repro.core import aws_catalog, make_job
from repro.core.workloads import NUM_WORKLOADS

from .common import print_table, run_sim, save_results


def _multitask_trace(n_jobs, seed, n_tasks=4, dur_range=(0.5, 16.0),
                     mean_interarrival_s=1200.0):
    """Table 6 setup: jobs of 4 identical tasks sampled from Table 7,
    durations U[0.5, 16] h."""
    rng = np.random.default_rng(seed)
    t, jobs = 0.0, []
    for i in range(n_jobs):
        t += rng.exponential(mean_interarrival_s)
        w = int(rng.integers(NUM_WORKLOADS))
        dur = rng.uniform(*dur_range) * 3600.0
        jobs.append(make_job(job_id=90000 + seed * 1000 + i, workload=w,
                             arrival_time=t, duration_s=dur, n_tasks=n_tasks))
    return jobs


def table6(trials=4, n_jobs=60, quick=False):
    if quick:
        trials, n_jobs = 2, 30
    rows = []
    for sched in ("no-packing", "eva-single", "eva"):
        costs, jcts = [], []
        for t in range(trials):
            jobs = _multitask_trace(n_jobs, seed=t)
            m = run_sim(sched, jobs, SimConfig(seed=t))
            costs.append(m["total_cost"])
            jcts.append(m["avg_jct_hours"])
        rows.append({"scheduler": sched,
                     "total_cost": round(float(np.mean(costs)), 1),
                     "jct_hours": f"{np.mean(jcts):.2f}±{np.std(jcts):.2f}"})
    base = rows[0]["total_cost"]
    for r in rows:
        r["norm_cost_pct"] = round(100 * r["total_cost"] / base, 1)
    print_table("Table 6: multi-task jobs (4 tasks/job)", rows,
                ["scheduler", "total_cost", "norm_cost_pct", "jct_hours"])
    return rows


def figure7(fractions=(0.0, 0.2, 0.4), n_jobs=400, quick=False):
    if quick:
        fractions, n_jobs = (0.0, 0.3), 150
    rows = []
    for f in fractions:
        for sched in ("no-packing", "stratus", "eva-single", "eva"):
            jobs = alibaba_like_trace(n_jobs=n_jobs, seed=3,
                                      multi_task_fraction=f)
            m = run_sim(sched, jobs, SimConfig(seed=3))
            rows.append({"multi_task_frac": f, "scheduler": sched,
                         "total_cost": m["total_cost"],
                         "jct_hours": m["avg_jct_hours"]})
    for f in set(r["multi_task_frac"] for r in rows):
        base = next(r["total_cost"] for r in rows
                    if r["multi_task_frac"] == f and r["scheduler"] == "no-packing")
        for r in rows:
            if r["multi_task_frac"] == f:
                r["norm_cost_pct"] = round(100 * r["total_cost"] / base, 1)
    print_table("Figure 7: multi-task share sweep", rows,
                ["multi_task_frac", "scheduler", "norm_cost_pct", "jct_hours"])
    return rows


def run(quick=False):
    out = {"table6": table6(quick=quick), "figure7": figure7(quick=quick)}
    save_results("bench_multitask", out)
    return out


if __name__ == "__main__":
    run()
