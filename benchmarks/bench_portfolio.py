"""Commitment portfolio + multi-provider arbitrage benchmark (beyond the
paper; arXiv 1110.5972's reserved/on-demand/spot portfolio question).

Runs the bundled steady-base + bursty-overflow trace
(``cluster/traces.portfolio_trace``) on a two-provider catalog
(``core.catalog.multi_provider_catalog``: an aws market with an OU spot
market and a 1yr commitment pool on c7i.2xlarge, next to a gcp market with
its own OU process) through three regimes:

* ``eva-portfolio`` — ``PortfolioLayer`` on the policy stack, pools sized
  to the steady base: committed slots fill first at marginal price ≈ 0,
  bursts overflow onto whichever provider's spot market is cheap, and the
  keep test never churns committed residents.
* pure-spot — the same providers with no commitments: the steady base pays
  spot prices (and eats spot churn) all day.
* pure-commit — pools sized at the burst *peak*: the burst capacity idles
  at the discounted rate between waves.

The acceptance invariant (also enforced in CI): eva-portfolio is strictly
cheaper than both pure regimes.  A pool-size sweep shows the dial — the
undersized pool also demonstrates the inventory pass growing the
commitment to the observed steady base mid-run (``commitment_resizes``).

    PYTHONPATH=src python -m benchmarks.run --quick --only portfolio
"""
from __future__ import annotations

import math

from repro.cluster import SimConfig, portfolio_trace
from repro.core import CommitmentModel, PriceModel, Provider, \
    multi_provider_catalog

from .common import print_table, run_sim, save_results

COLS = ["scheduler", "trace", "total_cost", "commitment_cost",
        "commitment_idle_cost", "commitment_resizes", "cost_provider_aws",
        "cost_provider_gcp", "egress_cost", "preemptions", "wall_s"]

COMMIT_TYPE = "c7i.2xlarge"  # the steady-base hardware the portfolio commits
RATE_FRACTION = 0.4          # 1yr committed rate as a fraction of on-demand


def _catalog(pool_size: int, seed: int = 7):
    """Two providers, each with its own OU spot process; a commitment pool
    on the aws side when ``pool_size`` > 0."""
    commitments = (CommitmentModel(instance_type=COMMIT_TYPE,
                                   pool_size=pool_size,
                                   rate_fraction=RATE_FRACTION),) \
        if pool_size > 0 else ()
    providers = [
        Provider(name="aws",
                 price_model=PriceModel.mean_reverting(discount=0.6,
                                                       seed=seed),
                 commitments=commitments),
        Provider(name="gcp", cost_scale=1.04,
                 price_model=PriceModel.mean_reverting(discount=0.62,
                                                       seed=seed + 1)),
    ]
    return multi_provider_catalog(providers)


def _trace(quick, seed=23):
    return portfolio_trace(n_steady=4 if quick else 6,
                           n_burst=6 if quick else 10, seed=seed)


def _sizes(quick):
    n_steady = 4 if quick else 6
    n_burst = 6 if quick else 10
    peak = n_steady + math.ceil(n_burst / 2)
    return n_steady, peak


def portfolio_vs_pure(quick=False, hazard=0.25, seed=5):
    cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
    right, peak = _sizes(quick)
    rows = []
    for name, pool, label in (
            ("eva-portfolio", right, "commit=steady-base"),
            ("eva-multiregion", 0, "pure-spot"),
            ("eva-portfolio", peak, "pure-commit (peak-sized)")):
        out = run_sim(name, _trace(quick), cfg, catalog=_catalog(pool))
        out["scheduler"] = name
        out["trace"] = label
        rows.append(out)
    print_table("Portfolio: committed base + spot overflow vs the pure "
                "regimes", rows, COLS)
    port, spot, commit = rows
    save_spot = 1.0 - port["total_cost"] / spot["total_cost"]
    save_commit = 1.0 - port["total_cost"] / commit["total_cost"]
    print(f"eva-portfolio ${port['total_cost']:.2f}: "
          f"{save_spot:+.1%} vs pure-spot, {save_commit:+.1%} vs "
          f"pure-commit (idle waste ${commit['commitment_idle_cost']:.2f})")
    assert port["total_cost"] < spot["total_cost"], \
        "the portfolio must beat pure-spot (the steady base should ride " \
        "the committed rate, not the market)"
    assert port["total_cost"] < commit["total_cost"], \
        "the portfolio must beat pure-commit (burst capacity should " \
        "overflow to spot, not idle in an oversized pool)"
    return rows


def pool_size_sweep(quick=False, hazard=0.25, seed=5):
    """The commitment dial: undersized pools leak the base onto the spot
    market (and the inventory pass grows them mid-run), oversized pools
    idle at the discounted rate."""
    right, peak = _sizes(quick)
    rows = []
    for pool in (2, right, peak):
        cfg = SimConfig(seed=seed, preemption_hazard_per_hour=hazard)
        out = run_sim("eva-portfolio", _trace(quick), cfg,
                      catalog=_catalog(pool))
        out["scheduler"] = "eva-portfolio"
        out["trace"] = f"pool={pool}"
        rows.append(out)
    print_table("Portfolio: pool-size sweep (inventory pass grows the "
                "undersized pool)", rows, COLS)
    return rows


def run(quick=False, full=False):
    out = {"portfolio_vs_pure": portfolio_vs_pure(quick=quick),
           "pool_size_sweep": pool_size_sweep(quick=quick)}
    save_results("bench_portfolio", out)
    return out


if __name__ == "__main__":
    run()
