#!/usr/bin/env python3
"""CI perf-regression gate: compare fresh benchmark results against the
committed baselines in ``benchmarks/baselines/``.

    python tools/bench_compare.py [--results PATH] [--baseline PATH] [--json PATH]
    python tools/bench_compare.py --update-baseline

The default paths gate the planner microbench; the simulator-throughput
bench is gated by a second invocation of the same tool:

    python tools/bench_compare.py --results results/bench_sim.json \\
        --baseline benchmarks/baselines/bench_sim.json

Timing cells are matched row-by-row on ``n_tasks`` (table5 and the scaling
curve).  A cell passes when

    fresh <= max(RATIO * base, base + FLOOR_S)

RATIO defaults to 1.5: CI runners are shared and noisy, so anything under
1.5x is indistinguishable from scheduling jitter, while a real regression
(losing the jit path, reintroducing a Python loop) costs 10-100x and trips
the gate immediately.  FLOOR_S (0.2 s) keeps millisecond-scale cells — the
incremental-repack column in particular — from failing on absolute noise
that is irrelevant at that magnitude.

Speedup ratios (jit_speedup / incr_speedup) are gated against *absolute*
floors, not the baseline: a ratio divides two noisy timings, so a
baseline-relative bound would trip on jitter the per-cell floors forgive.
The floors are the repo's acceptance criteria — jit >= 5x numpy at 10^4
tasks, incremental >= 10x a full re-plan at 10^5 — so the curve's shape
stays pinned even if a baseline update shifts the absolute numbers.

A row or timing cell present in the baseline but missing from the fresh
results fails the gate (a silently dropped benchmark is a regression).
Extra fresh rows (e.g. a locally run --full curve) are ignored.

Output is greppable ``[bench_compare] cell ... status=ok|fail`` lines;
``--json`` additionally writes every per-cell verdict as JSON (the CI
artifact).  ``--update-baseline`` copies the fresh results over the
baseline; commit the result when a deliberate perf change shifts the
curve.

The recording-overhead gate reuses this tool with ``--baseline`` pointed
at a recording-off run and ``--ratio 1.10``: the flight recorder must
stay within 10% of the bare benchmark.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import Reporter  # noqa: E402

RESULTS = ROOT / "results" / "bench_micro.json"
BASELINE = ROOT / "benchmarks" / "baselines" / "bench_micro.json"

#: sections gated, and which of their columns are timings (lower is better)
#: vs speedups (higher is better).  table4 is cost-accuracy, not perf: its
#: assertions live in the test suite, so it is not gated here.
#: Sections absent from both files are skipped, so one table serves every
#: results file this tool is pointed at (bench_micro and bench_sim).
TIMING_COLS = {
    "table5": ["numpy_s", "jax_jit_s"],
    "scaling": ["numpy_s", "jax_s", "incremental_s"],
    "sim_scenarios": ["scalar_s", "vectorized_s"],
    "sim_population": ["scalar_s", "vectorized_s"],
}
#: absolute floors for speedup ratios (section -> n_tasks -> col -> min).
#: These restate the repo's acceptance criteria for the jitted engine, the
#: incremental repack path, and the vectorized simulator core; see module
#: docstring for why they are not baseline-relative.
SPEEDUP_FLOORS = {
    "scaling": {
        10_000: {"jit_speedup": 5.0},
        100_000: {"incr_speedup": 10.0},
    },
    # the serving- and portfolio-class scenario cells (keyed by their task
    # populations) carry the >=10x end-to-end acceptance; the population
    # sweep pins vectorized >= 5x scalar at the 10^5 cell
    "sim_scenarios": {
        20_096: {"speedup": 10.0},
        14_400: {"speedup": 10.0},
    },
    "sim_population": {
        100_000: {"speedup": 5.0},
    },
}


def _rows_by_n(section):
    return {r["n_tasks"]: r for r in section}


def _num(cell):
    """Benchmark cells use '' (or 'n/a') for 'not measured at this size';
    any non-numeric cell is skipped rather than crashing the gate."""
    if cell in ("", None):
        return None
    try:
        return float(cell)
    except (TypeError, ValueError):
        return None


def compare(base: dict, fresh: dict, ratio: float, floor_s: float,
            rep: Reporter):
    failures, checked = [], 0
    for sec, cols in TIMING_COLS.items():
        base_rows = _rows_by_n(base.get(sec, []))
        fresh_rows = _rows_by_n(fresh.get(sec, []))
        for n, brow in sorted(base_rows.items()):
            frow = fresh_rows.get(n)
            if frow is None:
                rep.emit("missing_row", section=sec, n_tasks=n)
                failures.append(f"{sec}[n_tasks={n}]: row missing from fresh results")
                continue
            for col in cols:
                b = _num(brow.get(col))
                if b is None:
                    continue  # baseline didn't measure this cell (e.g. numpy cap)
                f = _num(frow.get(col))
                if f is None:
                    rep.emit("missing_cell", section=sec, n_tasks=n, col=col)
                    failures.append(f"{sec}[{n}].{col}: cell missing from fresh results")
                    continue
                checked += 1
                limit = max(ratio * b, b + floor_s)
                ok = f <= limit
                rep.emit("cell", section=sec, n_tasks=n, col=col,
                         base_s=round(b, 4), fresh_s=round(f, 4),
                         limit_s=round(limit, 4),
                         status="ok" if ok else "fail")
                if not ok:
                    failures.append(f"{sec}[{n}].{col}: {f:.4f}s > limit {limit:.4f}s "
                                    f"(base {b:.4f}s)")
            for col, limit in SPEEDUP_FLOORS.get(sec, {}).get(n, {}).items():
                f = _num(frow.get(col))
                if f is None:
                    rep.emit("missing_cell", section=sec, n_tasks=n, col=col)
                    failures.append(f"{sec}[{n}].{col}: cell missing from fresh results")
                    continue
                checked += 1
                ok = f >= limit
                rep.emit("speedup", section=sec, n_tasks=n, col=col,
                         fresh_x=round(f, 1), floor_x=round(limit, 1),
                         status="ok" if ok else "fail")
                if not ok:
                    failures.append(f"{sec}[{n}].{col}: speedup {f:.1f}x < floor "
                                    f"{limit:.1f}x")
    return failures, checked


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--results", type=Path, default=RESULTS,
                    help="fresh results JSON (default: results/bench_micro.json)")
    ap.add_argument("--baseline", type=Path, default=BASELINE,
                    help="committed baseline JSON")
    ap.add_argument("--ratio", type=float, default=1.5,
                    help="relative tolerance per cell (default 1.5x)")
    ap.add_argument("--floor", type=float, default=0.2,
                    help="absolute slack in seconds for sub-second cells")
    ap.add_argument("--json", type=Path, default=None,
                    help="write per-cell verdicts as JSON")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the fresh results")
    args = ap.parse_args(argv)

    rep = Reporter("bench_compare")
    if not args.results.exists():
        rep.emit("error", reason="no_fresh_results", path=str(args.results),
                 hint="python -m benchmarks.run --quick --only micro")
        return 1
    if args.update_baseline:
        args.baseline.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(args.results, args.baseline)
        rep.emit("baseline_updated", source=str(args.results),
                 baseline=str(args.baseline))
        return 0
    if not args.baseline.exists():
        rep.emit("error", reason="no_baseline", path=str(args.baseline),
                 hint="seed one with --update-baseline")
        return 1

    base = json.loads(args.baseline.read_text())
    fresh = json.loads(args.results.read_text())
    rep.emit("start", results=str(args.results), baseline=str(args.baseline),
             ratio=args.ratio, floor_s=args.floor)
    failures, checked = compare(base, fresh, args.ratio, args.floor, rep)
    rep.emit("verdict", status="fail" if failures else "pass",
             checked=checked, failed=len(failures))
    for f in failures:
        rep.emit("failure", detail=f)
    if args.json:
        rep.write_json(str(args.json), verdict="fail" if failures else "pass",
                       checked=checked)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
