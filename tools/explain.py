#!/usr/bin/env python3
"""Offline flight-recorder replay: answer "why" questions about a run.

    python tools/explain.py TRACE.jsonl summary
    python tools/explain.py TRACE.jsonl timeline [--kind K] [--instance I]
                                                 [--job J] [--limit N]
    python tools/explain.py TRACE.jsonl why-terminated --instance I
    python tools/explain.py TRACE.jsonl cost [--by category|key]
    python tools/explain.py TRACE.jsonl rounds [--round N]
    python tools/explain.py TRACE.jsonl attainment
    python tools/explain.py TRACE.jsonl prom

TRACE.jsonl is a ``FlightRecorder`` artifact (``benchmarks/run.py --obs``
saves one per simulated run under ``results/traces/``).  The flagship
query is ``why-terminated``: it joins the instance's ``terminate`` event
with the decision round that sealed its fate — the keep-test margin, the
keep-bonus slack decomposed by policy layer, and any pressure signals
(spot notices, credit drains) that forced the round.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import FlightRecorder  # noqa: E402
from repro.obs import events as EV  # noqa: E402


def _fields(e) -> str:
    parts = [f"t={e.t:g}", f"kind={e.kind}"]
    if e.instance_id is not None:
        parts.append(f"instance={e.instance_id}")
    if e.job_id is not None:
        parts.append(f"job={e.job_id}")
    for k, v in e.fields:
        parts.append(f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}")
    return " ".join(parts)


def cmd_summary(rec: FlightRecorder, args) -> int:
    for k, v in rec.meta.items():
        print(f"meta {k}={v}")
    counts = rec.events.counts()
    for kind in sorted(counts):
        print(f"events {kind}={counts[kind]}")
    print(f"cost total=${rec.events.total_cost():.2f} "
          f"entries={rec.events.cost_entries}")
    for cat, amt in sorted(rec.events.cost_by("category").items()):
        print(f"cost {cat}=${amt:.2f}")
    print(f"decisions rounds={len(rec.decisions)}")
    for name, total in sorted(rec.profiler.totals().items()):
        print(f"span {name} total_s={total:.4f} "
              f"n={len(rec.profiler.by_name(name))}")
    return 0


def cmd_timeline(rec: FlightRecorder, args) -> int:
    events = list(rec.events)
    if args.kind:
        events = [e for e in events if e.kind == args.kind]
    if args.instance is not None:
        events = [e for e in rec.events.for_instance(args.instance)
                  if e in events]
    if args.job is not None:
        events = [e for e in events if e.job_id == args.job]
    shown = events if args.limit is None else events[:args.limit]
    for e in shown:
        print(_fields(e))
    if len(shown) < len(events):
        print(f"... {len(events) - len(shown)} more "
              f"(raise --limit)")
    return 0


def cmd_why_terminated(rec: FlightRecorder, args) -> int:
    iid = args.instance
    terms = [e for e in rec.events.of_kind(EV.TERMINATE)
             if e.instance_id == iid]
    if not terms:
        alive = [e for e in rec.events.for_instance(iid)]
        if alive:
            print(f"instance {iid}: never terminated "
                  f"({len(alive)} events; alive at end of run)")
        else:
            print(f"instance {iid}: not found in this trace")
        return 1
    term = terms[-1]
    print(f"instance {iid} terminated at t={term.t:g} "
          f"reason={term.get('reason')} lifetime_s={term.get('lifetime_s'):g} "
          f"billed=${term.get('billed', 0.0):.4f}")
    # the decision round that sealed its fate
    round_rec, keep = rec.decisions.last_keep_entry(iid, term.t)
    if keep is not None:
        verdict = "kept" if keep.kept else "evicted"
        print(f"keep-test round={round_rec.round_index} t={round_rec.t:g} "
              f"kind={round_rec.kind}: {verdict} "
              f"saving=${keep.saving:.4f}/h cost=${keep.cost:.4f}/h "
              f"bonus=${keep.bonus:.4f}/h margin=${keep.margin:+.4f}/h")
        for layer, amt in sorted(keep.bonus_by_layer.items()):
            print(f"  bonus layer={layer} ${amt:.4f}/h")
        if not keep.kept:
            print(f"  -> evicted: the task set's reservation-price saving "
                  f"did not cover the instance's cost "
                  f"(short by ${-keep.margin:.4f}/h)")
    else:
        d = rec.decisions.at_or_before(term.t)
        if d is not None and iid in d.evacuated:
            print(f"forced-partial round={d.round_index} t={d.t:g} "
                  f"evacuated this instance (dirty={list(d.dirty)} "
                  f"fallback={d.incremental_fallback or 'none'})")
        elif d is None:
            print("no decision round at or before termination "
                  "(housekeeping release)")
        else:
            print(f"not in any keep table: released outside the keep test "
                  f"(reason={term.get('reason')})")
    # pressure context: notices / signals naming this instance
    for e in rec.events.for_instance(iid):
        if e.kind in (EV.NOTICE, EV.PRESSURE, EV.PREEMPT, EV.FAILURE,
                      EV.CREDIT_THROTTLE) and e.t <= term.t:
            print(f"context {_fields(e)}")
    return 0


def cmd_cost(rec: FlightRecorder, args) -> int:
    by = rec.events.cost_by(args.by)
    total = rec.events.total_cost()
    for k, v in sorted(by.items(), key=lambda kv: -kv[1]):
        share = (v / total * 100.0) if total else 0.0
        print(f"{args.by}={k} ${v:.2f} share={share:.1f}%")
    print(f"total ${total:.2f}")
    return 0


def cmd_rounds(rec: FlightRecorder, args) -> int:
    for d in rec.decisions:
        if args.round is not None and d.round_index != args.round:
            continue
        kept = sum(1 for e in d.keep_table if e.kept)
        line = (f"round={d.round_index} t={d.t:g} kind={d.kind} "
                f"d_hat_s={d.d_hat_s:g} tasks={d.n_tasks} "
                f"pending={d.n_pending} keep={kept}/{len(d.keep_table)}")
        if d.adopt_full is not None:
            line += (f" adopt_full={d.adopt_full} s_full={d.s_full:.3f}"
                     f" s_partial={d.s_partial:.3f}")
        if d.kind == "forced-partial":
            line += (f" evacuated={list(d.evacuated)}"
                     f" dirty={len(d.dirty)}")
            if d.incremental_fallback:
                line += f" fallback={d.incremental_fallback}"
        print(line)
        if args.round is not None:
            print(f"  rp min={d.rp_min:.4f} mean={d.rp_mean:.4f} "
                  f"max={d.rp_max:.4f} mask_layers={list(d.mask_layers)} "
                  f"caps_layer={d.caps_layer}")
            for e in d.keep_table:
                print(f"  keep instance={e.instance_id} type={e.type_index} "
                      f"saving={e.saving:.4f} cost={e.cost:.4f} "
                      f"bonus={e.bonus:.4f} margin={e.margin:+.4f} "
                      f"kept={e.kept} by_layer={e.bonus_by_layer}")
            for k, v in sorted(d.refine_deltas.items()):
                print(f"  refine {k}{v:+g}")
    return 0


def cmd_attainment(rec: FlightRecorder, args) -> int:
    """SLO-risk windows per serving job, from the slo_risk edge events."""
    risk = rec.events.of_kind(EV.SLO_RISK)
    if not risk:
        print("no slo_risk events in this trace (no serving jobs, or "
              "attainment never dipped)")
        return 0
    open_t: dict = {}
    windows = []
    for e in risk:
        if e.get("edge") == "on":
            open_t[e.job_id] = e
        else:
            start = open_t.pop(e.job_id, None)
            if start is not None:
                windows.append((e.job_id, start.t, e.t,
                                start.get("load_rps"),
                                start.get("capacity_rps")))
    for jid, t0, t1, load, cap in windows:
        print(f"dip job={jid} t={t0:g}..{t1:g} duration_s={t1 - t0:g} "
              f"load_rps={load:g} capacity_rps={cap:g}")
    for jid, e in open_t.items():
        print(f"dip job={jid} t={e.t:g}..end (unresolved at end of run)")
    series = rec.metrics.gauges.get("slo_risk_jobs")
    if series is not None and series.samples:
        vals = series.values()
        print(f"slo_risk_jobs max={max(vals):g} "
              f"rounds_at_risk={sum(1 for v in vals if v > 0)}"
              f"/{len(vals)}")
    return 0


def cmd_prom(rec: FlightRecorder, args) -> int:
    sys.stdout.write(rec.metrics.prom_text())
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="FlightRecorder JSONL artifact")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("summary", help="meta, event counts, cost, span totals")
    tl = sub.add_parser("timeline", help="filtered event stream")
    tl.add_argument("--kind", default=None)
    tl.add_argument("--instance", type=int, default=None)
    tl.add_argument("--job", type=int, default=None)
    tl.add_argument("--limit", type=int, default=50)
    wt = sub.add_parser("why-terminated",
                        help="join terminate event with its keep-test round")
    wt.add_argument("--instance", type=int, required=True)
    co = sub.add_parser("cost", help="cost ledger by category or key")
    co.add_argument("--by", choices=("category", "key"), default="category")
    ro = sub.add_parser("rounds", help="decision trace; --round N for detail")
    ro.add_argument("--round", type=int, default=None)
    sub.add_parser("attainment", help="SLO-risk windows (serving jobs)")
    sub.add_parser("prom", help="Prometheus text exposition of the metrics")
    args = ap.parse_args(argv)

    rec = FlightRecorder.load(args.trace)
    return {"summary": cmd_summary, "timeline": cmd_timeline,
            "why-terminated": cmd_why_terminated, "cost": cmd_cost,
            "rounds": cmd_rounds, "attainment": cmd_attainment,
            "prom": cmd_prom}[args.cmd](rec, args)


if __name__ == "__main__":
    sys.exit(main())
