#!/usr/bin/env python
"""Docs sanity checker (CI docs job; stdlib only).

* every intra-repo markdown link in README.md and docs/*.md resolves to an
  existing file;
* every fenced ``bash`` command in those files that references a path under
  ``benchmarks/``, ``examples/`` or ``tools/`` points at a file that exists
  (module spellings like ``-m benchmarks.run`` are resolved to their .py
  files too).

Exit code 0 = clean; 1 = problems (listed on stdout).
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```bash\n(.*?)```", re.DOTALL)
PATH_RE = re.compile(r"\b((?:benchmarks|examples|tools)/[\w./-]+)")
MODULE_RE = re.compile(r"-m\s+((?:benchmarks|tools)(?:\.\w+)+)")


def md_files():
    out = [os.path.join(ROOT, "README.md")]
    out += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return out


def check_links(path: str, text: str, problems: list) -> None:
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            problems.append(f"{os.path.relpath(path, ROOT)}: broken link "
                            f"-> {target}")


def check_bash_blocks(path: str, text: str, problems: list) -> None:
    for block in FENCE_RE.findall(text):
        for ref in PATH_RE.findall(block):
            ref = ref.rstrip(".")  # trailing sentence punctuation
            if not os.path.exists(os.path.join(ROOT, ref)):
                problems.append(f"{os.path.relpath(path, ROOT)}: bash block "
                                f"references missing file -> {ref}")
        for mod in MODULE_RE.findall(block):
            rel = mod.replace(".", os.sep) + ".py"
            if not os.path.exists(os.path.join(ROOT, rel)):
                problems.append(f"{os.path.relpath(path, ROOT)}: bash block "
                                f"references missing module -> {mod}")


def main() -> int:
    problems: list = []
    files = md_files()
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        check_links(path, text, problems)
        check_bash_blocks(path, text, problems)
    if problems:
        print(f"check_docs: {len(problems)} problem(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"check_docs: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
