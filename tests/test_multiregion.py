"""Multi-region layer: region-qualified catalogs, cross-region migration
costs, region-scoped simulation, and the multi-region Eva scheduler.

Contract tests anchoring the design:
* a single-region multi-region catalog is *bit-identical* to the plain
  spot catalog of PR 1 (scheduler decisions and simulator metrics);
* the cross-region migration penalty (egress fee) is charged exactly once
  per cross-region move, never for intra-region moves;
* eva-multiregion is cheaper than single-region eva-spot on the bundled
  dispersed-price 3-region market (the benchmark/CI invariant).
"""
import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator, physical_trace
from repro.core import (ClusterConfig, EvaScheduler, LiveInstance, PriceModel,
                        Region, SchedulerBase, TaskSet, TransferMatrix,
                        aws_catalog, checkpoint_size_gb, diff_configs,
                        dispersed_demo_regions, full_reconfiguration,
                        make_job, migration_cost, multi_region_catalog,
                        regional_reservation_prices, reservation_prices)

N_BASE = len(aws_catalog())


# ----------------------------------------------------------------- catalog
def test_region_expansion_layout():
    regs = dispersed_demo_regions(3)
    cat = multi_region_catalog(regs)
    assert len(cat) == 3 * N_BASE
    assert cat.is_multi_region
    assert cat.types[0].name == "region-0/p3.2xlarge"
    assert cat.types[N_BASE].name == "region-1/p3.2xlarge"
    np.testing.assert_array_equal(cat.region_ids,
                                  np.repeat(np.arange(3), N_BASE))
    np.testing.assert_array_equal(cat.base_index, np.tile(np.arange(N_BASE), 3))
    # capacities replicate the base catalog; base costs too (cost_scale=1)
    base = aws_catalog()
    for r in range(3):
        blk = slice(r * N_BASE, (r + 1) * N_BASE)
        np.testing.assert_array_equal(cat.capacities[blk], base.capacities)
        np.testing.assert_array_equal(cat.costs[blk], base.costs)


def test_snapshot_prices_each_region_with_its_own_model():
    regs = dispersed_demo_regions(3, low=0.25, high=0.85, period_s=3 * 3600.0)
    cat = multi_region_catalog(regs)
    base = aws_catalog().costs
    for t, cheap in ((0.0, 0), (3600.0, 1), (7200.0, 2)):
        snap = cat.at(t)
        for r in range(3):
            blk = slice(r * N_BASE, (r + 1) * N_BASE)
            mult = 0.25 if r == cheap else 0.85
            np.testing.assert_allclose(snap.costs[blk], base * mult)


def test_cost_scale_gives_static_dispersion():
    regs = (Region("cheap", cost_scale=0.5), Region("dear", cost_scale=1.0))
    cat = multi_region_catalog(regs)
    assert cat.price_model is None  # static: no models anywhere
    assert cat.at(999.0) is cat  # identity snapshot, PR-1 contract
    np.testing.assert_allclose(cat.costs[:N_BASE] * 2.0, cat.costs[N_BASE:])


def test_regional_rp_min_equals_global_rp():
    regs = dispersed_demo_regions(3)
    cat = multi_region_catalog(regs)
    tasks = TaskSet([j.tasks[0] for j in physical_trace(n_jobs=8, seed=3)])
    for t in (0.0, 3600.0, 7200.0):
        rr = regional_reservation_prices(tasks, cat, time_s=t)
        assert rr.shape == (len(tasks), 3)
        np.testing.assert_allclose(rr.min(axis=1),
                                   reservation_prices(tasks, cat, time_s=t))


def test_type_mask_restricts_packing_to_region():
    regs = dispersed_demo_regions(3)
    cat = multi_region_catalog(regs).at(3600.0)  # region-1 cheap
    tasks = TaskSet([j.tasks[0] for j in physical_trace(n_jobs=6, seed=3)])
    for r in range(3):
        cfg = full_reconfiguration(tasks, cat, None,
                                   type_mask=cat.region_type_mask(r))
        assert cfg.num_tasks() == len(tasks)
        assert all(cat.region_of(k) == r for k, _ in cfg.assignments)


def test_region_caps_overflow_to_next_region():
    """Algorithm 1 with per-region instance budgets fills a capped cheap
    region to its cap and overflows into the dearer one instead of
    over-provisioning (or starving) the cheap region."""
    regs = (Region("cheap", cost_scale=0.5), Region("dear", cost_scale=1.0))
    cat = multi_region_catalog(regs)
    jobs = [make_job(job_id=i + 1, workload=4, arrival_time=0.0,
                     duration_s=1000.0, n_tasks=1) for i in range(4)]  # gpt2
    tasks = TaskSet([j.tasks[0] for j in jobs])
    unbounded = full_reconfiguration(tasks, cat, None)
    assert all(cat.region_of(k) == 0 for k, _ in unbounded.assignments)
    capped = full_reconfiguration(tasks, cat, None, region_caps=(1, None))
    assert capped.num_tasks() == len(tasks)  # nobody starves
    by_region = [sum(1 for k, _ in capped.assignments
                     if cat.region_of(k) == r) for r in range(2)]
    assert by_region[0] == 1  # cheap region filled exactly to its cap
    assert by_region[1] >= 1  # overflow provisioned in the dear region


# ------------------------------------------------------- migration costing
def _two_region_cat(egress=0.1, bw=8.0):
    regs = (Region("a"), Region("b"))
    return multi_region_catalog(
        regs, transfer=TransferMatrix.uniform(2, egress_usd_per_gb=egress,
                                              bandwidth_gbps=bw))


def test_migration_cost_charges_cross_region_penalty():
    cat = _two_region_cat()
    base = aws_catalog()
    k_a = cat.index_of("a/p3.2xlarge")
    k_b = cat.index_of("b/p3.2xlarge")
    job = make_job(job_id=1, workload=3, arrival_time=0.0, duration_s=1000.0,
                   n_tasks=1)  # cyclegan: 7 GB checkpoint
    tid = job.tasks[0].task_id
    live = [LiveInstance(0, k_a, (tid,))]
    wl = {tid: 3}
    intra = migration_cost(diff_configs(live, ClusterConfig([(k_a, (tid,))])),
                           live, cat, wl)
    assert intra == 0.0  # stays put
    m_b = migration_cost(diff_configs(live, ClusterConfig([(k_b, (tid,))])),
                         live, cat, wl)
    # single-region move of the same shape (same base type, same price)
    plain_live = [LiveInstance(0, base.index_of("p3.2xlarge"), (tid,))]
    k2 = base.index_of("p3.8xlarge")
    m_plain = migration_cost(
        diff_configs(plain_live, ClusterConfig([(k2, (tid,))])),
        plain_live, base, wl)
    gb = checkpoint_size_gb(3)
    # cross-region adds exactly: egress fee + transfer time billed on both ends
    expected_extra = (gb * 0.1
                      + cat.transfer.transfer_time_s(0, 1, gb) / 3600.0
                      * (cat.costs[k_a] + cat.costs[k_b]))
    same_type_move = migration_cost(
        diff_configs(plain_live,
                     ClusterConfig([(base.index_of("p3.2xlarge"), (tid,))])),
        plain_live, base, wl)
    assert same_type_move == 0.0
    # compare against an identical-priced intra-catalog move: rebuild it as
    # a->a' is impossible (same type matches), so derive the no-penalty cost
    # from the plain catalog with dst == src type via the b-copy at equal price
    m_b_no_transfer = migration_cost(
        diff_configs(live, ClusterConfig([(k_b, (tid,))])), live,
        multi_region_catalog((Region("a"), Region("b")),
                             transfer=TransferMatrix.uniform(
                                 2, egress_usd_per_gb=0.0,
                                 bandwidth_gbps=1e12)),
        wl)
    assert m_b == pytest.approx(m_b_no_transfer + expected_extra)
    assert m_plain < m_b  # cross-region dearer than an in-region upgrade


class _Scripted(SchedulerBase):
    """Replays a fixed list of configurations, one per round."""

    name = "scripted"

    def __init__(self, catalog, script):
        super().__init__(catalog)
        self.script = list(script)
        self.round = 0

    def schedule(self, view):
        cfg = self.script[min(self.round, len(self.script) - 1)]
        self.round += 1
        return cfg


def test_egress_charged_exactly_once_per_cross_region_move():
    cat = _two_region_cat(egress=0.1, bw=8.0)
    k_a = cat.index_of("a/p3.2xlarge")
    k_b = cat.index_of("b/p3.2xlarge")
    job = make_job(job_id=1, workload=3, arrival_time=0.0, duration_s=4000.0,
                   n_tasks=1)  # cyclegan: 7 GB checkpoint, fast ckpt/launch
    tid = job.tasks[0].task_id
    cfg_a = ClusterConfig([(k_a, (tid,))])
    cfg_b = ClusterConfig([(k_b, (tid,))])
    # rounds: place in a, hold, move to b, hold, move back to a, stay
    sched = _Scripted(cat, [cfg_a, cfg_a, cfg_b, cfg_b, cfg_a, cfg_a])
    sim = Simulator(cat, [job], sched, SimConfig(seed=1))
    m = sim.run()
    gb = checkpoint_size_gb(3)
    assert m.cross_region_migrations == 2  # a->b and b->a, nothing else
    assert m.egress_cost == pytest.approx(2 * gb * 0.1)
    assert m.total_cost > m.egress_cost  # instance time billed on top
    assert job.completion_time is not None
    # region-scoped billing: both regions saw spend, egress billed to source
    assert m.cost_by_region["a"] > 0 and m.cost_by_region["b"] > 0
    assert sum(m.cost_by_region.values()) == pytest.approx(m.total_cost)


def test_intra_region_moves_pay_no_egress():
    cat = _two_region_cat()
    k_a1 = cat.index_of("a/p3.2xlarge")
    k_a2 = cat.index_of("a/p3.8xlarge")
    job = make_job(job_id=1, workload=3, arrival_time=0.0, duration_s=4000.0,
                   n_tasks=1)
    tid = job.tasks[0].task_id
    sched = _Scripted(cat, [ClusterConfig([(k_a1, (tid,))]),
                            ClusterConfig([(k_a1, (tid,))]),
                            ClusterConfig([(k_a2, (tid,))]),
                            ClusterConfig([(k_a2, (tid,))])])
    m = Simulator(cat, [job], sched, SimConfig(seed=1)).run()
    assert m.cross_region_migrations == 0
    assert m.egress_cost == 0.0
    assert m.migrations >= 1  # the a1 -> a2 move did happen
    assert m.cost_by_region["b"] == 0.0


# ------------------------------------------------------- strictly additive
def test_single_region_bit_identical_to_spot_path():
    """Acceptance: a 1-region multi-region catalog driven by
    EvaScheduler(multi_region=True) reproduces the PR-1 spot path
    (aws_catalog + spot_aware=True) metric for metric."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    jobs_kw = dict(n_jobs=12, seed=11, duration_range_h=(0.3, 0.6))
    cfg_kw = dict(seed=5, preemption_hazard_per_hour=0.5)

    cat_mr = multi_region_catalog((Region("solo", price_model=pm),))
    sched_mr = EvaScheduler(cat_mr, multi_region=True)
    m_mr = Simulator(cat_mr, physical_trace(**jobs_kw), sched_mr,
                     SimConfig(**cfg_kw)).run()

    cat_sp = aws_catalog(price_model=pm)
    sched_sp = EvaScheduler(cat_sp, spot_aware=True)
    m_sp = Simulator(cat_sp, physical_trace(**jobs_kw), sched_sp,
                     SimConfig(**cfg_kw)).run()

    assert m_mr.total_cost == m_sp.total_cost  # bit-for-bit
    assert m_mr.jct_sum == m_sp.jct_sum
    assert m_mr.migrations == m_sp.migrations
    assert m_mr.instances_launched == m_sp.instances_launched
    assert m_mr.preemptions == m_sp.preemptions
    assert m_mr.preemption_notices == m_sp.preemption_notices
    assert m_mr.cross_region_migrations == 0 and m_mr.egress_cost == 0.0
    assert sched_mr.arbitrage_moves == 0


def test_static_seed_path_untouched():
    """The plain static catalog path stays bit-identical to the seed (the
    multi-region layer adds no RNG draws and no events there)."""
    jobs_kw = dict(n_jobs=10, seed=11, duration_range_h=(0.3, 0.6))
    m1 = Simulator(aws_catalog(), physical_trace(**jobs_kw),
                   EvaScheduler(aws_catalog()), SimConfig(seed=5)).run()
    m2 = Simulator(aws_catalog(), physical_trace(**jobs_kw),
                   EvaScheduler(aws_catalog()), SimConfig(seed=5)).run()
    assert m1.summary() == m2.summary()
    assert m1.egress_cost == 0.0 and not m1.cost_by_region


# ----------------------------------------------------------- the scheduler
def test_region_arbitrage_rehomes_when_saving_beats_penalty():
    """A live, still-cost-efficient instance in a dear region is re-homed to
    the cheap same-hardware copy by the arbitrage pass (and not when egress
    makes the move unprofitable)."""
    from repro.core.scheduler import SchedulerView

    def build(egress):
        regs = (Region("dear", cost_scale=1.0), Region("cheap", cost_scale=0.5))
        cat = multi_region_catalog(
            regs, transfer=TransferMatrix.uniform(2, egress_usd_per_gb=egress,
                                                  bandwidth_gbps=8.0))
        return cat, EvaScheduler(cat, multi_region=True)

    job = make_job(job_id=1, workload=3, arrival_time=0.0, duration_s=4000.0,
                   n_tasks=1)
    tid = job.tasks[0].task_id
    tasks = TaskSet(job.tasks)

    cat, sched = build(egress=0.02)
    k_dear = cat.index_of("dear/p3.8xlarge")
    k_cheap = cat.index_of("cheap/p3.8xlarge")
    view = SchedulerView(time=0.0, tasks=tasks, pending_ids=set(),
                         live=[LiveInstance(0, k_dear, (tid,))],
                         task_workload={tid: 3})
    cfg = sched.stack.refine(ClusterConfig([(k_dear, (tid,))]), view, cat)
    assert cfg.assignments == [(k_cheap, (tid,))]
    assert sched.arbitrage_moves == 1

    # a prohibitive egress price kills the same move
    cat2, sched2 = build(egress=1000.0)
    view2 = SchedulerView(time=0.0, tasks=tasks, pending_ids=set(),
                          live=[LiveInstance(0, cat2.index_of("dear/p3.8xlarge"),
                                             (tid,))],
                          task_workload={tid: 3})
    cfg2 = sched2.stack.refine(
        ClusterConfig([(cat2.index_of("dear/p3.8xlarge"), (tid,))]), view2, cat2)
    assert cfg2.assignments == [(cat2.index_of("dear/p3.8xlarge"), (tid,))]
    assert sched2.arbitrage_moves == 0


def test_region_pin_keeps_all_packing_in_one_region():
    regs = dispersed_demo_regions(3)
    cat = multi_region_catalog(regs)
    jobs = physical_trace(n_jobs=8, seed=11, duration_range_h=(0.3, 0.5))
    sched = EvaScheduler(cat, multi_region=True, region="region-1")
    m = Simulator(cat, jobs, sched,
                  SimConfig(seed=5, preemption_hazard_per_hour=0.3)).run()
    assert all(j.completion_time is not None for j in jobs)
    assert m.cross_region_migrations == 0
    assert m.cost_by_region["region-0"] == 0.0
    assert m.cost_by_region["region-2"] == 0.0
    assert m.cost_by_region["region-1"] == pytest.approx(m.total_cost)


def test_region_capacity_is_enforced_and_routed_around():
    """Region 'a' holds one instance at most: the scheduler's per-region
    pack budget sends the overflow straight to 'b' (no launch denials
    needed) and nothing starves."""
    regs = (Region("a", max_instances=1), Region("b"))
    cat = multi_region_catalog(regs)
    jobs = [make_job(job_id=i + 1, workload=4, arrival_time=10.0 * i,
                     duration_s=2000.0, n_tasks=1) for i in range(4)]  # gpt2
    sched = EvaScheduler(cat, multi_region=True)
    sim = Simulator(cat, jobs, sched, SimConfig(seed=2))
    m = sim.run()
    assert all(j.completion_time is not None for j in jobs)
    # the budget-aware pack never over-asks, so the simulator never denies
    assert m.capacity_denied == 0
    # at no point were two instances alive in region 'a' simultaneously
    spans = [(i.request_t, i.terminated_t if i.terminated_t is not None
              else m.end_time)
             for i in sim.instances.values()
             if cat.region_of(i.type_index) == 0]
    for i, (s1, e1) in enumerate(spans):
        for s2, e2 in spans[i + 1:]:
            assert min(e1, e2) <= max(s1, s2) + 1e-9
    assert m.cost_by_region["b"] > 0  # overflow really ran in 'b'


def test_simulator_denies_launches_beyond_region_cap():
    """The simulator is the hard capacity backstop: a scheduler that asks
    for more instances than a region's cap gets denied, and the task lands
    once the config routes it elsewhere."""
    regs = (Region("a", max_instances=1), Region("b"))
    cat = multi_region_catalog(regs)
    k_a = cat.index_of("a/p3.8xlarge")
    k_b = cat.index_of("b/p3.8xlarge")
    jobs = [make_job(job_id=i + 1, workload=4, arrival_time=0.0,
                     duration_s=2000.0, n_tasks=1) for i in range(2)]
    t1, t2 = (j.tasks[0].task_id for j in jobs)
    over_ask = ClusterConfig([(k_a, (t1,)), (k_a, (t2,))])  # 2 > cap 1
    routed = ClusterConfig([(k_a, (t1,)), (k_b, (t2,))])
    sched = _Scripted(cat, [over_ask, routed, routed])
    sim = Simulator(cat, jobs, sched, SimConfig(seed=3))
    m = sim.run()
    assert m.capacity_denied >= 1
    assert all(j.completion_time is not None for j in jobs)
    assert m.cost_by_region["a"] > 0 and m.cost_by_region["b"] > 0


class _RestoreSched(SchedulerBase):
    """Places the task in region 'a'; after it has run once and come back
    pending (reclaimed), insists on region 'b' — forcing a cross-region
    checkpoint *restore* rather than a live migration."""

    name = "restore"

    def __init__(self, catalog, cfg_a, cfg_b, tid):
        super().__init__(catalog)
        self.cfg_a, self.cfg_b, self.tid = cfg_a, cfg_b, tid
        self.was_placed = False
        self.evacuated = False

    def schedule(self, view):
        if self.tid not in view.pending_ids:
            self.was_placed = True  # it is (or is becoming) resident
            return self.cfg_b if self.evacuated else self.cfg_a
        if self.was_placed:  # came back pending: it was reclaimed
            self.evacuated = True
            return self.cfg_b
        return self.cfg_a


def test_reclaim_then_restore_elsewhere_pays_the_transfer():
    """A checkpoint stranded in region 'a' by a reclaim pays egress +
    transfer when the task is restored in region 'b' — the restore path is
    priced like a live migration, exactly once."""
    regs = (Region("a", price_model=PriceModel.trace([0.0], [0.5])),
            Region("b", price_model=PriceModel.trace([0.0], [0.5])))
    cat = multi_region_catalog(
        regs, transfer=TransferMatrix.uniform(2, egress_usd_per_gb=0.1,
                                              bandwidth_gbps=8.0))
    k_a = cat.index_of("a/p3.2xlarge")
    k_b = cat.index_of("b/p3.2xlarge")
    job = make_job(job_id=1, workload=3, arrival_time=0.0, duration_s=600.0,
                   n_tasks=1)  # cyclegan: 7 GB checkpoint
    tid = job.tasks[0].task_id
    sched = _RestoreSched(cat, ClusterConfig([(k_a, (tid,))]),
                          ClusterConfig([(k_b, (tid,))]), tid)
    # enormous hazard: the 'a' instance is noticed at the first price update
    # and reclaimed (the scheduler ignores the notice), killing the task
    sim = Simulator(cat, [job], sched,
                    SimConfig(seed=4, preemption_hazard_per_hour=1e5,
                              checkpoint_period_s=60.0,
                              max_time_s=40000.0))
    m = sim.run()
    assert m.preemptions >= 1  # the reclaim actually hit the task
    gb = checkpoint_size_gb(3)
    # every cross-region charge is a restore (never a live a->b migration:
    # the scheduler only switches to 'b' once the task is already pending)
    assert m.cross_region_migrations >= 1
    assert m.egress_cost == pytest.approx(m.cross_region_migrations * gb * 0.1)


def test_arbitrage_fires_end_to_end_on_mild_dispersion():
    """Integration guard for the arbitrage pass: under mild price dispersion
    dense kept instances stay cost-efficient in dear regions (eviction never
    moves them), so cross-region re-homing must come from the S·D̂ > ΔM
    arbitrage rewrite."""
    regs = dispersed_demo_regions(3, low=0.65, high=0.8)
    cat = multi_region_catalog(regs)
    jobs = physical_trace(n_jobs=20, seed=11, duration_range_h=(0.5, 1.2))
    sched = EvaScheduler(cat, multi_region=True)
    m = Simulator(cat, jobs, sched, SimConfig(seed=5)).run()
    assert all(j.completion_time is not None for j in jobs)
    assert sched.arbitrage_moves > 0
    assert m.cross_region_migrations > 0


# ------------------------------------------------------------ the invariant
def test_multiregion_beats_single_region_spot_on_dispersed_trace():
    """Acceptance (benchmark/CI invariant): on the bundled dispersed-price
    3-region market, multi-region Eva is strictly cheaper than Eva locked to
    region-0's spot market, which in turn beats on-demand."""
    regs = dispersed_demo_regions(3)
    jobs_kw = dict(n_jobs=12, seed=11, duration_range_h=(0.3, 0.6))
    cfg = dict(seed=5, preemption_hazard_per_hour=0.3)

    cat_mr = multi_region_catalog(regs)
    m_mr = Simulator(cat_mr, physical_trace(**jobs_kw),
                     EvaScheduler(cat_mr, multi_region=True),
                     SimConfig(**cfg)).run()
    cat_sp = aws_catalog(price_model=regs[0].price_model)
    m_sp = Simulator(cat_sp, physical_trace(**jobs_kw),
                     EvaScheduler(cat_sp, spot_aware=True),
                     SimConfig(**cfg)).run()
    m_od = Simulator(aws_catalog(), physical_trace(**jobs_kw),
                     EvaScheduler(aws_catalog()), SimConfig(seed=5)).run()
    assert m_mr.total_cost < m_sp.total_cost < m_od.total_cost
    assert m_mr.cross_region_migrations > 0  # it really arbitrages
    assert m_mr.egress_cost > 0.0
    assert sum(m_mr.cost_by_region.values()) == pytest.approx(m_mr.total_cost)
