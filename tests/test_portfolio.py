"""Commitment portfolio: pool-qualified catalogs, the exactly-once standing
bill, the ``PortfolioLayer`` fill-first/keep/inventory behaviour, and
multi-provider arbitrage pricing.

Contract tests anchoring the design:
* a commitment pool fills before the market and overflow pays market
  prices (the pool cap bounds the committed fleet);
* an oversized pool bills idle waste — uncovered capacity-hours at the
  discounted rate — and utilization reports the covered fraction;
* the inventory pass grows pools monotonically toward the observed steady
  base (a commitment, once bought, never shrinks mid-run);
* pool residents get keep-test slack equal to the committed rate, market
  residents none;
* cross-provider moves price the source provider's egress into the
  migration cost; intra-provider moves (market <-> pool) are free of it;
* the provider/commitment ledgers stay additive under random pool sizes,
  rates, and hazards (hypothesis sweep + seeded fallback).
"""
import numpy as np
import pytest

from repro.autoscale.forecast import (MarketForecaster, OUForecaster,
                                      PriceForecaster)
from repro.cluster import SimConfig, Simulator, portfolio_trace
from repro.cluster.traces import _custom_job
from repro.core import (CommitmentModel, EvaScheduler, MarketPriceModel,
                        PriceModel, Provider, TaskSet, aws_catalog,
                        checkpoint_size_gb, multi_provider_catalog)
from repro.core.scheduler import SchedulerView
from repro.core.plan import LiveInstance
from repro.policies import MultiRegionLayer, PortfolioLayer, SpotLayer

COMMIT = "c7i.2xlarge"
N_BASE = len(aws_catalog())
STEADY = (0.0, 7.0, 14.0)  # one task per c7i.2xlarge (8 vCPU / 16 GB)


def _cat(pool=3, rate=0.4, pm_aws=None, pm_gcp=None, gcp_scale=1.04):
    commitments = (CommitmentModel(instance_type=COMMIT, pool_size=pool,
                                   rate_fraction=rate),) if pool else ()
    return multi_provider_catalog((
        Provider(name="aws", price_model=pm_aws, commitments=commitments),
        Provider(name="gcp", cost_scale=gcp_scale, price_model=pm_gcp)))


def _stack(**kw):
    return [SpotLayer(), MultiRegionLayer(), PortfolioLayer(**kw)]


# ----------------------------------------------------------------- catalog
def test_commitment_model_math():
    cm = CommitmentModel(instance_type=COMMIT, pool_size=5,
                         rate_fraction=0.4)
    assert cm.hourly_rate(0.357) == pytest.approx(0.1428)
    assert cm.standing_usd_per_hour(0.357) == pytest.approx(5 * 0.1428)
    with pytest.raises(AssertionError):
        CommitmentModel(instance_type=COMMIT, pool_size=-1)
    with pytest.raises(AssertionError):
        CommitmentModel(instance_type=COMMIT, pool_size=1, rate_fraction=0.0)


def test_multi_provider_catalog_layout():
    cat = _cat(pool=3, rate=0.4)
    assert [r.name for r in cat.regions] == \
        ["aws", f"aws/commit-{COMMIT}", "gcp"]
    assert len(cat) == 2 * N_BASE + 1
    assert cat.has_commitments and cat.has_providers
    (ri, cm), = cat.commitment_pools()
    assert cat.regions[ri].max_instances == 3
    assert cat.regions[ri].provider == "aws"
    assert cat.regions[ri].hazard_scale == 0.0  # committed capacity is firm
    mask = cat.commitment_type_mask()
    (k_pool,) = np.nonzero(mask)[0]
    assert cat.types[k_pool].name == f"aws/commit-{COMMIT}/{COMMIT}"
    # the pool bills the discounted rate and maps to the committed base
    assert cat.costs[k_pool] == pytest.approx(0.357 * 0.4)
    assert cat.base_index[k_pool] == \
        cat.base_index[cat.index_of(f"aws/{COMMIT}")]
    assert cat.provider_of(k_pool) == "aws"
    assert cat.provider_of(cat.index_of("gcp/" + COMMIT)) == "gcp"
    # transfer: intra-provider (market <-> pool) free, cross-provider pays
    # the source's egress over the thin link
    t = cat.transfer
    ri_aws, ri_gcp = 0, 2
    assert t.egress_usd(ri_aws, ri, 10.0) == 0.0
    assert t.egress_usd(ri_aws, ri_gcp, 10.0) == pytest.approx(0.2)
    assert t.egress_usd(ri_gcp, ri_aws, 10.0) == pytest.approx(0.2)
    assert t.bandwidth_gbps[ri_aws, ri] > t.bandwidth_gbps[ri_aws, ri_gcp]


def test_market_forecaster_composes_blocks():
    pm = PriceModel.mean_reverting(discount=0.5, seed=3)
    cat = _cat(pool=2, pm_aws=pm)  # gcp static
    assert isinstance(cat.price_model, MarketPriceModel)
    fc = PriceForecaster.for_catalog(cat)
    assert isinstance(fc, MarketForecaster)
    mm = fc.mean_multipliers(len(cat), 1800.0, 4 * 3600.0)
    assert mm.shape == (len(cat),)
    # the pool block (static) and the static gcp block forecast exactly 1
    (k_pool,) = np.nonzero(cat.commitment_type_mask())[0]
    assert mm[k_pool] == 1.0
    np.testing.assert_array_equal(mm[k_pool + 1:], np.ones(N_BASE))
    # the aws block matches the OU sub-forecaster verbatim
    np.testing.assert_allclose(
        mm[:N_BASE], OUForecaster(pm).mean_multipliers(N_BASE, 1800.0,
                                                       4 * 3600.0))


# --------------------------------------------------- fill-first / overflow
def test_pool_fills_first_then_overflows_to_market():
    cat = _cat(pool=2, rate=0.4)  # static: billing is exact
    jobs = portfolio_trace(n_steady=4, n_burst=0, seed=3, horizon_h=2.0)
    sched = EvaScheduler(cat, policies=_stack(resize=False))
    sim = Simulator(cat, jobs, sched, SimConfig(seed=5))
    m = sim.run()
    mask = cat.commitment_type_mask()
    pool_insts = [i for i in sim.instances.values() if mask[i.type_index]]
    mkt_insts = [i for i in sim.instances.values()
                 if not mask[i.type_index]]
    # the pool is filled to its cap — never beyond it concurrently — and
    # the rest overflows
    events = sorted([(i.request_t, 1) for i in pool_insts]
                    + [(i.terminated_t, -1) for i in pool_insts])
    peak = cur = 0
    for _, d in events:
        cur += d
        peak = max(peak, cur)
    assert peak == 2
    assert len(mkt_insts) >= 1
    assert m.commitment_utilization[f"aws/commit-{COMMIT}"] > 0.8
    # overflow pays market prices on top of the standing pool bill
    mkt_cost = sum((i.terminated_t - i.request_t) / 3600.0
                   * cat.costs[i.type_index] for i in mkt_insts)
    assert mkt_cost > 0.0
    assert m.total_cost == pytest.approx(
        m.commitment_cost + mkt_cost + m.egress_cost, rel=1e-9)
    # pool instances billed nothing marginal: the commitment bill is the
    # capacity integral alone, used-or-idle
    assert m.commitment_cost > 0.0
    assert all(j.completion_time is not None for j in jobs)


def test_oversized_pool_bills_idle_waste():
    cat = _cat(pool=4, rate=0.4)
    jobs = portfolio_trace(n_steady=1, n_burst=0, seed=3, horizon_h=2.0)
    sched = EvaScheduler(cat, policies=_stack(resize=False))
    sim = Simulator(cat, jobs, sched, SimConfig(seed=5))
    m = sim.run()
    # one resident in a 4-slot pool: everything bills through the pool
    assert m.total_cost == pytest.approx(m.commitment_cost, rel=1e-9)
    util = m.commitment_utilization[f"aws/commit-{COMMIT}"]
    assert 0.0 < util < 0.5
    assert m.commitment_idle_cost == pytest.approx(
        (1.0 - util) * m.commitment_cost, rel=1e-6)


# ---------------------------------------------------------- inventory pass
class _PoolSizeRecorder(Simulator):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.size_log = []

    def _apply_commitment_orders(self):
        super()._apply_commitment_orders()
        self.size_log.append(dict(self._pool_size))


def test_inventory_pass_grows_pool_monotonically():
    """Demand step: the steady base doubles mid-run; the inventory pass
    grows the undersized pool toward the new base — monotonically — once
    the base has persisted a full sample window."""
    cat = _cat(pool=1, rate=0.4)  # static: market od > committed rate
    jobs = [_custom_job(8, 60.0 * i, 5.5 * 3600.0, STEADY, 1)
            for i in range(2)]
    jobs += [_custom_job(8, 1.2 * 3600.0 + 60.0 * i, 4.0 * 3600.0, STEADY, 1)
             for i in range(3)]
    layer = PortfolioLayer(resize_interval_s=1800.0, window=4)
    sched = EvaScheduler(cat, policies=[SpotLayer(), MultiRegionLayer(),
                                        layer])
    sim = _PoolSizeRecorder(cat, jobs, sched, SimConfig(seed=5))
    m = sim.run()
    (ri, _), = cat.commitment_pools()
    sizes = [log[ri] for log in sim.size_log]
    assert sizes[0] == 1
    assert sizes[-1] > 1  # the pool grew to absorb the steady base
    assert all(b >= a for a, b in zip(sizes, sizes[1:]))  # never shrinks
    assert m.commitment_resizes >= 1
    assert layer.resizes_ordered >= m.commitment_resizes
    assert sched.stack.summary()["commitment_resizes_ordered"] == \
        layer.resizes_ordered
    # the applied size is the layer's last order for this pool
    assert sim._pool_size[ri] == \
        layer.commitment_orders[cat.regions[ri].name]


def test_inventory_pass_skips_when_market_is_cheaper():
    """A commitment at ~on-demand price never beats a deep-discount spot
    market, so the buy-more test must decline to grow the pool."""
    pm = PriceModel.mean_reverting(discount=0.3, seed=3)  # spot ~0.3 x od
    cat = _cat(pool=1, rate=0.95, pm_aws=pm)
    jobs = [_custom_job(8, 60.0 * i, 4.0 * 3600.0, STEADY, 1)
            for i in range(4)]
    layer = PortfolioLayer(resize_interval_s=1800.0, window=4)
    sched = EvaScheduler(cat, policies=[SpotLayer(), MultiRegionLayer(),
                                        layer])
    m = Simulator(cat, jobs, sched, SimConfig(seed=5)).run()
    assert layer.resizes_ordered == 0
    assert m.commitment_resizes == 0


# --------------------------------------------------------------- keep test
def test_keep_bonus_protects_pool_residents_only():
    cat = _cat(pool=2, rate=0.4)
    sched = EvaScheduler(cat, policies=[SpotLayer(),
                                        PortfolioLayer(resize=False)])
    (k_pool,) = np.nonzero(cat.commitment_type_mask())[0]
    k_mkt = cat.index_of(f"aws/{COMMIT}")
    job = _custom_job(8, 0.0, 3600.0, STEADY, 2)
    t1, t2 = (t.task_id for t in job.tasks)
    view = SchedulerView(
        time=0.0, tasks=TaskSet(job.tasks), pending_ids=set(),
        live=[LiveInstance(0, int(k_pool), (t1,)),
              LiveInstance(1, k_mkt, (t2,))],
        task_workload={t1: 8, t2: 8})
    raw, plan = sched.stack.plan(cat, view, 3600.0)
    # planning presents pool slots as sunk (price 0); billing never does
    assert plan.costs[k_pool] == 0.0
    assert raw.costs[k_pool] == pytest.approx(0.357 * 0.4)
    fn = sched.stack.keep_bonus(raw, plan, view)
    assert fn(int(k_pool), (t1,)) == pytest.approx(float(raw.costs[k_pool]))
    assert fn(k_mkt, (t2,)) == 0.0


# ------------------------------------------------- cross-provider pricing
def test_cross_provider_moves_price_egress():
    from repro.core import ClusterConfig, diff_configs, migration_cost
    cat = _cat(pool=2, rate=0.4)
    k_aws = cat.index_of(f"aws/{COMMIT}")
    k_gcp = cat.index_of(f"gcp/{COMMIT}")
    (k_pool,) = np.nonzero(cat.commitment_type_mask())[0]
    job = _custom_job(3, 0.0, 3600.0, STEADY, 1)  # cyclegan: 7 GB ckpt
    tid = job.tasks[0].task_id
    wl = {tid: 3}
    live = [LiveInstance(0, k_aws, (tid,))]
    to_gcp = migration_cost(
        diff_configs(live, ClusterConfig([(k_gcp, (tid,))])), live, cat, wl)
    to_pool = migration_cost(
        diff_configs(live, ClusterConfig([(int(k_pool), (tid,))])), live,
        cat, wl)
    gb = checkpoint_size_gb(3)
    # the cross-provider move carries the source provider's egress fee;
    # the intra-provider market -> pool move carries none
    assert to_gcp - to_pool > gb * 0.02 * 0.99
    r_aws, r_gcp = cat.region_of(k_aws), cat.region_of(k_gcp)
    assert cat.transfer.egress_usd(r_aws, r_gcp, gb) == \
        pytest.approx(gb * 0.02)
    assert cat.transfer.egress_usd(r_aws, cat.region_of(int(k_pool)),
                                   gb) == 0.0


# ------------------------------------------------------- ledger additivity
def _check_ledgers(pool, rate, hazard, seed):
    pm = PriceModel.mean_reverting(discount=0.5, seed=seed)
    cat = _cat(pool=pool, rate=rate, pm_aws=pm)
    jobs = portfolio_trace(n_steady=2, n_burst=2, seed=seed, horizon_h=1.5)
    sched = EvaScheduler(cat, policies=_stack())
    m = Simulator(cat, jobs, sched,
                  SimConfig(seed=seed,
                            preemption_hazard_per_hour=hazard)).run()
    assert m.total_cost == pytest.approx(sum(m.cost_by_provider.values()),
                                         rel=1e-9, abs=1e-9)
    assert m.total_cost == pytest.approx(sum(m.cost_by_region.values()),
                                         rel=1e-9, abs=1e-9)
    assert 0.0 <= m.commitment_cost <= m.total_cost + 1e-9
    assert m.commitment_idle_cost >= 0.0
    for util in m.commitment_utilization.values():
        assert 0.0 <= util <= 1.0 + 1e-12
    assert all(j.completion_time is not None for j in jobs)


SEEDED_LEDGER = [(1, 0.4, 0.0, 3), (3, 0.6, 0.4, 7), (2, 0.9, 0.2, 12)]


@pytest.mark.parametrize("pool,rate,hazard,seed", SEEDED_LEDGER)
def test_ledger_additivity_seeded(pool, rate, hazard, seed):
    _check_ledgers(pool, rate, hazard, seed)


def test_ledger_additivity_random():
    """Random pool sizes / rates / hazards keep every ledger additive; the
    seeded cases above pin the law when hypothesis is absent."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=6, deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(pool=st.integers(1, 4),
           rate=st.sampled_from([0.3, 0.5, 0.7, 0.95]),
           hazard=st.sampled_from([0.0, 0.3, 0.6]),
           seed=st.integers(0, 40))
    def inner(pool, rate, hazard, seed):
        _check_ledgers(pool, rate, hazard, seed)

    inner()
