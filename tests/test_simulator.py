"""Integration tests for the event-driven cluster simulator."""
import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator, physical_trace, alibaba_like_trace
from repro.core import EvaScheduler, NoPackingScheduler, aws_catalog
from repro.core.workloads import M_TRUE
from repro.schedulers import OwlScheduler, StratusScheduler, SynergyScheduler


def _run(scheduler_factory, jobs, **cfg):
    cat = aws_catalog()
    sim = Simulator(cat, jobs, scheduler_factory(cat), SimConfig(**cfg))
    return sim.run()


def make_all(cat):
    return {
        "no-packing": NoPackingScheduler(cat),
        "stratus": StratusScheduler(cat),
        "synergy": SynergyScheduler(cat),
        "owl": OwlScheduler(cat, M_TRUE),
        "eva": EvaScheduler(cat),
    }


def test_all_jobs_complete_all_schedulers():
    cat = aws_catalog()
    jobs_seed = 7
    for name, sched in make_all(cat).items():
        jobs = physical_trace(n_jobs=12, seed=jobs_seed,
                              duration_range_h=(0.2, 0.6))
        sim = Simulator(cat, jobs, sched, SimConfig(seed=1))
        m = sim.run()
        done = sum(1 for j in jobs if j.completion_time is not None)
        assert done == len(jobs), f"{name}: {done}/{len(jobs)} completed"
        assert m.total_cost > 0
        # every instance eventually terminated and billed
        for inst in sim.instances.values():
            assert inst.terminated_t is not None


def test_no_capacity_violation_during_sim():
    cat = aws_catalog()
    jobs = physical_trace(n_jobs=16, seed=3, duration_range_h=(0.2, 0.5))
    sched = EvaScheduler(cat)
    sim = Simulator(cat, jobs, sched, SimConfig(seed=2))

    # monkey-patch the executor to validate capacity after each config
    orig = sim._execute_config

    def checked(config):
        orig(config)
        from repro.core.catalog import FAMILIES
        for inst in sim.instances.values():
            if not inst.alive:
                continue
            fam = FAMILIES[cat.types[inst.type_index].family_id]
            used = np.zeros(3)
            for tid in inst.assigned:
                used += np.array(sim.tasks[tid].task.demand_for_family(fam))
            assert np.all(used <= cat.capacities[inst.type_index] + 1e-6)

    sim._execute_config = checked
    m = sim.run()
    assert all(j.completion_time is not None for j in jobs)


def test_packing_reduces_cost_vs_no_packing():
    """Headline claim (C1): Eva < No-Packing cost on a packing-friendly
    trace."""
    cost = {}
    for name in ("no-packing", "eva"):
        cat = aws_catalog()
        jobs = physical_trace(n_jobs=24, seed=11, duration_range_h=(0.5, 1.5))
        sched = make_all(cat)[name]
        m = Simulator(cat, jobs, sched, SimConfig(seed=5)).run()
        cost[name] = m.total_cost
    assert cost["eva"] < cost["no-packing"]


def test_no_packing_has_full_throughput():
    cat = aws_catalog()
    jobs = physical_trace(n_jobs=10, seed=2, duration_range_h=(0.2, 0.4))
    m = Simulator(cat, jobs, NoPackingScheduler(cat), SimConfig(seed=3)).run()
    assert m.norm_job_tput == pytest.approx(1.0, abs=1e-6)
    assert m.migrations == 0


def test_failure_recovery():
    """Beyond-paper fault tolerance: jobs still complete under instance
    failures (checkpoint/restart path)."""
    cat = aws_catalog()
    jobs = physical_trace(n_jobs=8, seed=5, duration_range_h=(0.3, 0.6))
    sim = Simulator(cat, jobs, EvaScheduler(cat),
                    SimConfig(seed=4, failure_mtbf_hours=1.0))
    m = sim.run()
    assert m.failures > 0
    assert all(j.completion_time is not None for j in jobs)


def test_uniform_interference_override():
    cat = aws_catalog()
    jobs = physical_trace(n_jobs=10, seed=9, duration_range_h=(0.2, 0.4))
    sim = Simulator(cat, jobs, EvaScheduler(cat),
                    SimConfig(seed=6, uniform_interference=0.8))
    m = sim.run()
    assert all(j.completion_time is not None for j in jobs)


def test_trace_statistics():
    jobs = alibaba_like_trace(n_jobs=4000, seed=0, duration_model="alibaba")
    dur_h = np.array([j.duration_s for j in jobs]) / 3600.0
    assert abs(np.median(dur_h) - 0.2) < 0.06      # Table 9 median 0.2 h
    assert abs(np.quantile(dur_h, 0.8) - 1.0) < 0.3
    assert abs(np.quantile(dur_h, 0.95) - 5.2) < 1.2
    assert 6.0 < dur_h.mean() < 13.0               # Table 9 mean 9.1 h
    gpus = np.array([j.tasks[0].demands["p3"][0] for j in jobs])
    assert abs((gpus == 0).mean() - 0.1341) < 0.03  # Table 8 mix
    assert abs((gpus == 1).mean() - 0.8617) < 0.03

    jobs_g = alibaba_like_trace(n_jobs=2000, seed=1, duration_model="gavel")
    dur_g = np.array([j.duration_s for j in jobs_g]) / 3600.0
    assert 2.0 < np.median(dur_g) < 8.0            # Table 9 median 4.5 h
