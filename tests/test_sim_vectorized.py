"""Unit tests for the vectorized simulator core's building blocks:
``cluster/fleet.SlotTable`` (structure-of-arrays fleet state),
``Catalog.prices_between`` (segment billing API), and the same-timestamp
event coalescing in ``Simulator.run``.  The end-to-end vectorized-vs-
scalar equality laws live in tests/test_invariants.py; these pin the
pieces in isolation.
"""
import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator
from repro.cluster.fleet import SlotTable
from repro.core import EvaScheduler, PriceModel, aws_catalog, make_job
from repro.core.workloads import WORKLOAD_INDEX
from repro.policies import SLOLayer, SpotLayer

A3C = WORKLOAD_INDEX["a3c"]


# ----------------------------------------------------------- SlotTable
def test_slot_table_add_get_set_remove():
    t = SlotTable(("bal", "net"), ("throttled",))
    t.add(7, bal=1.5, net=-0.25)
    t.add(9, bal=2.0, throttled=True)
    assert len(t) == 2 and 7 in t and 9 in t and 8 not in t
    assert t.get(7, "bal") == 1.5
    assert t.get(9, "throttled") is True
    assert t.get(7, "throttled") is False  # unnamed columns start zeroed
    t.set(7, "bal", 3.0)
    assert t.live("bal")[t.slot[7]] == 3.0
    fin = t.remove(7)
    assert fin == {"bal": 3.0, "net": -0.25, "throttled": False}
    assert 7 not in t and len(t) == 1


def test_slot_table_swap_remove_keeps_slots_current():
    t = SlotTable(("x",))
    for eid in range(5):
        t.add(eid, x=float(eid) * 10.0)
    t.remove(1)  # row 4 swaps into slot 1
    assert len(t) == 4
    for eid in (0, 2, 3, 4):
        assert t.get(eid, "x") == float(eid) * 10.0
    assert set(t.ids[:t.n].tolist()) == {0, 2, 3, 4}


def test_slot_table_recycled_rows_are_zeroed():
    t = SlotTable(("x",), ("flag",))
    t.add(1, x=5.0, flag=True)
    t.remove(1)
    t.add(2)  # re-uses the row 1 left behind
    assert t.get(2, "x") == 0.0
    assert t.get(2, "flag") is False


def test_slot_table_growth_and_duplicate_add():
    t = SlotTable(("x",))
    n = 300  # forces several capacity doublings past the initial 64
    for eid in range(n):
        t.add(eid, x=float(eid))
    assert len(t) == n
    assert all(t.get(eid, "x") == float(eid) for eid in (0, 63, 64, 299))
    with pytest.raises(ValueError):
        t.add(0)


# ------------------------------------------------- Catalog.prices_between
def test_prices_between_static_catalog_is_base_costs():
    cat = aws_catalog()
    np.testing.assert_array_equal(cat.prices_between(0.0, 3600.0),
                                  cat.costs)


def test_prices_between_matches_snapshot_costs():
    cat = aws_catalog(
        price_model=PriceModel.mean_reverting(discount=0.4, seed=3))
    for t in (0.0, 450.0, 3600.0, 86_400.0):
        np.testing.assert_allclose(cat.prices_between(t, t + 300.0),
                                   cat.at(t).costs, rtol=0, atol=0)


# --------------------------------------------------- event coalescing
class _Counting(Simulator):
    """Records how many accrual sweeps had run when each arrival fired."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.accrue_calls = 0
        self.arrival_accrues = []

    def _accrue(self, now):
        self.accrue_calls += 1
        super()._accrue(now)

    def _on_arrival(self, *a):
        self.arrival_accrues.append(self.accrue_calls)
        super()._on_arrival(*a)


@pytest.mark.parametrize("vectorized", [True, False])
def test_arrival_wave_coalesces_into_one_sweep(vectorized):
    """A simultaneous JOB_ARRIVE wave drains under a single accrual sweep
    (same count observed by every arrival), in both simulator modes."""
    cat = aws_catalog()
    n = 30
    jobs = [make_job(job_id=i, workload=A3C, arrival_time=0.0,
                     duration_s=1800.0) for i in range(n)]
    sched = EvaScheduler(cat, policies=[SpotLayer(), SLOLayer()])
    sim = _Counting(cat, jobs, sched, SimConfig(seed=2),
                    vectorized=vectorized)
    m = sim.run()
    assert len(sim.arrival_accrues) == n
    assert len(set(sim.arrival_accrues)) == 1
    assert m.total_cost > 0.0
