"""Hypothesis property tests on the scheduler's invariants.

Skips cleanly when hypothesis is not installed (it is a ``test`` extra, not a
runtime dependency): ``pip install -e .[test]`` pulls it in.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (TaskSet, ThroughputTable, aws_catalog,
                        evaluate_assignments, full_reconfiguration, make_task,
                        reservation_prices)
from repro.core.full_reconfig import EPS
from repro.core.workloads import NUM_WORKLOADS

CAT = aws_catalog()


def _taskset(workloads):
    return TaskSet([make_task(job_id=i, workload=w)
                    for i, w in enumerate(workloads)])


w_lists = st.lists(st.integers(0, NUM_WORKLOADS - 1), min_size=1, max_size=40)


@given(w_lists)
@settings(max_examples=40, deadline=None)
def test_packing_respects_capacity(ws):
    tasks = _taskset(ws)
    cfg = full_reconfiguration(tasks, CAT, None, interference_aware=False,
                               multi_task_aware=False)
    for k, tids in cfg.assignments:
        fam = CAT.family_ids[k]
        used = np.zeros(3)
        for t in tids:
            used += tasks.demand_by_family[tasks.row(t), fam]
        assert np.all(used <= CAT.capacities[k] + 1e-6)


@given(w_lists)
@settings(max_examples=40, deadline=None)
def test_every_assignment_cost_efficient(ws):
    """Algorithm-1 guarantee: RP(T_i) >= C_i for every provisioned
    instance."""
    tasks = _taskset(ws)
    cfg = full_reconfiguration(tasks, CAT, None, interference_aware=False,
                               multi_task_aware=False)
    tnrps, costs = evaluate_assignments(cfg.assignments, tasks, CAT, None,
                                        multi_task_aware=False)
    assert np.all(tnrps >= costs - EPS)


@given(w_lists)
@settings(max_examples=40, deadline=None)
def test_all_tasks_assigned_once(ws):
    tasks = _taskset(ws)
    cfg = full_reconfiguration(tasks, CAT, None, interference_aware=False,
                               multi_task_aware=False)
    got = sorted(t for _, tids in cfg.assignments for t in tids)
    assert got == sorted(tasks.ids.tolist())


@given(w_lists)
@settings(max_examples=40, deadline=None)
def test_packed_cost_never_exceeds_no_packing(ws):
    """Without interference, the packed configuration costs at most the sum
    of reservation prices (assigning each task separately)."""
    tasks = _taskset(ws)
    cfg = full_reconfiguration(tasks, CAT, None, interference_aware=False,
                               multi_task_aware=False)
    rp = reservation_prices(tasks, CAT)
    assert cfg.total_hourly_cost(CAT) <= rp.sum() + 1e-6


@given(w_lists, st.floats(0.7, 1.0))
@settings(max_examples=30, deadline=None)
def test_interference_never_exceeds_no_packing(ws, t_default):
    """With any interference level, total cost stays bounded by No-Packing
    (Σ C_i ≤ Σ TNRP(T_i) ≤ Σ RP).  NOTE a property-test discovery: the
    intuitive claim "more interference ⇒ higher cost" is FALSE — at
    break-even ties (TNRP == cost of the larger type) interference pushes
    the greedy off the big bin onto a strictly cheaper type (e.g. two
    RP-$12.24 tasks: no-interference accepts p3.16xlarge at 24.48 ≥ 24.48,
    with t=0.95 it rejects and packs both on p3.8xlarge for $12.24).  This
    is a faithful Algorithm-1 artifact, so only the upper bound is law."""
    tasks = _taskset(ws)
    table = ThroughputTable(NUM_WORKLOADS, default=t_default)
    cfg = full_reconfiguration(tasks, CAT, table, interference_aware=True,
                               multi_task_aware=False)
    rp = reservation_prices(tasks, CAT)
    assert cfg.total_hourly_cost(CAT) <= rp.sum() + 1e-6


@given(st.lists(st.tuples(st.integers(0, NUM_WORKLOADS - 1),
                          st.floats(0.5, 1.0)), min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_throughput_table_lookup_bounds(obs):
    table = ThroughputTable(NUM_WORKLOADS, default=0.95)
    for w, v in obs:
        table.observe_single(w, ((w + 1) % NUM_WORKLOADS,), v)
    for w, _ in obs:
        t = table.lookup(w, ((w + 1) % NUM_WORKLOADS,))
        assert 0.0 < t <= 1.0
    assert table.lookup(0, ()) == 1.0
