"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_chunked, attention_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_assoc, rglru_scan_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_decode_step, ssd_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd,window", [
    (1, 128, 2, 2, 64, None),
    (2, 256, 4, 2, 64, None),
    (1, 256, 4, 1, 128, None),     # MQA
    (2, 256, 4, 2, 64, 64),        # local window
    (1, 512, 2, 2, 64, 128),
])
def test_flash_attention_pallas_vs_ref(B, S, H, KH, hd, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, KH, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KH, hd)), dtype)
    ref = attention_ref(q, k, v, causal=True, window=window)
    pal = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,KH,hd,window", [
    (2, 1024, 4, 2, 64, None),
    (1, 2048, 2, 1, 64, 256),
])
def test_attention_chunked_vs_ref(B, S, H, KH, hd, window):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KH, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KH, hd)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    chk = attention_chunked(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_attention_chunked_grads_finite():
    q = jnp.asarray(RNG.normal(size=(1, 1024, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1024, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1024, 2, 64)), jnp.float32)
    g = jax.grad(lambda q, k, v: attention_chunked(q, k, v).sum(),
                 argnums=(0, 1, 2))(q, k, v)
    for x in g:
        assert np.all(np.isfinite(np.asarray(x)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Bt,S,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 32, 16),
    (2, 128, 4, 16, 2, 32, 32),
    (1, 96, 2, 32, 1, 16, 32),     # padding path (96 % 32 == 0; also 80)
    (1, 80, 2, 16, 1, 16, 32),     # pad 80 -> 96
])
def test_ssd_pallas_vs_sequential(Bt, S, H, P, G, N, chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(Bt, S, H, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, size=(Bt, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(Bt, S, G, N)), dtype)
    C = jnp.asarray(RNG.normal(size=(Bt, S, G, N)), dtype)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    y_ref, h_ref = ssd_ref(x, dt, A, B, C, D)
    y_pal, h_pal = ssd(x, dt, A, B, C, D, chunk=chunk, impl="pallas",
                       interpret=True)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_matches_sequential_and_decode():
    Bt, S, H, P, G, N = 2, 64, 4, 16, 2, 32
    x = jnp.asarray(RNG.normal(size=(Bt, S + 1, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, size=(Bt, S + 1, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(Bt, S + 1, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(Bt, S + 1, G, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    y_all, _ = ssd_ref(x, dt, A, B, C, D)
    y_chk, h = ssd_chunked_ref(x[:, :S], dt[:, :S], A, B[:, :S], C[:, :S], D,
                               chunk=16)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_all[:, :S]),
                               rtol=2e-4, atol=2e-4)
    y_dec, _ = ssd_decode_step(h, x[:, S], dt[:, S], A, B[:, S], C[:, S], D)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_all[:, S]),
                               rtol=2e-4, atol=2e-4)


def test_ssd_grads_finite():
    Bt, S, H, P, G, N = 1, 32, 2, 8, 1, 16
    x = jnp.asarray(RNG.normal(size=(Bt, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, size=(Bt, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(Bt, S, G, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(Bt, S, G, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)

    def loss(x, dt, B, C):
        y, _ = ssd_chunked_ref(x, dt, A, B, C, D, chunk=8)
        return (y ** 2).sum()

    g = jax.grad(loss, argnums=(0, 1, 2, 3))(x, dt, B, C)
    for t in g:
        assert np.all(np.isfinite(np.asarray(t)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,R,br,bs", [
    (1, 64, 64, 64, 16),
    (2, 128, 128, 64, 32),
    (2, 96, 192, 96, 32),
])
def test_rglru_pallas_vs_ref(B, S, R, br, bs, dtype):
    a = jnp.asarray(RNG.uniform(0.5, 0.999, size=(B, S, R)), dtype)
    u = jnp.asarray(RNG.normal(size=(B, S, R)), dtype)
    h0 = jnp.asarray(RNG.normal(size=(B, R)), jnp.float32)
    ref, _ = rglru_scan_ref(a, u, h0)
    pal = rglru_scan_pallas(a, u, h0, block_r=br, block_s=bs, interpret=True)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_rglru_assoc_matches_ref():
    a = jnp.asarray(RNG.uniform(0.5, 0.999, size=(2, 200, 32)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(2, 200, 32)), jnp.float32)
    r1, f1 = rglru_scan_ref(a, u)
    r2, f2 = rglru_scan_assoc(a, u)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5,
                               atol=1e-5)
