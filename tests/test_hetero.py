"""§4.2 heterogeneous-resource extension tests."""
import numpy as np
import pytest

from repro.core import TaskSet, aws_catalog, make_task
from repro.core.hetero import (family_tput_matrix, full_reconfiguration_hetero,
                               iteration_rp)


def test_iteration_rp_prefers_faster_family():
    cat = aws_catalog()
    # a3c: (0, 10, 8) on p3, (0, 4, 8) on c7i/r7i
    t = make_task(job_id=1, workload=7)
    ts = TaskSet([t])
    # same speed everywhere -> RP = cheapest fitting type
    rp_flat = iteration_rp(ts, cat, family_tput_matrix(ts, None))
    # 1.5x faster on c7i -> cost-per-iteration drops accordingly
    ft = {t.task_id: {"c7i": 1.5}}
    rp_fast = iteration_rp(ts, cat, family_tput_matrix(ts, ft))
    assert rp_fast[0] < rp_flat[0]
    assert rp_fast[0] == pytest.approx(rp_flat[0] / 1.5, rel=1e-6)


def test_hetero_pack_matches_flat_when_uniform():
    cat = aws_catalog()
    rng = np.random.default_rng(0)
    ts = TaskSet([make_task(job_id=i, workload=int(rng.integers(10)))
                  for i in range(20)])
    from repro.core import full_reconfiguration
    flat = full_reconfiguration(ts, cat, None, interference_aware=False,
                                multi_task_aware=False)
    het = full_reconfiguration_hetero(ts, cat, None, family_tput=None,
                                      interference_aware=False)
    assert het.total_hourly_cost(cat) == pytest.approx(
        flat.total_hourly_cost(cat), rel=1e-9)


def test_hetero_all_tasks_assigned_and_feasible():
    cat = aws_catalog()
    rng = np.random.default_rng(1)
    ts = TaskSet([make_task(job_id=i, workload=int(rng.integers(10)))
                  for i in range(25)])
    ft = {int(t): {"c7i": 1.3, "r7i": 1.2} for t in ts.ids.tolist()}
    cfg = full_reconfiguration_hetero(ts, cat, None, family_tput=ft,
                                      interference_aware=False)
    placed = sorted(t for _, tids in cfg.assignments for t in tids)
    assert placed == sorted(ts.ids.tolist())
    for k, tids in cfg.assignments:
        fam = cat.family_ids[k]
        used = np.zeros(3)
        for t in tids:
            used += ts.demand_by_family[ts.row(t), fam]
        assert np.all(used <= cat.capacities[k] + 1e-6)


def test_faster_family_attracts_cpu_tasks():
    """CPU tasks 2x faster on c7i should never land on r7i when both fit."""
    cat = aws_catalog()
    ts = TaskSet([make_task(job_id=i, workload=7) for i in range(6)])  # a3c
    ft = {int(t): {"c7i": 2.0} for t in ts.ids.tolist()}
    cfg = full_reconfiguration_hetero(ts, cat, None, family_tput=ft,
                                      interference_aware=False)
    fams = {cat.types[k].family for k, _ in cfg.assignments}
    assert fams == {"c7i"}
