"""Price-pressure autoscaling: horizon forecasts, admission control,
deadline-bounded deferral, and the autoscale-aware Eva scheduler.

Contract tests anchoring the design:
* forecasters: the static forecast is *exact*, the OU closed form
  converges to the long-run mean, the trace forecaster never peeks past
  ``now``, and the region/credit layers compose;
* ``autoscale=False`` (and ``autoscale=True`` on traces with no
  deferrable jobs) is *bit-identical* to PR 3 on the spot, multi-region
  and burstable demo catalogs — the deferral layer is strictly additive;
* the simulator's pending-job state machine: zero billing while pending,
  ``DEFER_DEADLINE`` signals fire an immediate extra round, withdrawals
  release admitted-but-unstarted placements, deadline misses are counted;
* eva-autoscale is strictly cheaper than always-admit eva-spot on the
  bundled OU market with zero deadline misses (the benchmark/CI
  invariant).
"""
import dataclasses

import numpy as np
import pytest

from repro.autoscale import (ADMIT_OVERHEAD_S, RUNTIME_MARGIN,
                             AdmissionController, OUForecaster,
                             PriceForecaster, RegionForecaster,
                             TraceForecaster, latest_start_s)
from repro.cluster import (SimConfig, Simulator, burstable_trace,
                           deferrable_trace, physical_trace)
from repro.cluster import traces as traces_mod
from repro.core import (ClusterConfig, EvaScheduler, PriceModel,
                        SchedulerBase, SchedulerView, TaskSet, aws_catalog,
                        burstable_demo_catalog, dispersed_demo_regions,
                        make_job, multi_region_catalog)
from repro.core.workloads import WORKLOADS


# -------------------------------------------------------------- forecasters
def test_static_forecast_exact():
    cat = aws_catalog()
    fore = PriceForecaster.for_catalog(cat)
    assert fore.kind == "static"
    # the static forecast is the identity: exact at every horizon
    assert fore.forecast_catalog(cat, 0.0, 3600.0) is cat
    assert fore.anchor_catalog(cat, 1e6) is cat
    np.testing.assert_array_equal(fore.mean_multipliers(len(cat), 0.0, 1e5),
                                  np.ones(len(cat)))
    # PriceModel.static() is also dispatched to the exact passthrough
    assert PriceForecaster.for_catalog(
        aws_catalog(PriceModel.static())).kind == "static"


def test_ou_forecast_converges_to_the_mean():
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.15, seed=3)
    cat = aws_catalog(price_model=pm)
    fore = PriceForecaster.for_catalog(cat)
    assert isinstance(fore, OUForecaster)
    now = 2 * 86400.0
    cur = pm.multipliers_at(len(cat), now)
    short = fore.mean_multipliers(len(cat), now, 300.0)
    long = fore.mean_multipliers(len(cat), now, 30 * 86400.0)
    # a short horizon tracks the current price, a long one the OU mean
    np.testing.assert_allclose(short, cur, rtol=0.05)
    np.testing.assert_allclose(long, pm.discount, rtol=0.02)
    # convergence is monotone toward the mean
    mid = fore.mean_multipliers(len(cat), now, 2 * 86400.0)
    assert np.all(np.abs(mid - pm.discount)
                  <= np.abs(short - pm.discount) + 1e-12)
    np.testing.assert_allclose(fore.anchor_multipliers(len(cat), now),
                               pm.discount)


def test_trace_forecast_never_peeks_past_now():
    times = np.arange(0.0, 10 * 3600.0, 600.0)
    past = 0.4 + 0.1 * (np.arange(len(times)) % 3)
    future_a, future_b = past.copy(), past.copy()
    cut = len(times) // 2
    future_a[cut:] = 5.0  # wildly different futures
    future_b[cut:] = 0.01
    now = float(times[cut]) - 1.0  # strictly before the divergence
    f_a = TraceForecaster(PriceModel.trace(times, future_a))
    f_b = TraceForecaster(PriceModel.trace(times, future_b))
    for h in (600.0, 3600.0, 86400.0):
        np.testing.assert_array_equal(f_a.mean_multipliers(4, now, h),
                                      f_b.mean_multipliers(4, now, h))
    np.testing.assert_array_equal(f_a.anchor_multipliers(4, now),
                                  f_b.anchor_multipliers(4, now))
    # the anchor is the empirical quantile of the observed history only
    np.testing.assert_allclose(f_a.anchor_multipliers(4, now),
                               np.quantile(past[:cut], 0.5))


def test_trace_forecast_blends_current_into_anchor():
    times = np.array([0.0, 600.0, 1200.0, 1800.0])
    mult = np.array([0.8, 0.8, 0.2, 0.8])
    fore = TraceForecaster(PriceModel.trace(times, mult))
    now = 1200.0  # current 0.2, median hold 600 s, anchor median 0.8
    short = fore.mean_multipliers(1, now, 60.0)[0]
    long = fore.mean_multipliers(1, now, 6 * 3600.0)[0]
    assert short == pytest.approx(0.2)
    assert long > 0.7  # dominated by the anchor
    assert fore.anchor_multipliers(1, now)[0] == pytest.approx(0.8)


def test_region_forecaster_blocks_and_composition():
    regs = dispersed_demo_regions(3)
    cat = multi_region_catalog(regs)
    fore = PriceForecaster.for_catalog(cat)
    assert isinstance(fore, RegionForecaster)
    n_base = len(cat) // 3
    now = 2 * 3600.0
    mult = fore.mean_multipliers(len(cat), now, 600.0)
    # each region block is forecast by its own sub-model: short-horizon
    # forecasts track each region's current (staggered) multiplier
    cur = cat.price_model.multipliers_at(len(cat), now)
    np.testing.assert_allclose(mult, cur, rtol=0.35)
    assert len({round(float(m), 6) for m in mult[::n_base]}) > 1
    snap = fore.forecast_catalog(cat, now, 600.0)
    np.testing.assert_allclose(snap.costs, snap.base_costs * mult)


def test_forecast_composes_with_credit_priced():
    pm = PriceModel.mean_reverting(discount=0.5, seed=9)
    cat = burstable_demo_catalog(price_model=pm)
    fore = PriceForecaster.for_catalog(cat)
    h = 8 * 3600.0
    snap = fore.forecast_catalog(cat, 3600.0, h)
    eff = snap.credit_priced(h)
    k = cat.index_of("t7i.2xlarge")
    speed = cat.avg_speed_over(h)[k]
    assert speed < 1.0  # launch credits do not cover an 8 h horizon
    assert eff.costs[k] == pytest.approx(snap.costs[k] / speed)


# ------------------------------------------------------ admission controller
def _one_job_view(cat, *, time, deadline, workload=8, remaining=1800.0,
                  deferrable=True, pending=True):
    job = make_job(job_id=1, workload=workload, arrival_time=0.0,
                   duration_s=remaining, n_tasks=1,
                   deadline_s=deadline, deferrable=deferrable)
    tid = job.tasks[0].task_id
    return SchedulerView(
        time=time, tasks=TaskSet(job.tasks), pending_ids={tid}, live=[],
        task_workload={tid: workload}, remaining_s={tid: remaining},
        deferrable={1} if deferrable else None,
        deadline_s={1: deadline}, pending={1} if pending else None)


def test_latest_start_bound_forces_admission():
    cat = aws_catalog()  # static: strike 0.9 would hold forever otherwise
    ctl = AdmissionController(cat, strike=0.9)
    dl = 4 * 3600.0
    early = _one_job_view(cat, time=0.0, deadline=dl)
    held, forced = ctl.review(early, d_hat_s=600.0)
    assert held == {1} and not forced
    late_t = latest_start_s(dl, 1800.0) + 1.0
    late = _one_job_view(cat, time=late_t, deadline=dl)
    held, forced = ctl.review(late, d_hat_s=600.0)
    assert not held and forced == {1}
    assert ctl.forced_admissions == 1
    # latest_start leaves margin x duration + overhead before the deadline
    assert late_t + RUNTIME_MARGIN * 1800.0 + ADMIT_OVERHEAD_S \
        == pytest.approx(dl + 1.0)


def test_strike_one_admits_on_static_market():
    cat = aws_catalog()
    ctl = AdmissionController(cat, strike=1.0)
    view = _one_job_view(cat, time=0.0, deadline=8 * 3600.0)
    held, forced = ctl.review(view, d_hat_s=600.0)
    assert not held and not forced  # forecast == anchor bar: admit now
    assert ctl.admissions == 1 and ctl.forced_admissions == 0


def test_re_deferral_needs_hysteresis():
    times = np.array([0.0, 600.0, 1200.0, 1800.0])
    mult = np.array([0.3, 0.3, 3.0, 3.0])  # cheap history, then a spike
    cat = aws_catalog(price_model=PriceModel.trace(times, mult))
    ctl = AdmissionController(cat, strike=1.0, hold_hysteresis=0.25)
    cheap = _one_job_view(cat, time=0.0, deadline=10 * 3600.0)
    held, _ = ctl.review(cheap, d_hat_s=600.0)
    assert not held and ctl.admissions == 1
    # spike: still pending, forecast way above bar x (1 + hysteresis)
    spike = _one_job_view(cat, time=1300.0, deadline=10 * 3600.0)
    held, _ = ctl.review(spike, d_hat_s=600.0)
    assert held == {1} and ctl.re_deferrals == 1
    # a started job (not in view.pending) is never touched
    started = _one_job_view(cat, time=1300.0, deadline=10 * 3600.0,
                            pending=False)
    held, _ = ctl.review(started, d_hat_s=600.0)
    assert not held


def test_region_pin_threads_mask_into_admission():
    """A region-pinned autoscale scheduler must strike-test against the
    pinned region's types only — another region's cheap window is not a
    market the packer can use."""
    cat = multi_region_catalog(dispersed_demo_regions(3))
    pinned = EvaScheduler(cat, multi_region=True, region="region-0",
                          autoscale=True)
    np.testing.assert_array_equal(pinned.admission.type_mask,
                                  cat.region_type_mask(0))
    unpinned = EvaScheduler(cat, multi_region=True, autoscale=True)
    assert unpinned.admission.type_mask is None


def test_custom_margin_honoured_by_defer_deadline_backstop():
    """The simulator's DEFER_DEADLINE backstop reads the live controller's
    margin/overhead, so a customized (looser) bound really is admitted
    later than the default one would be."""
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.02, seed=7)
    cat = aws_catalog(price_model=pm)
    dur = 0.4 * 3600.0
    dl = RUNTIME_MARGIN * dur + ADMIT_OVERHEAD_S + 3 * 3600.0
    job = make_job(job_id=1, workload=8, arrival_time=0.0, duration_s=dur,
                   n_tasks=1, deadline_s=dl, deferrable=True)
    ctl = AdmissionController(cat, strike=1e-6, margin=1.2, overhead_s=900.0)
    sched = EvaScheduler(cat, spot_aware=True, autoscale=True, admission=ctl)
    sim = Simulator(cat, [job], sched, SimConfig(seed=5))
    m = sim.run()
    custom_ls = latest_start_s(dl, dur, margin=1.2, overhead_s=900.0)
    assert custom_ls > latest_start_s(dl, dur)  # looser bound: starts later
    assert sim.jobs[1].admitted_t == pytest.approx(custom_ls, abs=1.0)
    assert m.deadline_misses == 0


# ------------------------------------------------------------- the simulator
def test_deferral_state_machine_zero_billing_while_pending():
    """A deferrable job on a market that never dips below its strike stays
    PENDING (zero billing) until its latest-start bound admits it; the
    deadline still holds and the wait is accounted."""
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.02, seed=7)
    cat = aws_catalog(price_model=pm)
    dur = 0.4 * 3600.0
    dl = RUNTIME_MARGIN * dur + ADMIT_OVERHEAD_S + 4 * 3600.0
    job = make_job(job_id=1, workload=8, arrival_time=0.0, duration_s=dur,
                   n_tasks=1, deadline_s=dl, deferrable=True)
    sched = EvaScheduler(cat, spot_aware=True, autoscale=True, strike=1e-6)
    sim = Simulator(cat, [job], sched, SimConfig(seed=5))
    m = sim.run()
    js = sim.jobs[1]
    assert job.completion_time is not None and m.deadline_misses == 0
    # held ~4 h, admitted only by the deadline bound
    assert js.admitted_t == pytest.approx(latest_start_s(dl, dur), abs=301.0)
    assert sched.admission.forced_admissions == 1
    assert sched.deadline_signals >= 1  # DEFER_DEADLINE signal arrived
    assert m.deferred_jobs == 1
    assert m.deferred_wait_s == pytest.approx(js.admitted_t)
    # zero billing while pending: exactly one instance, billed only from
    # its (post-admission) request
    assert m.instances_launched == 1
    inst = sim.instances[0]
    assert inst.request_t >= js.admitted_t
    summary = m.summary()
    assert summary["deadline_misses"] == 0 and summary["deferred_jobs"] == 1


def test_defer_deadline_fires_extra_round_off_grid():
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.02, seed=7)
    cat = aws_catalog(price_model=pm)
    dur = 0.4 * 3600.0
    dl = RUNTIME_MARGIN * dur + ADMIT_OVERHEAD_S + 2 * 3600.0 + 77.0
    job = make_job(job_id=1, workload=8, arrival_time=0.0, duration_s=dur,
                   n_tasks=1, deadline_s=dl, deferrable=True)
    times = []

    class _Probe(EvaScheduler):
        def schedule(self, view):
            times.append(view.time)
            return super().schedule(view)

    sched = _Probe(cat, spot_aware=True, autoscale=True, strike=1e-6)
    Simulator(cat, [job], sched, SimConfig(seed=5)).run()
    ls = latest_start_s(dl, dur)
    assert ls % 300.0 != 0.0 and ls in times, \
        "no extra round fired at the latest-start instant"


class _AssignThenDrop(SchedulerBase):
    """Assigns the task in round 1, omits it for ``drop_rounds`` rounds
    (re-deferral), then assigns again — exercising the executor's
    withdrawal of a reserved-but-unstarted placement."""

    name = "assign-then-drop"

    def __init__(self, catalog, k, tid, drop_rounds=2):
        super().__init__(catalog)
        self.k, self.tid = k, tid
        self.drop = range(2, 2 + drop_rounds)
        self.round = 0

    def schedule(self, view):
        self.round += 1
        if self.round in self.drop or self.tid not in set(
                view.tasks.ids.tolist()):
            return ClusterConfig([])
        return ClusterConfig([(self.k, (self.tid,))])


def test_withdrawal_releases_unstarted_placement():
    cat = aws_catalog()
    job = make_job(job_id=1, workload=8, arrival_time=0.0,
                   duration_s=1200.0, n_tasks=1,
                   deadline_s=10 * 3600.0, deferrable=True)
    tid = job.tasks[0].task_id
    k = cat.index_of("c7i.2xlarge")
    # 120 s rounds: round 2 lands inside the ~230 s acquisition+setup
    # window, so the task is still WAITING when the config omits it
    sched = _AssignThenDrop(cat, k, tid)
    sim = Simulator(cat, [job], sched, SimConfig(seed=1,
                                                 round_interval_s=120.0))
    m = sim.run()
    assert m.withdrawals >= 1
    assert job.completion_time is not None and m.deadline_misses == 0
    # the withdrawn placement's instance was released and a fresh one
    # carried the job
    assert m.instances_launched >= 2


def test_deferrable_trace_shape():
    jobs = deferrable_trace(n_jobs=40, seed=13)
    assert all(j.deferrable and j.deadline_s is not None for j in jobs)
    slack = [j.deadline_s - j.arrival_time - RUNTIME_MARGIN * j.duration_s
             - ADMIT_OVERHEAD_S for j in jobs]
    assert min(slack) >= 0.0  # every deadline is meetable at latest start
    assert min(slack) <= 0.5 * 3600.0  # tight population present
    assert max(slack) >= 3 * 3600.0  # loose population present
    cpu = deferrable_trace(n_jobs=10, seed=13, cpu_only=True)
    assert all(WORKLOADS[j.workload].demands["p3"][0] == 0 for j in cpu)


def test_workload_profile_defaults_stamped(monkeypatch):
    profiles = list(WORKLOADS)
    profiles[8] = dataclasses.replace(profiles[8], deferrable=True,
                                      deadline_s=7200.0)
    monkeypatch.setattr(traces_mod, "WORKLOADS", tuple(profiles))
    rng = np.random.default_rng(0)
    job = traces_mod._table7_job(rng, 8, arrival=100.0, duration=600.0)
    assert job.deferrable and job.deadline_s == pytest.approx(7300.0)
    plain = traces_mod._table7_job(rng, 3, arrival=100.0, duration=600.0)
    assert not plain.deferrable and plain.deadline_s is None


# ------------------------------------------------- strictly additive (PR 3)
def _bit_identical(catalog_fn, trace_fn, sched_kw, cfg_kw):
    m = []
    for autoscale in (True, False):
        cat = catalog_fn()
        kw = dict(sched_kw)
        if autoscale:
            kw["autoscale"] = True
        sim = Simulator(cat, trace_fn(), EvaScheduler(cat, **kw),
                        SimConfig(**cfg_kw))
        m.append(sim.run())
    assert m[0].summary() == m[1].summary()
    assert m[0].total_cost == m[1].total_cost  # bit-for-bit
    assert m[0].migrations == m[1].migrations
    assert m[0].instances_launched == m[1].instances_launched
    assert not m[0].has_deadlines and "deadline_misses" not in m[0].summary()


def test_autoscale_bit_identical_on_spot_catalog():
    """Acceptance: with no deferrable jobs in the trace, autoscale=True
    reproduces the autoscale=False (PR 3) spot run metric for metric."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    _bit_identical(
        lambda: aws_catalog(price_model=pm),
        lambda: physical_trace(n_jobs=10, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(spot_aware=True),
        dict(seed=5, preemption_hazard_per_hour=0.5))


def test_autoscale_bit_identical_on_multiregion_catalog():
    _bit_identical(
        lambda: multi_region_catalog(dispersed_demo_regions(3)),
        lambda: physical_trace(n_jobs=8, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(multi_region=True),
        dict(seed=5, preemption_hazard_per_hour=0.3))


def test_autoscale_bit_identical_on_burstable_catalog():
    _bit_identical(
        burstable_demo_catalog,
        lambda: burstable_trace(n_jobs=10, seed=11),
        dict(credit_aware=True),
        dict(seed=5))


# ------------------------------------------------------------ the acceptance
def test_autoscale_beats_always_admit_acceptance():
    """Acceptance (benchmark/CI invariant): on the bundled OU market,
    admission-controlled Eva is strictly cheaper than always-admit
    eva-spot with zero deadline misses."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    results = {}
    for name, kw in (("autoscale", dict(spot_aware=True, autoscale=True,
                                        strike=0.9)),
                     ("always-admit", dict(spot_aware=True))):
        cat = aws_catalog(price_model=pm)
        jobs = deferrable_trace(n_jobs=24, seed=13)
        m = Simulator(cat, jobs, EvaScheduler(cat, **kw),
                      SimConfig(seed=5, preemption_hazard_per_hour=0.3)).run()
        assert all(j.completion_time is not None for j in jobs)
        results[name] = m
    assert results["autoscale"].deadline_misses == 0
    assert results["autoscale"].total_cost \
        < results["always-admit"].total_cost
