"""Unit tests reproducing the paper's worked examples verbatim.

* Table 3 + §4.2 walkthrough of Algorithm 1 (τ1,τ2,τ4 on it1; τ3 on it3;
  hourly cost $12.8 vs $16.2 no-packing).
* §4.3 TNRP examples (12·0.8 + 3·0.9 = 12.3 > 12; 12·0.7 + 3·0.8 = 10.8 < 12).
* §4.4 multi-task TNRP reduction to tput·RP for single-task jobs.
* §4.5 D̂ formula.
"""
import numpy as np
import pytest

from repro.core import (ClusterConfig, EvaScheduler, TaskSet, ThroughputTable,
                        evaluate_assignments, full_reconfiguration,
                        mean_time_to_full_reconfig, reservation_prices,
                        table3_catalog, tnrp)
from repro.core.cluster_types import Task


def table3_tasks():
    # τ1..τ4 from Table 3(b); single-task jobs; workload ids 0..3.
    specs = [
        (2, 8, 24),
        (1, 4, 10),
        (0, 6, 20),
        (0, 4, 12),
    ]
    tasks = [Task(task_id=i, job_id=i, workload=i,
                  demands={"p3": tuple(map(float, s))})
             for i, s in enumerate(specs)]
    return TaskSet(tasks)


def test_reservation_prices_match_table3():
    tasks = table3_tasks()
    rp = reservation_prices(tasks, table3_catalog())
    assert rp.tolist() == [12.0, 3.0, 0.8, 0.4]


def test_full_reconfiguration_walkthrough():
    """§4.2 example: τ1, τ2, τ4 on it1 ($12+3+0.4 = 15.4 ≥ 12); τ3 alone on
    it3 (0.8 ≥ 0.8).  Total $12.8 < $16.2 (separate instances)."""
    tasks = table3_tasks()
    cat = table3_catalog()
    cfg = full_reconfiguration(tasks, cat, table=None,
                               interference_aware=False,
                               multi_task_aware=False)
    got = sorted((cat.types[k].name, tuple(sorted(tids)))
                 for k, tids in cfg.assignments)
    assert got == [("it1", (0, 1, 3)), ("it3", (2,))]
    assert cfg.total_hourly_cost(cat) == pytest.approx(12.8)
    rp = reservation_prices(tasks, cat)
    assert rp.sum() == pytest.approx(16.2)


@pytest.mark.parametrize("engine", ["python", "numpy"])
def test_walkthrough_all_engines(engine):
    tasks = table3_tasks()
    cat = table3_catalog()
    cfg = full_reconfiguration(tasks, cat, table=None,
                               interference_aware=False,
                               multi_task_aware=False, engine=engine)
    assert cfg.total_hourly_cost(cat) == pytest.approx(12.8)


def test_tnrp_example_cost_efficient():
    # §4.3: tputs (0.8, 0.9) -> 12.3 >= 12 cost-efficient;
    #       tputs (0.7, 0.8) -> 10.8 < 12 not cost-efficient.
    rp = np.array([12.0, 3.0])
    assert tnrp(rp, np.array([0.8, 0.9])).sum() == pytest.approx(12.3)
    assert tnrp(rp, np.array([0.7, 0.8])).sum() == pytest.approx(10.8)


def test_multitask_tnrp_reduces_to_single():
    # For a single-task job, RP - (1-tput)·RP == tput·RP.
    rp = np.array([5.0])
    t = np.array([0.83])
    assert tnrp(rp, t, job_rp=rp) == pytest.approx(t * rp)


def test_multitask_tnrp_penalty():
    # 4-task job, each RP=3; one task at tput 0.9 drags the whole job:
    # TNRP = 3 - (1-0.9)*12 = 1.8 (vs single-task view 2.7).
    rp = np.array([3.0])
    job_rp = np.array([12.0])
    assert tnrp(rp, np.array([0.9]), job_rp) == pytest.approx(1.8)


def test_interference_blocks_inefficient_packing():
    """With pairwise tput 0.7/0.8 between τ1 and τ2, packing both on it1 is
    not cost-efficient (10.8 < 12) -> Algorithm 1 must keep them apart."""
    tasks = table3_tasks().subset([0, 1])
    cat = table3_catalog()
    table = ThroughputTable(num_workloads=4, default=1.0)
    table.record(0, (1,), 0.7)  # τ1 with τ2 -> 0.7
    table.record(1, (0,), 0.8)  # τ2 with τ1 -> 0.8
    cfg = full_reconfiguration(tasks, cat, table, interference_aware=True,
                               multi_task_aware=False)
    names = sorted(cat.types[k].name for k, _ in cfg.assignments)
    assert names == ["it1", "it2"]  # solo on their RP types


def test_interference_allows_efficient_packing():
    tasks = table3_tasks().subset([0, 1])
    cat = table3_catalog()
    table = ThroughputTable(num_workloads=4, default=1.0)
    table.record(0, (1,), 0.8)
    table.record(1, (0,), 0.9)  # 12*0.8 + 3*0.9 = 12.3 >= 12
    cfg = full_reconfiguration(tasks, cat, table, interference_aware=True,
                               multi_task_aware=False)
    assert len(cfg.assignments) == 1
    k, tids = cfg.assignments[0]
    assert cat.types[k].name == "it1" and sorted(tids) == [0, 1]


def test_d_hat_formula():
    lam, p = 1.0 / 600.0, 0.25
    d = mean_time_to_full_reconfig(lam, p)
    assert d == pytest.approx(-1.0 / (lam * np.log(1 - p)))
    # monotone: higher p -> sooner next full reconfig
    assert mean_time_to_full_reconfig(lam, 0.5) < d


def test_evaluate_assignments_uses_exact_entries():
    tasks = table3_tasks().subset([0, 1])
    cat = table3_catalog()
    table = ThroughputTable(num_workloads=4, default=0.95)
    table.record(0, (1,), 0.8)
    table.record(1, (0,), 0.9)
    k1 = cat.index_of("it1")
    tnrps, costs = evaluate_assignments([(k1, (0, 1))], tasks, cat, table,
                                        multi_task_aware=False)
    assert tnrps[0] == pytest.approx(12 * 0.8 + 3 * 0.9)
    assert costs[0] == pytest.approx(12.0)
