"""Spot-market layer: price models, dynamic catalogs, preemption path.

Two contract tests anchor the design:
* the static price model is *strictly additive* — scheduler decisions and
  simulator metrics are bit-for-bit identical to a catalog with no model;
* a spot revocation never costs a job more than one checkpoint period of
  progress, no matter which scheduler is driving.
"""
import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator, physical_trace
from repro.cluster.simulator import PRICE_UPDATE
from repro.core import (EvaScheduler, NoPackingScheduler, PriceModel, TaskSet,
                        aws_catalog, full_reconfiguration, make_job, make_task,
                        reservation_prices)


def _metrics(sched_name, price_model, spot_aware=False, **cfg):
    cat = aws_catalog(price_model=price_model)
    jobs = physical_trace(n_jobs=12, seed=11, duration_range_h=(0.3, 0.6))
    if sched_name == "eva":
        sched = EvaScheduler(cat, spot_aware=spot_aware)
    else:
        sched = NoPackingScheduler(cat)
    m = Simulator(cat, jobs, sched, SimConfig(seed=5, **cfg)).run()
    return m, jobs


# --------------------------------------------------------------- price models
def test_static_model_is_identity():
    cat = aws_catalog()
    assert cat.at(12345.0) is cat
    cat_s = aws_catalog(price_model=PriceModel.static())
    assert cat_s.at(12345.0) is cat_s
    np.testing.assert_array_equal(
        PriceModel.static().prices_at(cat.costs, 7200.0), cat.costs)


def test_mean_reverting_bounds_and_determinism():
    pm = PriceModel.mean_reverting(discount=0.35, seed=3)
    base = aws_catalog().costs
    for t in (0.0, 3600.0, 86400.0, 10 * 86400.0):
        p1, p2 = pm.prices_at(base, t), pm.prices_at(base, t)
        np.testing.assert_array_equal(p1, p2)  # pure function of time
        assert np.all(p1 <= base + 1e-12)      # capped at on-demand
        assert np.all(p1 >= base * 0.035 - 1e-12)
    # prices actually move
    assert not np.array_equal(pm.prices_at(base, 0.0),
                              pm.prices_at(base, 86400.0))
    # same seed -> same path; different seed -> different path
    pm2 = PriceModel.mean_reverting(discount=0.35, seed=3)
    np.testing.assert_array_equal(pm.prices_at(base, 5e4),
                                  pm2.prices_at(base, 5e4))
    pm3 = PriceModel.mean_reverting(discount=0.35, seed=4)
    assert not np.array_equal(pm.prices_at(base, 5e4),
                              pm3.prices_at(base, 5e4))


def test_trace_model_replay():
    pm = PriceModel.trace([0.0, 100.0, 200.0], [0.5, 0.25, 1.0])
    base = np.array([2.0, 4.0])
    np.testing.assert_allclose(pm.prices_at(base, 0.0), [1.0, 2.0])
    np.testing.assert_allclose(pm.prices_at(base, 99.9), [1.0, 2.0])
    np.testing.assert_allclose(pm.prices_at(base, 100.0), [0.5, 1.0])
    np.testing.assert_allclose(pm.prices_at(base, 999.0), [2.0, 4.0])
    # pressure is multiplier over the long-run mean
    np.testing.assert_allclose(
        pm.pressure_at(2, 100.0), 0.25 / np.mean([0.5, 0.25, 1.0]))


def test_per_type_trace_pressure_uses_per_type_mean():
    """A type sitting at its own long-run mean has pressure 1 even when the
    market-wide mean differs (unbiased preemption hazard)."""
    pm = PriceModel.trace([0.0, 100.0],
                          [[0.2, 0.8], [0.2, 0.8]])  # flat per-type series
    np.testing.assert_allclose(pm.pressure_at(2, 50.0), [1.0, 1.0])
    np.testing.assert_allclose(pm.prices_at(np.array([1.0, 1.0]), 50.0),
                               [0.2, 0.8])


def test_snapshot_reorders_packing_order():
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.2, seed=7)
    cat = aws_catalog(price_model=pm)
    snap = cat.at(6 * 3600.0)
    assert snap is not cat
    np.testing.assert_array_equal(snap.order_desc,
                                  np.argsort(-snap.costs, kind="stable"))
    np.testing.assert_array_equal(snap.capacities, cat.capacities)
    # snapshots re-derive from base prices, not compounding multipliers
    snap2 = snap.at(6 * 3600.0)
    np.testing.assert_array_equal(snap2.costs, snap.costs)


def test_time_s_param_matches_catalog_snapshot():
    """The `time_s` view API and an explicit `catalog.at` snapshot must be
    interchangeable — two spot-pricing mechanisms may never diverge."""
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.2, seed=7)
    cat = aws_catalog(price_model=pm)
    t = 9 * 3600.0
    tasks = TaskSet([make_task(job_id=i, workload=w)
                     for i, w in enumerate((0, 3, 4, 6, 9))])
    np.testing.assert_array_equal(reservation_prices(tasks, cat, time_s=t),
                                  reservation_prices(tasks, cat.at(t)))
    a = full_reconfiguration(tasks, cat, None, interference_aware=False,
                             multi_task_aware=False, time_s=t)
    b = full_reconfiguration(tasks, cat.at(t), None, interference_aware=False,
                             multi_task_aware=False)
    assert a.assignments == b.assignments


# ------------------------------------------------------- strictly additive
def test_static_price_model_bit_identical_to_seed():
    """Acceptance: with PriceModel.static, total_cost / JCT / migrations are
    *exactly* the seed simulator's for the same seeds."""
    for name in ("eva", "no-packing"):
        m_none, _ = _metrics(name, None)
        m_static, _ = _metrics(name, PriceModel.static())
        assert m_static.total_cost == m_none.total_cost  # bit-for-bit
        assert m_static.jct_sum == m_none.jct_sum
        assert m_static.migrations == m_none.migrations
        assert m_static.instances_launched == m_none.instances_launched
        assert m_static.summary() == m_none.summary()
        assert m_static.preemptions == 0


def test_spot_aware_flag_is_noop_on_static_catalog():
    m_plain, _ = _metrics("eva", None, spot_aware=False)
    m_aware, _ = _metrics("eva", PriceModel.static(), spot_aware=True)
    assert m_aware.summary() == m_plain.summary()


# ------------------------------------------------------------- preemptions
def _single_task_jobs(n=10, duration_s=2400.0):
    # workloads 2..9 are single-task (resnet18 variants are multi-task), so
    # per-instance progress loss maps 1:1 onto per-job loss
    return [make_job(job_id=i + 1, workload=2 + (i % 8),
                     arrival_time=600.0 * (i + 1), duration_s=duration_s,
                     n_tasks=1) for i in range(n)]


def test_revocation_loses_at_most_one_checkpoint_period():
    """Acceptance: a revocation notice never loses more than
    checkpoint_period_s of progress (rate <= 1 iter/s)."""
    ckpt = 300.0
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    jobs = _single_task_jobs()
    # a NON-spot-aware scheduler rides out notices, so reclaims really fire
    sched = EvaScheduler(cat)
    sim = Simulator(cat, jobs, sched,
                    SimConfig(seed=3, preemption_hazard_per_hour=8.0,
                              checkpoint_period_s=ckpt,
                              preemption_notice_s=60.0))
    drops = []
    orig = sim._on_preempt_fire

    def recording(iid):
        before = {j: js.iters_done for j, js in sim.jobs.items()}
        orig(iid)
        drops.extend(before[j] - js.iters_done for j, js in sim.jobs.items()
                     if before[j] > js.iters_done)

    sim._on_preempt_fire = recording
    m = sim.run()
    assert m.preemptions > 0 and drops, "hazard 8/h must fire at least once"
    assert max(drops) <= ckpt + 1e-6
    assert all(j.completion_time is not None for j in jobs)


def test_spot_aware_eva_evacuates_on_notice():
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    jobs = _single_task_jobs()
    sched = EvaScheduler(cat, spot_aware=True)
    m = Simulator(cat, jobs, sched,
                  SimConfig(seed=3, preemption_hazard_per_hour=4.0)).run()
    assert m.preemption_notices > 0
    assert sched.forced_partials > 0  # notices forced partial reconfigs
    assert all(j.completion_time is not None for j in jobs)


def test_trace_breakpoints_get_billed_exactly():
    """Trace-model price changes are billed at their own breakpoints, not
    lagged to the periodic update grid."""
    pm = PriceModel.trace([0.0, 450.0, 33333.0], [1.0, 0.2, 0.5])
    cat = aws_catalog(price_model=pm)
    sim = Simulator(cat, _single_task_jobs(2), EvaScheduler(cat, spot_aware=True),
                    SimConfig(seed=1))
    times = {t for t, kind, _, _ in sim._heap if kind == PRICE_UPDATE}
    assert 450.0 in times and 33333.0 in times


def test_stale_price_events_do_not_inflate_end_time():
    """One-shot breakpoint events beyond the last job completion are purged,
    so end_time reflects the workload, not the price trace length."""
    week = 7 * 86400.0
    pm = PriceModel.trace(np.arange(0.0, week, 3600.0),
                          np.full(int(week // 3600), 0.4))
    cat = aws_catalog(price_model=pm)
    jobs = _single_task_jobs(3, duration_s=1200.0)
    m = Simulator(cat, jobs, EvaScheduler(cat, spot_aware=True),
                  SimConfig(seed=1)).run()
    assert all(j.completion_time is not None for j in jobs)
    assert m.end_time < 6 * 3600.0  # jobs end ~1h in; nowhere near the week


def test_evacuated_instance_terminates_before_reclaim():
    """A revoked instance whose tasks were all evacuated is terminated (and
    stops billing) during the notice window, so it does not count as a
    preemption; reclaims that actually hit tasks still do."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    jobs = _single_task_jobs()
    sched = EvaScheduler(cat, spot_aware=True)
    sim = Simulator(cat, jobs, sched,
                    SimConfig(seed=3, preemption_hazard_per_hour=4.0,
                              preemption_notice_s=240.0))
    m = sim.run()
    assert m.preemption_notices > 0
    # fast-checkpoint single-task workloads + a 4-min notice: at least one
    # instance must be fully evacuated and released early
    assert m.preemptions < m.preemption_notices
    assert all(j.completion_time is not None for j in jobs)


def test_spot_eva_cheaper_than_ondemand_eva():
    """Acceptance (benchmark invariant): Eva on the spot market beats
    on-demand-only Eva on total cost for the same trace."""
    m_spot, jobs_s = _metrics("eva", PriceModel.mean_reverting(seed=7),
                              spot_aware=True,
                              preemption_hazard_per_hour=0.3)
    m_od, jobs_o = _metrics("eva", None)
    assert all(j.completion_time is not None for j in jobs_s)
    assert m_spot.total_cost < m_od.total_cost
