"""Policy-layer stack: composition rules, flag-API bit-identity, and the
pressure bus.

Contract tests anchoring the refactor:
* the legacy boolean-flag API emits ``DeprecationWarning`` but builds a
  stack whose *decisions* are bit-identical to the explicit
  ``PolicyStack`` on every bundled demo catalog (spot, multi-region,
  burstable, deferrable);
* catalog-snapshot transforms keep the documented order — ``at`` (and any
  forecast) re-price from base costs and must precede ``credit_priced``;
  the stack validates this at construction and its pipeline equals the
  hand-composed chain;
* keep-test bonuses sum, so keep-bonus layers commute;
* the ``PressureBus`` delivers each signal to each subscriber exactly
  once, and coincident pressure signals fire exactly one immediate extra
  round (no double forced-partial).
"""
import numpy as np
import pytest

from repro.cluster import (SimConfig, Simulator, burstable_trace,
                           deferrable_trace, physical_trace)
from repro.core import (EvaScheduler, PriceModel, aws_catalog,
                        burstable_demo_catalog, dispersed_demo_regions,
                        make_job, multi_region_catalog)
from repro.core.plan import LiveInstance
from repro.core.scheduler import SchedulerView
from repro.core.cluster_types import TaskSet
from repro.policies import (AutoscaleLayer, CreditLayer, MultiRegionLayer,
                            PolicyStack, PressureBus, PressureSignal,
                            RegionPinLayer, SpotLayer, stack_from_flags)


# ------------------------------------------------------------- construction
def test_flag_api_emits_deprecation_warning():
    cat = aws_catalog()
    with pytest.warns(DeprecationWarning, match="policy stack"):
        sched = EvaScheduler(cat, spot_aware=True)
    assert sched.stack.has("spot") and sched.spot_aware


def test_flags_and_policies_are_mutually_exclusive():
    cat = aws_catalog()
    with pytest.raises(ValueError, match="not both"):
        EvaScheduler(cat, spot_aware=True, policies=[SpotLayer()])
    # knob-style legacy kwargs are rejected too, not silently ignored
    with pytest.raises(ValueError, match="not both"):
        EvaScheduler(cat, policies=[SpotLayer()], strike=0.7)
    with pytest.raises(ValueError, match="not both"):
        EvaScheduler(cat, policies=[SpotLayer()], region="region-0")


def test_two_admission_layers_stack():
    """An autoscale layer ahead of a stability layer strips its held jobs'
    tasks from the view; the second review must judge only the jobs still
    present instead of crashing on the stripped ones."""
    from repro.policies import StabilityLayer
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    sched = EvaScheduler(cat, policies=[
        SpotLayer(), AutoscaleLayer(strike=1e-6), StabilityLayer()])
    jobs = deferrable_trace(n_jobs=6, seed=13)
    m = Simulator(cat, jobs, sched, SimConfig(seed=5)).run()
    assert all(j.completion_time is not None for j in jobs)
    assert m.deadline_misses == 0  # the deadline backstop still holds


def test_explicit_stack_emits_no_warning(recwarn):
    sched = EvaScheduler(aws_catalog(), policies=[SpotLayer()])
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]
    assert sched.stack.describe() == "spot"


def test_stack_introspection():
    cat = multi_region_catalog(dispersed_demo_regions(3))
    sched = EvaScheduler(cat, policies=[SpotLayer(), MultiRegionLayer(),
                                        CreditLayer(),
                                        AutoscaleLayer(strike=0.9)])
    stack = sched.stack
    assert [la.name for la in stack] == ["spot", "multi-region", "credit",
                                         "autoscale"]
    assert stack.get("credit") is stack.get(CreditLayer)
    assert stack.get("nope") is None and not stack.has("nope")
    # legacy attribute surface still answers from the stack
    assert sched.spot_aware and sched.multi_region and sched.credit_aware
    assert sched.autoscale and sched.admission is not None
    assert sched.needs_runtime_estimates  # admission layers need D̂_j


def test_region_pin_layer_masks_and_asserts():
    cat = multi_region_catalog(dispersed_demo_regions(3))
    sched = EvaScheduler(cat, policies=[RegionPinLayer("region-1")])
    np.testing.assert_array_equal(sched.stack.mask, cat.region_type_mask(1))
    with pytest.raises(AssertionError):
        EvaScheduler(aws_catalog(), policies=[RegionPinLayer("region-1")])


# ------------------------------------------------------- composition order
def test_snapshot_before_planning_is_enforced():
    """``credit_priced`` derives effective prices from the *snapshot*;
    re-pricing from base costs afterwards would silently discard the
    credit adjustment — so the stack refuses the reversed order."""
    PolicyStack([SpotLayer(), CreditLayer()])  # documented order: fine
    with pytest.raises(ValueError, match="snapshot"):
        PolicyStack([CreditLayer(), SpotLayer()])


def test_catalog_pipeline_equals_manual_chain():
    pm = PriceModel.mean_reverting(discount=0.5, seed=9)
    cat = burstable_demo_catalog(price_model=pm)
    sched = EvaScheduler(cat, policies=[SpotLayer(), CreditLayer()])
    t, d_hat = 7200.0, 4 * 3600.0
    view = SchedulerView(time=t, tasks=TaskSet([]), pending_ids=set(),
                         live=[], task_workload={})
    raw, plan = sched.stack.plan(cat, view, d_hat)
    manual_raw = cat.at(t)
    np.testing.assert_array_equal(raw.costs, manual_raw.costs)
    np.testing.assert_array_equal(plan.costs,
                                  manual_raw.credit_priced(d_hat).costs)


def test_catalog_transforms_commute_where_documented():
    """Both transforms are per-type scalings, so on a *fresh* catalog the
    documented chain commutes: at→credit_priced == credit_priced→at.  The
    reason the stack still enforces snapshot-before-planning: once a
    snapshot pinned ``base_costs``, any later snapshot transform re-prices
    from base and silently discards the planning adjustment."""
    pm = PriceModel.mean_reverting(discount=0.5, seed=9)
    cat = burstable_demo_catalog(price_model=pm)
    t, h = 7200.0, 4 * 3600.0
    documented = cat.at(t).credit_priced(h)
    np.testing.assert_allclose(documented.costs,
                               cat.credit_priced(h).at(t).costs)
    # a snapshot applied *after* the documented chain reverts the credit
    # adjustment — exactly the misordering PolicyStack rejects
    clobbered = documented.at(t)
    np.testing.assert_allclose(clobbered.costs, cat.at(t).costs)
    assert not np.allclose(clobbered.costs, documented.costs)


def test_keep_bonus_layers_commute():
    """Keep-test slack sums across layers, so keep-bonus layers may appear
    in any order: region + credit bonuses agree either way."""
    base = list(burstable_demo_catalog().types)
    from repro.core import Region
    cat = multi_region_catalog((Region("a"), Region("b", cost_scale=0.5)),
                               base_types=base)
    job = make_job(job_id=1, workload=8, arrival_time=0.0, duration_s=3600.0,
                   n_tasks=1)
    tid = job.tasks[0].task_id
    k = cat.index_of("a/t7i.2xlarge")
    view = SchedulerView(
        time=0.0, tasks=TaskSet(job.tasks), pending_ids=set(),
        live=[LiveInstance(0, k, (tid,))], task_workload={tid: 8},
        instance_credits={0: 0.1}, throttled=None)
    vals = []
    for layers in ([MultiRegionLayer(), CreditLayer()],
                   [CreditLayer(), MultiRegionLayer()]):
        sched = EvaScheduler(cat, policies=layers)
        raw, plan = sched.stack.plan(cat, view, 3600.0)
        fn = sched.stack.keep_bonus(raw, plan, view)
        vals.append(fn(k, (tid,)))
    assert vals[0] == pytest.approx(vals[1])
    assert vals[0] != 0.0  # both parts contribute


# --------------------------------------------------- flag/stack bit-identity
class _Probe(EvaScheduler):
    """Records every round's decision (the returned config)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.trace = []

    def schedule(self, view):
        cfg = super().schedule(view)
        self.trace.append((view.time, tuple(cfg.assignments)))
        return cfg


def _decisions(catalog_fn, trace_fn, cfg_kw, flag_kw, stack_fn):
    """Run the flag API and the explicit stack side by side; return both
    (decision trace, metrics summary) pairs.  Task/job ids come from
    global trace counters, so decisions are normalized to id *ranks*
    before comparison."""
    out = []
    for use_stack in (False, True):
        cat = catalog_fn()
        jobs = trace_fn()
        rank = {t.task_id: i for i, t in enumerate(
            sorted((t for j in jobs for t in j.tasks),
                   key=lambda t: t.task_id))}
        if use_stack:
            sched = _Probe(cat, policies=stack_fn())
        else:
            with pytest.warns(DeprecationWarning):
                sched = _Probe(cat, **flag_kw)
        m = Simulator(cat, jobs, sched, SimConfig(**cfg_kw)).run()
        trace = [(t, tuple((k, tuple(rank[tid] for tid in tids))
                           for k, tids in assignments))
                 for t, assignments in sched.trace]
        out.append((trace, m.summary(), m.total_cost))
    return out


def _assert_bit_identical(runs):
    (tr_a, sum_a, cost_a), (tr_b, sum_b, cost_b) = runs
    assert tr_a == tr_b  # decision-level: every round's config matches
    assert sum_a == sum_b
    assert cost_a == cost_b  # bit-for-bit, not rounded


def test_bit_identity_spot_demo():
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    _assert_bit_identical(_decisions(
        lambda: aws_catalog(price_model=pm),
        lambda: physical_trace(n_jobs=8, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(seed=5, preemption_hazard_per_hour=0.5),
        dict(spot_aware=True),
        lambda: [SpotLayer()]))


def test_bit_identity_multiregion_demo():
    _assert_bit_identical(_decisions(
        lambda: multi_region_catalog(dispersed_demo_regions(3)),
        lambda: physical_trace(n_jobs=6, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(seed=5, preemption_hazard_per_hour=0.3),
        dict(multi_region=True),
        lambda: [SpotLayer(), MultiRegionLayer()]))


def test_bit_identity_burstable_demo():
    _assert_bit_identical(_decisions(
        burstable_demo_catalog,
        lambda: burstable_trace(n_jobs=8, seed=11),
        dict(seed=5),
        dict(credit_aware=True),
        lambda: [SpotLayer(), CreditLayer()]))


def test_bit_identity_deferrable_demo():
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    _assert_bit_identical(_decisions(
        lambda: aws_catalog(price_model=pm),
        lambda: deferrable_trace(n_jobs=10, seed=13),
        dict(seed=5, preemption_hazard_per_hour=0.3),
        dict(spot_aware=True, autoscale=True, strike=0.9),
        lambda: [SpotLayer(), AutoscaleLayer(strike=0.9)]))


def _stack_decisions(catalog_fn, trace_fn, cfg_kw, stack_fns):
    """Run each explicit stack on a fresh catalog/trace; return normalized
    (decision trace, summary, exact cost) triples."""
    out = []
    for stack_fn in stack_fns:
        cat = catalog_fn()
        jobs = trace_fn()
        rank = {t.task_id: i for i, t in enumerate(
            sorted((t for j in jobs for t in j.tasks),
                   key=lambda t: t.task_id))}
        sched = _Probe(cat, policies=stack_fn())
        m = Simulator(cat, jobs, sched, SimConfig(**cfg_kw)).run()
        trace = [(t, tuple((k, tuple(rank[tid] for tid in tids))
                           for k, tids in assignments))
                 for t, assignments in sched.trace]
        out.append((trace, m.summary(), m.total_cost))
    return out


def test_slo_layer_is_bit_identical_on_batch_traces():
    """PR 7 contract: ``SLOLayer`` present in the stack leaves every
    decision on a *service-free* trace bit-identical — every hook is the
    identity when the view carries no service jobs, so pre-serving runs
    replay exactly."""
    from repro.policies import SLOLayer
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    _assert_bit_identical(_stack_decisions(
        lambda: aws_catalog(price_model=pm),
        lambda: physical_trace(n_jobs=8, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(seed=5, preemption_hazard_per_hour=0.5),
        (lambda: [SpotLayer()],
         lambda: [SpotLayer(), SLOLayer()])))
    # composed with an admission layer on a deferrable trace too
    _assert_bit_identical(_stack_decisions(
        lambda: aws_catalog(price_model=pm),
        lambda: deferrable_trace(n_jobs=8, seed=13),
        dict(seed=5, preemption_hazard_per_hour=0.3),
        (lambda: [SpotLayer(), AutoscaleLayer(strike=0.9)],
         lambda: [SpotLayer(), AutoscaleLayer(strike=0.9), SLOLayer()])))


def test_portfolio_layer_is_bit_identical_without_pools():
    """PR 8 contract: ``PortfolioLayer`` in the stack leaves every decision
    on a *commitment-free* catalog bit-identical — every hook is the
    identity when the catalog carries no pools, so pre-portfolio runs
    replay exactly."""
    from repro.policies import PortfolioLayer
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    _assert_bit_identical(_stack_decisions(
        lambda: aws_catalog(price_model=pm),
        lambda: physical_trace(n_jobs=8, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(seed=5, preemption_hazard_per_hour=0.5),
        (lambda: [SpotLayer()],
         lambda: [SpotLayer(), PortfolioLayer()])))
    # and on a multi-region catalog (the closest pre-existing axis)
    _assert_bit_identical(_stack_decisions(
        lambda: multi_region_catalog(dispersed_demo_regions(3)),
        lambda: physical_trace(n_jobs=6, seed=11,
                               duration_range_h=(0.3, 0.6)),
        dict(seed=5, preemption_hazard_per_hour=0.3),
        (lambda: [SpotLayer(), MultiRegionLayer()],
         lambda: [SpotLayer(), MultiRegionLayer(), PortfolioLayer()])))


def test_single_provider_catalog_matches_multi_region():
    """A commitment-free ``multi_provider_catalog`` is the same market as
    the equivalent ``multi_region_catalog`` — provider qualification adds
    a ledger axis, not a decision change.  Pinned decision-for-decision
    (with ``PortfolioLayer`` riding on the provider side): only the
    provider-ledger summary keys may differ."""
    from repro.core import Provider, Region, multi_provider_catalog
    from repro.policies import PortfolioLayer

    def pms():
        return (PriceModel.mean_reverting(discount=0.35, seed=7),
                PriceModel.mean_reverting(discount=0.4, seed=9))

    def region_cat():
        pm_a, pm_b = pms()
        return multi_region_catalog(
            (Region("aws", price_model=pm_a),
             Region("gcp", cost_scale=1.03, price_model=pm_b)))

    def provider_cat():
        pm_a, pm_b = pms()
        return multi_provider_catalog(
            (Provider(name="aws", price_model=pm_a),
             Provider(name="gcp", cost_scale=1.03, price_model=pm_b)))

    # the markets are numerically the same catalog
    ca, cb = region_cat(), provider_cat()
    assert [t.name for t in ca.types] == [t.name for t in cb.types]
    np.testing.assert_array_equal(ca.costs, cb.costs)
    np.testing.assert_array_equal(ca.transfer.egress_usd_per_gb,
                                  cb.transfer.egress_usd_per_gb)

    out = []
    for cat_fn, stack_fn in (
            (region_cat, lambda: [SpotLayer(), MultiRegionLayer()]),
            (provider_cat, lambda: [SpotLayer(), MultiRegionLayer(),
                                    PortfolioLayer()])):
        cat = cat_fn()
        jobs = physical_trace(n_jobs=6, seed=11,
                              duration_range_h=(0.3, 0.6))
        rank = {t.task_id: i for i, t in enumerate(
            sorted((t for j in jobs for t in j.tasks),
                   key=lambda t: t.task_id))}
        sched = _Probe(cat, policies=stack_fn())
        m = Simulator(cat, jobs, sched,
                      SimConfig(seed=5, preemption_hazard_per_hour=0.3)).run()
        trace = [(t, tuple((k, tuple(rank[tid] for tid in tids))
                           for k, tids in assignments))
                 for t, assignments in sched.trace]
        summary = {k: v for k, v in m.summary().items()
                   if not k.startswith("cost_provider_")}
        out.append((trace, summary, m.total_cost))
    _assert_bit_identical(out)


def test_stack_from_flags_matches_flag_shim():
    """The factory translation (`stack_from_flags`) builds the same layer
    sequence the deprecation shim does."""
    stack = stack_from_flags(multi_region=True, credit_aware=True,
                             autoscale=True, strike=0.8)
    assert [la.name for la in stack] == ["spot", "multi-region", "credit",
                                         "autoscale"]
    cat = multi_region_catalog(dispersed_demo_regions(3),
                               base_types=burstable_demo_catalog().types)
    with pytest.warns(DeprecationWarning):
        shim = EvaScheduler(cat, multi_region=True, credit_aware=True,
                            autoscale=True, strike=0.8)
    assert [la.name for la in shim.stack] == [la.name for la in stack]
    assert shim.stack.get("autoscale").controller.strike == 0.8


# -------------------------------------------------------------- pressure bus
def test_pressure_bus_exactly_once_per_subscriber():
    bus = PressureBus()
    got_a, got_b = [], []
    bus.subscribe(got_a.append)
    bus.subscribe(got_b.append)
    sig = PressureSignal("credit", (3,), 100.0)
    bus.publish(sig)
    assert got_a == [sig] and got_b == [sig]
    assert bus.published == 1 and bus.delivered == 2


def test_bus_carries_all_three_kinds_to_legacy_hooks():
    cat = aws_catalog()

    class _Recorder(EvaScheduler):
        def __init__(self, catalog):
            super().__init__(catalog)
            self.kinds = []

        def on_preemption_notice(self, ids, t):
            self.kinds.append("spot")

        def on_credit_pressure(self, ids, t):
            self.kinds.append("credit")

        def on_deadline_pressure(self, ids, t):
            self.kinds.append("deadline")

    sched = _Recorder(cat)
    for kind in ("spot", "credit", "deadline"):
        sched.on_pressure(PressureSignal(kind, (1,), 0.0))
    assert sched.kinds == ["spot", "credit", "deadline"]


def test_coincident_deadline_signals_fire_one_round():
    """Two deferrable jobs with the same latest-start time raise two
    DEFER_DEADLINE signals at the same instant; the simulator must react
    with exactly one extra round (one forced partial), not one per
    signal."""
    pm = PriceModel.mean_reverting(discount=0.35, volatility=0.02, seed=7)
    cat = aws_catalog(price_model=pm)
    dur = 0.4 * 3600.0
    from repro.autoscale import ADMIT_OVERHEAD_S, RUNTIME_MARGIN
    dl = RUNTIME_MARGIN * dur + ADMIT_OVERHEAD_S + 2 * 3600.0 + 77.0
    jobs = [make_job(job_id=i + 1, workload=8, arrival_time=0.0,
                     duration_s=dur, n_tasks=1, deadline_s=dl,
                     deferrable=True) for i in range(2)]
    times = []

    class _Count(EvaScheduler):
        def schedule(self, view):
            times.append(view.time)
            return super().schedule(view)

    sched = _Count(cat, policies=[SpotLayer(),
                                  AutoscaleLayer(strike=1e-6)])
    sim = Simulator(cat, jobs, sched, SimConfig(seed=5))
    m = sim.run()
    from repro.autoscale import latest_start_s
    ls = latest_start_s(dl, dur)
    assert ls % 300.0 != 0.0  # genuinely off the round grid
    assert times.count(ls) == 1, "coincident signals double-fired the round"
    assert sim.pressure_bus.published == 2  # both signals still delivered
    assert sched.deadline_signals == 2
    assert m.deadline_misses == 0
