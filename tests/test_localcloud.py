"""End-to-end physical-mode test: Eva schedules real JAX training jobs on
the LocalCloud (threads = instances, migration = checkpoint/restore)."""
import pytest

from repro.cluster.localcloud import LocalCloud, LocalJob
from repro.configs import ARCHS
from repro.core import Catalog, EvaScheduler
from repro.core.catalog import InstanceType


@pytest.mark.slow
def test_local_cluster_trains_real_jobs():
    catalog = Catalog.from_types([
        InstanceType("local.large", "c7i", (0, 4, 16), 1.0),
        InstanceType("local.small", "c7i", (0, 2, 8), 0.55),
    ])
    jobs = [
        LocalJob(job_id=1, workload=7, arch_cfg=ARCHS["smollm-135m"].reduced(),
                 total_steps=30, demand=(0, 1, 4), standalone_sps=20.0),
        LocalJob(job_id=2, workload=6, arch_cfg=ARCHS["qwen3-0.6b"].reduced(),
                 total_steps=30, demand=(0, 1, 4), standalone_sps=15.0),
    ]
    cloud = LocalCloud(catalog, EvaScheduler(catalog), jobs, round_s=2.0)
    out = cloud.run(timeout_s=420)
    assert out["all_done"], out
    assert out["cost"] > 0
    assert all(s >= 30 for s in out["steps"].values())
