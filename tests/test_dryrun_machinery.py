"""Dry-run machinery on a small (2,2) debug mesh via a subprocess (the
512-device flag must be set before jax initializes, so in-process testing is
impossible).  Exercises lower+compile+analysis for representative reduced
cells, including the multi-pod (2,2,2) pod axis."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
from repro.configs import ARCHS
from repro.configs.base import ShapeSpec
from repro.models.sharding import mesh_context
from repro.launch.specs import input_specs
from repro.launch.hlo_analysis import analyze
from repro.models.steps import make_train_step, make_decode_step

out = {}
for name, multi_pod in (("smollm-135m", False), ("granite-moe-3b-a800m", False),
                        ("mamba2-780m", True)):
    cfg = ARCHS[name].reduced()
    if multi_pod:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((2, 2), ("data", "model"))
    shape = ShapeSpec("t", "train", 64, 8)
    with mesh_context(mesh):
        inputs = input_specs(cfg, shape, mesh)
        compiled = jax.jit(make_train_step(cfg), donate_argnums=0).lower(*inputs).compile()
    res = analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    out[name] = {"flops": res["flops"], "coll": res["collective_bytes"],
                 "temp": int(ma.temp_size_in_bytes)}
    # decode path too
    shape_d = ShapeSpec("d", "decode", 64, 8)
    with mesh_context(mesh, profile="inference-tp"):
        inputs = input_specs(cfg, shape_d, mesh, profile="inference-tp")
        jax.jit(make_decode_step(cfg), donate_argnums=1).lower(*inputs).compile()
    out[name]["decode_ok"] = True
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for name, r in out.items():
        assert r["flops"] > 0, name
        assert r["coll"] > 0, name
        assert r["decode_ok"], name


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS
from repro.models import lm
from repro.models.steps import init_train_state
from repro.train.checkpoint import save_checkpoint, restore_checkpoint

cfg = ARCHS["smollm-135m"].reduced()
mesh_a = jax.make_mesh((2, 2), ("data", "model"))
mesh_b = jax.make_mesh((4, 2), ("data", "model"))  # elastic re-scale 4 -> 8

state = init_train_state(cfg, jax.random.PRNGKey(0))
specs_a = lm.param_pspecs(cfg, mesh_a)
params_a = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)),
    state["params"], specs_a)

d = tempfile.mkdtemp()
save_checkpoint(d, {"params": params_a}, step=1)

specs_b = lm.param_pspecs(cfg, mesh_b)
shardings_b = {"params": jax.tree.map(
    lambda s: NamedSharding(mesh_b, s), specs_b)}
restored, step, _ = restore_checkpoint(d, shardings=shardings_b)

ok = True
for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(restored["params"])):
    ok &= bool(np.array_equal(np.asarray(a), np.asarray(b)))
    ok &= len(b.sharding.device_set) >= 1
print(json.dumps({"ok": ok, "step": step}))
"""


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Fault-tolerance / elasticity: a checkpoint written on a (2,2) mesh
    restores bit-exactly onto a (4,2) mesh with new shardings."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["step"] == 1
