"""Flight-recorder contract tests.

The subsystem's hard invariant, pinned here: the recorder is a **pure
observer**.  Attaching a ``FlightRecorder`` to a run must leave every
decision — each round's adopted config, the metrics summary, the exact
total cost — bit-identical to the unrecorded run, across every scenario
axis (spot, multi-region, burstable, deferrable, serving, portfolio).

The rest of the file unit-tests the recorder surfaces (event log +
aggregated cost ledger, decision trace, metrics registry + Prometheus
export, wall-clock profiler, JSONL round-trip, structured reporter) and
drives the ``tools/explain.py`` replay CLI end-to-end on a real trace.
"""
import io
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.cluster import (SimConfig, Simulator, burstable_trace,
                           deferrable_trace, physical_trace, portfolio_trace,
                           serving_trace)
from repro.core import (CommitmentModel, EvaScheduler, PriceModel, Provider,
                        aws_catalog, burstable_demo_catalog,
                        dispersed_demo_regions, multi_provider_catalog,
                        multi_region_catalog)
from repro.obs import (EventLog, FlightRecorder, Histogram, MetricsRegistry,
                       Profiler, Reporter, events as EV, profiler as prof_mod)
from repro.policies import (AutoscaleLayer, CreditLayer, MultiRegionLayer,
                            PortfolioLayer, SLOLayer, SpotLayer)

ROOT = Path(__file__).resolve().parent.parent


# -------------------------------------------------- observer-inertness pins
def _spot_pm(seed=7):
    return PriceModel.mean_reverting(discount=0.35, seed=seed)


#: scenario -> (catalog_fn, trace_fn, layers_fn, simcfg_kw); one per demo
#: axis, mirroring the composed scenarios the conservation harness sweeps
SCENARIOS = {
    "spot": (lambda: aws_catalog(price_model=_spot_pm()),
             lambda: physical_trace(n_jobs=8, seed=11,
                                    duration_range_h=(0.3, 0.6)),
             lambda: [SpotLayer()],
             dict(seed=5, preemption_hazard_per_hour=0.5)),
    "multiregion": (lambda: multi_region_catalog(dispersed_demo_regions(3)),
                    lambda: physical_trace(n_jobs=6, seed=11,
                                           duration_range_h=(0.3, 0.6)),
                    lambda: [SpotLayer(), MultiRegionLayer()],
                    dict(seed=5, preemption_hazard_per_hour=0.3)),
    "burstable": (lambda: burstable_demo_catalog(price_model=_spot_pm()),
                  lambda: burstable_trace(n_jobs=8, seed=11),
                  lambda: [SpotLayer(), CreditLayer()],
                  dict(seed=5)),
    "deferrable": (lambda: aws_catalog(price_model=_spot_pm()),
                   lambda: deferrable_trace(n_jobs=10, seed=13),
                   lambda: [SpotLayer(), AutoscaleLayer(strike=0.9)],
                   dict(seed=5, preemption_hazard_per_hour=0.3)),
    "serving": (aws_catalog,
                lambda: serving_trace(n_batch=4, seed=17, horizon_h=2.0,
                                      users=200_000),
                lambda: [SLOLayer()],
                dict(seed=5)),
    "portfolio": (lambda: multi_provider_catalog([
                      Provider(name="aws", price_model=_spot_pm(),
                               commitments=(CommitmentModel(
                                   instance_type="c7i.2xlarge", pool_size=2,
                                   rate_fraction=0.5),)),
                      Provider(name="gcp", cost_scale=1.03,
                               price_model=_spot_pm(seed=9))]),
                  lambda: portfolio_trace(n_steady=2, n_burst=3, seed=23,
                                          horizon_h=2.0),
                  lambda: [SpotLayer(), MultiRegionLayer(),
                           PortfolioLayer()],
                  dict(seed=5, preemption_hazard_per_hour=0.3)),
}


class _Probe(EvaScheduler):
    """Records every round's adopted config for decision-level diffing."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.probe = []

    def schedule(self, view):
        cfg = super().schedule(view)
        self.probe.append((view.time, tuple(cfg.assignments)))
        return cfg


def _run(scenario, recorder):
    catalog_fn, trace_fn, layers_fn, cfg_kw = SCENARIOS[scenario]
    cat = catalog_fn()
    jobs = trace_fn()
    # task/job ids come from global counters: normalize to ranks so the
    # two runs (fresh traces each) compare decision-for-decision
    rank = {t.task_id: i for i, t in enumerate(
        sorted((t for j in jobs for t in j.tasks), key=lambda t: t.task_id))}
    sched = _Probe(cat, policies=layers_fn(), recorder=recorder)
    m = Simulator(cat, jobs, sched, SimConfig(**cfg_kw),
                  recorder=recorder).run()
    trace = [(t, tuple((k, tuple(rank[tid] for tid in tids))
                       for k, tids in assignments))
             for t, assignments in sched.probe]
    return trace, m.summary(), m.total_cost, m


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_recording_is_decision_identical(scenario):
    tr_off, sum_off, cost_off, _ = _run(scenario, recorder=None)
    rec = FlightRecorder(meta={"scenario": scenario})
    tr_on, sum_on, cost_on, m = _run(scenario, recorder=rec)
    assert tr_on == tr_off          # every round's adopted config matches
    assert sum_on == sum_off        # full metrics summary, key for key
    assert cost_on == cost_off      # bit-for-bit, not rounded
    # and the recorder actually observed the run it rode along on
    assert len(rec.events) > 0
    assert len(rec.decisions) == len(tr_on)
    assert rec.events.total_cost() == pytest.approx(cost_on, rel=1e-9,
                                                    abs=1e-9)
    assert m.events is rec.events   # exposed on Metrics for callers
    assert "events" not in sum_on   # ...but never leaks into summary()
    # round events and decision records index the same rounds
    rounds = rec.events.of_kind(EV.ROUND)
    assert [e.get("round_index") for e in rounds] == \
        [d.round_index for d in rec.decisions]


def test_decision_trace_explains_keep_test():
    """Keep tables carry the margin decomposition on a recorded spot run."""
    rec = FlightRecorder()
    _run("spot", recorder=rec)
    entries = [e for d in rec.decisions for e in d.keep_table]
    assert entries, "keep tables never populated"
    for e in entries:
        assert e.margin == pytest.approx(e.saving - (e.cost - e.bonus))
        assert e.bonus == pytest.approx(sum(e.bonus_by_layer.values())
                                        if e.bonus_by_layer else 0.0)
    # spot pressure forces partial rounds; their context is recorded
    forced = [d for d in rec.decisions if d.kind == "forced-partial"]
    assert forced and all(d.evacuated for d in forced)


# ------------------------------------------------------------ event log
def test_event_log_queries_and_ledger():
    log = EventLog()
    log.emit(0.0, EV.PROVISION, instance_id=1, type="m5.large")
    log.emit(5.0, EV.PLACE, instance_id=1, job_id=3, task_id=7)
    log.emit(9.0, EV.PRESSURE, signal="spot", ids=(1, 2))
    log.emit(10.0, EV.TERMINATE, instance_id=1, reason="idle")
    log.record_cost(EV.COST_INSTANCE, "m5.large", 1.5)
    log.record_cost(EV.COST_INSTANCE, "m5.large", 0.5)
    log.record_cost(EV.COST_EGRESS, "region-0", 0.25)
    assert len(log) == 4
    assert [e.kind for e in log.of_kind(EV.PROVISION, EV.TERMINATE)] == \
        [EV.PROVISION, EV.TERMINATE]
    # for_instance includes pressure signals whose id payload names it
    assert [e.kind for e in log.for_instance(1)] == \
        [EV.PROVISION, EV.PLACE, EV.PRESSURE, EV.TERMINATE]
    assert [e.kind for e in log.for_instance(2)] == [EV.PRESSURE]
    assert [e.t for e in log.between(4.0, 9.0)] == [5.0, 9.0]
    assert log.counts()[EV.PROVISION] == 1
    # the ledger aggregates micro-charges into per-cell running sums
    assert log.costs[(EV.COST_INSTANCE, "m5.large")] == pytest.approx(2.0)
    assert log.cost_entries == 3
    assert log.total_cost() == pytest.approx(2.25)
    assert log.cost_by("category") == pytest.approx(
        {"instance": 2.0, "egress": 0.25})
    assert log.cost_by("key") == pytest.approx(
        {"m5.large": 2.0, "region-0": 0.25})


# ------------------------------------------------------- metrics registry
def test_metrics_registry_roundtrip_and_prom():
    reg = MetricsRegistry(maxlen=3)
    reg.inc("rounds")
    reg.inc("rounds", 2)
    for t in range(5):  # overflows the ring buffer: dropped is explicit
        reg.sample("cost_total", float(t), t * 1.5)
    reg.sample("cost_region:us-east", 1.0, 9.25)
    reg.observe("pack_ms", 0.05)
    reg.observe("pack_ms", 50.0)
    assert reg.counters["rounds"] == 3
    assert reg.gauges["cost_total"].dropped == 2
    assert reg.gauges["cost_total"].values() == [3.0, 4.5, 6.0]
    text = reg.prom_text()
    assert "rounds 3" in text
    assert 'cost_region{key="us-east"} 9.25' in text
    assert 'pack_ms_bucket{le="0.1"} 1' in text
    assert "pack_ms_count 2" in text
    back = MetricsRegistry.from_dict(
        json.loads(json.dumps(reg.to_dict())))
    assert back.prom_text() == text
    assert back.gauges["cost_total"].dropped == 2


def test_histogram_cumulative_buckets():
    h = Histogram(buckets=(1.0, 10.0, float("inf")))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    assert h.cumulative() == [1, 2, 4]
    assert h.total == 4 and h.sum == pytest.approx(555.5)


# ------------------------------------------------------------- profiler
def test_profiler_spans_and_module_hook():
    p = Profiler()
    with p.span("outer", stage="a"):
        with p.span("inner"):
            pass
    assert [s.name for s in p.spans] == ["inner", "outer"]
    assert p.totals()["outer"] >= p.totals()["inner"] >= 0.0
    assert p.by_name("outer")[0].tags == {"stage": "a"}
    # module hook: inert (shared nullcontext) unless activated
    assert prof_mod.active() is None
    with prof_mod.span("nope") as s:
        assert s is None
    prof_mod.activate(p)
    try:
        with prof_mod.span("hooked") as s:
            assert s is not None
    finally:
        prof_mod.activate(None)
    assert p.by_name("hooked")


# ------------------------------------------------------------- reporter
def test_reporter_lines_and_json(tmp_path):
    buf = io.StringIO()
    rep = Reporter("gate", stream=buf)
    rep.emit("cell", col="jax_s", fresh_s=0.25, ok=True)
    rep.emit("note", msg="two words")
    assert buf.getvalue().splitlines() == [
        "[gate] cell col=jax_s fresh_s=0.25 ok=true",
        '[gate] note msg="two words"',
    ]
    assert rep.of("cell") == [{"event": "cell", "col": "jax_s",
                               "fresh_s": 0.25, "ok": True}]
    out = tmp_path / "rep.json"
    rep.write_json(str(out), verdict="pass")
    data = json.loads(out.read_text())
    assert data["scope"] == "gate" and data["verdict"] == "pass"
    assert len(data["records"]) == 2


# ------------------------------------------------- artifact + explain CLI
def test_flight_recorder_roundtrip_and_explain_cli(tmp_path):
    rec = FlightRecorder(meta={"scenario": "spot"})
    _run("spot", recorder=rec)
    with rec.profiler.span("plan"):
        pass
    path = str(tmp_path / "trace.jsonl")
    rec.save(path)
    back = FlightRecorder.load(path)
    assert back.meta == rec.meta
    assert back.events.events == rec.events.events
    assert back.events.costs == pytest.approx(rec.events.costs)
    assert [d.to_dict() for d in back.decisions] == \
        [d.to_dict() for d in rec.decisions]
    assert back.metrics.prom_text() == rec.metrics.prom_text()
    assert [s.name for s in back.profiler.spans] == \
        [s.name for s in rec.profiler.spans]

    def explain(*args):
        r = subprocess.run(
            [sys.executable, str(ROOT / "tools" / "explain.py"), path,
             *args], capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stdout + r.stderr
        return r.stdout
    out = explain("summary")
    assert "meta scenario=spot" in out and "decisions rounds=" in out
    out = explain("cost", "--by", "category")
    assert "category=instance" in out and "total $" in out
    # flagship query: why was this instance terminated?
    term = rec.events.of_kind(EV.TERMINATE)[0]
    out = explain("why-terminated", "--instance", str(term.instance_id))
    assert f"instance {term.instance_id} terminated" in out
    assert f"reason={term.get('reason')}" in out
    out = explain("rounds", "--round", "0")
    assert "round=0" in out
    out = explain("timeline", "--kind", "provision", "--limit", "3")
    assert "kind=provision" in out
