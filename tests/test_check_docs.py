"""tools/check_docs.py — the CI docs gate — gets its own tests: the link
checker, the fenced-bash path/module extraction, and a full run over the
real repo docs (which must be clean, since CI enforces exactly that)."""
import importlib.util
import os
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]
_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def _write(root, rel, text):
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)
    return path


def _run_in(tmp_path, monkeypatch, readme):
    """Point the checker at a synthetic repo and collect its problems."""
    monkeypatch.setattr(check_docs, "ROOT", str(tmp_path))
    _write(tmp_path, "README.md", readme)
    problems = []
    for path in check_docs.md_files():
        text = open(path, encoding="utf-8").read()
        check_docs.check_links(path, text, problems)
        check_docs.check_bash_blocks(path, text, problems)
    return problems


def test_md_files_covers_readme_and_docs(tmp_path, monkeypatch):
    monkeypatch.setattr(check_docs, "ROOT", str(tmp_path))
    _write(tmp_path, "README.md", "hi")
    _write(tmp_path, "docs/B.md", "b")
    _write(tmp_path, "docs/A.md", "a")
    files = [os.path.relpath(p, tmp_path) for p in check_docs.md_files()]
    # README first, docs sorted; nothing else scanned
    assert files == ["README.md", os.path.join("docs", "A.md"),
                     os.path.join("docs", "B.md")]


def test_link_checker_flags_broken_and_accepts_good(tmp_path, monkeypatch):
    _write(tmp_path, "docs/REAL.md", "exists")
    problems = _run_in(tmp_path, monkeypatch,
                       "[ok](docs/REAL.md) [anchor](docs/REAL.md#sec)\n"
                       "[web](https://example.com) [frag](#local)\n"
                       "[gone](docs/MISSING.md)\n")
    assert len(problems) == 1
    assert "MISSING.md" in problems[0] and "broken link" in problems[0]


def test_links_resolve_relative_to_the_containing_file(tmp_path, monkeypatch):
    # docs/X.md linking ../README.md must resolve against docs/, not ROOT
    _write(tmp_path, "docs/X.md", "[up](../README.md) [bad](../nope.md)")
    problems = _run_in(tmp_path, monkeypatch, "root readme")
    assert len(problems) == 1 and "nope.md" in problems[0]


def test_bash_blocks_flag_missing_paths_and_modules(tmp_path, monkeypatch):
    _write(tmp_path, "benchmarks/run.py", "# exists")
    _write(tmp_path, "examples/demo.py", "# exists")
    readme = (
        "```bash\n"
        "python benchmarks/run.py --quick\n"
        "python examples/demo.py\n"
        "python -m benchmarks.run --quick\n"
        "python benchmarks/bench_missing.py\n"
        "python -m benchmarks.bench_ghost\n"
        "```\n"
        "outside a fence: benchmarks/never_checked.py\n")
    problems = _run_in(tmp_path, monkeypatch, readme)
    assert len(problems) == 2
    joined = "\n".join(problems)
    assert "benchmarks/bench_missing.py" in joined
    assert "benchmarks.bench_ghost" in joined
    assert "never_checked" not in joined  # only fenced bash is enforced


def test_trailing_sentence_punctuation_is_stripped(tmp_path, monkeypatch):
    _write(tmp_path, "examples/demo.py", "# exists")
    problems = _run_in(tmp_path, monkeypatch,
                       "```bash\nsee examples/demo.py.\n```\n")
    assert problems == []


def test_main_exit_codes(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr(check_docs, "ROOT", str(tmp_path))
    _write(tmp_path, "README.md", "[gone](missing.md)")
    assert check_docs.main() == 1
    assert "broken link" in capsys.readouterr().out
    _write(tmp_path, "README.md", "all good")
    assert check_docs.main() == 0
    assert "OK" in capsys.readouterr().out


def test_real_repo_docs_are_clean(capsys):
    """The actual repo must pass its own gate (CI runs this same check)."""
    assert check_docs.ROOT == str(REPO)
    assert check_docs.main() == 0, capsys.readouterr().out
