"""Stability-vs-cost admission (drift-plus-penalty, arXiv 2201.09050).

Contract tests anchoring the axis:
* the drift-plus-penalty trade-off: a job facing a standing premium is
  held while its backlog is small and admitted once ``q·rp_a >
  V·premium`` — long before its latest-start deadline backstop;
* ``V`` is the patience dial: larger V holds longer, V=0 admits after a
  single held round, and the deadline bound still forces admission;
* warm-keep pricing: keep-test slack appears exactly while jobs are
  queued, scaled by queue pressure;
* eva-stability bounds the pending queue below the deep-strike chaser at
  comparable cost with zero deadline misses (the benchmark/CI
  invariant), and a stack with a StabilityLayer is bit-identical to the
  plain spot scheduler on traces with no deferrable jobs.
"""
import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator, deferrable_trace, physical_trace
from repro.core import (EvaScheduler, PriceModel, TaskSet, aws_catalog,
                        make_job)
from repro.core.plan import LiveInstance
from repro.core.scheduler import SchedulerView
from repro.policies import (AutoscaleLayer, SpotLayer, StabilityController,
                            StabilityLayer)


def _dear_market_catalog():
    """Cheap history, then permanently dear: the strike chaser would hold
    until its deadline backstop; stability must not."""
    times = np.arange(0.0, 48 * 3600.0, 600.0)
    mult = np.where(times < 2 * 3600.0, 0.3, 0.9)
    return aws_catalog(price_model=PriceModel.trace(times, mult))


def _one_job_view(time, deadline, remaining=1800.0):
    job = make_job(job_id=1, workload=8, arrival_time=0.0,
                   duration_s=remaining, n_tasks=1,
                   deadline_s=deadline, deferrable=True)
    tid = job.tasks[0].task_id
    return SchedulerView(
        time=time, tasks=TaskSet(job.tasks), pending_ids={tid}, live=[],
        task_workload={tid: 8}, remaining_s={tid: remaining},
        deferrable={1}, deadline_s={1: deadline}, pending={1})


# ------------------------------------------------------------ the controller
def test_drift_dominates_after_bounded_backlog():
    """On a permanently dear market the pure chaser holds forever (until
    the deadline bound); the stability controller admits once the
    held-round backlog outweighs the premium — and the patience scales
    with V."""
    cat = _dear_market_catalog()
    dl = 40 * 3600.0  # deadline far enough that the backstop never fires
    admitted_at = {}
    for v in (4.0, 16.0):
        ctl = StabilityController(cat, v=v, strike=0.9)
        for r in range(200):
            t = 3 * 3600.0 + r * 300.0  # review every round, market dear
            held, forced = ctl.review(_one_job_view(t, dl), d_hat_s=600.0)
            if not held:
                admitted_at[v] = r
                break
        assert not forced, "must admit by drift, not the deadline backstop"
        assert v in admitted_at, "drift term never dominated"
        assert ctl.admissions == 1 and ctl.forced_admissions == 0
    assert 0 < admitted_at[4.0] < admitted_at[16.0] < 200  # V = patience


def test_v_zero_admits_after_one_held_round():
    cat = _dear_market_catalog()
    ctl = StabilityController(cat, v=0.0, strike=0.9)
    held, _ = ctl.review(_one_job_view(3 * 3600.0, 40 * 3600.0), 600.0)
    assert held == {1}  # backlog 0: q·rp_a > 0 is false, hold once
    held, _ = ctl.review(_one_job_view(3 * 3600.0 + 300.0, 40 * 3600.0),
                         600.0)
    assert not held and ctl.held_job_rounds == 1


def test_queue_pressure_vetoes_re_deferral():
    """A spike never bounces a job back to the queue once its backlog
    would immediately re-admit it."""
    cat = _dear_market_catalog()
    ctl = StabilityController(cat, v=8.0, strike=0.9)
    ctl._admitted.add(1)
    ctl._held_rounds[1] = 100  # deep backlog: drift dominates any premium
    held, _ = ctl.review(_one_job_view(3 * 3600.0, 40 * 3600.0), 600.0)
    assert not held and ctl.re_deferrals == 0


def test_deadline_backstop_still_forces():
    cat = _dear_market_catalog()
    ctl = StabilityController(cat, v=1e9, strike=0.9)  # infinite patience
    from repro.autoscale import latest_start_s
    dl = 10 * 3600.0
    late = latest_start_s(dl, 1800.0) + 1.0
    held, forced = ctl.review(_one_job_view(late, dl), 600.0)
    assert not held and forced == {1} and ctl.forced_admissions == 1


# --------------------------------------------------------------- warm keep
def test_warm_keep_slack_appears_with_queue():
    cat = aws_catalog()
    layer = StabilityLayer()
    sched = EvaScheduler(cat, policies=[layer])
    job = make_job(job_id=1, workload=8, arrival_time=0.0,
                   duration_s=3600.0, n_tasks=1)
    tid = job.tasks[0].task_id
    k = cat.index_of("c7i.2xlarge")
    view = SchedulerView(time=0.0, tasks=TaskSet(job.tasks),
                         pending_ids=set(),
                         live=[LiveInstance(0, k, (tid,))],
                         task_workload={tid: 8})
    assert layer.keep_bonus(cat, cat, view) is None  # empty queue: no slack
    layer.last_held = {7}
    fn = layer.keep_bonus(cat, cat, view)
    assert fn is not None and fn(k, (tid,)) > 0.0
    # slack scales with queue pressure up to the warm_ref saturation
    layer.last_held = {7, 8, 9, 10}
    fn4 = layer.keep_bonus(cat, cat, view)
    assert fn4(k, (tid,)) == pytest.approx(4.0 * fn(k, (tid,)))
    # ... and can be disabled
    off = StabilityLayer(warm_keep=False)
    off.bind(sched)
    off.last_held = {7}
    assert off.keep_bonus(cat, cat, view) is None


# ------------------------------------------------- strictly additive (PR 5)
def test_stability_bit_identical_without_deferrable_jobs():
    """On a trace with no deferrable jobs the StabilityLayer never runs a
    review and adds no keep slack: decisions are bit-for-bit the plain
    spot scheduler's."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    m = []
    for layers in ([SpotLayer()], [SpotLayer(), StabilityLayer()]):
        cat = aws_catalog(price_model=pm)
        sim = Simulator(cat,
                        physical_trace(n_jobs=8, seed=11,
                                       duration_range_h=(0.3, 0.6)),
                        EvaScheduler(cat, policies=layers),
                        SimConfig(seed=5, preemption_hazard_per_hour=0.5))
        m.append(sim.run())
    assert m[0].summary() == m[1].summary()
    assert m[0].total_cost == m[1].total_cost  # bit-for-bit


# ------------------------------------------------------------ the acceptance
def test_stability_bounds_queue_at_comparable_cost():
    """Acceptance (benchmark/CI invariant): on the bundled OU market,
    eva-stability holds the max pending-queue length below the
    always-defer chaser at a total cost within 5%, with zero deadline
    misses."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    results = {}
    for name, layers in (
            ("stability", [SpotLayer(), StabilityLayer()]),
            ("chaser", [SpotLayer(), AutoscaleLayer(strike=0.7)])):
        cat = aws_catalog(price_model=pm)
        jobs = deferrable_trace(n_jobs=24, seed=13)
        m = Simulator(cat, jobs, EvaScheduler(cat, policies=layers),
                      SimConfig(seed=5, preemption_hazard_per_hour=0.3)).run()
        assert all(j.completion_time is not None for j in jobs)
        results[name] = m
    stab, chase = results["stability"], results["chaser"]
    assert stab.deadline_misses == 0
    assert stab.max_pending_jobs < chase.max_pending_jobs
    assert stab.total_cost <= 1.05 * chase.total_cost
