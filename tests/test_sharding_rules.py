"""Unit tests for the logical-axis sharding resolution (divisibility
fallbacks, profiles) — no multi-device mesh needed beyond jax.make_mesh on
1 device? No: uses abstract Mesh via jax.sharding.Mesh over a device grid of
1 is impossible for 16-way axes, so we build meshes from AbstractDevice...
Instead we validate against a fake mesh-shape mapping through spec_for's
contract using a stub."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import (ACT_RULES, PARAM_RULES, PROFILES,
                                   _axis_size, _resolve_dim, spec_for)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_vocab_divisible_shards_on_model():
    spec = spec_for((256000, 8192), ("vocab", "embed+"), MESH)
    assert spec == P("model", "data")


def test_vocab_indivisible_falls_back():
    # whisper vocab 51865 is odd -> embedding shards features instead
    spec = spec_for((51865, 1024), ("vocab", "embed+"), MESH)
    assert spec[0] is None
    assert spec[1] == "data"


def test_kv_heads_indivisible_replicates():
    # 8 kv heads can't shard 16 ways; batch 128 shards on data
    spec = spec_for((128, 32768, 8, 128), ("batch", None, "kv_heads", None),
                    MESH)
    assert spec == P("data", None, None, None)


def test_no_axis_reuse_within_param():
    # heads takes model; ffn candidate list only has model -> must replicate
    spec = spec_for((64, 128, 4096), ("heads", "ffn", None), MESH)
    assert spec[0] == "model" and spec[1] is None


def test_batch_one_replicates():
    spec = spec_for((1, 1), ("batch", None), MESH, rules=ACT_RULES)
    assert spec == P(None, None)


def test_multipod_batch_uses_pod_and_data():
    spec = spec_for((256, 4096), ("batch", None), MESH3, rules=ACT_RULES)
    assert spec == P(("pod", "data"), None)


def test_fsdp_profile_shards_over_both_axes():
    prules = PROFILES["fsdp"][0]
    spec = spec_for((8192, 22528), ("embed", "ffn"), MESH, rules=prules)
    assert spec[0] == ("data", "model")


def test_inference_tp_profile_no_fsdp_dim():
    prules = PROFILES["inference-tp"][0]
    spec = spec_for((8192, 64, 128), ("embed", "heads", "head_dim"), MESH,
                    rules=prules)
    assert spec == P(None, "model", None)
