"""Partial Reconfiguration (§4.5) and ensemble-criterion unit tests."""
import numpy as np
import pytest

from repro.core import (EventRateEstimator, LiveInstance, TaskSet,
                        ThroughputTable, aws_catalog, choose, diff_configs,
                        full_reconfiguration, make_task, migration_cost,
                        partial_reconfiguration)
from repro.core.cluster_types import ClusterConfig
from repro.core.workloads import NUM_WORKLOADS

CAT = aws_catalog()


def _tasks(workloads):
    return TaskSet([make_task(job_id=i, workload=w)
                    for i, w in enumerate(workloads)])


def test_partial_keeps_cost_efficient_instances():
    tasks = _tasks([0, 3, 7])  # resnet, cyclegan, a3c
    full = full_reconfiguration(tasks, CAT, None, interference_aware=False,
                                multi_task_aware=False)
    # all current instances cost-efficient, nothing pending -> unchanged
    out = partial_reconfiguration(tasks, full.assignments, set(), CAT, None,
                                  interference_aware=False,
                                  multi_task_aware=False)
    assert sorted(out.assignments) == sorted(full.assignments)


def test_partial_packs_only_pending():
    tasks = _tasks([0, 3, 7, 8])
    sub = tasks.subset(tasks.ids[:3].tolist())
    full3 = full_reconfiguration(sub, CAT, None, interference_aware=False,
                                 multi_task_aware=False)
    pending = {int(tasks.ids[3])}
    out = partial_reconfiguration(tasks, full3.assignments, pending, CAT,
                                  None, interference_aware=False,
                                  multi_task_aware=False)
    placed = {t for _, tids in out.assignments for t in tids}
    assert placed == set(tasks.ids.tolist())
    # the original instances survive untouched
    for a in full3.assignments:
        assert a in out.assignments


def test_partial_evicts_inefficient_instance():
    tasks = _tasks([7])  # a3c: RP = cheapest c7i fitting (10 cpu, 8 ram)
    # place it on a wildly oversized instance: p3.16xlarge
    k_big = CAT.index_of("p3.16xlarge")
    live = [(k_big, tuple(tasks.ids.tolist()))]
    out = partial_reconfiguration(tasks, live, set(), CAT, None,
                                  interference_aware=False,
                                  multi_task_aware=False)
    types = [CAT.types[k].name for k, _ in out.assignments]
    assert "p3.16xlarge" not in types  # evicted and re-packed cheaply


def test_interference_triggers_eviction():
    # two tasks co-located; recorded mutual interference so bad that TNRP
    # falls below the instance cost -> partial reconfig splits them
    tasks = _tasks([5, 8])  # graphsage + diamond (worst pair in M_TRUE)
    full = full_reconfiguration(tasks, CAT, None, interference_aware=False,
                                multi_task_aware=False)
    packed = [a for a in full.assignments if len(a[1]) == 2]
    if not packed:
        pytest.skip("not packed under no-interference")
    table = ThroughputTable(NUM_WORKLOADS, default=0.95)
    w = tasks.workloads
    table.record(int(w[0]), (int(w[1]),), 0.3)
    table.record(int(w[1]), (int(w[0]),), 0.3)
    out = partial_reconfiguration(tasks, full.assignments, set(), CAT, table,
                                  interference_aware=True,
                                  multi_task_aware=False)
    assert all(len(tids) == 1 for _, tids in out.assignments)


def test_diff_configs_minimizes_migrations():
    live = [LiveInstance(10, 1, (1, 2)), LiveInstance(11, 3, (3,))]
    new = ClusterConfig([(1, (1, 2)), (3, (3, 4))])
    plan = diff_configs(live, new)
    assert plan.num_migrations == 1  # only task 4 moves (fresh placement)
    assert plan.migrations[0].task_id == 4
    assert not plan.terminations
    assert not plan.launches


def test_migration_cost_positive_and_scales():
    live = [LiveInstance(10, CAT.index_of("p3.8xlarge"), (1,))]
    new = ClusterConfig([(CAT.index_of("p3.2xlarge"), (1,))])
    plan = diff_configs(live, new)
    wmap = {1: 4}  # gpt2: 30 s ckpt + 15 s launch
    m1 = migration_cost(plan, live, CAT, wmap, delay_scale=1.0)
    m2 = migration_cost(plan, live, CAT, wmap, delay_scale=4.0)
    assert m1 > 0
    assert m2 > 2 * m1  # scales with delay (launch cost dominates)


def test_ensemble_prefers_partial_when_migration_expensive():
    d = choose(s_full=1.0, m_full=100.0, s_partial=0.9, m_partial=0.0,
               d_hat_s=3600.0)
    assert not d.adopt_full
    d2 = choose(s_full=1.0, m_full=0.01, s_partial=0.5, m_partial=0.0,
                d_hat_s=3600.0)
    assert d2.adopt_full


def test_event_rate_estimator():
    est = EventRateEstimator()
    for i in range(20):
        est.on_event(100.0 * i)
    assert est.lam == pytest.approx(1 / 100.0, rel=1e-6)
    for _ in range(5):
        est.on_full_reconfig()
    assert 0 < est.p < 1
    assert est.d_hat() > 0