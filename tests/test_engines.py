"""Equivalence of the three packing engines (paper-faithful python loop,
vectorized numpy, jitted JAX incremental formulation)."""
import numpy as np
import pytest

from repro.core import (Catalog, InstanceType, TaskSet, ThroughputTable,
                        aws_catalog, dispersed_demo_regions,
                        full_reconfiguration, make_task,
                        multi_region_catalog, table3_catalog)
from repro.core.catalog import AWS_CATALOG, FAMILIES
from repro.core.cluster_types import Task
from repro.core.workloads import NUM_WORKLOADS

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal envs
    HAVE_HYPOTHESIS = False


def _random_tasks(n, seed):
    rng = np.random.default_rng(seed)
    return TaskSet([make_task(job_id=1000 * seed + i,
                              workload=int(rng.integers(NUM_WORKLOADS)))
                    for i in range(n)])


def _random_table(seed, default=0.95):
    rng = np.random.default_rng(seed)
    t = ThroughputTable(NUM_WORKLOADS, default=default)
    for _ in range(25):
        w1, w2 = rng.integers(NUM_WORKLOADS, size=2)
        t.record(int(w1), (int(w2),), float(rng.uniform(0.7, 1.0)))
    return t


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("interference", [False, True])
def test_numpy_matches_python(seed, interference):
    tasks = _random_tasks(40, seed)
    cat = aws_catalog()
    table = _random_table(seed) if interference else None
    kw = dict(interference_aware=interference, multi_task_aware=False)
    c_py = full_reconfiguration(tasks, cat, table, engine="python", **kw)
    c_np = full_reconfiguration(tasks, cat, table, engine="numpy", **kw)
    assert sorted(c_py.assignments) == sorted(c_np.assignments)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_numpy_matches_python_multitask(seed):
    rng = np.random.default_rng(seed)
    tasks = []
    for j in range(12):
        w = int(rng.integers(NUM_WORKLOADS))
        for _ in range(int(rng.integers(1, 4))):
            tasks.append(make_task(job_id=j, workload=w))
    ts = TaskSet(tasks)
    cat = aws_catalog()
    table = _random_table(seed)
    kw = dict(interference_aware=True, multi_task_aware=True)
    c_py = full_reconfiguration(ts, cat, table, engine="python", **kw)
    c_np = full_reconfiguration(ts, cat, table, engine="numpy", **kw)
    assert sorted(c_py.assignments) == sorted(c_np.assignments)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("interference", [False, True])
def test_jax_matches_numpy(seed, interference):
    tasks = _random_tasks(50, seed)
    cat = aws_catalog()
    table = _random_table(seed, default=0.97) if interference else None
    kw = dict(interference_aware=interference, multi_task_aware=True)
    c_np = full_reconfiguration(tasks, cat, table, engine="numpy", **kw)
    c_jx = full_reconfiguration(tasks, cat, table, engine="jax", **kw)
    # same total cost (tie-breaks may differ by float association)
    assert c_jx.total_hourly_cost(cat) == pytest.approx(
        c_np.total_hourly_cost(cat), rel=1e-6)
    # every task assigned exactly once in both
    for c in (c_np, c_jx):
        tids = sorted(t for _, ts_ in c.assignments for t in ts_)
        assert tids == sorted(tasks.ids.tolist())


def _canon(cfg):
    """Partition-canonical view: the jax engine emits each instance's tasks
    grouped by collapsed class, numpy in pick order."""
    return sorted((k, tuple(sorted(t))) for k, t in cfg.assignments)


def _random_catalog(seed):
    """Random market: continuous costs (no reservation-price ties), random
    sizes, anchored by the three largest AWS types so every workload stays
    feasible on each family."""
    rng = np.random.default_rng(seed)
    types = [t for t in AWS_CATALOG
             if t.name in ("p3.16xlarge", "c7i.24xlarge", "r7i.24xlarge")]
    assert len(types) == 3
    for i in range(int(rng.integers(6, 12))):
        fam = FAMILIES[int(rng.integers(len(FAMILIES)))]
        if fam == "p3":
            gpu = float(rng.integers(1, 9))
            cap = (gpu, 8.0 * gpu, 61.0 * gpu)
        else:
            cpu = float(2 ** rng.integers(1, 7))
            cap = (0.0, cpu, cpu * (2.0 if fam == "c7i" else 8.0))
        types.append(InstanceType(f"rnd-{seed}-{i}", fam, cap,
                                  float(rng.uniform(0.05, 30.0))))
    return Catalog.from_types(types)


def _check_random_catalog(seed):
    cat = _random_catalog(seed)
    tasks = _random_tasks(45, seed)
    kw = dict(interference_aware=False, multi_task_aware=True)
    c_np = full_reconfiguration(tasks, cat, None, engine="numpy", **kw)
    c_jx = full_reconfiguration(tasks, cat, None, engine="jax", **kw)
    assert c_jx.total_hourly_cost(cat) == pytest.approx(
        c_np.total_hourly_cost(cat), rel=1e-6)
    for c in (c_np, c_jx):
        tids = sorted(t for _, ts_ in c.assignments for t in ts_)
        assert tids == sorted(tasks.ids.tolist())


@pytest.mark.parametrize("seed", [10, 11, 12, 13, 14, 15])
def test_jax_matches_numpy_random_catalog(seed):
    _check_random_catalog(seed)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 100_000))
    def test_jax_matches_numpy_random_catalog_property(seed):
        _check_random_catalog(seed)


def test_jax_x64_exact_partition_match():
    """Under x64 the engine's accept/score tolerances collapse below EPS,
    so the jitted plan is partition-identical to numpy, not just cost-equal."""
    import jax
    jax.config.update("jax_enable_x64", True)
    try:
        kw = dict(interference_aware=False, multi_task_aware=True)
        for seed, cat in ((0, aws_catalog()), (20, _random_catalog(20))):
            tasks = _random_tasks(60, seed)
            c_np = full_reconfiguration(tasks, cat, None, engine="numpy", **kw)
            c_jx = full_reconfiguration(tasks, cat, None, engine="jax", **kw)
            assert _canon(c_np) == _canon(c_jx)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_jax_type_mask_matches_numpy():
    cat = aws_catalog()
    # forbid the GPU family: CPU-feasible packing must agree across engines
    mask = np.array([t.family != "p3" for t in cat.types])
    rng = np.random.default_rng(5)
    cpu_ok = [w for w in range(NUM_WORKLOADS) if _cpu_feasible(cat, mask, w)]
    tasks = TaskSet([make_task(job_id=7000 + i,
                               workload=int(rng.choice(cpu_ok)))
                     for i in range(30)])
    kw = dict(interference_aware=False, multi_task_aware=True,
              type_mask=mask)
    c_np = full_reconfiguration(tasks, cat, None, engine="numpy", **kw)
    c_jx = full_reconfiguration(tasks, cat, None, engine="jax", **kw)
    assert c_jx.total_hourly_cost(cat) == pytest.approx(
        c_np.total_hourly_cost(cat), rel=1e-6)
    for k, _ in c_jx.assignments:
        assert mask[k]


def _cpu_feasible(cat, mask, workload):
    from repro.core import reservation_prices
    ts = TaskSet([make_task(job_id=0, workload=workload, task_id=0)])
    try:
        return bool(np.isfinite(reservation_prices(ts, cat,
                                                   type_mask=mask)[0]))
    except ValueError:  # fits no unmasked type
        return False


def test_jax_region_caps_match_numpy():
    cat = multi_region_catalog(dispersed_demo_regions(3)).at(3600.0)
    rng = np.random.default_rng(9)
    tasks = TaskSet([make_task(job_id=8000 + i,
                               workload=int(rng.integers(NUM_WORKLOADS)))
                     for i in range(35)])
    kw = dict(interference_aware=False, multi_task_aware=True)
    plans = {}
    for eng in ("numpy", "jax"):
        caps = [3, None, 4]
        plans[eng] = full_reconfiguration(tasks, cat, None, engine=eng,
                                          region_caps=caps, **kw)
        per_region = np.bincount(
            [cat.region_of(k) for k, _ in plans[eng].assignments],
            minlength=3)
        assert per_region[0] <= 3 and per_region[2] <= 4
    assert plans["jax"].total_hourly_cost(cat) == pytest.approx(
        plans["numpy"].total_hourly_cost(cat), rel=1e-6)


def test_table3_walkthrough_jax_engine():
    specs = [(2, 8, 24), (1, 4, 10), (0, 6, 20), (0, 4, 12)]
    ts = TaskSet([Task(i, i, i, {"p3": tuple(map(float, s))})
                  for i, s in enumerate(specs)])
    cat = table3_catalog()
    cfg = full_reconfiguration(ts, cat, None, interference_aware=False,
                               multi_task_aware=False, engine="jax")
    assert cfg.total_hourly_cost(cat) == pytest.approx(12.8)
