"""Equivalence of the three packing engines (paper-faithful python loop,
vectorized numpy, jitted JAX incremental formulation)."""
import numpy as np
import pytest

from repro.core import (TaskSet, ThroughputTable, aws_catalog,
                        full_reconfiguration, make_task, table3_catalog)
from repro.core.cluster_types import Task
from repro.core.workloads import NUM_WORKLOADS


def _random_tasks(n, seed):
    rng = np.random.default_rng(seed)
    return TaskSet([make_task(job_id=1000 * seed + i,
                              workload=int(rng.integers(NUM_WORKLOADS)))
                    for i in range(n)])


def _random_table(seed, default=0.95):
    rng = np.random.default_rng(seed)
    t = ThroughputTable(NUM_WORKLOADS, default=default)
    for _ in range(25):
        w1, w2 = rng.integers(NUM_WORKLOADS, size=2)
        t.record(int(w1), (int(w2),), float(rng.uniform(0.7, 1.0)))
    return t


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("interference", [False, True])
def test_numpy_matches_python(seed, interference):
    tasks = _random_tasks(40, seed)
    cat = aws_catalog()
    table = _random_table(seed) if interference else None
    kw = dict(interference_aware=interference, multi_task_aware=False)
    c_py = full_reconfiguration(tasks, cat, table, engine="python", **kw)
    c_np = full_reconfiguration(tasks, cat, table, engine="numpy", **kw)
    assert sorted(c_py.assignments) == sorted(c_np.assignments)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_numpy_matches_python_multitask(seed):
    rng = np.random.default_rng(seed)
    tasks = []
    for j in range(12):
        w = int(rng.integers(NUM_WORKLOADS))
        for _ in range(int(rng.integers(1, 4))):
            tasks.append(make_task(job_id=j, workload=w))
    ts = TaskSet(tasks)
    cat = aws_catalog()
    table = _random_table(seed)
    kw = dict(interference_aware=True, multi_task_aware=True)
    c_py = full_reconfiguration(ts, cat, table, engine="python", **kw)
    c_np = full_reconfiguration(ts, cat, table, engine="numpy", **kw)
    assert sorted(c_py.assignments) == sorted(c_np.assignments)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("interference", [False, True])
def test_jax_matches_numpy(seed, interference):
    tasks = _random_tasks(50, seed)
    cat = aws_catalog()
    table = _random_table(seed, default=0.97) if interference else None
    kw = dict(interference_aware=interference, multi_task_aware=True)
    c_np = full_reconfiguration(tasks, cat, table, engine="numpy", **kw)
    c_jx = full_reconfiguration(tasks, cat, table, engine="jax", **kw)
    # same total cost (tie-breaks may differ by float association)
    assert c_jx.total_hourly_cost(cat) == pytest.approx(
        c_np.total_hourly_cost(cat), rel=1e-6)
    # every task assigned exactly once in both
    for c in (c_np, c_jx):
        tids = sorted(t for _, ts_ in c.assignments for t in ts_)
        assert tids == sorted(tasks.ids.tolist())


def test_table3_walkthrough_jax_engine():
    specs = [(2, 8, 24), (1, 4, 10), (0, 6, 20), (0, 4, 12)]
    ts = TaskSet([Task(i, i, i, {"p3": tuple(map(float, s))})
                  for i, s in enumerate(specs)])
    cat = table3_catalog()
    cfg = full_reconfiguration(ts, cat, None, interference_aware=False,
                               multi_task_aware=False, engine="jax")
    assert cfg.total_hourly_cost(cat) == pytest.approx(12.8)
