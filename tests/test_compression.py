"""Gradient-compression numerics: int8 + error feedback must not break
training (loss still decreases, errors stay bounded)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data.pipeline import SyntheticTokens
from repro.models.steps import init_train_state, make_train_step
from repro.train.compression import compress_grads, quantize_dequantize_int8
from repro.train.optimizer import OptConfig


def test_qdq_bounded_error():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 3.0,
                    jnp.float32)
    deq, err = quantize_dequantize_int8(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= scale * 0.5 + 1e-7
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)


def test_error_feedback_reinjects():
    g = {"w": jnp.full((8, 8), 0.001, jnp.float32)}  # tiny grads
    comp1, err1 = compress_grads(g, None)
    # second step with the same grads: the accumulated error must be carried
    comp2, err2 = compress_grads(g, err1)
    total_seen = np.asarray(comp1["w"] + comp2["w"] + err2["w"])
    np.testing.assert_allclose(total_seen, 2 * np.asarray(g["w"]), rtol=1e-5,
                               atol=1e-7)


def test_training_with_int8_grads_converges():
    cfg = ARCHS["smollm-135m"].reduced()
    key = jax.random.PRNGKey(0)
    losses = {}
    for mode in ("none", "int8"):
        state = init_train_state(cfg, key)
        step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, total_steps=50),
                                       grad_compression=mode))
        src = SyntheticTokens(cfg.vocab, 4, 32, seed=1)
        ls = []
        for _ in range(12):
            batch = {k: jnp.asarray(v) for k, v in src.next_batch().items()}
            state, m = step(state, batch)
            ls.append(float(m["loss"]))
        losses[mode] = ls
    assert losses["int8"][-1] < losses["int8"][0]  # still learning
    # compressed run tracks the uncompressed one closely
    assert abs(losses["int8"][-1] - losses["none"][-1]) < 0.25
