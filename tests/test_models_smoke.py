"""Per-architecture smoke tests: reduced configs, one train step + prefill +
decode on CPU, asserting output shapes and NaN-freedom; plus decode-vs-
prefill logit consistency (the KV-cache/state path must agree with the
full-sequence path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.lm import forward, init_params, logits_from_hidden, num_params
from repro.models.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.train.optimizer import OptConfig

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, key)
    batch = _batch(cfg, key)
    ts = jax.jit(make_train_step(cfg, OptConfig(total_steps=10)))
    state2, metrics = ts(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed (exact comparison: one AdamW step moves every
    # trained leaf by ~lr, but norm scales move by <1e-5)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert changed


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_smoke(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, cache = jax.jit(make_prefill_step(cfg))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    lg, cache2 = jax.jit(make_decode_step(cfg))(
        params, cache, batch["tokens"][:, :1], jnp.int32(S))
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_full_forward(name):
    """Prefill S tokens, decode token S; compare against a full forward over
    S+1 tokens.  Validates cache semantics (ring buffers, SSM states)."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    kw = {}
    if cfg.enc_dec:
        kw["enc_embeds"] = jax.random.normal(key, (B, cfg.enc_seq,
                                                   cfg.d_model)) * 0.02
    # full forward over S+1
    h_full, _ = forward(params, cfg, toks, mode="train", **kw)
    ref = logits_from_hidden(params, h_full[:, -1:], cfg)
    # prefill S (cache sized S+1 to hold the decoded token), decode one
    _, cache = forward(params, cfg, toks[:, :S], mode="prefill",
                       cache_len=S + 1, **kw)
    h_dec, _ = forward(params, cfg, toks[:, S:S + 1], mode="decode",
                       cache=cache, pos=jnp.int32(S))
    got = logits_from_hidden(params, h_dec, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_full_config_param_counts():
    """Full (non-reduced) configs must land near their published sizes."""
    expect = {
        "smollm-135m": (0.10e9, 0.20e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "granite-3-2b": (2.0e9, 3.0e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "command-r-35b": (30e9, 40e9),
        "chameleon-34b": (30e9, 39e9),
        "whisper-medium": (0.6e9, 1.0e9),
    }
    for name, (lo, hi) in expect.items():
        n = num_params(ARCHS[name])
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params not in [{lo/1e9}, {hi/1e9}]B"
