"""Incremental partial reconfiguration: dirty-set locality.

Pins the contract documented on ``incremental_reconfiguration``: for any
random dirty/evacuate set that does not trip a fallback, the plan is
bit-identical to clean-instance pass-through plus an ordinary
``partial_reconfiguration`` over just the affected sub-problem, and the
untouched assignments survive verbatim.  Skips cleanly when hypothesis is
not installed (it is a ``test`` extra, not a runtime dep).
"""
import functools

import numpy as np
import pytest

from repro.core import (ClusterConfig, LiveInstance, TaskSet, aws_catalog,
                        full_reconfiguration, incremental_reconfiguration,
                        make_task, partial_reconfiguration)
from repro.core.workloads import NUM_WORKLOADS

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal envs
    HAVE_HYPOTHESIS = False

CAT = aws_catalog()
KW = dict(interference_aware=False, multi_task_aware=True, engine="numpy")


@functools.lru_cache(maxsize=None)
def _fleet(n_tasks, seed, n_pending=0):
    """Planned fleet of single-task jobs (+ optional unplaced pending tasks)."""
    rng = np.random.default_rng(seed)
    placed = [make_task(job_id=10_000 * seed + i,
                        workload=int(rng.integers(NUM_WORKLOADS)),
                        task_id=10_000 * seed + i)
              for i in range(n_tasks)]
    cfg = full_reconfiguration(TaskSet(placed), CAT, None,
                               interference_aware=False,
                               multi_task_aware=True)
    live = tuple(LiveInstance(iid, k, tuple(tids))
                 for iid, (k, tids) in enumerate(cfg.assignments))
    pending = [make_task(job_id=10_000 * seed + n_tasks + i,
                         workload=int(rng.integers(NUM_WORKLOADS)),
                         task_id=10_000 * seed + n_tasks + i)
               for i in range(n_pending)]
    return TaskSet(placed + pending), live, frozenset(t.task_id for t in pending)


def _reference(tasks, live, dirty, evac, pending):
    """The documented decomposition, built from the public API."""
    dirty = set(dirty) | set(evac)
    affected = [i for i in live if i.instance_id in dirty]
    clean = [(i.type_index, i.task_ids) for i in live
             if i.instance_id not in dirty]
    evac_tasks = {t for i in affected if i.instance_id in evac
                  for t in i.task_ids}
    sub_ids = sorted({t for i in affected for t in i.task_ids} | set(pending))
    if not sub_ids:
        return ClusterConfig(clean)
    sub_live = [(i.type_index, i.task_ids) for i in affected
                if i.instance_id not in evac]
    cfg = partial_reconfiguration(tasks.subset(sub_ids), sub_live,
                                  set(pending) | evac_tasks, CAT, None, **KW)
    return ClusterConfig(clean + cfg.assignments)


def _check_matches_subset_replan(tasks, live, pending, dirty, evac):
    cfg, fb = incremental_reconfiguration(tasks, live, dirty, set(pending),
                                          CAT, None, evacuate=evac, **KW)
    assert fb is None
    ref = _reference(tasks, live, dirty, evac, pending)
    assert sorted(cfg.assignments) == sorted(ref.assignments)
    # untouched instances survive verbatim
    out = list(cfg.assignments)
    for inst in live:
        if inst.instance_id not in dirty | evac:
            assert (inst.type_index, inst.task_ids) in out
            out.remove((inst.type_index, inst.task_ids))
    # every task placed exactly once
    placed = sorted(t for _, tids in cfg.assignments for t in tids)
    assert placed == sorted(tasks.ids.tolist())


def test_incremental_matches_subset_replan_seeded():
    """Always-on version of the property: random dirty/evac sets per seed."""
    for seed in range(4):
        tasks, live, pending = _fleet(40, seed, n_pending=3)
        ids = sorted(i.instance_id for i in live)
        rng = np.random.default_rng(100 + seed)
        for _ in range(6):
            k = int(rng.integers(0, max(len(ids) // 2, 1) + 1))
            dirty = set(rng.choice(ids, size=k, replace=False).tolist())
            evac = {i for i in dirty if rng.random() < 0.4}
            _check_matches_subset_replan(tasks, live, pending, dirty, evac)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(seed=st.integers(0, 3), data=st.data())
    def test_incremental_matches_subset_replan(seed, data):
        tasks, live, pending = _fleet(40, seed, n_pending=3)
        ids = sorted(i.instance_id for i in live)
        # stay under max_dirty_fraction so the incremental path actually runs
        dirty = data.draw(st.sets(st.sampled_from(ids),
                                  max_size=len(ids) // 2))
        evac = (data.draw(st.sets(st.sampled_from(sorted(dirty))))
                if dirty else set())
        _check_matches_subset_replan(tasks, live, pending, dirty, evac)


def test_empty_dirty_set_is_pure_passthrough():
    tasks, live, _ = _fleet(30, 7)
    cfg, fb = incremental_reconfiguration(tasks, live, set(), set(), CAT,
                                          None, **KW)
    assert fb is None
    assert cfg.assignments == [(i.type_index, i.task_ids) for i in live]


def test_dirty_fraction_fallback_matches_full_partial():
    tasks, live, _ = _fleet(30, 2)
    dirty = {i.instance_id for i in live}  # whole fleet disturbed
    evac = {live[0].instance_id}
    cfg, fb = incremental_reconfiguration(tasks, live, dirty, set(), CAT,
                                          None, evacuate=evac, **KW)
    assert fb == "dirty-fraction"
    ref = partial_reconfiguration(
        tasks, [(i.type_index, i.task_ids) for i in live[1:]],
        set(live[0].task_ids), CAT, None, **KW)
    assert sorted(cfg.assignments) == sorted(ref.assignments)


def test_job_straddle_falls_back():
    # job 0 = {t0, t1} split across two instances: dirtying only one of them
    # cannot be priced locally under the job-RP penalty (§4.4).
    t = [make_task(job_id=50_000 + i // 2, workload=0, task_id=50_000 + i)
         for i in range(4)]
    tasks = TaskSet(t)
    live = (LiveInstance(0, 0, (t[0].task_id, t[2].task_id)),
            LiveInstance(1, 0, (t[1].task_id, t[3].task_id)))
    cfg, fb = incremental_reconfiguration(tasks, live, {0}, set(), CAT,
                                          None, **KW)
    assert fb == "job-straddle"
    placed = sorted(tid for _, tids in cfg.assignments for tid in tids)
    assert placed == sorted(tasks.ids.tolist())
    # with multi-task awareness off there is no job penalty, so the same
    # disturbance stays local
    kw1 = dict(KW, multi_task_aware=False)
    cfg1, fb1 = incremental_reconfiguration(tasks, live, {0}, set(), CAT,
                                            None, **kw1)
    assert fb1 is None
    assert (live[1].type_index, live[1].task_ids) in cfg1.assignments


def test_scheduler_incremental_rounds_end_to_end():
    """Spot notices drive incremental reaction rounds through the scheduler;
    every job still completes and fallbacks are counted, not raised."""
    from repro.cluster import SimConfig, Simulator, physical_trace
    from repro.core import EvaScheduler, PriceModel, aws_catalog
    from repro.policies import SpotLayer

    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    jobs = physical_trace(n_jobs=10, seed=11, duration_range_h=(0.3, 0.6))
    sched = EvaScheduler(cat, policies=[SpotLayer()], incremental=True)
    m = Simulator(cat, jobs, sched,
                  SimConfig(seed=3, preemption_hazard_per_hour=4.0)).run()
    assert m.preemption_notices > 0
    assert sched.incremental_rounds > 0
    assert sched.incremental_fallbacks <= sched.incremental_rounds
    assert all(j.completion_time is not None for j in jobs)


def test_incremental_jax_engine_matches_numpy():
    tasks, live, _ = _fleet(40, 3)
    dirty = {live[0].instance_id, live[1].instance_id}
    evac = {live[0].instance_id}
    kw_jx = dict(KW, engine="jax")
    cfg_np, fb_np = incremental_reconfiguration(tasks, live, dirty, set(),
                                                CAT, None, evacuate=evac,
                                                **KW)
    cfg_jx, fb_jx = incremental_reconfiguration(tasks, live, dirty, set(),
                                                CAT, None, evacuate=evac,
                                                **kw_jx)
    assert fb_np is None and fb_jx is None
    # same partition; the jax engine emits each instance's tasks grouped by
    # collapsed class, so canonicalize intra-instance order before comparing
    def canon(cfg):
        return sorted((k, tuple(sorted(t))) for k, t in cfg.assignments)
    assert canon(cfg_np) == canon(cfg_jx)
