"""Property-test harness pinning the simulator's conservation laws across
randomly *composed* scenarios (spot × multi-region × burstable ×
deferrable × service).

Every billing and signalling pathway in the simulator must balance no
matter which scenario axes are stacked:

* **billing conservation** — on static catalogs the total cost equals the
  per-instance recompute (lifetime × hourly price, summed over every
  instance ever launched — commitment-pool instances excluded: they bill
  zero marginal) plus the standing pool bills (pool capacity-hours × the
  discounted rate: each pool-hour paid exactly once, used or idle) plus
  egress; on multi-region catalogs the per-region ledger sums to the
  total either way, and on multi-provider catalogs so does the
  per-provider ledger;
* **commitment accounting** — ``commitment_cost`` re-derives from the
  capacity integral, utilization stays in [0, 1], and idle waste is
  exactly the uncovered capacity-hours at the discounted rate;
* **egress exactly once** — each cross-region checkpoint move bills the
  egress fee exactly once (the instrumented charge log matches both the
  egress total and the migration counter);
* **no billing while pending** — a job held by an admission controller
  has no instances, so nothing accrues before its first admission;
* **bus exactly-once** — every pressure signal reaches every subscriber
  exactly once, including a second independent subscriber;
* **serving accounting** — served requests integrate the request profile
  exactly over the job's active window, and the SLO counters never exceed
  it.

The hypothesis sweep (bounded profile: few examples, no deadline — CI
installs the ``test`` extra) drives random axis combinations through the
laws; seeded fallback tests run the same checker without hypothesis so the
laws stay pinned even in a bare environment.
"""
import pytest

from repro.autoscale import latest_start_s
from repro.cluster import (SimConfig, Simulator, burstable_trace,
                           deferrable_trace, physical_trace, portfolio_trace)
from repro.core import (CommitmentModel, EvaScheduler, PriceModel, Provider,
                        RequestProfile, ServiceSpec, UtilityCurve,
                        aws_catalog, burstable_demo_catalog,
                        dispersed_demo_regions, make_job,
                        multi_provider_catalog, multi_region_catalog)
from repro.core.workloads import WORKLOAD_INDEX, checkpoint_size_gb
from repro.obs import FlightRecorder
from repro.policies import (AutoscaleLayer, CreditLayer, MultiRegionLayer,
                            PortfolioLayer, SLOLayer, SpotLayer)

EMBED = WORKLOAD_INDEX["embed-serve"]


class _Instrumented(Simulator):
    """Logs every cross-region egress charge and adds a second pressure-bus
    subscriber, so the conservation checker can audit both."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.egress_calls = []
        self.bus_copy = []
        self.pressure_bus.subscribe(self.bus_copy.append)

    def _cross_region_charge(self, workload, r_s, r_d):
        if r_s != r_d:
            self.egress_calls.append((workload, r_s, r_d))
        return super()._cross_region_charge(workload, r_s, r_d)


def _service_job(job_id, duration_s=2700.0):
    """Small embed-serve fleet with a stepped request profile (a breakpoint
    inside the window keeps the integral law non-trivial)."""
    spec = ServiceSpec(
        requests=RequestProfile((0.0, 600.0, 1500.0), (0.0, 80.0, 40.0)),
        utility=UtilityCurve(100.0), per_replica_rps=400.0,
        base_latency_ms=25.0)
    return make_job(job_id=job_id, workload=EMBED, arrival_time=0.0,
                    duration_s=duration_s, n_tasks=2, service=spec)


def _lambda_integral(prof, a, b):
    ts = (a,) + prof.breakpoints_between(a, b) + (b,)
    return sum(prof.rate_at(t0) * (t1 - t0) for t0, t1 in zip(ts, ts[1:]))


def _compose(catalog_kind, spot, deferrable, service, hazard, n_jobs, seed):
    """Build one composed scenario: catalog, jobs, stack, sim config."""
    pm = PriceModel.mean_reverting(discount=0.4, seed=seed + 1) if spot \
        else None
    if catalog_kind == "multiregion":
        cat = multi_region_catalog(dispersed_demo_regions(2))
        layers = [SpotLayer(), MultiRegionLayer()]
    elif catalog_kind == "provider":
        # two providers + a commitment pool: the full portfolio grid
        cm = CommitmentModel(instance_type="c7i.2xlarge", pool_size=2,
                             rate_fraction=0.5)
        pm2 = PriceModel.mean_reverting(discount=0.45, seed=seed + 2) \
            if spot else None
        cat = multi_provider_catalog([
            Provider(name="aws", price_model=pm, commitments=(cm,)),
            Provider(name="gcp", cost_scale=1.03, price_model=pm2)])
        layers = [SpotLayer(), MultiRegionLayer(), PortfolioLayer()]
    elif catalog_kind == "burstable":
        cat = burstable_demo_catalog(price_model=pm)
        layers = [SpotLayer(), CreditLayer()]
    else:
        cat = aws_catalog(price_model=pm)
        layers = [SpotLayer()]
    if deferrable:
        jobs = deferrable_trace(n_jobs=n_jobs, seed=seed)
        layers.append(AutoscaleLayer(strike=0.9))
    elif catalog_kind == "burstable":
        jobs = burstable_trace(n_jobs=n_jobs, seed=seed)
    elif catalog_kind == "provider":
        # steady base that can fill the pool + bursts that overflow it
        jobs = portfolio_trace(n_steady=2, n_burst=n_jobs, seed=seed,
                               horizon_h=2.0)
    else:
        jobs = physical_trace(n_jobs=n_jobs, seed=seed,
                              duration_range_h=(0.2, 0.5))
    layers.append(SLOLayer())
    if service:
        jobs = jobs + [_service_job(job_id=10_000 + seed)]
    cfg = SimConfig(seed=seed,
                    preemption_hazard_per_hour=hazard if spot else 0.0)
    return cat, jobs, layers, cfg


def _run_composed(catalog_kind, spot, deferrable, service, hazard, n_jobs,
                  seed):
    cat, jobs, layers, cfg = _compose(catalog_kind, spot, deferrable,
                                      service, hazard, n_jobs, seed)
    # a flight recorder rides along on every composed scenario: the
    # event-cost conservation law below audits its ledger against the
    # metrics, and recording must never perturb any of the other laws
    rec = FlightRecorder(meta={"catalog": catalog_kind, "seed": seed})
    sched = EvaScheduler(cat, policies=layers, recorder=rec)
    sim = _Instrumented(cat, jobs, sched, cfg, recorder=rec)
    m = sim.run()
    return sim, m, cat, jobs


def _pool_standing(sim):
    """Σ pool capacity-hours × discounted rate (the exactly-once pool bill)."""
    if not getattr(sim, "_commit", False):
        return 0.0
    return sum(sim._pool_capacity_s[ri] / 3600.0 * sim._pool_rate[ri]
               for ri, _cm in sim._pools)


def _check_conservation(sim, m, cat, jobs):
    # --- billing: every instance ever launched, lifetime × hourly price;
    # pool instances bill zero marginal (the standing pool bill — capacity-
    # hours × discounted rate, exactly once per pool-hour — covers them)
    assert m.total_cost >= 0.0
    pool_inst = lambda inst: (getattr(sim, "_commit", False)  # noqa: E731
                              and sim._pool_type[inst.type_index])
    if not sim._spot:
        recomputed = sum(
            (inst.terminated_t - inst.request_t) / 3600.0
            * cat.costs[inst.type_index]
            for inst in sim.instances.values() if not pool_inst(inst))
        assert m.total_cost == pytest.approx(
            recomputed + _pool_standing(sim) + m.egress_cost,
            rel=1e-9, abs=1e-9)
    for inst in sim.instances.values():  # nothing left accruing
        assert inst.terminated_t is not None
    # --- ledgers: always present (empty-safe dicts), gated by explicit
    # flags; each ledger sums to the total on its axis
    assert isinstance(m.cost_by_region, dict)
    assert isinstance(m.cost_by_provider, dict)
    assert isinstance(m.commitment_utilization, dict)
    assert m.has_regions == (cat.regions is not None)
    if m.has_regions:
        assert m.total_cost == pytest.approx(
            sum(m.cost_by_region.values()), rel=1e-9, abs=1e-9)
    else:
        assert m.cost_by_region == {}
    assert m.has_providers == (cat.regions is not None and any(
        r.provider is not None for r in cat.regions))
    if m.has_providers:
        assert m.total_cost == pytest.approx(
            sum(m.cost_by_provider.values()), rel=1e-9, abs=1e-9)
    else:
        assert m.cost_by_provider == {}
    # --- commitments: standing bill re-derived from the capacity integral,
    # utilization bounded, idle waste = uncovered capacity at the rate
    assert m.has_commitments == cat.has_commitments
    if m.has_commitments:
        assert m.commitment_cost == pytest.approx(_pool_standing(sim),
                                                  rel=1e-9, abs=1e-9)
        assert m.commitment_cost <= m.total_cost + 1e-9
        idle = 0.0
        for ri, _cm in sim._pools:
            name = cat.regions[ri].name
            util = m.commitment_utilization[name]
            assert 0.0 <= util <= 1.0 + 1e-12
            cap_s = sim._pool_capacity_s[ri]
            cov_s = sim._pool_covered_s[ri]
            assert 0.0 <= cov_s <= cap_s + 1e-9
            idle += (cap_s - cov_s) / 3600.0 * sim._pool_rate[ri]
        assert m.commitment_idle_cost == pytest.approx(idle, rel=1e-9,
                                                       abs=1e-9)
    else:
        assert m.commitment_cost == 0.0
        assert m.commitment_idle_cost == 0.0
        assert m.commitment_utilization == {}
    # --- egress: exactly once per cross-region move, fee re-derived
    assert len(sim.egress_calls) == m.cross_region_migrations
    if cat.transfer is not None:
        fees = sum(cat.transfer.egress_usd(r_s, r_d, checkpoint_size_gb(w))
                   for w, r_s, r_d in sim.egress_calls)
        assert m.egress_cost == pytest.approx(fees, rel=1e-9, abs=1e-9)
    else:
        assert m.egress_cost == 0.0
    # --- pressure bus: exactly once per subscriber, audited by the copy
    bus = sim.pressure_bus
    n_subs = len(bus._subscribers)
    assert n_subs >= 2  # scheduler + instrumented copy
    assert bus.delivered == bus.published * n_subs
    assert len(sim.bus_copy) == bus.published
    # --- serving: request accounting integrates the profile exactly
    service_jobs = [j for j in jobs if j.service is not None]
    assert m.has_service == bool(service_jobs)
    if service_jobs:
        expect = sum(
            _lambda_integral(j.service.requests, j.arrival_time,
                             j.arrival_time + j.duration_s)
            for j in service_jobs)
        assert m.slo_requests_total == pytest.approx(expect, rel=1e-9)
        assert m.slo_requests_ok <= m.slo_requests_total + 1e-9
        assert m.service_utility_sum <= m.slo_requests_total + 1e-9
        for j in service_jobs:  # wall-clock window, not iterations
            assert j.completion_time == pytest.approx(
                j.arrival_time + j.duration_s)
    # --- every job completes (deadline backstops, service windows, batch)
    for j in jobs:
        assert j.completion_time is not None
    # --- event-cost conservation: every dollar the simulator bills flows
    # through the flight recorder's ledger exactly once, so the aggregated
    # (category, key) cells sum back to the metrics totals on every axis
    log = m.events
    if log is not None:
        assert sum(log.costs.values()) == pytest.approx(m.total_cost,
                                                        rel=1e-9, abs=1e-9)
        by_cat = log.cost_by("category")
        assert by_cat.get("egress", 0.0) == pytest.approx(m.egress_cost,
                                                          rel=1e-9, abs=1e-9)
        assert by_cat.get("commitment", 0.0) == pytest.approx(
            m.commitment_cost, rel=1e-9, abs=1e-9)
        if m.has_regions:
            by_key = log.cost_by("key")
            for name, amt in m.cost_by_region.items():
                assert by_key.get(name, 0.0) == pytest.approx(amt, rel=1e-9,
                                                              abs=1e-9)
        # lifecycle sanity: one terminate per provision (the billing law
        # above already pinned that nothing is left accruing)
        counts = log.counts()
        assert counts.get("terminate", 0) == counts.get("provision", 0)


# --------------------------------------------------------- seeded fallback
SEEDED = [
    ("aws", True, False, True, 0.4, 4, 2),
    ("multiregion", False, False, True, 0.0, 3, 5),
    ("burstable", True, True, False, 0.3, 4, 8),
    ("provider", True, False, False, 0.3, 3, 11),
    ("provider", False, False, True, 0.0, 3, 21),
]


@pytest.mark.parametrize("kind,spot,defer,service,hazard,n,seed", SEEDED)
def test_conservation_seeded(kind, spot, defer, service, hazard, n, seed):
    _check_conservation(*_run_composed(kind, spot, defer, service, hazard,
                                       n, seed))


def test_no_billing_while_pending():
    """A never-admit strike controller holds every deferrable job until
    its latest-start deadline: no instance may even be *requested* (let
    alone billed) before the earliest latest-start in the trace."""
    cat = aws_catalog()  # static: billing is exactly instance lifetimes
    jobs = deferrable_trace(n_jobs=5, seed=3)
    assert all(j.deferrable for j in jobs)
    sched = EvaScheduler(cat, policies=[SpotLayer(),
                                        AutoscaleLayer(strike=1e-9),
                                        SLOLayer()])
    sim = _Instrumented(cat, jobs, sched, SimConfig(seed=5))
    m = sim.run()
    first_ls = min(latest_start_s(j.deadline_s, j.duration_s) for j in jobs)
    assert m.instances_launched > 0
    for inst in sim.instances.values():
        assert inst.request_t >= first_ls - 1e-6
    assert m.deadline_misses == 0
    _check_conservation(sim, m, cat, jobs)


def test_ledgers_always_present_and_gated():
    """Regression for the latent ledger gap: every ledger dict exists on
    every run (empty-safe — no AttributeError / KeyError probing), and
    ``summary()`` keys are gated by the explicit ``has_*`` flags, not dict
    truthiness (a multi-region run whose ledger happens to be all-zero
    must still report it)."""
    # single-region, commitment-free: flags off, ledgers empty, no keys
    sim, m, _, _ = _run_composed("aws", False, False, False, 0.0, 2, 3)
    assert (m.has_regions, m.has_providers, m.has_commitments) == \
        (False, False, False)
    assert m.cost_by_region == {} and m.cost_by_provider == {}
    assert m.commitment_utilization == {}
    s = m.summary()
    assert "egress_cost" not in s and "capacity_denied" not in s
    assert not any(k.startswith(("cost_provider_", "util_")) for k in s)
    assert "commitment_cost" not in s
    # multi-region without providers: region keys present even while the
    # provider axis stays silent
    sim, m, cat_mr, _ = _run_composed("multiregion", False, False, False,
                                      0.0, 2, 3)
    assert m.has_regions and not m.has_providers
    s = m.summary()
    assert "egress_cost" in s
    assert all(f"cost_{r.name}" in s for r in cat_mr.regions)
    assert not any(k.startswith("cost_provider_") for k in s)
    # full provider grid: all three axes report
    sim, m, cat, _ = _run_composed("provider", False, False, False, 0.0,
                                   2, 3)
    assert m.has_regions and m.has_providers and m.has_commitments
    s = m.summary()
    assert any(k.startswith("cost_provider_") for k in s)
    assert any(k.startswith("util_") for k in s)
    assert "commitment_cost" in s and "commitment_idle_cost" in s


# ---------------------------------------- vectorized vs scalar equality
def _run_mode(kind, spot, defer, service, hazard, n, seed, *, vectorized,
              recording):
    """One composed scenario in one simulator mode; fresh jobs per run
    (the simulator mutates Job objects)."""
    cat, jobs, layers, cfg = _compose(kind, spot, defer, service, hazard,
                                      n, seed)
    rec = FlightRecorder(meta={"mode": "vec" if vectorized else "scalar"}) \
        if recording else None
    sched = EvaScheduler(cat, policies=layers, recorder=rec)
    sim = Simulator(cat, jobs, sched, cfg, recorder=rec,
                    vectorized=vectorized)
    return sim.run()


def _dicts_close(ds, dv, label):
    assert set(ds) == set(dv), label
    for k in ds:
        assert dv[k] == pytest.approx(ds[k], rel=1e-9, abs=1e-9), \
            f"{label}[{k}]"


def _check_vec_scalar_equality(kind, spot, defer, service, hazard, n, seed):
    """``Simulator(..., vectorized=True)`` must replay the exact event
    trajectory of the scalar reference: identical counters, summaries,
    ledgers, and recorder cost cells within the documented <=1e-9 relative
    tolerance (float reassociation on the vectorized sums), with recording
    both off and on."""
    for recording in (False, True):
        mv = _run_mode(kind, spot, defer, service, hazard, n, seed,
                       vectorized=True, recording=recording)
        ms = _run_mode(kind, spot, defer, service, hazard, n, seed,
                       vectorized=False, recording=recording)
        ss, sv = ms.summary(), mv.summary()
        assert set(ss) == set(sv)
        for k, a in ss.items():
            b = sv[k]
            if isinstance(a, float) or isinstance(b, float):
                assert b == pytest.approx(a, rel=1e-9, abs=1e-9), k
            else:
                assert a == b, k  # counters are decisions: exact
        _dicts_close(ms.cost_by_region, mv.cost_by_region, "cost_by_region")
        _dicts_close(ms.cost_by_provider, mv.cost_by_provider,
                     "cost_by_provider")
        _dicts_close(ms.commitment_utilization, mv.commitment_utilization,
                     "commitment_utilization")
        if recording:
            # event-cost conservation holds in both modes, and the
            # aggregated ledger cells agree cell-by-cell
            for m in (ms, mv):
                assert sum(m.events.costs.values()) == pytest.approx(
                    m.total_cost, rel=1e-9, abs=1e-9)
            _dicts_close(ms.events.cost_by("category"),
                         mv.events.cost_by("category"), "cost_by_category")
            _dicts_close(ms.events.cost_by("key"), mv.events.cost_by("key"),
                         "cost_by_key")
            assert ms.events.counts() == mv.events.counts()


@pytest.mark.parametrize("kind,spot,defer,service,hazard,n,seed", SEEDED)
def test_vectorized_matches_scalar_seeded(kind, spot, defer, service,
                                          hazard, n, seed):
    _check_vec_scalar_equality(kind, spot, defer, service, hazard, n, seed)


# ------------------------------------------------------- hypothesis sweep
@pytest.fixture(scope="module")
def _hyp():
    return pytest.importorskip("hypothesis")


def test_conservation_random_compositions(_hyp):
    """Random axis compositions through the same conservation checker.

    Bounded profile (few examples, no deadline): each example is a full
    simulator run, so the sweep stays CI-sized; the seeded tests above
    keep the laws pinned when hypothesis is absent.
    """
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(
        kind=st.sampled_from(["aws", "multiregion", "burstable",
                              "provider"]),
        spot=st.booleans(),
        deferrable=st.booleans(),
        service=st.booleans(),
        hazard=st.sampled_from([0.0, 0.3, 0.6]),
        n_jobs=st.integers(2, 5),
        seed=st.integers(0, 50),
    )
    def inner(kind, spot, deferrable, service, hazard, n_jobs, seed):
        _check_conservation(*_run_composed(kind, spot, deferrable, service,
                                           hazard, n_jobs, seed))

    inner()
