"""Online serving axis: the latency/utility model, the simulator's
SLO accounting, and the ``SLOLayer`` semantics.

Pins the serving contracts:
* the utility curve is 1.0 at/below the p99 target and decays
  monotonically beyond it;
* the M/M/1-style p99 model is monotone in utilization and the
  ``ServiceSpec`` risk margin has the documented edge behaviour;
* diurnal request profiles peak where told and surge windows multiply;
* a service job completes exactly at ``arrival + duration`` (wall-clock
  window, not iterations) and attains its SLO when capacity is ample;
* ``SLOLayer``: planning-view headroom inflation survives ``subset``,
  the warm-keep exemption holds exactly while the job is at utility risk
  and expires when the risk clears, price-dip damping is risk-gated, the
  capacity-aware move veto staggers replica migrations, and every hook is
  the identity on service-free views;
* ``slo`` pressure signals fire on the risk rising edge only;
* admission controllers never hold service jobs.

The acceptance test runs the quick serving trace end-to-end: the
eva-slo stack must keep fleet p99-SLO attainment at/above the target the
benchmark documents (bench_serving pins the comparison against the
headroom-blind stack and the batch-only cost anchor).
"""
import math

import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator, serving_trace
from repro.core import (EvaScheduler, PriceModel, RequestProfile, ServiceSpec,
                        UtilityCurve, aws_catalog, make_job, p99_latency_ms)
from repro.core.cluster_types import ClusterConfig, TaskSet
from repro.core.plan import LiveInstance
from repro.core.scheduler import SchedulerView
from repro.core.workloads import WORKLOAD_INDEX
from repro.policies import SLOLayer, SpotLayer, stack_from_flags

EMBED = WORKLOAD_INDEX["embed-serve"]
LLM = WORKLOAD_INDEX["llm-serve"]


# ------------------------------------------------------------ latency model
def test_utility_curve_monotone_and_saturating():
    u = UtilityCurve(target_p99_ms=100.0, softness_ms=50.0)
    assert u.utility(0.0) == 1.0 and u.utility(100.0) == 1.0
    lats = np.linspace(0.0, 2000.0, 200)
    vals = [u.utility(x) for x in lats]
    assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))
    assert u.utility(float("inf")) == u.floor
    assert u.utility(float("nan")) == u.floor


def test_p99_monotone_in_utilization():
    rhos = np.linspace(0.0, 0.99, 50)
    lats = [p99_latency_ms(25.0, r) for r in rhos]
    assert all(a < b for a, b in zip(lats, lats[1:]))
    assert p99_latency_ms(25.0, 0.0) == 25.0
    assert math.isinf(p99_latency_ms(25.0, 1.0))


def test_service_spec_risk_edges():
    spec = ServiceSpec(
        requests=RequestProfile((0.0,), (100.0,)),
        utility=UtilityCurve(100.0), per_replica_rps=400.0,
        base_latency_ms=25.0)
    assert spec.max_utilization() == pytest.approx(0.75)
    assert not spec.at_risk(0.0, 0.0)  # no load, no risk
    assert spec.at_risk(1.0, 0.0)  # any load with zero capacity
    # threshold: risk_fraction × max_utilization = 0.6
    assert not spec.at_risk(0.59 * 800.0, 800.0)
    assert spec.at_risk(0.61 * 800.0, 800.0)
    # feasible ceiling: p99 at max_utilization equals the target exactly
    assert spec.p99_ms(0.75 * 800.0, 800.0) == pytest.approx(100.0)


def test_request_profile_rate_and_breakpoints():
    prof = RequestProfile((0.0, 100.0, 200.0), (5.0, 50.0, 10.0))
    assert prof.rate_at(-1.0) == 0.0
    assert prof.rate_at(0.0) == 5.0 and prof.rate_at(99.9) == 5.0
    assert prof.rate_at(100.0) == 50.0 and prof.rate_at(1e9) == 10.0
    assert prof.breakpoints_between(0.0, 200.0) == (100.0,)
    assert prof.breakpoints_between(50.0, 300.0) == (100.0, 200.0)
    with pytest.raises(ValueError):
        RequestProfile((0.0, 0.0), (1.0, 1.0))


def test_diurnal_profile_peaks_and_surges():
    day = 24 * 3600.0
    prof = RequestProfile.diurnal(1000.0, duration_s=day, step_s=900.0,
                                  trough=0.2, peak_hour=14.0)
    assert prof.rate_at(14 * 3600.0) == pytest.approx(1000.0, rel=1e-3)
    # trough is 12h opposite the peak
    assert prof.rate_at(2 * 3600.0) == pytest.approx(200.0, rel=1e-2)
    surged = RequestProfile.diurnal(
        1000.0, duration_s=day, step_s=900.0, trough=0.2, peak_hour=14.0,
        surges=((10 * 3600.0, 11 * 3600.0, 2.0),))
    t = 10.5 * 3600.0
    assert surged.rate_at(t) == pytest.approx(2.0 * prof.rate_at(t), rel=1e-6)
    assert surged.peak_rps() >= prof.peak_rps()


# -------------------------------------------------------- simulator serving
def _embed_spec(rps=100.0, warmup_s=600.0, per_replica=400.0):
    """Constant-rate spec after a warmup long enough to launch replicas."""
    profile = (RequestProfile((0.0,), (rps,)) if warmup_s <= 0 else
               RequestProfile((0.0, warmup_s), (0.0, rps)))
    return ServiceSpec(
        requests=profile, utility=UtilityCurve(100.0),
        per_replica_rps=per_replica, base_latency_ms=25.0)


def test_service_job_runs_full_window_and_attains():
    """Ample capacity: the job completes at arrival+duration exactly and
    every post-warmup request lands inside the SLO."""
    spec = _embed_spec()
    job = make_job(job_id=1, workload=EMBED, arrival_time=0.0,
                   duration_s=2 * 3600.0, n_tasks=2, service=spec)
    cat = aws_catalog()
    sched = EvaScheduler(cat, policies=[SpotLayer(), SLOLayer()])
    sim = Simulator(cat, [job], sched, SimConfig(seed=3))
    m = sim.run()
    assert job.completion_time == pytest.approx(2 * 3600.0)
    assert m.has_service
    assert m.slo_attainment == pytest.approx(1.0)
    assert m.service_utility == pytest.approx(1.0)
    # ∫λdt over the window: 100 rps for (7200 - 600) s
    assert m.slo_requests_total == pytest.approx(100.0 * 6600.0)
    assert m.slo_pressure_signals == 0  # warmup covers the launch window


def test_slo_pressure_fires_on_rising_edge_only():
    """An undersized fleet under load is at risk from the moment its load
    appears; the signal fires once per risk entry, not once per round."""
    spec = _embed_spec(rps=700.0, warmup_s=0.0)  # 2 replicas = 800 rps cap
    job = make_job(job_id=1, workload=EMBED, arrival_time=0.0,
                   duration_s=1.0 * 3600.0, n_tasks=2, service=spec)
    cat = aws_catalog()
    sched = EvaScheduler(cat, policies=[SpotLayer(), SLOLayer()])
    sim = Simulator(cat, [job], sched, SimConfig(seed=3))
    m = sim.run()
    # risk entered at arrival (capacity 0, load > 0) and again only if the
    # fleet ever left risk; ρ = 700/800 = 0.875 ≥ 0.6 stays at risk
    assert m.slo_pressure_signals == 1
    assert sched.stack.get("slo").slo_signals == 1


# --------------------------------------------------------- SLOLayer hooks
def _bound_layer(**kw):
    sched = EvaScheduler(aws_catalog(), policies=[SLOLayer(**kw)])
    return sched, sched.stack.get("slo")


def _service_view(jid=7, n=2, lam=100.0, cap=800.0, risk=(), live=(),
                  extra_jobs=()):
    jobs = [make_job(job_id=jid, workload=EMBED, arrival_time=0.0,
                     duration_s=3600.0, n_tasks=n,
                     service=_embed_spec(rps=lam, warmup_s=0.0))]
    jobs += list(extra_jobs)
    tasks = [t for j in jobs for t in j.tasks]
    return SchedulerView(
        time=0.0, tasks=TaskSet(tasks), pending_ids=set(),
        live=list(live), task_workload={t.task_id: t.workload for t in tasks},
        service={jid}, service_rps={jid: lam}, service_capacity={jid: cap},
        slo_risk=set(risk) or None,
        service_specs={jid: jobs[0].service}), jobs[0]


def test_pre_round_identity_without_service():
    sched, layer = _bound_layer()
    job = make_job(job_id=1, workload=0, arrival_time=0.0, duration_s=3600.0)
    view = SchedulerView(time=0.0, tasks=TaskSet(job.tasks), pending_ids=set(),
                         live=[], task_workload={})
    out, resumed = layer.pre_round(view, 3600.0)
    assert out is view and resumed == set()
    assert layer.plan_catalog(sched.catalog, out, 3600.0) is sched.catalog
    assert layer.keep_bonus(sched.catalog, sched.catalog, out) is None
    cfg = ClusterConfig([])
    assert layer.refine(cfg, out, sched.catalog) is cfg


def test_headroom_inflates_planning_demand_and_survives_subset():
    sched, layer = _bound_layer(headroom=1.5)
    view, job = _service_view()
    out, _ = layer.pre_round(view, 3600.0)
    tid = job.tasks[0].task_id
    before = view.tasks.demand_by_family[view.tasks.row(tid)]
    after = out.tasks.demand_by_family[out.tasks.row(tid)]
    np.testing.assert_allclose(after[:, 0], before[:, 0])  # gpu exact
    np.testing.assert_allclose(after[:, 1:], before[:, 1:] * 1.5)
    # inflation must survive a downstream subset (admission layers subset)
    sub = out.tasks.subset({tid})
    np.testing.assert_allclose(sub.demand_by_family[sub.row(tid), :, 1:],
                               before[:, 1:] * 1.5)


def test_warm_keep_exemption_expires_with_risk():
    from repro.policies.slo import EXEMPT_SLACK
    sched, layer = _bound_layer()
    cat = sched.catalog
    k = cat.index_of("c7i.4xlarge")
    view, job = _service_view(risk=(7,))
    tids = tuple(t.task_id for t in job.tasks)
    layer.pre_round(view, 3600.0)
    bonus = layer.keep_bonus(cat, cat, view)
    assert bonus(k, tids[:1]) == EXEMPT_SLACK  # at risk: exempt
    assert bonus(k, (10 ** 9,)) == 0.0  # non-service tasks: no slack
    # risk clears -> the exemption expires to the standing hold slack
    view2 = SchedulerView(**{**view.__dict__, "slo_risk": None})
    layer.pre_round(view2, 3600.0)
    bonus2 = layer.keep_bonus(cat, cat, view2)
    held = bonus2(k, tids[:1])
    assert 0.0 < held < EXEMPT_SLACK / 1e3  # finite standing slack, not 1e9


def test_price_dip_damping_is_risk_gated():
    sched, layer = _bound_layer()
    cat = sched.catalog
    view, _ = _service_view(risk=())
    layer.pre_round(view, 3600.0)
    layer.plan_catalog(cat, view, 3600.0)  # seeds the EMA at current costs
    import dataclasses
    dipped = dataclasses.replace(cat, costs=cat.costs * 0.5)
    # off-risk: dips pass through untouched
    assert layer.plan_catalog(dipped, view, 3600.0) is dipped
    # at risk: the dip is lifted toward the EMA, rises untouched
    layer._ema = cat.costs.copy()
    view_r, _ = _service_view(risk=(7,))
    layer.pre_round(view_r, 3600.0)
    damped = layer.plan_catalog(dipped, view_r, 3600.0)
    assert np.all(damped.costs >= dipped.costs)
    assert np.any(damped.costs > dipped.costs)
    np.testing.assert_array_equal(
        np.argsort(-damped.costs, kind="stable"), damped.order_desc)


def test_move_veto_staggers_replica_migrations():
    """A config that puts every replica in flight at once is rewritten to
    move only as many as the surviving capacity can spare at the current
    request rate; at high load nothing moves."""
    sched, layer = _bound_layer()
    cat = sched.catalog
    k = cat.index_of("c7i.4xlarge")
    # high load: ρ would blow the risk margin with any replica offline
    view, job = _service_view(lam=700.0, cap=800.0)
    t1, t2 = (t.task_id for t in job.tasks)
    view = SchedulerView(**{**view.__dict__,
                            "live": [LiveInstance(101, k, (t1,)),
                                     LiveInstance(102, k, (t2,))]})
    layer.pre_round(view, 3600.0)
    moved = ClusterConfig([(k, (t1,)), (k, (t2,))])
    # the diff matches slots back to the live instances (same type and
    # tasks), so this config moves nothing — identity
    assert layer.refine(moved, view, cat).assignments == moved.assignments
    k2 = cat.index_of("c7i.8xlarge")
    churn = ClusterConfig([(k2, (t1, t2))])  # both replicas in flight
    out = layer.refine(churn, view, cat)
    assert layer.move_vetoes == 2
    assert sorted(out.assignments) == [(k, (t1,)), (k, (t2,))]
    # low load: one replica may chase the cheaper type, never both at once
    view_lo, job = _service_view(lam=100.0, cap=800.0)
    t1, t2 = (t.task_id for t in job.tasks)
    view_lo = SchedulerView(**{**view_lo.__dict__,
                               "live": [LiveInstance(101, k, (t1,)),
                                        LiveInstance(102, k, (t2,))]})
    layer.pre_round(view_lo, 3600.0)
    out = layer.refine(ClusterConfig([(k2, (t1, t2))]), view_lo, cat)
    in_flight = [a for a in out.assignments if a[0] == k2]
    assert len(in_flight) == 1 and len(in_flight[0][1]) == 1
    assert layer.move_vetoes == 3  # one of the two vetoed this time


def test_escape_moves_are_never_vetoed():
    """Moves off a revoked (or throttled) host raise capacity and must
    pass the veto even under full load."""
    sched, layer = _bound_layer()
    cat = sched.catalog
    k = cat.index_of("c7i.4xlarge")
    view, job = _service_view(lam=700.0, cap=800.0)
    t1, t2 = (t.task_id for t in job.tasks)
    view = SchedulerView(**{**view.__dict__,
                            "live": [LiveInstance(101, k, (t1,)),
                                     LiveInstance(102, k, (t2,))],
                            "revoked": {101}})
    layer.pre_round(view, 3600.0)
    k2 = cat.index_of("c7i.8xlarge")
    out = layer.refine(ClusterConfig([(k2, (t1,)), (k, (t2,))]), view, cat)
    assert (k2, (t1,)) in out.assignments  # escape allowed
    assert layer.move_vetoes == 0


# ----------------------------------------------------- admission exclusion
def test_admission_never_holds_service_jobs():
    """Even a never-admit strike controller must not defer a service job:
    latency work held for a price dip forfeits utility permanently."""
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    spec = _embed_spec()
    jobs = [make_job(job_id=1, workload=EMBED, arrival_time=0.0,
                     duration_s=3600.0, n_tasks=2, service=spec,
                     deferrable=True, deadline_s=10 * 3600.0)]
    stack = stack_from_flags(spot_aware=True, autoscale=True, strike=1e-9,
                             slo=True)
    sched = EvaScheduler(cat, policies=stack)
    sim = Simulator(cat, jobs, sched, SimConfig(seed=5))
    m = sim.run()
    assert sim.jobs[1].admitted_t is not None
    assert sim.jobs[1].admitted_t < 600.0  # first rounds, not the deadline
    assert m.deferred_jobs == 0


# ------------------------------------------------------------- acceptance
def test_quick_serving_trace_attains_slo():
    """End-to-end acceptance on the quick diurnal trace: the eva-slo stack
    keeps fleet p99-SLO attainment at/above the benchmark target."""
    SLO_TARGET = 0.95  # keep in sync with benchmarks/bench_serving.py
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    cat = aws_catalog(price_model=pm)
    jobs = serving_trace(n_batch=8, horizon_h=6.0, seed=17)
    sched = EvaScheduler(cat, policies=stack_from_flags(spot_aware=True,
                                                        slo=True))
    cfg = SimConfig(seed=5, preemption_hazard_per_hour=0.25)
    m = Simulator(cat, jobs, sched, cfg).run()
    assert m.has_service
    assert m.slo_attainment >= SLO_TARGET
    assert m.service_utility >= SLO_TARGET
    # every batch job still completes next to the inference fleet
    for j in jobs:
        assert j.completion_time is not None
