"""§4.4 attribution rules, unit-level."""
from repro.core import ThroughputTable


def test_rule1_no_previous_observations():
    t = ThroughputTable(5)
    # job with 3 tasks: placements (workload, co-located workloads)
    placements = [(0, (1,)), (1, (0, 2)), (2, ())]
    t.observe_job(placements, 0.8)
    # updates the task co-located with the MOST tasks -> (1, (0, 2))
    assert t.recorded(1, (0, 2)) == 0.8
    assert t.recorded(0, (1,)) is None


def test_rule2_raise_lowest_recorded():
    t = ThroughputTable(5)
    t.record(0, (1,), 0.6)
    t.record(1, (0, 2), 0.7)
    placements = [(0, (1,)), (1, (0, 2))]
    t.observe_job(placements, 0.75)
    # both recorded below 0.75 -> raise the LOWEST (0, (1,))
    assert t.recorded(0, (1,)) == 0.75
    assert t.recorded(1, (0, 2)) == 0.7


def test_rule3_unrecorded_straggler():
    t = ThroughputTable(5)
    t.record(0, (1,), 0.95)
    placements = [(0, (1,)), (1, (0, 2)), (3, (4,))]
    t.observe_job(placements, 0.7)
    # all recorded (0.95) are higher -> straggler must be unrecorded; the
    # one with most co-located tasks is (1, (0, 2))
    assert t.recorded(1, (0, 2)) == 0.7
    assert t.recorded(0, (1,)) == 0.95


def test_solo_tasks_never_updated():
    t = ThroughputTable(5)
    t.observe_job([(0, ()), (1, ())], 0.5)  # all solo -> noise, ignore
    assert len(t) == 0


def test_lookup_exact_beats_pairwise():
    t = ThroughputTable(5, default=0.9)
    t.record(0, (1, 2), 0.5)
    assert t.lookup(0, (2, 1)) == 0.5  # order-insensitive exact hit
    assert abs(t.lookup(0, (1, 3)) - 0.9 * 0.9) < 1e-12  # pairwise product
