"""Burstable-credit (CASH) layer: credit dynamics in the catalog, throttling
in the simulator, credit-adjusted reservation prices, and the credit-aware
Eva scheduler.

Contract tests anchoring the design:
* non-burstable catalogs are *bit-identical* to PR 2 — on-demand and spot
  runs driven with ``credit_aware=True`` reproduce the plain runs metric
  for metric (the credit layer is strictly additive);
* throttling collapses throughput while the bill stays flat (the
  cost/throughput asymmetry), and exhaustion is a deterministic event;
* a throttled instance triggers migration off via the decayed keep test +
  forced drain (the acceptance test), and fresh slots are never matched
  onto exhausted instances;
* eva-credit is strictly cheaper than credit-blind eva AND on-demand eva on
  the bundled ``burstable_demo_catalog`` market (the benchmark/CI
  invariant).
"""
import numpy as np
import pytest

from repro.cluster import SimConfig, Simulator, burstable_trace
from repro.core import (Catalog, ClusterConfig, CreditModel, EvaScheduler,
                        InstanceType, LiveInstance, PriceModel, Region,
                        SchedulerBase, SchedulerView, TaskSet, aws_catalog,
                        burstable_demo_catalog, full_reconfiguration,
                        make_job, multi_region_catalog, reservation_prices)

B = 0.2  # demo baseline fraction
PRICE_FRACTION = 0.42


# ------------------------------------------------------------- credit model
def test_credit_model_dynamics():
    cm = CreditModel(baseline_fraction=B, launch_credit_hours=0.5,
                     credit_cap_hours=2.0)
    assert cm.accrual_per_hour == B  # T-family identity default
    assert cm.drain_per_hour() == pytest.approx(1.0 - B)
    assert cm.burst_hours(0.5) == pytest.approx(0.5 / 0.8)
    # sustainable duty never exhausts
    assert cm.burst_hours(0.5, duty=B) == float("inf")
    assert cm.avg_speed_over(0.5, 10.0, duty=B) == 1.0
    # instantaneous speed: full with balance, baseline at zero
    assert cm.speed(0.3) == 1.0
    assert cm.speed(0.0) == B
    # average over a horizon: full while the balance lasts, baseline after
    assert cm.avg_speed_over(0.8, 1.0) == 1.0  # 1h burst covers 1h horizon
    assert cm.avg_speed_over(0.0, 1.0) == pytest.approx(B)
    t_full = cm.burst_hours(0.5)
    expect = (t_full + (2.0 - t_full) * B) / 2.0
    assert cm.avg_speed_over(0.5, 2.0) == pytest.approx(expect)


def test_credit_priced_identity_and_adjustment():
    plain = aws_catalog()
    assert plain.credit_models is None and not plain.is_burstable
    assert plain.credit_priced(3600.0) is plain  # identity, PR-2 contract
    cat = burstable_demo_catalog()
    assert cat.credit_priced(None) is cat
    burst = np.array([cm is not None for cm in cat.credit_models])
    # zero balances: burstable types inflate by exactly 1/baseline
    zero = cat.credit_priced(3600.0, balances=np.zeros(len(cat)))
    np.testing.assert_allclose(zero.costs[burst], cat.costs[burst] / B)
    np.testing.assert_array_equal(zero.costs[~burst], cat.costs[~burst])
    # launch balances cover a short horizon: identity prices, same order
    short = cat.credit_priced(1200.0)
    np.testing.assert_allclose(short.costs, cat.costs)
    # billing-side costs of the original catalog are never touched
    np.testing.assert_array_equal(cat.costs[burst],
                                  np.array([t.hourly_cost for t, b in
                                            zip(cat.types, burst) if b]))


def test_launch_credits_clamped_to_cap_everywhere():
    """Planner and simulator must agree on the launch balance when the
    configured launch credits exceed the cap."""
    cm = CreditModel(baseline_fraction=B, launch_credit_hours=3.0,
                     credit_cap_hours=2.0)
    assert cm.effective_launch_hours == 2.0
    cat = burstable_demo_catalog(launch_credit_hours=3.0,
                                 credit_cap_hours=2.0)
    k_b = cat.index_of("t7i.2xlarge")
    assert cat.launch_balances[k_b] == 2.0  # what credit_priced forecasts
    job = _one_job(8, 600.0)
    sched = _Scripted(cat, [ClusterConfig([(k_b, (job.tasks[0].task_id,))])])
    sim = Simulator(cat, [job], sched, SimConfig(seed=1))
    sim.run()
    # the simulator granted the same clamped balance the planner priced
    # (minus the drain, plus setup-idle accrual — both bounded by the cap)
    assert sim.instances[0].credit_hours <= 2.0


def test_burstable_demo_catalog_shape():
    cat = burstable_demo_catalog()
    assert len(cat) == len(aws_catalog()) + 7
    assert sum(cm is not None for cm in cat.credit_models) == 7
    k_b = cat.index_of("t7i.2xlarge")
    k_od = cat.index_of("c7i.2xlarge")
    assert cat.costs[k_b] == pytest.approx(cat.costs[k_od] * PRICE_FRACTION)
    np.testing.assert_array_equal(cat.capacities[k_b], cat.capacities[k_od])
    # throttled, a burstable type is dearer per unit of work than its twin
    assert cat.costs[k_b] / B > cat.costs[k_od]
    bal = cat.launch_balances
    assert bal[k_b] == 0.5 and bal[k_od] == 0.0


def test_reservation_prices_credit_horizon():
    cat = burstable_demo_catalog()
    tasks = TaskSet(make_job(job_id=1, workload=8, arrival_time=0.0,
                             duration_s=1000.0, n_tasks=1).tasks)  # diamond
    k_b, k_od = cat.index_of("t7i.2xlarge"), cat.index_of("c7i.2xlarge")
    # short horizon: launch credits outlast it -> burstable sticker price
    rp_short = reservation_prices(tasks, cat, credit_horizon_s=1200.0)
    assert rp_short[0] == pytest.approx(cat.costs[k_b])
    # long horizon: the burst window is a sliver -> anchors to the on-demand
    # twin (the credit-adjusted burstable price exceeds it)
    rp_long = reservation_prices(tasks, cat, credit_horizon_s=8 * 3600.0)
    assert rp_long[0] == pytest.approx(cat.costs[k_od])
    # no horizon: the credit-blind sticker price
    assert reservation_prices(tasks, cat)[0] == pytest.approx(cat.costs[k_b])


def test_full_reconfig_credit_horizon_switches_types():
    cat = burstable_demo_catalog()
    jobs = [make_job(job_id=i + 1, workload=8, arrival_time=0.0,
                     duration_s=1000.0, n_tasks=1) for i in range(3)]
    tasks = TaskSet([j.tasks[0] for j in jobs])
    short = full_reconfiguration(tasks, cat, None, credit_horizon_s=1200.0)
    assert short.num_tasks() == 3
    assert all(cat.credit_models[k] is not None for k, _ in short.assignments)
    long = full_reconfiguration(tasks, cat, None, credit_horizon_s=8 * 3600.0)
    assert long.num_tasks() == 3
    assert all(cat.credit_models[k] is None for k, _ in long.assignments)


def test_multi_region_catalog_carries_credit_models():
    base = burstable_demo_catalog().types
    regs = (Region("a"), Region("b", cost_scale=1.1))
    cat = multi_region_catalog(regs, base_types=base)
    assert cat.is_burstable
    assert len(cat.credit_models) == 2 * len(base)
    pattern = [t.credit_model is not None for t in base]
    assert [cm is not None for cm in cat.credit_models] == pattern * 2
    # the credit-priced planning view composes with region expansion
    zero = cat.credit_priced(3600.0, balances=np.zeros(len(cat)))
    k = cat.index_of("b/t7i.2xlarge")
    assert zero.costs[k] == pytest.approx(cat.costs[k] / B)


# ---------------------------------------------------------------- simulator
class _Scripted(SchedulerBase):
    """Replays a fixed list of configurations, one per round."""

    name = "scripted"

    def __init__(self, catalog, script):
        super().__init__(catalog)
        self.script = list(script)
        self.round = 0

    def schedule(self, view):
        cfg = self.script[min(self.round, len(self.script) - 1)]
        self.round += 1
        return cfg


def _one_job(workload, duration_s, arrival=0.0):
    return make_job(job_id=1, workload=workload, arrival_time=arrival,
                    duration_s=duration_s, n_tasks=1)


def test_throttle_collapses_throughput_but_not_the_bill():
    """A pinned diamond job exhausts its launch credits mid-run: progress
    drops to the baseline rate (completion stretches accordingly) while
    billing stays at the unchanged hourly price — the CASH asymmetry."""
    cat = burstable_demo_catalog()
    k_b = cat.index_of("t7i.2xlarge")
    job = _one_job(8, 0.9 * 3600.0)  # diamond, 0.9 h of work
    tid = job.tasks[0].task_id
    sched = _Scripted(cat, [ClusterConfig([(k_b, (tid,))])])
    sim = Simulator(cat, [job], sched, SimConfig(seed=1))
    m = sim.run()
    assert job.completion_time is not None
    assert m.credit_exhaustions == 1
    inst = sim.instances[0]
    # credits accrue from request until the task starts running (setup is
    # idle time), then drain at 1 - accrual per busy hour
    t_run = inst.ready_t + 12.0  # diamond launch delay (Table 7)
    bal = 0.5 + B * (t_run - inst.request_t) / 3600.0
    t_full_h = bal / (1.0 - B)  # busy hours until exhaustion
    assert t_full_h < 0.9  # the job really outlasts its burst window
    # the remaining work crawls at the baseline rate
    expect_throttled = (0.9 - t_full_h) / B * 3600.0
    assert m.throttled_s == pytest.approx(expect_throttled, rel=1e-6)
    assert job.completion_time == pytest.approx(
        t_run + t_full_h * 3600.0 + expect_throttled)
    alive_h = (inst.terminated_t - inst.request_t) / 3600.0
    # the bill is exactly price x alive time: throttling never discounts it
    assert m.total_cost == pytest.approx(cat.costs[k_b] * alive_h)
    assert m.summary()["credit_exhaustions"] == 1


def test_burst_duty_scales_the_drain():
    """a3c (duty 0.7) drains credits slower than diamond (duty 1.0): the
    same 0.8 h job throttles on diamond's drain rate but finishes within
    a3c's longer burst window."""
    cat = burstable_demo_catalog()
    runs = {}
    for w, type_name in ((8, "t7i.2xlarge"), (7, "t7i.xlarge")):
        job = _one_job(w, 0.8 * 3600.0)
        k = cat.index_of(type_name)
        sched = _Scripted(cat, [ClusterConfig([(k, (job.tasks[0].task_id,))])])
        runs[w] = Simulator(cat, [job], sched, SimConfig(seed=1)).run()
    assert runs[8].credit_exhaustions == 1  # 0.8 h > 0.5/0.8 h burst
    assert runs[7].credit_exhaustions == 0  # 0.8 h < 0.5/0.5 h burst
    assert runs[7].throttled_s == 0.0


class _Recorder(EvaScheduler):
    """Credit-blind Eva that records observe_single samples and
    credit-pressure signals."""

    def __init__(self, catalog):
        super().__init__(catalog)
        self.samples = []
        self.pressure = []

    def observe_single(self, workload, colocated, value):
        self.samples.append(float(value))
        super().observe_single(workload, colocated, value)

    def on_credit_pressure(self, instance_ids, time_s):
        self.pressure.append((tuple(instance_ids), float(time_s)))
        super().on_credit_pressure(instance_ids, time_s)


def test_throttled_observations_withheld_from_monitor():
    """Two co-located a3c tasks on one burstable instance: interference
    samples flow to the monitor only while the instance is unthrottled —
    a throttled sample would read ~baseline x interference and poison the
    co-location table."""
    cat = burstable_demo_catalog()
    k = cat.index_of("t7i.2xlarge")  # fits two a3c (4 vCPU each)
    jobs = [make_job(job_id=i + 1, workload=7, arrival_time=0.0,
                     duration_s=2.5 * 3600.0, n_tasks=1) for i in range(2)]
    t1, t2 = (j.tasks[0].task_id for j in jobs)
    cfg = ClusterConfig([(k, (t1, t2))])
    sched = _Recorder(cat)
    sched.schedule = lambda view: cfg  # pin the placement, keep the hooks
    m = Simulator(cat, jobs, sched, SimConfig(seed=1)).run()
    assert m.credit_exhaustions >= 1 and m.throttled_s > 0
    assert sched.samples, "unthrottled rounds must still report"
    # every sample is pure co-location interference, never x baseline
    assert min(sched.samples) > 0.5
    assert sched.pressure and sched.pressure[0][0] == (0,)


def test_credit_pressure_fires_an_extra_round():
    """Exhaustion schedules an immediate extra round (off the fixed round
    grid) so the scheduler can react within the event, mirroring the spot
    revocation wiring."""
    cat = burstable_demo_catalog()
    k = cat.index_of("t7i.2xlarge")
    job = _one_job(8, 1.2 * 3600.0)
    tid = job.tasks[0].task_id

    times = []

    class _Pinned(_Scripted):
        def schedule(self, view):
            times.append(view.time)
            return super().schedule(view)

    sched = _Pinned(cat, [ClusterConfig([(k, (tid,))])])
    m = Simulator(cat, [job], sched, SimConfig(seed=1)).run()
    assert m.credit_exhaustions == 1
    off_grid = [t for t in times if t % 300.0 != 0.0]
    assert off_grid, "no extra round fired at the exhaustion instant"


def test_fresh_slots_never_match_throttled_instances():
    """Anonymous-slot matching may not hand a brand-new task an exhausted
    instance: a zero-overlap slot of a burstable type launches fresh (with
    launch credits) instead."""
    cat = burstable_demo_catalog()
    k_b = cat.index_of("t7i.2xlarge")
    k_od = cat.index_of("c7i.2xlarge")
    j1 = make_job(job_id=1, workload=8, arrival_time=0.0,
                  duration_s=2.0 * 3600.0, n_tasks=1)
    j2 = make_job(job_id=2, workload=8, arrival_time=3600.0,
                  duration_s=0.5 * 3600.0, n_tasks=1)
    t1, t2 = j1.tasks[0].task_id, j2.tasks[0].task_id

    class _TwoPhase(SchedulerBase):
        name = "two-phase"

        def schedule(self, view):
            ids = set(view.tasks.ids.tolist())
            if t2 not in ids and j2.completion_time is None:
                return ClusterConfig([(k_b, (t1,))])
            # j1's instance is throttled by now; move t1 to on-demand and
            # ask for a burstable instance for t2 — zero overlap with the
            # exhausted one, so the executor must launch fresh
            slots = [(k_od, (t1,))] if t1 in ids else []
            if t2 in ids:
                slots.append((k_b, (t2,)))
            return ClusterConfig(slots)

    sim = Simulator(cat, [j1, j2], _TwoPhase(cat), SimConfig(seed=1))
    m = sim.run()
    assert m.credit_exhaustions == 1
    assert all(j.completion_time is not None for j in (j1, j2))
    # three instances: t1's exhausted t7i, t1's c7i escape, t2's fresh t7i
    assert m.instances_launched == 3
    # t2 ran at full speed on its fresh instance: jct ~ duration + overheads
    jct2 = j2.completion_time - j2.arrival_time
    assert jct2 < 0.8 * 3600.0  # throttled it would take ~2.5 h


# ------------------------------------------------- strictly additive (PR 2)
def test_ondemand_bit_identical_with_credit_aware_flag():
    """Acceptance: a non-burstable catalog driven by
    EvaScheduler(credit_aware=True) reproduces the plain PR-2 run metric
    for metric, and a plain catalog run carries no credit metrics."""
    from repro.cluster import physical_trace
    jobs_kw = dict(n_jobs=10, seed=11, duration_range_h=(0.3, 0.6))
    m1 = Simulator(aws_catalog(), physical_trace(**jobs_kw),
                   EvaScheduler(aws_catalog(), credit_aware=True),
                   SimConfig(seed=5)).run()
    m2 = Simulator(aws_catalog(), physical_trace(**jobs_kw),
                   EvaScheduler(aws_catalog()), SimConfig(seed=5)).run()
    assert m1.summary() == m2.summary()
    assert m1.total_cost == m2.total_cost  # bit-for-bit
    assert m1.jct_sum == m2.jct_sum
    assert m1.migrations == m2.migrations
    assert not m1.has_credits and "credit_exhaustions" not in m1.summary()


def test_spot_bit_identical_with_credit_aware_flag():
    """The spot path of PR 1/2 is also untouched: credit_aware on a
    non-burstable spot catalog changes nothing, preemptions included."""
    from repro.cluster import physical_trace
    pm = PriceModel.mean_reverting(discount=0.35, seed=7)
    jobs_kw = dict(n_jobs=12, seed=11, duration_range_h=(0.3, 0.6))
    cfg_kw = dict(seed=5, preemption_hazard_per_hour=0.5)
    m1 = Simulator(aws_catalog(price_model=pm), physical_trace(**jobs_kw),
                   EvaScheduler(aws_catalog(price_model=pm), spot_aware=True,
                                credit_aware=True),
                   SimConfig(**cfg_kw)).run()
    m2 = Simulator(aws_catalog(price_model=pm), physical_trace(**jobs_kw),
                   EvaScheduler(aws_catalog(price_model=pm), spot_aware=True),
                   SimConfig(**cfg_kw)).run()
    assert m1.total_cost == m2.total_cost
    assert m1.preemptions == m2.preemptions
    assert m1.preemption_notices == m2.preemption_notices
    assert m1.migrations == m2.migrations
    assert m1.instances_launched == m2.instances_launched


# ------------------------------------------------------------ the scheduler
def test_keep_test_healthy_balance_keeps_exhausted_drains():
    """The balance-decayed keep test: a burstable instance with a healthy
    balance is kept; a throttled one is drained onto its steady twin."""
    cat = burstable_demo_catalog()
    k_b = cat.index_of("t7i.2xlarge")
    job = _one_job(8, 4000.0)
    tid = job.tasks[0].task_id
    tasks = TaskSet(job.tasks)

    sched = EvaScheduler(cat, credit_aware=True)
    healthy = SchedulerView(time=600.0, tasks=tasks, pending_ids=set(),
                            live=[LiveInstance(0, k_b, (tid,))],
                            task_workload={tid: 8},
                            instance_credits={0: 0.5})
    cfg = sched.schedule(healthy)
    assert (k_b, (tid,)) in [(k, tuple(t)) for k, t in cfg.assignments]
    assert sched.credit_drains == 0

    exhausted = SchedulerView(time=3000.0, tasks=tasks, pending_ids=set(),
                              live=[LiveInstance(0, k_b, (tid,))],
                              task_workload={tid: 8},
                              instance_credits={0: 0.0}, throttled={0})
    cfg2 = sched.schedule(exhausted)
    assert cfg2.num_tasks() == 1
    assert all(cat.credit_models[k] is None for k, _ in cfg2.assignments)
    assert sched.credit_drains == 1


def test_throttle_triggers_migration_acceptance():
    """Acceptance: on a single long CPU job, credit-aware Eva bursts on the
    cheap instance, migrates off at exhaustion (S·D̂ beats ΔM once the
    throughput collapses), and beats the credit-blind run on both cost and
    JCT; the blind run rides the throttle to completion."""
    runs = {}
    for aware in (True, False):
        cat = burstable_demo_catalog()
        job = _one_job(8, 1.2 * 3600.0)  # diamond, 1.2 h of work
        sched = EvaScheduler(cat, credit_aware=aware)
        m = Simulator(cat, [job], sched, SimConfig(seed=3)).run()
        assert job.completion_time is not None
        runs[aware] = (m, sched, job)
    m_aware, s_aware, j_aware = runs[True]
    m_blind, s_blind, j_blind = runs[False]
    # the blind run throttles and crawls; the aware run escapes
    assert m_blind.throttled_s > 3600.0
    assert m_aware.migrations >= 1  # it really moved off
    assert s_aware.credit_signals >= 1  # the pressure signal arrived
    assert s_aware.credit_drains >= 1
    assert m_aware.throttled_s < 600.0  # at most the drain round latency
    assert j_aware.completion_time < j_blind.completion_time
    assert m_aware.total_cost < m_blind.total_cost


def test_credit_aware_beats_blind_and_ondemand():
    """Acceptance (benchmark/CI invariant): on the bundled burstable demo
    market, credit-aware Eva is strictly cheaper than credit-blind Eva AND
    always-on-demand Eva."""
    costs = {}
    for name, cat, kw in (
            ("credit", burstable_demo_catalog(), dict(credit_aware=True)),
            ("blind", burstable_demo_catalog(), {}),
            ("ondemand", aws_catalog(), {})):
        jobs = burstable_trace(n_jobs=16, seed=11)
        m = Simulator(cat, jobs, EvaScheduler(cat, **kw),
                      SimConfig(seed=5)).run()
        assert all(j.completion_time is not None for j in jobs)
        costs[name] = m.total_cost
    assert costs["credit"] < costs["blind"]
    assert costs["credit"] < costs["ondemand"]
