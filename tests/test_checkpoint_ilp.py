"""Checkpoint round-trip / resume determinism + ILP exactness on tiny
instances."""
import os

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import TaskSet, aws_catalog, full_reconfiguration, make_task, table3_catalog
from repro.core.cluster_types import Task
from repro.core.ilp import cost_lower_bound, solve_ilp
from repro.models.steps import init_train_state, make_train_step
from repro.data.pipeline import SyntheticTokens
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.optimizer import OptConfig


def test_checkpoint_roundtrip(tmp_path):
    cfg = ARCHS["smollm-135m"].reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), state, step=3, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 3
    restored, step, extra = restore_checkpoint(str(tmp_path))
    assert step == 3 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_deterministic(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg = ARCHS["smollm-135m"].reduced()
    oc = OptConfig(total_steps=10)
    step_fn = jax.jit(make_train_step(cfg, oc))

    def batches(start):
        return SyntheticTokens(cfg.vocab, 2, 16, seed=1, start_step=start)

    s_a = init_train_state(cfg, jax.random.PRNGKey(0))
    src = batches(0)
    for _ in range(4):
        s_a, _ = step_fn(s_a, {k: jax.numpy.asarray(v)
                               for k, v in src.next_batch().items()})

    s_b = init_train_state(cfg, jax.random.PRNGKey(0))
    src = batches(0)
    for _ in range(2):
        s_b, _ = step_fn(s_b, {k: jax.numpy.asarray(v)
                               for k, v in src.next_batch().items()})
    save_checkpoint(str(tmp_path), s_b, step=2)
    s_b, step, _ = restore_checkpoint(str(tmp_path))
    src = batches(step)
    for _ in range(2):
        s_b, _ = step_fn(s_b, {k: jax.numpy.asarray(v)
                               for k, v in src.next_batch().items()})

    for a, b in zip(jax.tree.leaves(s_a["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-6)


def test_async_checkpointer(tmp_path):
    cfg = ARCHS["smollm-135m"].reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(state, 1)
    ck.save(state, 2)  # waits for the first
    ck.wait()
    assert latest_step(str(tmp_path)) == 2


def test_ilp_exact_on_table3():
    specs = [(2, 8, 24), (1, 4, 10), (0, 6, 20), (0, 4, 12)]
    ts = TaskSet([Task(i, i, i, {"p3": tuple(map(float, s))})
                  for i, s in enumerate(specs)])
    cat = table3_catalog()
    res = solve_ilp(ts, cat, time_limit_s=30.0)
    assert res.config is not None
    # optimal known from the walkthrough: $12.8/hr
    assert res.cost == pytest.approx(12.8, abs=1e-6)


def test_heuristic_close_to_ilp_small():
    rng = np.random.default_rng(3)
    ts = TaskSet([make_task(job_id=i, workload=int(rng.integers(10)))
                  for i in range(10)])
    cat = aws_catalog()
    res = solve_ilp(ts, cat, time_limit_s=60.0)
    cfg = full_reconfiguration(ts, cat, None, interference_aware=False,
                               multi_task_aware=False)
    assert res.config is not None
    # paper Table 4: heuristic within ~1% of ILP; allow 10% slack here
    assert cfg.total_hourly_cost(cat) <= res.cost * 1.10 + 1e-6
    assert res.cost >= cost_lower_bound(ts, cat) - 1e-6
