"""Serving example: prefill a batch of prompts and decode with the KV-cache
path for any assigned architecture.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen3-0.6b --tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.lm import init_params
from repro.models.steps import make_decode_step, make_prefill_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCHS))
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--tokens", type=int, default=24)
args = ap.parse_args()

cfg = ARCHS[args.arch].reduced()
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
B, P = args.batch, args.prompt_len
prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
batch = {"tokens": prompts}
if cfg.enc_dec:
    batch["enc_embeds"] = jax.random.normal(
        key, (B, cfg.enc_seq, cfg.d_model)) * 0.02

prefill = jax.jit(make_prefill_step(cfg, cache_len=P + args.tokens))
decode = jax.jit(make_decode_step(cfg), donate_argnums=1)

t0 = time.time()
logits, cache = prefill(params, batch)
tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
out = [tok]
for i in range(args.tokens - 1):
    logits, cache = decode(params, cache, tok, jnp.int32(P + i))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
seq = jnp.concatenate(out, axis=1)
print(f"[serve] arch={cfg.name} batch={B} prompt={P} new={args.tokens}")
print(f"[serve] wall={dt:.2f}s  tokens/s={B * args.tokens / dt:.1f}")
print(f"[serve] sample continuation ids: {seq[0, :12].tolist()}")
