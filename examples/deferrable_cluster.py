"""Price-pressure autoscaling walkthrough: horizon price forecasts,
strike-priced admission, and deadline-bounded deferral.

    PYTHONPATH=src python examples/deferrable_cluster.py [--jobs 24]

1. Forecast an OU spot market: the closed-form mean-reversion forecast
   starts at the current price and converges to the long-run anchor as the
   horizon grows — the signal admission control trades on.
2. Watch the strike test on one job: cheap forecast -> admit, dear
   forecast -> hold, latest-start reached -> deadline-forced admission.
3. Run the bundled mixed tight/loose deferrable trace under
   admission-controlled Eva vs always-admit eva-spot and compare cost,
   JCT, deferrals and deadline misses.
"""
import argparse

from repro.autoscale import PriceForecaster, latest_start_s
from repro.cluster import SimConfig, Simulator, deferrable_trace
from repro.policies import AutoscaleLayer, SpotLayer
from repro.core import (EvaScheduler, PriceModel, TaskSet, aws_catalog,
                        make_task, reservation_prices)

ap = argparse.ArgumentParser()
ap.add_argument("--jobs", type=int, default=24)
ap.add_argument("--strike", type=float, default=0.9)
args = ap.parse_args()

# -- 1. horizon price forecasts ----------------------------------------------
pm = PriceModel.mean_reverting(discount=0.35, seed=7)
cat = aws_catalog(price_model=pm)
fore = PriceForecaster.for_catalog(cat)
now = 6 * 3600.0
k = cat.index_of("c7i.2xlarge")
cur = cat.at(now).costs[k]
anchor = fore.anchor_catalog(cat, now).costs[k]
print(f"c7i.2xlarge at t=6h: current ${cur:.3f}/h, long-run anchor "
      f"${anchor:.3f}/h (on-demand ${cat.costs[k]:.3f}/h)")
for h in (0.5, 2.0, 8.0, 48.0):
    f = fore.forecast_catalog(cat, now, h * 3600.0).costs[k]
    print(f"  forecast mean over {h:4.1f}h horizon: ${f:.3f}/h")
print("-> the forecast starts at the current price and reverts to the "
      "anchor;\n   a strike below 1.0 admits only when the market is "
      "genuinely cheap")

# -- 2. the strike test on one job -------------------------------------------
tasks = TaskSet([make_task(job_id=1, workload=8)])  # diamond: 8 vCPU / 16 GB
dur = 0.5 * 3600.0
deadline = now + 6 * 3600.0
ls = latest_start_s(deadline, dur)
print(f"\none diamond job, duration {dur / 3600.0:g}h, deadline at "
      f"t={deadline / 3600.0:g}h -> latest start t={ls / 3600.0:.2f}h "
      f"(strike {args.strike:g})")
for t_h in (2.0, 6.0, 16.0):
    t = t_h * 3600.0
    rp_f = reservation_prices(tasks, fore.forecast_catalog(cat, t, dur))[0]
    rp_a = reservation_prices(tasks, fore.anchor_catalog(cat, t))[0]
    verdict = "ADMIT" if rp_f <= args.strike * rp_a else "hold"
    print(f"  t={t_h:4.1f}h  RP(forecast)=${rp_f:.4f}/h  "
          f"strike bar=${args.strike * rp_a:.4f}/h  -> {verdict}")
print("-> held jobs wait for a dip; the latest-start bound admits them "
      "unconditionally")

# -- 3. schedulers head to head ----------------------------------------------
print(f"\n{args.jobs} deferrable jobs (mixed tight/loose deadlines) on the "
      "OU spot market")
results = {}
for name in ("eva-autoscale", "eva-spot"):
    c = aws_catalog(price_model=pm)
    layers = [SpotLayer()]
    if name == "eva-autoscale":
        layers.append(AutoscaleLayer(strike=args.strike))
    sched = EvaScheduler(c, policies=layers)
    jobs = deferrable_trace(n_jobs=args.jobs, seed=13)
    m = Simulator(c, jobs, sched,
                  SimConfig(seed=5, preemption_hazard_per_hour=0.3)).run()
    results[name] = m
    extra = ""
    if sched.admission is not None:
        a = sched.admission
        extra = (f"  deferred={m.deferred_jobs} (wait "
                 f"{m.deferred_wait_s / 3600.0:.1f}h)"
                 f" forced={a.forced_admissions}"
                 f" misses={m.deadline_misses}")
    print(f"  {name:13s} ${m.total_cost:7.2f}  jct={m.avg_jct_hours:5.2f}h"
          f"{extra}")

saving = 1.0 - (results["eva-autoscale"].total_cost
                / results["eva-spot"].total_cost)
print(f"\nadmission-controlled Eva saves {saving:.1%} vs always-admit "
      "eva-spot by running the deferrable jobs in the market's cheap "
      "windows — with every deadline met")
