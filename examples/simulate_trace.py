"""End-to-end simulation: all five schedulers on an Alibaba-like trace.

    PYTHONPATH=src python examples/simulate_trace.py [--jobs 400] [--model gavel]
"""
import argparse

from repro.cluster import SimConfig, Simulator, alibaba_like_trace
from repro.core import EvaScheduler, NoPackingScheduler, aws_catalog
from repro.core.workloads import M_TRUE
from repro.schedulers import OwlScheduler, StratusScheduler, SynergyScheduler

ap = argparse.ArgumentParser()
ap.add_argument("--jobs", type=int, default=400)
ap.add_argument("--model", default="gavel", choices=["alibaba", "gavel"])
args = ap.parse_args()

cat = aws_catalog()
factories = {
    "no-packing": lambda: NoPackingScheduler(cat),
    "stratus": lambda: StratusScheduler(cat),
    "synergy": lambda: SynergyScheduler(cat),
    "owl": lambda: OwlScheduler(cat, M_TRUE),
    "eva": lambda: EvaScheduler(cat),
}
base = None
print(f"{args.jobs} jobs, {args.model} durations")
for name, f in factories.items():
    jobs = alibaba_like_trace(n_jobs=args.jobs, seed=42,
                              duration_model=args.model)
    m = Simulator(cat, jobs, f(), SimConfig(seed=1)).run()
    base = base or m.total_cost
    s = m.summary()
    print(f"  {name:11s} ${s['total_cost']:>10.2f} "
          f"({m.total_cost / base * 100:5.1f}%)  "
          f"jct={s['avg_jct_hours']:6.2f}h tput={s['norm_job_tput']:.3f} "
          f"tasks/inst={s['tasks_per_instance']:.2f}")
