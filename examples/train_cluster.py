"""End-to-end driver: Eva schedules REAL JAX training jobs on the local
"cloud" (threads = instances, billing by uptime, migration =
checkpoint/restore, interference = genuine CPU contention).

    PYTHONPATH=src python examples/train_cluster.py [--steps 120]

Three jobs (smollm / qwen3 / mamba2 reduced configs) are trained to
completion under Eva's scheduler; compare the bill against No-Packing.
"""
import argparse

from repro.cluster.localcloud import LocalCloud, LocalJob
from repro.configs import ARCHS
from repro.core import Catalog, EvaScheduler, NoPackingScheduler
from repro.core.catalog import InstanceType

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--scheduler", default="eva", choices=["eva", "no-packing"])
args = ap.parse_args()

# a tiny local "cloud": slots measured in CPU shares
local_catalog = Catalog.from_types([
    InstanceType("local.large", "c7i", (0, 4, 16), 1.0),
    InstanceType("local.small", "c7i", (0, 2, 8), 0.55),
    InstanceType("local.micro", "c7i", (0, 1, 4), 0.30),
])

jobs = [
    LocalJob(job_id=1, workload=7, arch_cfg=ARCHS["smollm-135m"].reduced(),
             total_steps=args.steps, demand=(0, 1, 4), standalone_sps=20.0),
    LocalJob(job_id=2, workload=6, arch_cfg=ARCHS["qwen3-0.6b"].reduced(),
             total_steps=args.steps, demand=(0, 1, 4), standalone_sps=15.0),
    LocalJob(job_id=3, workload=9, arch_cfg=ARCHS["mamba2-780m"].reduced(),
             total_steps=max(args.steps // 2, 20), demand=(0, 2, 8),
             standalone_sps=10.0),
]

sched = (EvaScheduler(local_catalog) if args.scheduler == "eva"
         else NoPackingScheduler(local_catalog))
cloud = LocalCloud(local_catalog, sched, jobs, round_s=3.0)
print(f"[cluster] scheduler={args.scheduler}: 3 real training jobs "
      f"({args.steps} steps each) ...")
out = cloud.run(timeout_s=900)
print(f"[cluster] all_done={out['all_done']} steps={out['steps']}")
print(f"[cluster] bill=${out['cost'] * 3600:.4f} (per-second billing), "
      f"migrations={out['migrations']}")
