"""Stability-vs-cost walkthrough: the policy stack, drift-plus-penalty
admission, and bounded pending queues (arXiv 2201.09050).

    PYTHONPATH=src python examples/stability_cluster.py [--jobs 24] [--v 32]

1. Compose a scheduler from policy layers (the same API every scenario
   axis now uses) and show the stack.
2. Watch the drift-plus-penalty trade-off on one held job: the backlog
   term grows each held round until it outweighs the price premium.
3. Run the bundled deferrable trace on the OU spot market under
   eva-stability vs the always-defer strike chaser vs always-admit
   eva-spot, and compare cost / queue peak / deadline misses.
"""
import argparse

from repro.cluster import SimConfig, Simulator, deferrable_trace
from repro.core import EvaScheduler, PriceModel, aws_catalog
from repro.policies import AutoscaleLayer, SpotLayer, StabilityLayer

ap = argparse.ArgumentParser()
ap.add_argument("--jobs", type=int, default=24)
ap.add_argument("--v", type=float, default=32.0,
                help="queue patience per unit of relative price premium")
args = ap.parse_args()

# -- 1. a scheduler is Algorithm 1 + a stack of policy layers ----------------
pm = PriceModel.mean_reverting(discount=0.35, seed=7)
cat = aws_catalog(price_model=pm)
sched = EvaScheduler(cat, policies=[SpotLayer(),
                                    StabilityLayer(v=args.v)])
print(f"policy stack: {sched.stack.describe()}")
ctl = sched.admission
print(f"stability controller: strike={ctl.strike:g}, V={ctl.v:g} "
      "(V->inf = pure strike chasing, V=0 = admit after one held round)")

# -- 2. drift vs penalty on one held job -------------------------------------
# admit when  q · rp_anchor  >  V · (rp_forecast − strike · rp_anchor):
# a standing 30% premium over the strike bar is outweighed after V·0.3
# held rounds — the queue backlog is bounded without any deadline help.
premium_rel = 0.3
rounds = args.v * premium_rel
print(f"\na job facing a standing {premium_rel:.0%} premium over its "
      f"strike bar is admitted after ~{rounds:.0f} held rounds "
      f"({rounds * 300 / 3600.0:.1f}h at 5-min rounds)")

# -- 3. schedulers head to head ----------------------------------------------
print(f"\n{args.jobs} deferrable jobs (mixed tight/loose deadlines) on the "
      "OU spot market")
runs = (
    ("eva-stability", [SpotLayer(), StabilityLayer(v=args.v)]),
    ("eva-chaser-0.7", [SpotLayer(), AutoscaleLayer(strike=0.7)]),
    ("eva-spot", [SpotLayer()]),
)
results = {}
for name, layers in runs:
    c = aws_catalog(price_model=pm)
    s = EvaScheduler(c, policies=layers)
    jobs = deferrable_trace(n_jobs=args.jobs, seed=13)
    m = Simulator(c, jobs, s,
                  SimConfig(seed=5, preemption_hazard_per_hour=0.3)).run()
    results[name] = m
    extra = ""
    if s.admission is not None:
        extra = (f"  queue_peak={m.max_pending_jobs}"
                 f" held_rounds={s.admission.held_job_rounds}"
                 f" misses={m.deadline_misses}")
    print(f"  {name:14s} ${m.total_cost:7.2f}  jct={m.avg_jct_hours:5.2f}h"
          f"{extra}")

stab, chase = results["eva-stability"], results["eva-chaser-0.7"]
print(f"\neva-stability holds the pending queue at {stab.max_pending_jobs} "
      f"vs the chaser's {chase.max_pending_jobs}, at "
      f"{stab.total_cost / chase.total_cost:.1%} of its cost — bounded "
      "queues without runaway spending, every deadline met")
