"""Multi-region spot-arbitrage walkthrough: region-qualified prices,
cross-region migration costs, and the multi-region Eva scheduler.

    PYTHONPATH=src python examples/multiregion_cluster.py [--jobs 24] [--hazard 0.3]

1. Build the bundled 3-region dispersed-price market and watch the cheap
   window rotate between regions (and the region-qualified Algorithm-1
   packing order follow it).
2. Price a cross-region migration: checkpoint transfer time + egress fee.
3. Run the same trace under multi-region Eva, single-region spot Eva (locked
   to region-0's market) and on-demand Eva, and compare cost / JCT /
   cross-region moves / per-region spend.
"""
import argparse

from repro.cluster import SimConfig, Simulator, physical_trace
from repro.policies import MultiRegionLayer, SpotLayer
from repro.core import (EvaScheduler, TaskSet, aws_catalog,
                        checkpoint_size_gb, dispersed_demo_regions, make_task,
                        multi_region_catalog, regional_reservation_prices)

ap = argparse.ArgumentParser()
ap.add_argument("--jobs", type=int, default=24)
ap.add_argument("--hazard", type=float, default=0.3,
                help="baseline preemptions per instance-hour at mean price")
args = ap.parse_args()

# -- 1. the rotating cheap window -------------------------------------------
regions = dispersed_demo_regions(3)
cat = multi_region_catalog(regions)
base = aws_catalog()
k0 = base.index_of("p3.8xlarge")
print("p3.8xlarge ($%.2f/h on demand) across regions over 3 hours:"
      % base.costs[k0])
for minute in (0, 60, 120, 180):
    snap = cat.at(minute * 60.0)
    row = "  ".join(f"{r.name}=${snap.costs[i * len(base) + k0]:6.3f}/h"
                    for i, r in enumerate(regions))
    print(f"  t={minute:3d}min  {row}")

# the same dispersion, task-eye view: per-region reservation prices
tasks = TaskSet([make_task(job_id=1, workload=2), make_task(job_id=2, workload=4)])
rr = regional_reservation_prices(tasks, cat, time_s=0.0)
for row, label in zip(rr, ("vit", "gpt2")):
    spread = "  ".join(f"{r.name}=${v:6.3f}/h" for r, v in zip(regions, row))
    print(f"  RP({label:5s}) at t=0: {spread}")

# -- 2. what a cross-region move costs --------------------------------------
w_gpt2 = 4  # Table-7 workload index
gb = checkpoint_size_gb(w_gpt2)
t_x = cat.transfer.transfer_time_s(0, 1, gb)
fee = cat.transfer.egress_usd(0, 1, gb)
print(f"\nmoving a gpt2 task region-0 -> region-1: {gb:.0f} GB checkpoint, "
      f"{t_x:.0f}s transfer, ${fee:.2f} egress")

# -- 3. schedulers head to head ---------------------------------------------
print(f"\n{args.jobs} jobs, hazard {args.hazard}/instance-hour, "
      "3-region dispersed-price market")
results = {}
for name in ("eva-multiregion", "eva-spot", "eva"):
    jobs = physical_trace(n_jobs=args.jobs, seed=11,
                          duration_range_h=(0.3, 0.8))
    if name == "eva-multiregion":
        c = multi_region_catalog(regions)
        sched = EvaScheduler(c, policies=[SpotLayer(), MultiRegionLayer()])
        cfg = SimConfig(seed=5, preemption_hazard_per_hour=args.hazard)
    elif name == "eva-spot":
        c = aws_catalog(price_model=regions[0].price_model)
        sched = EvaScheduler(c, policies=[SpotLayer()])
        cfg = SimConfig(seed=5, preemption_hazard_per_hour=args.hazard)
    else:
        c = aws_catalog()
        sched = EvaScheduler(c)
        cfg = SimConfig(seed=5)
    m = Simulator(c, jobs, sched, cfg).run()
    results[name] = m
    extra = ""
    if name == "eva-multiregion":
        spend = ", ".join(f"{r}=${v:.0f}"
                          for r, v in sorted(m.cost_by_region.items()))
        extra = (f"  x-region moves={m.cross_region_migrations}"
                 f" egress=${m.egress_cost:.2f}"
                 f" arbitrage={sched.arbitrage_moves}  [{spend}]")
    print(f"  {name:16s} ${m.total_cost:8.2f}  jct={m.avg_jct_hours:5.2f}h"
          f"  migrations={m.migrations}{extra}")

saving = 1.0 - (results["eva-multiregion"].total_cost
                / results["eva-spot"].total_cost)
print(f"\nmulti-region Eva saves {saving:.1%} vs single-region spot Eva "
      "(chases the cheap window across markets; egress + transfer time are "
      "charged per cross-region move)")
